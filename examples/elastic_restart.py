"""Elastic-scaling demo: train on a 4-way data-parallel mesh, checkpoint,
then restore the SAME checkpoint onto an 8-way mesh and continue — the
fault-tolerance path a 1000-node deployment takes when nodes join/leave.

This file forces 8 host devices BEFORE importing jax (standalone script).

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import shutil

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_config
from repro.core.power_plane import StepProfile
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import registry
from repro.optim import adamw
from repro.optim.schedule import wsd
from repro.parallel.sharding import named_shardings
from repro.train.step import StepConfig, make_train_step

CKPT = "/tmp/voltune_elastic_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = get_config("minicpm_2b", tiny=True)
api = registry.build(cfg, remat="none")
opt_cfg = adamw.AdamWConfig()
sched = lambda s: wsd(s, peak_lr=1e-3, warmup_steps=2, stable_steps=40,
                      decay_steps=40)
profile = StepProfile(5e9, 5e8, 2e8, 1.8e8)
data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 8, seed=0))


def build(mesh):
    step = make_train_step(lambda p, b: api.loss_fn(p, b), opt_cfg, sched,
                           profile, StepConfig())
    bspec = NamedSharding(mesh, P("data"))
    return jax.jit(step, in_shardings=(None, None, None, None,
                                       {"tokens": bspec, "labels": bspec}))


def run_steps(mesh, state, start, n):
    step_fn = build(mesh)
    losses = []
    for s in range(start, start + n):
        batch = jax.device_put(data.jax_batch(s), NamedSharding(mesh, P("data")))
        p, o, pl, ef, m = step_fn(state["params"], state["opt"],
                                  state["plane"], state["ef"], batch)
        state.update(params=p, opt=o, plane=pl, ef=ef)
        losses.append(float(m["loss"]))
    return losses


# --- phase 1: 4-device mesh ----------------------------------------------
mesh4 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
params = api.init(jax.random.PRNGKey(0))
from repro.train.trainer import initial_plane_and_ef
plane, ef = initial_plane_and_ef(params)
state = {"params": params, "opt": adamw.init_state(params, opt_cfg),
         "plane": plane, "ef": ef}
l1 = run_steps(mesh4, state, 0, 10)
print(f"phase 1 (4 devices): steps 0-9, loss {l1[0]:.4f} -> {l1[-1]:.4f}")

cm = CheckpointManager(CKPT, async_save=False)
cm.save(10, {"params": state["params"], "opt": state["opt"]})
print("checkpoint written at step 10")

# --- phase 2: restore onto an 8-device mesh -------------------------------
mesh8 = jax.make_mesh((8,), ("data",))
shardings = {"params": named_shardings(
    jax.eval_shape(lambda: state["params"]), mesh8)}
step, restored = cm.restore({"params": state["params"], "opt": state["opt"]},
                            shardings=shardings)
state2 = {"params": restored["params"], "opt": restored["opt"],
          "plane": plane, "ef": ef}
l2 = run_steps(mesh8, state2, step, 10)
print(f"phase 2 (8 devices): steps {step}-{step+9}, "
      f"loss {l2[0]:.4f} -> {l2[-1]:.4f}")

# --- verify continuity: an uninterrupted 4-device run matches -------------
state3 = {"params": api.init(jax.random.PRNGKey(0)), "plane": plane, "ef": ef}
state3["opt"] = adamw.init_state(state3["params"], opt_cfg)
ref = run_steps(mesh4, state3, 0, 20)
drift = abs(ref[10] - l2[0]) / max(abs(ref[10]), 1e-9)
print(f"\ncontinuity check: restored-step loss {l2[0]:.5f} vs "
      f"uninterrupted {ref[10]:.5f} (rel drift {drift:.2e})")
print("elastic restore onto a larger mesh: OK" if drift < 1e-3
      else "WARNING: drift exceeds tolerance")
