"""Quickstart: the VolTune control plane in 60 lines.

Programs a rail voltage through the PMBus-simulated PowerManager, watches
the transition settle (paper Fig 7), and reads back telemetry — then shows
the same opcode interface driving the TPU logical rails.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import PowerManager, settling_time
from repro.core.control_plane import HostRailController
from repro.core.power_manager import Opcode
from repro.core.power_plane import PowerPlaneState
from repro.core.rails import KC705_RAIL_MAP

# --- 1. KC705: set VCCBRAM to 0.9 V (the paper's §IV-E example) -----------
pm = PowerManager(KC705_RAIL_MAP, path="hw", clock_hz=400_000)
lane = KC705_RAIL_MAP.by_name("VCCBRAM").lane
res = pm.set_voltage(lane, 0.9)
print(f"set_voltage(VCCBRAM, 0.9V): ok={res.ok}, "
      f"{len(res.completions)} PMBus transactions, "
      f"command time {res.elapsed_s*1e3:.2f} ms")

# --- 2. watch the transition settle (Fig 7 methodology) -------------------
tr = pm.measure_transition(KC705_RAIL_MAP.by_name("MGTAVCC").lane, 0.85,
                           duration_s=5e-3)
det = settling_time(tr.times, tr.volts, n=8, band_pct=1.0)
print(f"MGTAVCC 1.0->0.85V: settled={det.settled}, "
      f"end-to-end latency {tr.end_to_end_latency_s()*1e3:.2f} ms "
      f"(sampling interval {pm.measurement_interval_s()*1e3:.1f} ms)")

# --- 3. raw opcode interface (Table III) -----------------------------------
r = pm.execute(Opcode.GET_VOLTAGE, lane)
print(f"opcode 0x5 GET_VOLTAGE(VCCBRAM) -> {r.value:.4f} V "
      f"in {r.elapsed_s*1e3:.2f} ms")

# --- 4. the same stack driving TPU logical rails ---------------------------
hc = HostRailController()   # SW-path analogue of the unified control plane
import dataclasses
import jax.numpy as jnp
want = dataclasses.replace(PowerPlaneState.nominal(),
                           v_io=jnp.float32(0.80))   # undervolt ICI SerDes
achieved = hc.actuate(want)
print(f"TPU VDD_IO 0.95->0.80V via PMBus: achieved {float(achieved.v_io):.3f} V, "
      f"actuation cost {hc.actuation_seconds*1e3:.2f} ms "
      f"({hc.pm.bus.transaction_count} transactions)")
print("readback:", {k: round(v, 3) for k, v in hc.readback().items()})
