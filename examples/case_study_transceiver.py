"""The paper's §VI case study, end to end: runtime voltage sweeps on the GTX
transceiver rail, identifying the three operating regimes and the
reliability-constrained energy optimum.

Run:  PYTHONPATH=src python examples/case_study_transceiver.py
"""

import math

from repro.core.transceiver import SPEEDS_GBPS, GtxLinkModel

m = GtxLinkModel()

print("=== Fig 12: 10 Gbps reliability under voltage tuning ===")
sweep = m.sweep(10.0, mode="both")
onset = next(r for r in sweep if r.ber > 0)
collapse = next(r for r in sweep if r.bytes_received < 0.9 * r.bytes_sent)
print(f"  near-zero-BER plateau: 1.000 -> {onset.v_rx+0.001:.3f} V")
print(f"  bounded-BER band: BER rises to 1e-6 by "
      f"{next(r.v_rx for r in sweep if r.ber >= 1e-6):.3f} V")
print(f"  instability: throughput collapses at {collapse.v_rx:.3f} V "
      f"(received {100*collapse.bytes_received/collapse.bytes_sent:.0f}%)")

print("\n=== Fig 13: TX-only vs RX-only sensitivity ===")
for mode in ("tx", "rx"):
    sw = m.sweep(10.0, mode=mode)
    o = next((r for r in sw if r.ber > 0), None)
    v = (o.v_tx if mode == "tx" else o.v_rx) if o else None
    print(f"  {mode}-swept: BER onset at {v} V"
          + (" (RX-dominant degradation)" if mode == "rx" else ""))

print("\n=== Fig 14: link-speed impact ===")
for speed in SPEEDS_GBPS:
    sw = m.sweep(speed, mode="both")
    o = next((r.v_rx for r in sw if r.ber > 0), None)
    print(f"  {speed:>4} Gbps: BER onset {o:.3f} V "
          f"(headroom {1.0-o:.3f} V)")

print("\n=== Fig 16: BER-aware power savings at 10 Gbps ===")
p_nom = sweep[0].tx_power_w
nz = next(r for r in sweep if r.ber > 0)
b6 = next(r for r in sweep if r.ber >= 1e-6)
print(f"  nominal:            {p_nom:.4f} W @ 1.000 V")
print(f"  near-zero boundary: {nz.tx_power_w:.4f} W @ {nz.v_rx:.3f} V "
      f"-> {100*(1-nz.tx_power_w/p_nom):.1f}% saving  (paper: 28.4%)")
print(f"  BER<=1e-6 boundary: {b6.tx_power_w:.4f} W @ {b6.v_rx:.3f} V "
      f"-> {100*(1-b6.tx_power_w/p_nom):.1f}% saving  (paper: 29.3%)")
print(f"  (log10 BER at that point: {math.log10(b6.ber):.1f})")
print("\nMost of the practical saving comes before the near-zero-BER "
      "boundary; the bounded-BER band adds ~1% more — matching the paper.")
