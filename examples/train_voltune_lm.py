"""End-to-end training driver: a real LM trained for a few hundred steps
with the full production stack — VolTune power plane (phase-aware policy +
host PMBus controller), error-feedback int8 gradient collectives,
step-atomic checkpointing with simulated failure recovery, straggler
mitigation, and telemetry.

Run:  PYTHONPATH=src python examples/train_voltune_lm.py [--steps 300]
      [--d-model 512 --layers 8]   (~100M params: --d-model 768 --layers 12)
"""

import argparse
import dataclasses
import shutil

import jax

from repro.configs.base import ModelConfig
from repro.core.control_plane import HostRailController
from repro.core.policy import PhaseAware, StaticNominal
from repro.core.power_plane import StepProfile
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import registry
from repro.optim import adamw
from repro.optim.schedule import wsd
from repro.train.step import StepConfig, make_train_step, shard_map_ef_step
from repro.train.trainer import (FaultConfig, Trainer, TrainerConfig,
                                 initial_plane_and_ef)

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--d-model", type=int, default=512)
ap.add_argument("--layers", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--policy", choices=("phase-aware", "static"),
                default="phase-aware")
ap.add_argument("--grad-sync", choices=("auto", "ef_int8"), default="ef_int8")
ap.add_argument("--ckpt-dir", default="/tmp/voltune_train_ckpt")
args = ap.parse_args()

cfg = ModelConfig(
    name="voltune-demo-lm", family="dense", n_layers=args.layers,
    d_model=args.d_model, n_heads=args.d_model // 64,
    n_kv_heads=max(1, args.d_model // 128), d_ff=args.d_model * 4 * 2 // 3,
    vocab_size=4096, tp=1)
api = registry.build(cfg, remat="none")
params = api.init(jax.random.PRNGKey(0))
n_params = sum(p.size for p in jax.tree_util.tree_leaves(params))
print(f"model: {cfg.n_layers}L d={cfg.d_model} -> {n_params/1e6:.1f}M params")

opt_cfg = adamw.AdamWConfig()
opt = adamw.init_state(params, opt_cfg)
plane, ef = initial_plane_and_ef(params)

# roofline profile of this step (scale-correct for the energy model)
tokens = args.batch * args.seq
profile = StepProfile(
    flops_per_chip=6.0 * n_params * tokens,
    hbm_bytes_per_chip=14.0 * n_params + 8.0 * tokens * cfg.d_model,
    ici_bytes_per_chip=4.0 * n_params,
    grad_bytes_per_chip=4.0 * n_params)

policy = PhaseAware() if args.policy == "phase-aware" else StaticNominal()
sched = lambda s: wsd(s, peak_lr=3e-4, warmup_steps=20,
                      stable_steps=int(args.steps * 0.7),
                      decay_steps=int(args.steps * 0.2))
step_cfg = StepConfig(microbatches=1, grad_sync=args.grad_sync, policy=policy)
raw_step = make_train_step(lambda p, b: api.loss_fn(p, b), opt_cfg, sched,
                           profile, step_cfg)
if args.grad_sync != "auto":
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    train_step = jax.jit(shard_map_ef_step(raw_step, mesh))
else:
    train_step = jax.jit(raw_step)

shutil.rmtree(args.ckpt_dir, ignore_errors=True)
data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch, seed=0))
# SW-path analogue: actuate the in-graph policy's decisions through the
# simulated PMBus stack (achieved voltages are written back into the plane)
hc = HostRailController()
tcfg = TrainerConfig(
    total_steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt_dir,
    async_ckpt=True, controller=hc,
    faults=FaultConfig(fail_prob=0.004, straggler_prob=0.02,
                       straggler_factor=6.0, grace=1.5, seed=7))
trainer = Trainer(train_step, data, tcfg,
                  {"params": params, "opt": opt, "plane": plane, "ef": ef})

print(f"training {args.steps} steps (policy={args.policy}, "
      f"grad_sync={args.grad_sync}, failure+straggler injection ON)...")
log = trainer.run()

records = list(log.records)
head = sum(r.loss for r in records[:10]) / 10
tail = sum(r.loss for r in records[-10:]) / 10
s = trainer.summary()
print(f"\nloss: {head:.4f} -> {tail:.4f}   "
      f"({'improved' if tail < head else 'NO IMPROVEMENT'})")
print(f"energy: {s['energy_j']:.1f} J over {s['time_s']:.2f} modelled-s "
      f"(mean {s['mean_power_w']:.1f} W/chip)")
print(f"fault tolerance: {s['restarts']} restarts, "
      f"{s['straggler_events']} stragglers mitigated, "
      f"{s['ckpt_writes']} checkpoints")
print(f"rails at end: v_core={records[-1].v_core:.3f} "
      f"v_hbm={records[-1].v_hbm:.3f} v_io={records[-1].v_io:.3f} "
      f"comp_level={records[-1].comp_level}")

# compare with the static-nominal baseline energy at identical step math
if args.policy == "phase-aware":
    from repro.core.power_plane import PowerPlaneState, account_step
    nominal_plane = PowerPlaneState.nominal()
    _, m = account_step(profile, nominal_plane)
    e_nominal = float(m["energy_step_j"]) * len(records)
    print(f"\nVolTune saving vs static-nominal margins: "
          f"{100*(1-s['energy_j']/e_nominal):.1f}% "
          f"({e_nominal:.1f} J -> {s['energy_j']:.1f} J) — "
          f"the paper's thesis, at training-system scale")
