"""Batched serving example: prefill + greedy decode with KV caches, with the
power plane accounting energy per token and the phase-aware policy
undervolting during the memory-bound decode phase (paper §I's
'communication-light phases' argument, serving-side).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import numpy as np
import jax

from repro.configs.base import ModelConfig
from repro.core.policy import PhaseAware
from repro.core.power_plane import StepProfile
from repro.models import registry
from repro.serve.engine import ServeEngine

cfg = ModelConfig(name="serve-demo", family="dense", n_layers=6, d_model=256,
                  n_heads=4, n_kv_heads=2, d_ff=768, vocab_size=4096, tp=1)
api = registry.build(cfg)
params = api.init(jax.random.PRNGKey(0))
n = sum(p.size for p in jax.tree_util.tree_leaves(params))
print(f"serving {n/1e6:.1f}M-param model, batch=4")

B, Tp, new = 4, 32, 48
# profiles: prefill is compute-bound, decode is HBM-bound — the policy sees
# this through the roofline terms and adapts rails per phase
prefill_profile = StepProfile(2.0 * n * B * Tp, 2.0 * n, 0.0)
decode_profile = StepProfile(2.0 * n * B, 2.0 * n + 4e6 * B, 0.0)

engine = ServeEngine(cfg, params, max_len=Tp + new + 8, batch_size=B,
                     prefill_profile=prefill_profile,
                     decode_profile=decode_profile,
                     policy=PhaseAware())

prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (B, Tp))
out = engine.generate(prompts.astype(np.int32), max_new_tokens=new)
print(f"generated {out.shape[1]} tokens x {B} sequences")
print("first sequence:", out[0][:16], "...")

s = engine.summary()
print(f"\nenergy: {s['energy_j']:.3f} J total, "
      f"{1e3*s['j_per_decoded_token']:.2f} mJ/token")
print(f"rails after decode phase: v_core={s['v_core']:.3f} "
      f"v_io={s['v_io']:.3f} (undervolted: decode is HBM-bound, "
      f"core/ICI have slack)")

# determinism check: greedy decode is reproducible
engine2 = ServeEngine(cfg, params, max_len=Tp + new + 8, batch_size=B,
                      prefill_profile=prefill_profile,
                      decode_profile=decode_profile)
out2 = engine2.generate(prompts.astype(np.int32), max_new_tokens=new)
print("\ndeterministic generation:", bool((out == out2).all()))
