"""Paper Fig 14: link-speed impact — BER onset shifts down as speed drops
(0.869 / 0.787 / 0.745 / 0.744 V for 10 / 7.5 / 5 / 2.5 Gbps), widening the
usable undervolting headroom."""

from __future__ import annotations

from benchmarks.common import row, timed
from repro.core.transceiver import REFCLK_MHZ, GtxLinkModel

PAPER_ONSETS = {10.0: 0.869, 7.5: 0.787, 5.0: 0.745, 2.5: 0.744}
PAPER_COLLAPSE = {10.0: 0.80, 5.0: 0.72}


def run():
    m = GtxLinkModel()
    rows = []
    for speed in (2.5, 5.0, 7.5, 10.0):
        sweep, us = timed(lambda s=speed: m.sweep(s, mode="both"), repeats=1)
        onset = next((r.v_rx for r in sweep if r.ber > 0), None)
        collapse = next((r.v_rx for r in sweep
                         if r.bytes_received < 0.9 * r.bytes_sent), None)
        exp_c = PAPER_COLLAPSE.get(speed, "below sweep floor (not observed)")
        rows.append(row(f"fig14.speed_{speed}G", us,
                        f"refclk={REFCLK_MHZ[speed]}MHz onset={onset:.3f}V "
                        f"(paper {PAPER_ONSETS[speed]}) collapse={collapse} "
                        f"(paper {exp_c})"))
    headroom = {s: round(1.0 - PAPER_ONSETS[s], 3) for s in PAPER_ONSETS}
    rows.append(row("fig14.headroom_vs_speed", 0.0,
                    f"usable_headroom_V={headroom} (widens as speed drops)"))
    return rows
