"""Beyond-paper: headroom-aware fleet serving vs headroom-blind placement.

The SOR learner (core/sor.py) gives every chip a per-rail learned safe
envelope; `serve/router.py` is the first consumer that SPENDS those margins
at placement time instead of merely clamping voltages with them. This bench
routes one seeded bursty traffic trace (`serve/traffic.py`) over the same
fleet twice — once with the `HeadroomRouter` (place decode-heavy work on the
deepest-VDD_HBM-headroom chips, drain pinned chips) and once with the
`RoundRobinRouter` baseline (next free slot, envelope-blind) — and reports
tokens/joule and the p50/p95/p99 request latency of each.

The world that makes headroom worth money (same frontier shape as
fleet_frontier's learned-vs-static sweep, plus load coupling):

* per-chip per-rail frontier onsets from the seeded FleetSpec process
  variation, bands chosen to STRADDLE the policy's walking floors — weak
  chips' learned floors sit above the floor the policy walks to (arbitration
  pins them there: they hold MORE voltage, burn more power, and have ~zero
  headroom), strong chips keep 20-30 mV of margin;
* onsets shift up by `LOAD_SHIFT_V x busy_frac` — a loaded chip's frontier
  encroaches on its operating point (the consolidated-margins load
  dependence), so parking work on a zero-headroom chip pushes it over the
  error bound and its goodput degrades (`ServeEngine.serve_trace` halves the
  token rate while over bound — the BER retransmission analogue);
* the policy walks each rail on its own observable but is ENVELOPE-BLIND
  (`decide_env` discards the envelopes): envelopes act only at arbitration,
  so pinning is genuinely per-chip — exactly the regime where placement has
  information to exploit.

The committed record (reports/BENCH_serve_router.json) carries both routers'
tokens/joule and latency percentiles; check_bench_regression.py gates the
roundrobin/headroom tokens-per-joule ratio and the headroom/roundrobin p99
ratio, so the headroom win must survive every PR.
"""

from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import sor
from repro.core.control_plane import InGraphRailController, pinned_chip_mask
from repro.core.hwspec import FleetSpec
from repro.core.policy import MultiRailClosedLoop
from repro.core.power_plane import StepProfile
from repro.serve.router import HeadroomRouter, RoundRobinRouter
from repro.serve.traffic import bursty_trace

PROFILE = StepProfile(flops_per_chip=2e12, hbm_bytes_per_chip=8e9,
                      ici_bytes_per_chip=4e9, grad_bytes_per_chip=3e9)
ERROR_BOUND = 5e-3
LOG_SLOPE = 30.0       # decades of error per volt below the onset
# frontier encroachment at full load on the decode-bound rails (VDD_HBM /
# VDD_IO; the compute rail does not load-shift under decode). Chosen to
# outrun both the guard band (4 mV) and one backoff step of the serving
# policy (~10 mV), so a loaded low-headroom chip stays over the bound
# while the controller chases it — persistent degraded goodput, the cost
# headroom-aware placement avoids
LOAD_SHIFT_V = 0.025
SEED = 23

# CI bench-smoke knobs: the default config IS the committed-baseline config
# (reports/BENCH_serve_router.json), so the CI smoke runs it unchanged and
# the ratio gate compares like with like
N_CHIPS = int(os.environ.get("REPRO_BENCH_SERVE_CHIPS", "16"))
N_REQUESTS = int(os.environ.get("REPRO_BENCH_SERVE_REQUESTS", "72"))
MAX_TICKS = int(os.environ.get("REPRO_BENCH_SERVE_TICKS", "1400"))
CAPACITY = 4

# the policy's walking floors, at the TOP of each rail's unloaded onset
# band: every chip walks to (nearly) the same held voltage — placement pays
# no static speed tax for preferring deep-headroom chips (f scales with v) —
# and what differs per chip is the MARGIN below it. The weakest chip per
# rail pins at its learned floor just above the walking floor; the rest hold
# the floor with a 0-60 mV graded margin that LOAD_SHIFT_V eats into.
POLICY_FLOORS = {"VDD_CORE": 0.652, "VDD_HBM": 0.995, "VDD_IO": 0.725}
# (base = strongest chip's onset, spread); VDD_HBM/VDD_IO ride the BER-curve
# sensitivity (src - 1 in [0, 1.2]), VDD_CORE the leakage spread
ONSETS = {"VDD_CORE": (0.635, 0.05), "VDD_HBM": (0.935, 0.05),
          "VDD_IO": (0.665, 0.05)}
# control rounds on the idle fleet before the trace starts: the SOR
# envelopes converge (capacity 32, refresh_every 4) so the trace routes
# against LEARNED margins, not the learning transient
WARMUP_ROUNDS = int(os.environ.get("REPRO_BENCH_SERVE_WARMUP", "48"))
SOR_CFG = sor.SorConfig(capacity=32, refresh_every=4, decay=0.96,
                        error_bound=ERROR_BOUND, guard_v=0.004,
                        max_extension_v=0.12, ingest="frames",
                        rails=sor.ALL_RAIL_OBSERVABLES)


class _EnvelopeBlindWalk(MultiRailClosedLoop):
    """MultiRailClosedLoop that ignores the envelopes at decision time (the
    walk targets its static floors); arbitration still clamps per-chip, so
    weak chips pin at their learned floors while strong chips walk free —
    per-chip pinning, the regime the router exploits. (A warm-started walk
    converges every chip onto its own envelope floor: all pinned or none,
    nothing for placement to read.)"""

    def decide_env(self, state, frame, envelope=None):
        return super().decide_env(state, frame, None)


def _onset_voltages(fs: FleetSpec, rail: str) -> jnp.ndarray:
    base, spread = ONSETS[rail]
    src = (fs.leakage_scale if rail == "VDD_CORE" else fs.error_sensitivity)
    return base + spread * (jnp.asarray(src) - 1.0)


def _frontier_error(v, v_onset, key, n_chips):
    """Frontier-shaped observable: crosses ERROR_BOUND at each chip's own
    (load-shifted) onset, log-linear in the transition band below it."""
    noise = 1.0 + 0.05 * jax.random.normal(key, (n_chips,))
    return ERROR_BOUND * noise * 10.0 ** jnp.clip(
        LOG_SLOPE * (v_onset - v), -6.0, 3.0)


def _make_observe(fs: FleetSpec, n_chips: int):
    """The measured error world for serve_trace: per-rail frontier errors at
    onsets that encroach with the chip's CURRENT load (busy_frac)."""
    v_on = {r: _onset_voltages(fs, r) for r in POLICY_FLOORS}

    def observe(plane, frame, tick, busy_frac):
        k = jax.random.fold_in(jax.random.PRNGKey(SEED), tick)
        k_io, k_core, k_hbm = jax.random.split(k, 3)
        # decode load stresses the memory and collective paths: only the
        # VDD_HBM/VDD_IO frontiers encroach with occupancy
        shift = LOAD_SHIFT_V * busy_frac
        return dataclasses.replace(
            frame,
            grad_error=_frontier_error(
                plane.v_io, v_on["VDD_IO"] + shift, k_io, n_chips),
            extras={**frame.extras,
                    "straggle_rate": _frontier_error(
                        plane.v_core, v_on["VDD_CORE"], k_core, n_chips),
                    "hbm_error_rate": _frontier_error(
                        plane.v_hbm, v_on["VDD_HBM"] + shift, k_hbm,
                        n_chips)})

    return observe


def _routed_run(router):
    """One traced serve run: fresh engine (same fleet seed, same SOR-learning
    envelope-blind controller), warmed-up envelopes, same seeded bursty
    trace, `router` placing."""
    from repro.configs import get_config
    from repro.core.power_plane import account_fleet_and_observe
    from repro.models import registry
    from repro.serve.engine import ServeEngine
    cfg = get_config("minicpm_2b", tiny=True)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    fs = FleetSpec.sample(N_CHIPS, seed=SEED)
    # backoff 1.01 (~10 mV): the controller recovers from an over-bound
    # excursion in a few rounds, but cannot outrun a sustained 25 mV load
    # shift — a loaded zero-headroom chip keeps re-crossing the bound
    ctrl = InGraphRailController(
        _EnvelopeBlindWalk(floors=dict(POLICY_FLOORS), backoff=1.01,
                           name="envelope-blind-walk"),
        sor=SOR_CFG)
    eng = ServeEngine(cfg, params, max_len=24, batch_size=2,
                      prefill_profile=PROFILE, decode_profile=PROFILE,
                      fleet=fs, controller=ctrl, router=router)
    observe = _make_observe(fs, N_CHIPS)
    # envelope warmup on the idle fleet (busy_frac 0, tick keys disjoint
    # from the trace's): walks settle, weak chips pin, confidence builds
    idle = jnp.zeros((N_CHIPS,), jnp.float32)
    for w in range(WARMUP_ROUNDS):
        eng.plane, frame, _ = account_fleet_and_observe(
            eng.decode_profile, eng.plane, fs)
        frame = observe(eng.plane, frame, 1_000_000 + w, idle)
        eng._control_tick(frame)
    trace = bursty_trace(N_REQUESTS, seed=SEED, quiet_rate_hz=8.0,
                         burst_rate_hz=40.0, decode_mean=48.0)
    ledger = eng.serve_trace(trace, observe=observe,
                             max_ticks=MAX_TICKS, error_bound=ERROR_BOUND)
    return eng, ledger


def run():
    rows = []
    results = {}
    wall_us = {}
    for router in (HeadroomRouter(capacity=CAPACITY),
                   RoundRobinRouter(capacity=CAPACITY)):
        # timed manually (not benchmarks.common.timed): its warmup call
        # would re-run the whole deterministic trace a second time
        t0 = time.perf_counter()
        eng, ledger = _routed_run(router)
        us = (time.perf_counter() - t0) * 1e6
        s = ledger.summary()
        results[router.name] = {"engine": eng, "summary": s,
                                "trace": eng.last_trace}
        wall_us[router.name] = us
    h, rr = results["headroom"]["summary"], results["roundrobin"]["summary"]
    tpj = {"headroom": h["tokens_per_joule"],
           "roundrobin": rr["tokens_per_joule"]}
    p99 = {"headroom": h["p99_latency_s"], "roundrobin": rr["p99_latency_s"]}
    record = {
        "n_chips": N_CHIPS, "n_requests": N_REQUESTS, "steps": MAX_TICKS,
        "capacity": CAPACITY, "seed": SEED,
        "load_shift_v": LOAD_SHIFT_V,
        "tokens_per_joule": tpj,
        "p99_latency_s": p99,
        "p95_latency_s": {"headroom": h["p95_latency_s"],
                          "roundrobin": rr["p95_latency_s"]},
        "p50_latency_s": {"headroom": h["p50_latency_s"],
                          "roundrobin": rr["p50_latency_s"]},
        "completed": {"headroom": h["completed"],
                      "roundrobin": rr["completed"]},
        "defers": {"headroom": h["defers"], "roundrobin": rr["defers"]},
        "defers_by_reason": {"headroom": h["defers_by_reason"],
                             "roundrobin": rr["defers_by_reason"]},
        "fleet_energy_j": {"headroom": h["fleet_energy_j"],
                           "roundrobin": rr["fleet_energy_j"]},
        "degraded_chip_ticks": {
            "headroom": results["headroom"]["trace"]["degraded_chip_ticks"],
            "roundrobin":
                results["roundrobin"]["trace"]["degraded_chip_ticks"]},
        "ticks": {"headroom": results["headroom"]["trace"]["ticks"],
                  "roundrobin": results["roundrobin"]["trace"]["ticks"]},
        "pinned_chips": {
            name: int(pinned_chip_mask(
                res["engine"].plane, res["engine"].controller.last_request,
                envelope=res["engine"].controller.last_envelope).sum())
            for name, res in results.items()},
    }
    gain = tpj["headroom"] / max(tpj["roundrobin"], 1e-12)
    rows.append({**row(
        f"serve.{N_CHIPS}chips.headroom_vs_roundrobin",
        wall_us["headroom"],
        f"tok/J={tpj['headroom']:.2f}hd/{tpj['roundrobin']:.2f}rr "
        f"(x{gain:.2f}) "
        f"p99={p99['headroom']:.2f}s/{p99['roundrobin']:.2f}s "
        f"completed={h['completed']}hd/{rr['completed']}rr"
        f"/{N_REQUESTS}req "
        f"degraded_ticks="
        f"{record['degraded_chip_ticks']['headroom']}hd/"
        f"{record['degraded_chip_ticks']['roundrobin']}rr"),
        "bench": "serve_router",
        "record": record})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
