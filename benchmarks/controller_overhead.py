"""Paper Tables VII-IX (§V-F): controller overhead.

Reproduces the paper's headline ratios from the structured Vivado data
(HW 1.45% LUTs / 0.015 W ~ 2% share; SW 57.52% BRAM = 31.96x; static power
5.60x), then measures the SAME property for THIS system's controller: the
in-graph (HW-analogue) policy update and energy accounting must stay <2% of
the training step, and the host (SW-analogue) path's per-step cost is
reported like Table VI."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.core import overhead, sor
from repro.core.control_plane import HostRailController, InGraphRailController
from repro.core.hwspec import FleetSpec
from repro.core.policy import MultiRailClosedLoop, PhaseAware
from repro.core.power_plane import (PowerPlaneState, StepProfile,
                                    account_fleet_and_observe, account_step)

LEARNED_ROUND_CHIPS = 64


def _learned_round_rows() -> list:
    """Fused vs unfused learned control round at fleet scale: the SAME
    SorState/frame through `InGraphRailController.control_round` compiled
    two ways. Unfused is the PR-5 composition (full windowed EWLS refit
    computed every round, off-cadence results discarded by select); fused
    is the single-pass round (one-kernel accumulate+solve, refit gated by
    `lax.cond` on the refresh cadence). The amortized fused number weights
    the on-cadence (refit) and hold rounds by the cadence — that is what a
    scanned rollout actually pays per round."""
    n = LEARNED_ROUND_CHIPS
    from benchmarks.fleet_frontier import (FLEET_SEED, PROFILE, SOR_CFG,
                                           SOR_POLICY_FLOORS)
    fs = FleetSpec.sample(n, seed=FLEET_SEED)
    ctrl = InGraphRailController(
        MultiRailClosedLoop(floors=dict(SOR_POLICY_FLOORS)), sor=SOR_CFG)
    plane = PowerPlaneState.from_fleet(fs)
    plane, frame, _ = account_fleet_and_observe(PROFILE, plane, fs)
    ss = sor.init_state(SOR_CFG, n)
    for _ in range(SOR_CFG.refresh_every * 2):
        ss = sor.observe(ss, frame, SOR_CFG)

    fused = jax.jit(lambda p, f, s: ctrl.control_round(p, f, s, fused=True))
    unfused = jax.jit(
        lambda p, f, s: ctrl.control_round(p, f, s, fused=False))
    r = SOR_CFG.refresh_every
    on = dataclasses.replace(ss, tick=jnp.int32(r))        # refit round
    off = dataclasses.replace(ss, tick=jnp.int32(r + 1))   # hold round

    def bench(fn, s):
        return timed(lambda: jax.block_until_ready(
            fn(plane, frame, s)[0].v_io), repeats=20)[1]

    us_on, us_off = bench(fused, on), bench(fused, off)
    us_fused = (us_on + (r - 1) * us_off) / r
    us_unfused = bench(unfused, on)
    record = {
        "n_chips": n, "refresh_every": r,
        "us_per_round": {
            "fused_amortized": us_fused,
            "fused_refit_round": us_on,
            "fused_hold_round": us_off,
            "unfused": us_unfused,
        },
        "speedup": us_unfused / us_fused,
    }
    return [{**row(
        f"ours.learned_round.{n}chips.fused_vs_unfused", us_fused,
        f"fused={us_fused:.0f}us (refit={us_on:.0f} hold={us_off:.0f} "
        f"/{r}) unfused={us_unfused:.0f}us "
        f"speedup={us_unfused / us_fused:.1f}x"),
        "bench": "controller_overhead", "record": record}]


DONATION_CHIPS = 4096


def _donation_rows() -> list:
    """Donation on/off delta for the cached learned-round jit: the same
    `control_step_sor` round compiled with and without
    `donate_argnums=(plane, sor_state)`. What donation buys is the
    O(capacity x rails x chips) history-ring copy — without it every
    round materializes a fresh ring alongside the old one; with it XLA
    updates the donated buffer in place. Both the wall-clock delta and
    the ring's live-byte footprint (the peak-memory saving: 1 resident
    ring instead of 2) are recorded. Run at `DONATION_CHIPS` so the ring
    dwarfs the fixed dispatch cost; each controller re-binds its carry
    every call, which is the contract donation imposes on callers."""
    n = DONATION_CHIPS
    from benchmarks.fleet_frontier import (FLEET_SEED, PROFILE, SOR_CFG,
                                           SOR_POLICY_FLOORS)
    fs = FleetSpec.sample(n, seed=FLEET_SEED)
    plane0 = PowerPlaneState.from_fleet(fs)
    plane0, frame, _ = account_fleet_and_observe(PROFILE, plane0, fs)
    ss0 = sor.init_state(SOR_CFG, n)
    for _ in range(SOR_CFG.refresh_every * 2):
        ss0 = sor.observe(ss0, frame, SOR_CFG)
    ring_mb = sum(
        v.size * v.dtype.itemsize
        for v in (ss0.history.v, ss0.history.obs, ss0.history.valid,
                  ss0.history.age_s, ss0.history.polled)) / 2**20

    def bench(donate: bool) -> float:
        ctrl = InGraphRailController(
            MultiRailClosedLoop(floors=dict(SOR_POLICY_FLOORS)),
            sor=SOR_CFG, donate=donate)
        # compile outside timing — on a copy, since the donated SorState
        # buffer is invalidated by the call
        ctrl.control_step_sor(
            plane0, frame, jax.tree_util.tree_map(jnp.copy, ss0))

        def roll():
            # re-bind the carry as a real control loop does; a fresh ring
            # copy per repeat so the donated original is never re-read
            p = plane0
            s = jax.tree_util.tree_map(jnp.copy, ss0)
            for _ in range(8):
                p, s = ctrl.control_step_sor(p, frame, s)
            return jax.block_until_ready(p.v_io)

        return timed(roll, repeats=10)[1] / 8

    us_off, us_on = bench(False), bench(True)
    record = {
        "n_chips": n, "capacity": SOR_CFG.capacity,
        "history_ring_mb": ring_mb,
        "us_per_round": {"donate_off": us_off, "donate_on": us_on},
        "saving_pct": 100.0 * (1.0 - us_on / us_off),
        # live rings during the round: donation keeps one resident copy
        "peak_ring_copies": {"donate_off": 2, "donate_on": 1},
    }
    return [{**row(
        f"ours.learned_round.{n}chips.donation",
        us_on,
        f"donate_on={us_on:.0f}us donate_off={us_off:.0f}us "
        f"saving={record['saving_pct']:.1f}% ring={ring_mb:.1f}MB "
        f"(peak live rings 1 vs 2)"),
        "bench": "controller_overhead", "record": record}]


def run():
    rows = []
    rows.append(row("tableVII.hw_utilization", 0.0,
                    f"LUT={overhead.HW_UTILIZATION_PCT['total']['slice_luts']}% "
                    f"BRAM={overhead.HW_UTILIZATION_PCT['total']['bram_tiles']}% "
                    f"(paper: 1.45% / 1.80%)"))
    rows.append(row("tableVIII.sw_utilization", 0.0,
                    f"LUT={overhead.SW_UTILIZATION_PCT['total']['slice_luts']}% "
                    f"BRAM={overhead.SW_UTILIZATION_PCT['total']['bram_tiles']}% "
                    f"bram_ratio={overhead.bram_ratio():.2f}x (paper: 31.96x)"))
    rows.append(row("tableIX.static_power", 0.0,
                    f"hw={overhead.HW_STATIC_TOTAL_W}W sw={overhead.SW_STATIC_TOTAL_W}W "
                    f"ratio={overhead.static_power_ratio():.2f}x (paper: 5.60x, "
                    f"hw share ~2%)"))

    # our controller, through the unified control plane: in-graph (HW-path
    # analogue) cost vs a representative step
    profile = StepProfile(2e12, 8e9, 4e9, 3e9)
    in_graph = InGraphRailController(PhaseAware())

    @jax.jit
    def controller_only(plane):
        plane, m = account_step(profile, plane)
        return in_graph.control_step(plane, m)

    plane = PowerPlaneState.nominal()
    _, us_ctrl = timed(lambda: jax.block_until_ready(controller_only(plane)),
                       repeats=20)
    t_step_target = float(jax.device_get(
        account_step(profile, plane)[1]["t_step_s"]))
    frac = (us_ctrl * 1e-6) / t_step_target
    rows.append(row("ours.in_graph_controller", us_ctrl,
                    f"cost_vs_step={100*frac:.3f}% (<2% budget: {frac < 0.02}; "
                    f"in-graph ops are ~30 scalars — free once fused)"))

    # host path (SW analogue): PMBus actuation cost per adjustment
    hc = HostRailController()
    st = PowerPlaneState.nominal()
    st2 = dataclasses.replace(st, v_io=jnp.float32(0.85))
    _, us_host = timed(lambda: hc.actuate(st2), repeats=1)
    rows.append(row("ours.host_controller_actuation", us_host,
                    f"simulated_pmbus_latency={hc.actuation_seconds*1e3:.2f}ms "
                    f"(ms-scale, matches paper §VII-C)"))

    # fused in-graph learned round vs the unfused PR-5 composition —
    # emits the structured record run.py routes to
    # reports/BENCH_controller_overhead.json
    rows.extend(_learned_round_rows())
    # buffer-donation delta on the cached learned-round jit at fleet scale
    rows.extend(_donation_rows())
    return rows
