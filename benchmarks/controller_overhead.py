"""Paper Tables VII-IX (§V-F): controller overhead.

Reproduces the paper's headline ratios from the structured Vivado data
(HW 1.45% LUTs / 0.015 W ~ 2% share; SW 57.52% BRAM = 31.96x; static power
5.60x), then measures the SAME property for THIS system's controller: the
in-graph (HW-analogue) policy update and energy accounting must stay <2% of
the training step, and the host (SW-analogue) path's per-step cost is
reported like Table VI."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.core import overhead
from repro.core.control_plane import HostRailController, InGraphRailController
from repro.core.policy import PhaseAware
from repro.core.power_plane import PowerPlaneState, StepProfile, account_step


def run():
    rows = []
    rows.append(row("tableVII.hw_utilization", 0.0,
                    f"LUT={overhead.HW_UTILIZATION_PCT['total']['slice_luts']}% "
                    f"BRAM={overhead.HW_UTILIZATION_PCT['total']['bram_tiles']}% "
                    f"(paper: 1.45% / 1.80%)"))
    rows.append(row("tableVIII.sw_utilization", 0.0,
                    f"LUT={overhead.SW_UTILIZATION_PCT['total']['slice_luts']}% "
                    f"BRAM={overhead.SW_UTILIZATION_PCT['total']['bram_tiles']}% "
                    f"bram_ratio={overhead.bram_ratio():.2f}x (paper: 31.96x)"))
    rows.append(row("tableIX.static_power", 0.0,
                    f"hw={overhead.HW_STATIC_TOTAL_W}W sw={overhead.SW_STATIC_TOTAL_W}W "
                    f"ratio={overhead.static_power_ratio():.2f}x (paper: 5.60x, "
                    f"hw share ~2%)"))

    # our controller, through the unified control plane: in-graph (HW-path
    # analogue) cost vs a representative step
    profile = StepProfile(2e12, 8e9, 4e9, 3e9)
    in_graph = InGraphRailController(PhaseAware())

    @jax.jit
    def controller_only(plane):
        plane, m = account_step(profile, plane)
        return in_graph.control_step(plane, m)

    plane = PowerPlaneState.nominal()
    _, us_ctrl = timed(lambda: jax.block_until_ready(controller_only(plane)),
                       repeats=20)
    t_step_target = float(jax.device_get(
        account_step(profile, plane)[1]["t_step_s"]))
    frac = (us_ctrl * 1e-6) / t_step_target
    rows.append(row("ours.in_graph_controller", us_ctrl,
                    f"cost_vs_step={100*frac:.3f}% (<2% budget: {frac < 0.02}; "
                    f"in-graph ops are ~30 scalars — free once fused)"))

    # host path (SW analogue): PMBus actuation cost per adjustment
    hc = HostRailController()
    st = PowerPlaneState.nominal()
    import dataclasses
    st2 = dataclasses.replace(st, v_io=jnp.float32(0.85))
    _, us_host = timed(lambda: hc.actuate(st2), repeats=1)
    rows.append(row("ours.host_controller_actuation", us_host,
                    f"simulated_pmbus_latency={hc.actuation_seconds*1e3:.2f}ms "
                    f"(ms-scale, matches paper §VII-C)"))
    return rows
