"""Beyond-paper: the fleet energy/BER frontier — fleet size x policy.

The multi-FPGA related work (Salamat et al.; Khaleghi et al.) shows the
interesting regime is *fleets* of devices with per-device margins. This sweep
runs the whole control plane at fleet scale: per-chip batched
`PowerPlaneState` advanced by a vmapped in-graph controller over a scan of
steps (per-chip gradient-error telemetry with chip-to-chip process spread),
fleet-level reductions through the kernels.ops.fleet_reduce hot path, and one
host-path actuation round through the event-scheduled multi-segment PMBus bus
to price what deploying the decided operating points costs in fleet time.

Reported per (fleet size, policy): energy saving vs static-nominal margins,
worst-chip error vs the bound, and the bus actuation overlap speedup
(max-over-segments vs a serialized single bus).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core.control_plane import HostRailController, InGraphRailController
from repro.core.fleet import FleetPowerManager
from repro.core.policy import (BERBounded, ClosedLoop, StaticNominal,
                               WorstChipGate)
from repro.core.power_plane import PowerPlaneState, StepProfile, account_step
from repro.kernels import ops

PROFILE = StepProfile(flops_per_chip=2e12, hbm_bytes_per_chip=8e9,
                      ici_bytes_per_chip=4e9, grad_bytes_per_chip=3e9)
ERROR_BOUND = 5e-3
STEPS = 200

FLEET_SIZES = (64, 256)
POLICIES = (StaticNominal(), BERBounded(), ClosedLoop(),
            WorstChipGate(ClosedLoop()))


# jit caches on function identity, so the compiled rollout is memoized per
# (fleet size, policy) — timed()'s warmup then genuinely warms the cache.
_ROLLOUT_CACHE: dict = {}


def _rollout_fn(n_chips: int, policy):
    key = (n_chips, policy.name)
    if key in _ROLLOUT_CACHE:
        return _ROLLOUT_CACHE[key]
    ctrl = InGraphRailController(policy)
    # per-chip error sensitivity: worst chip ~2.2x the median
    spread = 1.0 + 1.2 * jax.random.uniform(jax.random.PRNGKey(17), (n_chips,))

    def round_fn(plane, key):
        plane, metrics = jax.vmap(lambda s: account_step(PROFILE, s))(plane)
        # measured gradient error grows as VDD_IO digs below nominal
        margin = jnp.maximum(0.0, 0.95 - plane.v_io) / 0.95
        noise = 1.0 + 0.1 * jax.random.normal(key, (n_chips,))
        err = ERROR_BOUND * spread * noise * (0.2 + 12.0 * margin)
        telemetry = {**metrics, "grad_error": err}
        plane = ctrl.control_step(plane, telemetry)
        out = {"power_w": metrics["power_w"], "grad_error": err}
        return plane, out

    @jax.jit
    def rollout():
        keys = jax.random.split(jax.random.PRNGKey(3), STEPS)
        plane = PowerPlaneState.fleet(n_chips)
        plane, hist = jax.lax.scan(round_fn, plane, keys)
        return plane, hist

    _ROLLOUT_CACHE[key] = rollout
    return rollout


def _fleet_rollout(n_chips: int, policy
                   ) -> "tuple[PowerPlaneState, dict[str, jnp.ndarray]]":
    """STEPS control rounds of a fleet under one in-graph controller,
    compiled as a single scan; per-chip grad-error telemetry with a fixed
    chip-to-chip spread (process variation analogue)."""
    plane, hist = _rollout_fn(n_chips, policy)()
    jax.block_until_ready(plane.energy_j)
    return plane, hist


def run():
    rows = []
    baseline_j: dict[int, float] = {}
    for n in FLEET_SIZES:
        for policy in POLICIES:
            (plane, hist), us = timed(lambda n=n, p=policy: _fleet_rollout(n, p),
                                      repeats=1)
            # fleet telemetry reduction through the kernel hot path:
            # [n_chips, n_fields] -> per-field worst/best/total
            telem = jnp.stack([plane.energy_j, plane.v_io,
                               hist["grad_error"][-1]], axis=1)
            t_max, t_min, t_sum = ops.fleet_reduce(telem)
            total_j = float(t_sum[0])
            worst_err = float(t_max[2])
            if policy.name == "static-nominal":
                baseline_j[n] = total_j
            saving = 1.0 - total_j / baseline_j[n]
            rows.append(row(
                f"fleet.{n}chips.{policy.name}", us,
                f"energy={total_j:.0f}J saving={100*saving:.1f}% "
                f"v_io=[{float(t_min[1]):.3f},{float(t_max[1]):.3f}] "
                f"worst_err={worst_err:.2e} (bound {ERROR_BOUND:.0e}) "
                f"steps={STEPS}"))

        # price ONE host-path deployment of the decided operating points
        # through the event-scheduled multi-segment bus (SW path, 400 kHz);
        # timed manually — timed()'s warmup would run a second real round
        hc = HostRailController(n_chips=n)
        t0 = time.perf_counter()
        hc.actuate(plane)
        us_bus = (time.perf_counter() - t0) * 1e6
        rep = hc.last_report
        rows.append(row(
            f"fleet.{n}chips.bus_actuation", us_bus,
            f"fleet_time={rep.elapsed_s*1e3:.2f}ms "
            f"serialized={rep.serialized_s*1e3:.1f}ms "
            f"overlap_speedup={rep.overlap_speedup:.0f}x "
            f"writes={rep.lane_writes}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
