"""Beyond-paper: the fleet energy/BER frontier — fleet size x policy.

The multi-FPGA related work (Salamat et al.; Khaleghi et al.) shows the
interesting regime is *fleets* of devices with per-device margins. This sweep
runs the whole control plane at fleet scale: per-chip batched
`PowerPlaneState` seeded from a `FleetSpec` (per-chip process-varied nominal
voltages, leakage, and BER-curve offsets — hwspec.py, not a telemetry-side
hack), advanced by a vmapped in-graph controller over a scan of steps,
fleet-level reductions through the kernels.ops.fleet_reduce hot path, and one
host-path actuation round through the event-scheduled multi-segment PMBus bus
to price what deploying the decided operating points costs in fleet time.

Two rollout paths per the paper's control-path split (both speak the
decision-as-data API: TelemetryFrame observations in, RailRequests out,
arbitration in control_plane):
  * in-graph (HW analogue): the whole rollout compiles into one scan —
    scales to 1024 chips;
  * host (SW analogue, `_host_rollout`): decisions between steps from the
    controller's *own* READ_VOUT polling telemetry (`decide_from="poll"` —
    closed loop on sampled voltages, sampling age included), actuated
    through PMBus with Table VI polling interleaved; the control period is
    chosen from the *measured* actuation latency so control costs at most
    `DUTY` of the timeline (paper §VII-C latency/energy tradeoff).

Reported per (fleet size, policy): energy saving vs static-nominal margins,
worst-chip error vs the bound, and the bus actuation overlap speedup
(max-over-segments vs a serialized single bus).
"""

from __future__ import annotations

import dataclasses
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core import sor
from repro.core.control_plane import HostRailController, InGraphRailController
from repro.core.hwspec import FleetSpec
from repro.core.policy import (BERBounded, ClosedLoop, MultiRailClosedLoop,
                               StaticNominal, WorstChipGate)
from repro.core.power_plane import (PowerPlaneState, StepProfile,
                                    account_fleet_and_observe, step_time_s)
from repro.core.rails import TPU_V5E_RAIL_MAP
from repro.kernels import ops

PROFILE = StepProfile(flops_per_chip=2e12, hbm_bytes_per_chip=8e9,
                      ici_bytes_per_chip=4e9, grad_bytes_per_chip=3e9)
ERROR_BOUND = 5e-3
STEPS = 200
FLEET_SEED = 17

FLEET_SIZES = (64, 256, 1024)
HOST_FLEET_SIZES = (64,)      # SW path: every board is a python PMBus stack
HOST_ROUNDS = 12
DUTY = 0.10                   # control may occupy <= 10% of the timeline

POLICIES = (StaticNominal(), BERBounded(), ClosedLoop(),
            WorstChipGate(ClosedLoop()))


def _grad_error(plane, fs_io_nom, sens, key, n_chips):
    """Per-chip measured gradient-domain error: each chip's BER-curve offset
    (FleetSpec.error_sensitivity) x its own VDD_IO undervolt margin."""
    margin = jnp.maximum(0.0, fs_io_nom - plane.v_io) / fs_io_nom
    noise = 1.0 + 0.1 * jax.random.normal(key, (n_chips,))
    return ERROR_BOUND * sens * noise * (0.2 + 12.0 * margin)


# jit caches on function identity, so the compiled rollout is memoized per
# (fleet size, policy, steps) — timed()'s warmup then genuinely warms the
# cache.
_ROLLOUT_CACHE: dict = {}


def _rollout_fn(n_chips: int, policy, steps: int):
    key = (n_chips, policy.name, steps)
    if key in _ROLLOUT_CACHE:
        return _ROLLOUT_CACHE[key]
    ctrl = InGraphRailController(policy)
    fs = FleetSpec.sample(n_chips, seed=FLEET_SEED)
    v_nom_io = jnp.asarray(fs.v_io_nominal)
    sens = jnp.asarray(fs.error_sensitivity)

    def round_fn(plane, k):
        # typed EXACT observation, anchored to the FleetSpec per-chip
        # nominals; per-chip measured error overlaid before the decision
        plane, frame, metrics = account_fleet_and_observe(PROFILE, plane, fs)
        err = _grad_error(plane, v_nom_io, sens, k, n_chips)
        plane = ctrl.control_step(
            plane, dataclasses.replace(frame, grad_error=err))
        out = {"power_w": metrics["power_w"], "grad_error": err}
        return plane, out

    @jax.jit
    def rollout():
        keys = jax.random.split(jax.random.PRNGKey(3), steps)
        plane = PowerPlaneState.from_fleet(fs)
        plane, hist = jax.lax.scan(round_fn, plane, keys)
        return plane, hist

    _ROLLOUT_CACHE[key] = rollout
    return rollout


def _fleet_rollout(n_chips: int, policy, steps: int = STEPS
                   ) -> "tuple[PowerPlaneState, dict[str, jnp.ndarray]]":
    """`steps` control rounds of a fleet under one in-graph controller,
    compiled as a single scan, with FleetSpec per-chip process variation."""
    plane, hist = _rollout_fn(n_chips, policy, steps)()
    jax.block_until_ready(plane.energy_j)
    return plane, hist


def _host_rollout(n_chips: int, policy, rounds: int = HOST_ROUNDS,
                  duty: float = DUTY):
    """Host-path fleet rollout with an actuation-latency-aware control
    period (paper §VII-C): measure what one fleet actuation round costs on
    the event-scheduled bus, then space control rounds so actuation occupies
    at most `duty` of the fleet timeline. Table VI READ_VOUT polling runs
    interleaved on every segment throughout, and the controller *decides
    from it* (`decide_from="poll"`): each round's rail observations are the
    aged PMBus samples, not oracle state — the ROADMAP poll-driven closed
    loop at fleet scale."""
    fs = FleetSpec.sample(n_chips, seed=FLEET_SEED)
    hc = HostRailController(policy, n_chips=n_chips, decide_from="poll")
    hc.enable_polling()
    plane = PowerPlaneState.from_fleet(fs)
    v_nom_io = jnp.asarray(fs.v_io_nominal)
    sens = jnp.asarray(fs.error_sensitivity)
    t_step = float(jnp.mean(step_time_s(PROFILE, plane)))

    account = jax.jit(
        lambda p: account_fleet_and_observe(PROFILE, p, fs)[:2])
    keys = jax.random.split(jax.random.PRNGKey(11), rounds)

    # calibration: one actuation round prices the control path, then the
    # control period is ceil(latency/duty) worth of train steps
    hc.actuate(plane)
    act_s = hc.last_report.elapsed_s if hc.last_report else 0.0
    period_steps = max(1, math.ceil(act_s / max(duty * t_step, 1e-12)))

    for r in range(rounds):
        for _ in range(period_steps):
            plane, frame = account(plane)
        hc.fleet.idle(period_steps * t_step)   # polls fire through train time
        err = _grad_error(plane, v_nom_io, sens, keys[r], n_chips)
        plane = hc.control_step(
            plane, dataclasses.replace(frame, grad_error=err))
    st = hc.stats()
    fleet_time = hc.fleet.clock.now
    poll = hc.fleet.poll_stats
    mean_poll_iv = float(np.nanmean([p.achieved_interval_s
                                     for p in poll.values()])) if poll else 0.0
    age = (float(np.mean(np.asarray(hc.last_frame.age_s)))
           if hc.last_frame is not None else 0.0)
    return plane, {
        "period_steps": period_steps,
        "actuation_duty": st.actuation_seconds / max(fleet_time, 1e-12),
        "actuation_s": st.actuation_seconds,
        "fleet_time_s": fleet_time,
        "polls": st.polls,
        "polls_deferred": st.polls_deferred,
        "poll_interval_ms": mean_poll_iv * 1e3,
        "poll_decisions": st.poll_decisions,
        "sample_age_ms": age * 1e3,
    }


# ---------------------------------------------------------------------------
# Learned vs static safe-operating regions (core/sor.py, docs/sor.md)
# ---------------------------------------------------------------------------
#
# The shared static envelopes leave the strong chips' headroom on the table:
# every chip is clamped at the same platform floors even though each has its
# own frontier — on EVERY rail, with a different failure mode per rail
# (paper §VII-B: per-rail envelopes; Khaleghi/Papadimitriou: rail- and
# workload-specific margins). This comparison runs the same in-graph
# MultiRailClosedLoop fleet twice — once against the static envelopes, once
# with the three-rail SOR learner threading FrameHistory/SorEstimate through
# the scan — in a synthetic per-rail frontier world: VDD_IO crosses the
# bound on measured gradient-domain error (the BER analogue), VDD_CORE on
# the straggler rate, VDD_HBM on the HBM interface error rate, each with its
# own per-chip onset spread. Reported per rail: recovered headroom below the
# shared static floor, with the modeled observable still at/below the bound.

# CI bench-smoke knobs: the workflow's regression gate runs the same
# learned-vs-static sweep at a small fleet so it fits a CI minute — the
# ratio-based check (benchmarks/check_bench_regression.py) is what makes
# the small run meaningful across machines
SOR_STEPS = int(os.environ.get("REPRO_BENCH_SOR_STEPS", "160"))
SOR_FLEET_SIZES = tuple(
    int(x) for x in os.environ.get("REPRO_BENCH_SOR_CHIPS", "64").split(","))
# timing repeats for the rollout wall times: 1 for the full-size record
# (the 64-chip rollouts are pricey), >1 for the CI smoke so the gated
# learned/static ratio averages over run-to-run jitter
SOR_REPEATS = int(os.environ.get("REPRO_BENCH_SOR_REPEATS", "1"))
# sharded control plane (control_plane.sharded_control_round): > 1 runs the
# learned rollouts with the SorState shard-resident on a `chips` mesh of
# that many devices. CPU hosts need
# XLA_FLAGS=--xla_force_host_platform_device_count=N set BEFORE process
# start to expose N devices. 0 (default) keeps the single-device path;
# run_weak_scaling falls back to all visible devices when unset.
SOR_SHARDS = int(os.environ.get("REPRO_BENCH_SOR_SHARDS", "0"))
# weak-scaling sweep (run_weak_scaling): fleet sizes ride the shard count
# while per-shard work stays fixed; the gated ratio is µs/step vs the
# single-device anchor at SOR_WEAK_BASE_CHIPS
SOR_WEAK_CHIPS = tuple(int(x) for x in os.environ.get(
    "REPRO_BENCH_SOR_WEAK_CHIPS", "256,1024,4096").split(","))
SOR_WEAK_STEPS = int(os.environ.get("REPRO_BENCH_SOR_WEAK_STEPS",
                                    str(SOR_STEPS)))
SOR_WEAK_BASE_CHIPS = int(os.environ.get("REPRO_BENCH_SOR_WEAK_BASE", "64"))
SOR_LOG_SLOPE = 30.0           # decades of error per volt below the onset
#                                (the paper's ~5 mV Fig-12c transition band)
# shared static policy floors under test (per rail)
SOR_POLICY_FLOORS = {"VDD_CORE": 0.70, "VDD_HBM": 1.00, "VDD_IO": 0.70}
# per-rail onset bands: (base = strongest chip's onset, spread) — chosen so
# each band straddles its rail's platform floor (0.60/0.90/0.65): strong
# chips have real headroom below the shared static envelope, weak chips'
# frontiers sit above it
SOR_ONSETS = {"VDD_CORE": (0.598, 0.05), "VDD_HBM": (0.878, 0.05),
              "VDD_IO": (0.62, 0.05)}
SOR_CFG = sor.SorConfig(capacity=32, refresh_every=4, decay=0.96,
                        error_bound=ERROR_BOUND, guard_v=0.004,
                        max_extension_v=0.12, ingest="frames",
                        rails=sor.ALL_RAIL_OBSERVABLES)
_STATIC_FLOORS = {r: TPU_V5E_RAIL_MAP.by_name(r).v_min
                  for r in SOR_POLICY_FLOORS}


def _onset_voltages(fs: FleetSpec, rail: str) -> jnp.ndarray:
    """Per-chip frontier onset voltage for one rail: the seeded process
    variation mapped onto a Fig-12-style onset band (weak chips' frontiers
    sit above the strong chips'). VDD_IO/VDD_HBM ride the BER-curve offset,
    VDD_CORE the leakage spread — per-rail orderings genuinely differ, as
    they do across real failure modes."""
    base, spread = SOR_ONSETS[rail]
    src = (fs.leakage_scale if rail == "VDD_CORE" else fs.error_sensitivity)
    return base + spread * (jnp.asarray(src) - 1.0)


def _frontier_error(v, v_onset, key, n_chips):
    """Synthetic frontier-shaped observable: crosses ERROR_BOUND exactly at
    each chip's own onset, log-linear below it (steep transition band)."""
    noise = 1.0 + 0.05 * jax.random.normal(key, (n_chips,))
    return ERROR_BOUND * noise * 10.0 ** jnp.clip(
        SOR_LOG_SLOPE * (v_onset - v), -6.0, 3.0)


def _sor_mesh(shards: int):
    """1-D `chips` mesh over the first `shards` devices (None for <= 1)."""
    if shards <= 1:
        return None
    from jax.sharding import Mesh
    devs = jax.devices()
    if len(devs) < shards:
        raise RuntimeError(
            f"asked for {shards} shards but only {len(devs)} device(s) "
            f"visible — on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={shards} before "
            f"process start (it cannot be set in-process)")
    return Mesh(np.array(devs[:shards]), ("chips",))


def _sor_rollout_fn(n_chips: int, learned: bool, steps: int,
                    shards: int = 0):
    key = ("sor", n_chips, learned, steps, shards)
    if key in _ROLLOUT_CACHE:
        return _ROLLOUT_CACHE[key]
    ctrl = InGraphRailController(
        MultiRailClosedLoop(floors=dict(SOR_POLICY_FLOORS)),
        sor=SOR_CFG if learned else None)
    fs = FleetSpec.sample(n_chips, seed=FLEET_SEED)
    v_on = {r: _onset_voltages(fs, r) for r in SOR_POLICY_FLOORS}
    # sharded learned rollout: per-shard resident SorState/plane through
    # control_plane.sharded_control_round — trajectories match the
    # single-device path (the frame observables are drawn on global shapes)
    mesh = _sor_mesh(shards) if learned else None
    sharded_round = None
    if mesh is not None:
        from repro.core.control_plane import sharded_control_round
        if n_chips % shards:
            raise ValueError(f"{n_chips} chips not divisible by "
                             f"{shards} shards")
        sharded_round = sharded_control_round(ctrl, mesh)

    def round_fn(carry, k):
        plane, ss = carry
        plane, frame, metrics = account_fleet_and_observe(PROFILE, plane, fs)
        k_io, k_core, k_hbm = jax.random.split(k, 3)
        frame = dataclasses.replace(
            frame,
            grad_error=_frontier_error(plane.v_io, v_on["VDD_IO"], k_io,
                                       n_chips),
            extras={**frame.extras,
                    "straggle_rate": _frontier_error(
                        plane.v_core, v_on["VDD_CORE"], k_core, n_chips),
                    "hbm_error_rate": _frontier_error(
                        plane.v_hbm, v_on["VDD_HBM"], k_hbm, n_chips)})
        if sharded_round is not None:
            plane, ss, _, _ = sharded_round(plane, frame, ss)
        elif learned:
            plane, ss = ctrl.control_step_sor(plane, frame, ss)
        else:
            plane = ctrl.control_step(plane, frame)
        return (plane, ss), {"power_w": metrics["power_w"],
                             "v_io": plane.v_io}

    def scan_rollout(plane, ss):
        keys = jax.random.split(jax.random.PRNGKey(5), steps)
        (plane, ss), hist = jax.lax.scan(round_fn, (plane, ss), keys)
        return plane, ss, hist

    if mesh is None:
        @jax.jit
        def rollout():
            return scan_rollout(PowerPlaneState.from_fleet(fs),
                                sor.init_state(SOR_CFG, n_chips))
    else:
        # sharded path: init outside the jit so the carry enters (and the
        # scan runs) with the chip axis physically sharded over the mesh
        compiled = jax.jit(scan_rollout)

        def rollout():
            plane = ops.shard_chip_tree(PowerPlaneState.from_fleet(fs),
                                        mesh, n_chips)
            ss = ops.shard_chip_tree(sor.init_state(SOR_CFG, n_chips),
                                     mesh, n_chips)
            return compiled(plane, ss)

    _ROLLOUT_CACHE[key] = rollout
    return rollout


def _sor_rollout(n_chips: int, learned: bool, steps: int = SOR_STEPS,
                 shards: int = 0):
    plane, ss, hist = _sor_rollout_fn(n_chips, learned, steps, shards)()
    jax.block_until_ready(plane.energy_j)
    return plane, ss, hist


def _phase_split_us(n_chips: int, shards: int = 0) -> dict:
    """Per-phase cost of one learned control round, each phase timed as its
    own compiled program — the split future PRs read to see which phase
    stops scaling: `ingest` is one FrameHistory ring push, `refit` the
    windowed EWLS solve (runs every `refresh_every` rounds — its amortized
    per-round share is what the fused round actually pays),
    `decide_arbitrate` the off-cadence round (ingest + per-rail envelope
    blend + policy walk + arbitration clamp), `reduce` the cross-chip
    worst/mean fleet reduction (the only phase whose traffic crosses
    shards), and `actuate` one host PMBus deployment of the decided points
    through the event-scheduled bus (paid only when the deadband scheduler
    lets a write through, so it is reported per round, not per step). With
    `shards` > 1 the in-graph phases run on chip-sharded inputs (per-shard
    resident ring; the reduction through the shard_map collectives)."""
    mesh = _sor_mesh(shards)
    fs = FleetSpec.sample(n_chips, seed=FLEET_SEED)
    ctrl = InGraphRailController(
        MultiRailClosedLoop(floors=dict(SOR_POLICY_FLOORS)), sor=SOR_CFG)
    v_on = {r: _onset_voltages(fs, r) for r in SOR_POLICY_FLOORS}
    plane = PowerPlaneState.from_fleet(fs)
    plane, frame, _ = account_fleet_and_observe(PROFILE, plane, fs)
    k = jax.random.split(jax.random.PRNGKey(7), 3)
    frame = dataclasses.replace(
        frame,
        grad_error=_frontier_error(plane.v_io, v_on["VDD_IO"], k[0],
                                   n_chips),
        extras={**frame.extras,
                "straggle_rate": _frontier_error(
                    plane.v_core, v_on["VDD_CORE"], k[1], n_chips),
                "hbm_error_rate": _frontier_error(
                    plane.v_hbm, v_on["VDD_HBM"], k[2], n_chips)})
    ss = sor.init_state(SOR_CFG, n_chips)
    for _ in range(SOR_CFG.refresh_every * 2):
        ss = sor.observe(ss, frame, SOR_CFG)
    if mesh is not None:
        # chip-sharded inputs: the jitted phases inherit the sharding, so
        # each runs on its per-shard slice exactly as inside the round
        plane = ops.shard_chip_tree(plane, mesh, n_chips)
        frame = ops.shard_chip_tree(frame, mesh, n_chips)
        ss = ops.shard_chip_tree(ss, mesh, n_chips)

    ingest = jax.jit(lambda h, f: h.push(f))
    _, us_ingest = timed(
        lambda: jax.block_until_ready(ingest(ss.history, frame).v),
        repeats=20)

    refit = jax.jit(lambda h: sor.fit_history(h, SOR_CFG, fused=True))
    _, us_refit = timed(
        lambda: jax.block_until_ready(refit(ss.history).v_frontier),
        repeats=20)

    # pin the tick off-cadence so the jitted round's lax.cond takes the
    # hold branch: this is what refresh_every-1 of every refresh_every
    # rounds cost
    off = dataclasses.replace(ss, tick=jnp.int32(SOR_CFG.refresh_every + 1))
    round_jit = jax.jit(lambda p, f, s: ctrl.control_round(p, f, s))
    _, us_round = timed(
        lambda: jax.block_until_ready(round_jit(plane, frame, off)[0].v_io),
        repeats=20)

    # the cross-chip fleet reduction — on a mesh, the one collective phase
    stacked = jnp.stack([plane.v_core, plane.v_hbm, plane.v_io,
                         frame.grad_error, frame.power_w], axis=1)
    if mesh is not None:
        reduce_fn = jax.jit(lambda x: ops.sharded_fleet_reduce(
            x, mesh=mesh, axis_name="chips", use_shard_map=True))
    else:
        reduce_fn = jax.jit(ops.fleet_reduce)
    _, us_reduce = timed(
        lambda: jax.block_until_ready(reduce_fn(stacked)[0]), repeats=20)

    hc = HostRailController(n_chips=n_chips)
    t0 = time.perf_counter()
    hc.actuate(plane)
    us_act = (time.perf_counter() - t0) * 1e6

    r = SOR_CFG.refresh_every
    return {
        "ingest_us": us_ingest,
        "refit_us": us_refit,
        "decide_arbitrate_us": us_round,
        "reduce_us": us_reduce,
        "actuate_us": us_act,
        "per_round_us": us_round + us_refit / r,
        "refresh_every": r,
        "shards": max(shards, 1),
    }


def run_learned(fleet_sizes=SOR_FLEET_SIZES, steps: int = SOR_STEPS):
    """Learned-vs-static envelope comparison: same fleet, same policy, same
    per-rail error world — the only difference is whether the controller
    consumes the static shared envelopes or the online-fitted per-rail
    per-chip SOR. Each returned row carries a machine-readable `record`
    (rail-power saving, per-rail learned-vs-static floors, wall time) that
    `benchmarks/run.py --json-out` accumulates into the bench trajectory."""
    rows = []
    for n in fleet_sizes:
        (p_st, _, h_st), us_st = timed(
            lambda n=n: _sor_rollout(n, False, steps), repeats=SOR_REPEATS)
        (p_ln, ss, h_ln), us_ln = timed(
            lambda n=n: _sor_rollout(n, True, steps, shards=SOR_SHARDS),
            repeats=SOR_REPEATS)
        est = ss.estimate
        envs = sor.rail_envelopes(est, SOR_CFG)
        # the paper's headline metric is rail POWER reduction; energy is
        # reported too but couples back through step time (undervolted ICI
        # slows collectives), so it can move either way per profile
        tail = max(1, steps // 4)
        p_mean_st = float(jnp.mean(h_st["power_w"][-tail:]))
        p_mean_ln = float(jnp.mean(h_ln["power_w"][-tail:]))
        e_st = float(jnp.sum(p_st.energy_j))
        e_ln = float(jnp.sum(p_ln.energy_j))
        saving_pct = 100 * (1 - p_mean_ln / p_mean_st)

        rail_records = {}
        derived_rails = []
        for i, spec in enumerate(SOR_CFG.rails):
            rail = spec.rail
            static_floor = _STATIC_FLOORS[rail]
            floors = np.asarray(envs[rail].floor(static_floor))
            conf = np.asarray(est.confidence[i])
            below = int((floors < static_floor - 1e-4).sum())
            headroom = np.clip(static_floor - floors, 0.0, None)
            # safety: the modeled observable at the operating points the
            # learned run actually holds stays at/below the rail's bound
            held = getattr(p_ln, spec.voltage)
            modeled = np.asarray(est.rail(i).log10_error_at(held))
            worst_modeled = (float(modeled[conf > 0].max())
                             if (conf > 0).any() else float("nan"))
            rail_records[rail] = {
                "static_floor_v": float(static_floor),
                "chips_below_static": below,
                "headroom_mean_mv": float(1e3 * headroom.mean()),
                "headroom_max_mv": float(1e3 * headroom.max()),
                "conf_mean": float(conf.mean()),
                "worst_modeled_log10err": worst_modeled,
                "bound_log10": math.log10(ERROR_BOUND),
            }
            derived_rails.append(
                f"{rail}:below={below}/{n} "
                f"headroom={1e3 * headroom.mean():.1f}mV "
                f"conf={conf.mean():.2f} "
                f"log10err={worst_modeled:.2f}")

        phase = _phase_split_us(n, shards=SOR_SHARDS)
        record = {
            "n_chips": n, "steps": steps, "shards": max(SOR_SHARDS, 1),
            "power_saving_pct": saving_pct,
            "energy_delta_pct": 100 * (e_ln / e_st - 1),
            "wall_time_us": {"static": us_st, "learned": us_ln},
            "us_per_step": {"static": us_st / steps,
                            "learned": us_ln / steps},
            "phase_us": phase,
            "rails": rail_records,
        }
        rows.append({**row(
            f"sor.{n}chips.learned_vs_static", us_ln,
            f"power_saving={saving_pct:.1f}% "
            f"energy_delta={100 * (e_ln / e_st - 1):+.1f}% "
            f"us/step={us_ln / steps:.0f}ln/{us_st / steps:.0f}st "
            f"phase[ingest={phase['ingest_us']:.0f} "
            f"refit={phase['refit_us']:.0f}/"
            f"{phase['refresh_every']} "
            f"decide={phase['decide_arbitrate_us']:.0f} "
            f"reduce={phase['reduce_us']:.0f} "
            f"actuate={phase['actuate_us']:.0f}]us "
            + " ".join(derived_rails)
            + f" (bound {math.log10(ERROR_BOUND):.2f}) steps={steps}"),
            "record": record})
    return rows


def run_weak_scaling(fleet_sizes=None, steps=None):
    """Weak-scaling record for the sharded control plane: learned-control
    µs/step as the fleet grows with the shard count (per-shard work held
    near-constant), against the same run's single-device anchor at
    `SOR_WEAK_BASE_CHIPS` — the PR-6 reference size. `ratio_vs_base` is
    the PER-CHIP per-step cost normalized to the anchor's —
    (us_per_step/n) / (base_us_per_step/base_chips) — the weak-scaling
    efficiency: ≈1 means fleet growth is fully absorbed by the shard
    mesh, and a near-flat ratio is the point of per-shard SOR state (the
    O(capacity x rails x chips) ring never gathers, so per-chip control
    cost stays put while the fleet scales). Raw µs/step is also recorded
    but not gated: N chips on a fixed shard count is N/base more work,
    so the raw ratio necessarily grows with N. Each fleet size emits one
    record (bench tag `fleet_frontier_weak_scaling` ->
    BENCH_fleet_frontier_weak_scaling.json) carrying the per-shard phase
    split; `ratio_vs_base` is what check_bench_regression.py gates.

    Needs multiple devices to mean anything (REPRO_BENCH_SOR_SHARDS, or
    all visible devices when unset); on one device it still runs and
    records, flagged `shards: 1`."""
    fleet_sizes = tuple(fleet_sizes or SOR_WEAK_CHIPS)
    steps = steps or SOR_WEAK_STEPS
    shards = SOR_SHARDS or len(jax.devices())
    n_base = SOR_WEAK_BASE_CHIPS

    rows = []
    # single-device anchor: the committed BENCH_fleet_frontier reference
    _, us_base = timed(lambda: _sor_rollout(n_base, True, steps),
                       repeats=SOR_REPEATS)
    base_per_step = us_base / steps
    for n in fleet_sizes:
        if n % shards:
            print(f"run_weak_scaling: skipping n_chips={n} "
                  f"(not divisible by {shards} shards)")
            continue
        (p_ln, ss, _), us = timed(
            lambda n=n: _sor_rollout(n, True, steps, shards=shards),
            repeats=SOR_REPEATS)
        per_step = us / steps
        # weak-scaling efficiency: per-chip per-step cost vs the anchor's
        ratio = (per_step / n) / (base_per_step / n_base)
        phase = _phase_split_us(n, shards=shards)
        conf = np.asarray(ss.estimate.confidence)
        record = {
            "n_chips": n, "steps": steps, "shards": shards,
            "base_chips": n_base,
            "base_us_per_step": base_per_step,
            "us_per_step": per_step,
            "us_per_chip_step": per_step / n,
            "ratio_vs_base": ratio,
            "phase_us": phase,
            "conf_mean": float(conf.mean()),
        }
        rows.append({**row(
            f"sor.weak_scaling.{n}chips.{shards}shards", us,
            f"us/step={per_step:.0f} vs base={base_per_step:.0f} "
            f"({n_base}chips/1dev) per_chip_ratio={ratio:.2f} "
            f"phase[ingest={phase['ingest_us']:.0f} "
            f"refit={phase['refit_us']:.0f}/{phase['refresh_every']} "
            f"decide={phase['decide_arbitrate_us']:.0f} "
            f"reduce={phase['reduce_us']:.0f}]us "
            f"conf={conf.mean():.2f} steps={steps}"),
            "bench": "fleet_frontier_weak_scaling",
            "record": record})
    return rows


def run(fleet_sizes=FLEET_SIZES, steps: int = STEPS,
        host_fleet_sizes=HOST_FLEET_SIZES, host_rounds: int = HOST_ROUNDS):
    rows = []
    baseline_j: dict[int, float] = {}
    for n in fleet_sizes:
        for policy in POLICIES:
            (plane, hist), us = timed(
                lambda n=n, p=policy: _fleet_rollout(n, p, steps), repeats=1)
            # fleet telemetry reduction through the kernel hot path:
            # [n_chips, n_fields] -> per-field worst/best/total
            telem = jnp.stack([plane.energy_j, plane.v_io,
                               hist["grad_error"][-1]], axis=1)
            t_max, t_min, t_sum = ops.fleet_reduce(telem)
            total_j = float(t_sum[0])
            worst_err = float(t_max[2])
            if policy.name == "static-nominal":
                baseline_j[n] = total_j
            saving = 1.0 - total_j / baseline_j[n]
            rows.append(row(
                f"fleet.{n}chips.{policy.name}", us,
                f"energy={total_j:.0f}J saving={100*saving:.1f}% "
                f"v_io=[{float(t_min[1]):.3f},{float(t_max[1]):.3f}] "
                f"worst_err={worst_err:.2e} (bound {ERROR_BOUND:.0e}) "
                f"steps={steps}"))

        # price ONE host-path deployment of the decided operating points
        # through the event-scheduled multi-segment bus (SW path, 400 kHz);
        # timed manually — timed()'s warmup would run a second real round
        hc = HostRailController(n_chips=n)
        t0 = time.perf_counter()
        hc.actuate(plane)
        us_bus = (time.perf_counter() - t0) * 1e6
        rep = hc.last_report
        rows.append(row(
            f"fleet.{n}chips.bus_actuation", us_bus,
            f"fleet_time={rep.elapsed_s*1e3:.2f}ms "
            f"serialized={rep.serialized_s*1e3:.1f}ms "
            f"overlap_speedup={rep.overlap_speedup:.0f}x "
            f"writes={rep.lane_writes}"))

    # host-path (SW analogue) rollout: decisions between steps, PMBus
    # actuation + Table VI polling on the fleet timeline, control period
    # derived from measured actuation latency (§VII-C)
    for n in host_fleet_sizes:
        (plane, info), us = timed(
            lambda n=n: _host_rollout(n, ClosedLoop(), rounds=host_rounds),
            repeats=1)
        rows.append(row(
            f"fleet.{n}chips.host_rollout", us,
            f"period={info['period_steps']}steps "
            f"duty={100*info['actuation_duty']:.1f}% "
            f"polls={info['polls']} deferred={info['polls_deferred']} "
            f"poll_iv={info['poll_interval_ms']:.2f}ms "
            f"sample_age={info['sample_age_ms']:.2f}ms "
            f"v_io_mean={float(jnp.mean(plane.v_io)):.3f}"))
    return rows


if __name__ == "__main__":
    for r in run() + run_learned():
        print(r)
