"""Beyond-paper (DESIGN.md §2.2): the TPU-native analogue of Fig 16 —
error-bounded gradient collectives. Sweeps the ICI "voltage knob"
(compression level) on a real training run and reports the gradient-error /
wire-bytes / energy frontier, mirroring the paper's BER/power frontier."""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import row, timed
from repro.configs import get_config
from repro.core import ecollectives as ec
from repro.core.power_plane import PowerPlaneState, StepProfile, account_step
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import registry
from repro.optim import adamw
from repro.optim.schedule import wsd
from repro.train.step import StepConfig, make_train_step, shard_map_ef_step

STEPS = 20
PROFILE = StepProfile(flops_per_chip=5e9, hbm_bytes_per_chip=5e8,
                      ici_bytes_per_chip=4e8, grad_bytes_per_chip=3.6e8)


def _train(grad_sync: str, k_fraction: float = 0.25):
    cfg = get_config("minicpm_2b", tiny=True)
    api = registry.build(cfg, remat="none")
    params = api.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig()
    opt = adamw.init_state(params, opt_cfg)
    plane = PowerPlaneState.nominal()
    ef = ec.zeros_like_residuals(params)
    sched = lambda s: wsd(s, peak_lr=1e-3, warmup_steps=2, stable_steps=50,
                          decay_steps=50)
    step_cfg = StepConfig(microbatches=1, grad_sync=grad_sync,
                          k_fraction=k_fraction)
    raw = make_train_step(lambda p, b: api.loss_fn(p, b), opt_cfg, sched,
                          PROFILE, step_cfg)
    if grad_sync != "auto":
        mesh = jax.make_mesh((1,), ("data",))
        step = jax.jit(shard_map_ef_step(raw, mesh))
    else:
        step = jax.jit(raw)
    data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 4, seed=0))
    losses, errs = [], []
    for s in range(STEPS):
        params, opt, plane, ef, m = step(params, opt, plane, ef,
                                         data.jax_batch(s))
        losses.append(float(m["loss"]))
        errs.append(float(m.get("grad_error", 0.0)))
    return np.mean(losses[-5:]), max(errs)


def run():
    rows = []
    base_loss, _ = _train("auto")
    lossless_wire = ec.wire_cost(ec.LEVEL_LOSSLESS).bytes_per_element

    for name, sync, level, kf in (
            ("int8+EF", "ef_int8", ec.LEVEL_INT8, 0.25),
            ("int8+topk25+EF", "ef_int8_topk", ec.LEVEL_INT8_TOPK, 0.25)):
        (loss, err), us = timed(lambda s=sync, k=kf: _train(s, k), repeats=1)
        wire = ec.wire_cost(level, kf).bytes_per_element
        ratio = wire / lossless_wire
        # ICI rail energy scales with wire bytes x link utilization window
        # (the transceiver-case-study analogue: bytes saved = link energy
        # saved at equal voltage, or deeper undervolt at equal throughput)
        rows.append(row(f"frontier.{name}", us,
                        f"loss={loss:.4f} (lossless {base_loss:.4f}, "
                        f"delta={100*(loss-base_loss)/base_loss:+.2f}%) "
                        f"grad_err_max={err:.2e} wire_bytes={ratio:.2f}x "
                        f"ici_byte_saving={100*(1-ratio):.0f}%"))

    rows.append(row("frontier.interpretation", 0.0,
                    "bounded-error region: int8+EF converges within noise of "
                    "lossless at ~4x fewer ICI bytes — the gradient-domain "
                    "equivalent of the paper's 29.3%-savings BER<=1e-6 region"))
    return rows
