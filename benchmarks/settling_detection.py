"""Paper §V-D / Fig 9: settling-time detection — correctness on synthetic
transitions with overshoot/noise, and in-graph (jit) throughput."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core.settling import settling_time, settling_time_jax


def run():
    rows = []
    t = np.linspace(0, 5e-3, 256)
    v = 0.5 + 0.5 * np.exp(-t / 3e-4) * (1 + 0.15 * np.cos(t / 8e-5))
    v += np.random.default_rng(0).normal(0, 3e-4, t.shape)

    res, us = timed(lambda: settling_time(t, v, n=8, band_pct=1.0))
    rows.append(row("fig9.detector.host", us,
                    f"settled={res.settled} t_s={res.settling_time_s*1e3:.2f}ms "
                    f"v_avg={res.v_avg:.4f}"))

    jit_fn = jax.jit(lambda tt, vv: settling_time_jax(tt, vv, n=8, band_pct=1.0))
    tj, vj = jnp.asarray(t, jnp.float32), jnp.asarray(v, jnp.float32)
    out, us = timed(lambda: jax.block_until_ready(jit_fn(tj, vj)))
    rows.append(row("fig9.detector.in_graph_jit", us,
                    f"t_s={float(out)*1e3:.2f}ms (usable inside compiled step)"))

    # robustness: band/window sensitivity (paper §VII-C: report consistently)
    for n, band in ((4, 0.5), (8, 1.0), (16, 2.0)):
        r = settling_time(t, v, n=n, band_pct=band)
        rows.append(row(f"fig9.sensitivity.n{n}.band{band}", 0.0,
                        f"t_s={r.settling_time_s*1e3:.2f}ms settled={r.settled}"))
    return rows
