"""Deliverable (g): the full roofline table from the dry-run artifacts —
three terms per (arch x shape x mesh), dominant bottleneck, useful-FLOPs
ratio, and the hillclimb picks. Reads reports/dryrun_single_multi.json."""

from __future__ import annotations

import os

from benchmarks.common import row
from repro.roofline.analysis import (analyze_report, format_table,
                                     pick_hillclimb_cells)

REPORT = os.environ.get("REPRO_DRYRUN_REPORT",
                        "reports/dryrun_single_multi.json")


def run():
    rows = []
    if not os.path.exists(REPORT):
        return [row("roofline.missing", 0.0,
                    f"{REPORT} not found — run `python -m repro.launch.dryrun "
                    f"--all --mesh both --out reports --save-hlo` first")]
    for mesh in ("single", "multi"):
        try:
            rrows = analyze_report(REPORT, mesh)
        except Exception as e:
            rows.append(row(f"roofline.{mesh}.error", 0.0, str(e)))
            continue
        print(f"\n=== Roofline ({mesh}-pod) ===")
        print(format_table(rrows))
        for r in rrows:
            rows.append(row(
                f"roofline.{mesh}.{r.arch}.{r.shape}", 0.0,
                f"t_comp={r.t_compute_s*1e3:.2f}ms t_mem={r.t_memory_s*1e3:.2f}ms "
                f"t_coll={r.t_collective_s*1e3:.2f}ms dom={r.dominant} "
                f"useful={r.useful_ratio:.2f} roofline={100*r.roofline_fraction:.1f}%"))
        if mesh == "single":
            picks = pick_hillclimb_cells(rrows)
            for k, r in picks.items():
                rows.append(row(f"roofline.hillclimb.{k}", 0.0,
                                f"{r.arch} x {r.shape} dom={r.dominant} "
                                f"roofline={100*r.roofline_fraction:.1f}%"))
    return rows
