"""Paper Fig 12: 10 Gbps reliability under voltage tuning — the three
regimes: near-zero BER >= 0.869 V, bounded-BER band 0.869-0.864 V
(1e-10 -> 1e-6), throughput collapse near 0.80 V."""

from __future__ import annotations

import math

from benchmarks.common import row, timed
from repro.core.transceiver import GtxLinkModel


def run():
    m = GtxLinkModel()
    sweep, us = timed(lambda: m.sweep(10.0, mode="both"), repeats=1)
    # find onsets from the sweep itself (the paper's methodology)
    onset = next(r.v_rx for r in sweep if r.ber > 0)
    collapse = next((r.v_rx for r in sweep
                     if r.bytes_received < 0.9 * r.bytes_sent), None)
    b866 = next(r for r in sweep if abs(r.v_rx - 0.866) < 5e-4)
    b864 = next(r for r in sweep if abs(r.v_rx - 0.864) < 5e-4)
    rows = [
        row("fig12.sweep_301pts_10G", us,
            f"BER_onset={onset:.3f}V (paper 0.869) "
            f"collapse={collapse:.3f}V (paper ~0.80)"),
        row("fig12c.ber_at_0.866V", 0.0,
            f"log10BER={math.log10(b866.ber):.2f} (paper ~-7)"),
        row("fig12c.ber_at_0.864V", 0.0,
            f"log10BER={math.log10(b864.ber):.2f} (paper ~-6)"),
        row("fig12a.received_at_0.79V", 0.0,
            f"frac={next(r.bytes_received/r.bytes_sent for r in sweep if abs(r.v_rx-0.79)<5e-4):.3f} "
            f"(hard link failure regime)"),
    ]
    return rows
