"""Paper Fig 15: link latency under voltage tuning — stable baselines
(~100/130/200/410 ns), excursions below the per-speed onset voltages."""

from __future__ import annotations

import numpy as np

from benchmarks.common import row, timed
from repro.core.transceiver import (LATENCY_BASE_NS,
                                    LATENCY_EXCURSION_ONSET_V, GtxLinkModel)


def run():
    m = GtxLinkModel()
    rows = []
    for speed, base in LATENCY_BASE_NS.items():
        def sweep_lat(s=speed):
            vs = np.arange(1.0, 0.70, -0.002)
            return np.array([m.latency_ns(v, v, s) for v in vs]), vs

        (lats, vs), us = timed(sweep_lat, repeats=1)
        stable = lats[vs >= LATENCY_EXCURSION_ONSET_V[speed] + 0.01]
        unstable = lats[vs < LATENCY_EXCURSION_ONSET_V[speed] - 0.01]
        rows.append(row(
            f"fig15.speed_{speed}G", us,
            f"baseline={stable.mean():.0f}ns (paper {base:.0f}) "
            f"excursion_onset~{LATENCY_EXCURSION_ONSET_V[speed]}V "
            f"max_spike={unstable.max() if unstable.size else 0:.0f}ns"))
    return rows
