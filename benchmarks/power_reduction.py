"""Paper Fig 16 + Table XII: BER-aware power savings at 10 Gbps.

The headline reproduction: 28.4% rail-power reduction at the near-zero-BER
boundary; 29.3% cumulative allowing BER <= 1e-6; only ~1.2% incremental gain
inside the bounded-BER band; larger savings require entering instability."""

from __future__ import annotations

from benchmarks.common import row, timed
from repro.core.transceiver import GtxLinkModel


def run():
    m = GtxLinkModel()
    rows = []

    def frontier():
        sweep = m.sweep(10.0, mode="both")
        p_nom = sweep[0].tx_power_w
        near_zero = next(r for r in sweep if r.ber > 0)        # first errors
        b6 = next(r for r in sweep if r.ber >= 1e-6)
        return p_nom, near_zero, b6

    (p_nom, nz, b6), us = timed(frontier, repeats=1)
    save_nz = 1 - nz.tx_power_w / p_nom
    save_b6 = 1 - b6.tx_power_w / p_nom
    rows.append(row("fig16.near_zero_BER_boundary", us,
                    f"P={nz.tx_power_w:.4f}W@{nz.v_rx:.3f}V "
                    f"saving={100*save_nz:.1f}% (paper 28.4%)"))
    rows.append(row("fig16.BER_1e-6_boundary", 0.0,
                    f"P={b6.tx_power_w:.4f}W@{b6.v_rx:.3f}V "
                    f"saving={100*save_b6:.1f}% (paper 29.3%) "
                    f"incremental={100*(save_b6-save_nz):.2f}% (paper ~1.2% rel)"))

    # Table XII anchor grid
    for speed in (2.5, 5.0, 7.5, 10.0):
        p10t = m.rail_power_w("tx", 1.0, speed)
        p08t = m.rail_power_w("tx", 0.8, speed)
        p10r = m.rail_power_w("rx", 1.0, speed)
        p08r = m.rail_power_w("rx", 0.8, speed)
        rows.append(row(f"tableXII.speed_{speed}G", 0.0,
                        f"TX {p10t:.3f}->{p08t:.3f}W ({100*(1-p08t/p10t):.0f}%) "
                        f"RX {p10r:.3f}->{p08r:.3f}W ({100*(1-p08r/p10r):.0f}%) "
                        f"(paper: ~33-36% TX, ~33-35% RX, 2.5G RX ~25-30%)"))
    return rows
