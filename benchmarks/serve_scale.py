"""Fleet-scale serving throughput: the fused one-dispatch serve tick vs
the PR-8 per-tick host loop (docs/serve.md "serving at fleet scale").

PR 8 proved headroom-aware placement wins; this bench measures whether the
fleet can afford to RUN it at scale. The historical `serve_trace` loop
pays ~8 blocking `device_get`s, per-chip Python loops and one eager
control dispatch per tick, so its tick rate collapses as chips grow. The
fused path compiles accounting -> observe overlay -> control round ->
busy/idle energy rescale -> rate/over-bound flags into ONE jitted dispatch
returning one packed host bundle, with slot bookkeeping vectorized over
`[n_chips, capacity]` numpy arrays — the serving analogue of PR 6's fused
control round.

Both paths route the same committed `benchmarks/serve_router.py` world
(same fleet seed, SOR-learning envelope-blind controller, load-coupled
frontier observables, seeded bursty trace) at each fleet size; tests pin
their ledgers equal, so this file measures pure tick machinery: ticks/sec,
µs/tick and per-chip µs/tick, fused vs loop.

The load weak-scales: requests AND arrival rate grow with the fleet
(`REQ_PER_CHIP` requests/chip, rates x n/CHIPS[0]), holding per-chip
occupancy constant — a 1024-chip fleet serves 1024 chips' worth of
traffic, not 64's. That is what exposes the loop path's O(resident slots)
per-tick Python cost next to the fused path's vectorized bookkeeping; an
absolute-request config (a starved big fleet) measures only the shared
jitted control round and understates the gap.

The committed record (reports/BENCH_serve_scale.json) is ratio-gated by
check_bench_regression.py:

* ``ticks_per_sec{fused,loop}`` gates the loop/fused ratio — growth means
  the fused speedup shrank (acceptance: >= 5x at 1024 chips, >= 2x at 64);
* ``per_chip_us_ratio_vs_base`` gates the fused per-chip µs/tick at each
  fleet size against the same run's smallest-fleet anchor — growth means
  per-chip tick cost stopped amortizing with scale.

Env knobs (SOR bench conventions): REPRO_BENCH_SERVE_SCALE_CHIPS
(comma-separated fleet sizes, default "64,256,1024"),
REPRO_BENCH_SERVE_SCALE_REQ_PER_CHIP (weak-scaled load, default 1.5),
REPRO_BENCH_SERVE_SCALE_TICKS. The CI smoke runs a reduced config against
its own committed baseline
(reports/BENCH_smoke_serve_scale_baseline.json), full size is committed
from a dev box.
"""

from __future__ import annotations

import os
import time

import jax

from benchmarks import serve_router as sr
from benchmarks.common import row
from repro.core.control_plane import InGraphRailController
from repro.core.hwspec import FleetSpec
from repro.serve.router import HeadroomRouter
from repro.serve.traffic import bursty_trace

CHIPS = [int(x) for x in os.environ.get(
    "REPRO_BENCH_SERVE_SCALE_CHIPS", "64,256,1024").split(",")]
REQ_PER_CHIP = float(os.environ.get(
    "REPRO_BENCH_SERVE_SCALE_REQ_PER_CHIP", "1.5"))
MAX_TICKS = int(os.environ.get("REPRO_BENCH_SERVE_SCALE_TICKS", "400"))
CAPACITY = 4


def _trace(n_chips: int):
    """Weak-scaled seeded traffic: `REQ_PER_CHIP * n_chips` requests with
    arrival rates scaled by n_chips/CHIPS[0], so the trace span (and each
    chip's offered load) stays constant across fleet sizes."""
    scale = n_chips / CHIPS[0]
    return bursty_trace(int(REQ_PER_CHIP * n_chips), seed=sr.SEED,
                        quiet_rate_hz=8.0 * scale,
                        burst_rate_hz=40.0 * scale, decode_mean=48.0)


def _engine(n_chips: int, *, capacity: int = CAPACITY,
            batch_cap: "int | None" = None, decode_profile=None):
    """The serve_router bench world at `n_chips` (same fleet seed, same
    SOR-learning envelope-blind controller, same load-coupled frontier
    observables) — a fresh engine per timed path so neither run rides the
    other's learned state. `capacity`/`batch_cap`/`decode_profile` let
    benchmarks/serve_batching.py build the continuous-batching variants
    of the same world."""
    from repro.configs import get_config
    from repro.models import registry
    from repro.serve.engine import ServeEngine
    cfg = get_config("minicpm_2b", tiny=True)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    fs = FleetSpec.sample(n_chips, seed=sr.SEED)
    ctrl = InGraphRailController(
        sr._EnvelopeBlindWalk(floors=dict(sr.POLICY_FLOORS), backoff=1.01,
                              name="envelope-blind-walk"),
        sor=sr.SOR_CFG)
    eng = ServeEngine(cfg, params, max_len=24, batch_size=2,
                      prefill_profile=sr.PROFILE,
                      decode_profile=decode_profile or sr.PROFILE,
                      fleet=fs, controller=ctrl,
                      router=HeadroomRouter(capacity=capacity),
                      batch_cap=batch_cap)
    return eng, sr._make_observe(fs, n_chips)


def _timed_trace(n_chips: int, fused: bool):
    """(wall_us, ticks, summary) of one full traced run on a fresh engine.
    A 3-tick prime run first pays the jit compiles (the fused serve tick,
    or the loop path's control_step_sor round), so the timed run measures
    steady-state tick machinery."""
    eng, observe = _engine(n_chips)
    trace = _trace(n_chips)
    kw = dict(observe=observe, error_bound=sr.ERROR_BOUND, fused=fused)
    eng.serve_trace(trace, max_ticks=3, **kw)
    t0 = time.perf_counter()
    ledger = eng.serve_trace(trace, max_ticks=MAX_TICKS, **kw)
    wall_us = (time.perf_counter() - t0) * 1e6
    return wall_us, eng.last_trace["ticks"], ledger.summary()


def run():
    rows = []
    base_pcus = None
    for n in CHIPS:
        n_requests = int(REQ_PER_CHIP * n)
        wall, ticks, done = {}, {}, {}
        for path in ("fused", "loop"):
            wall[path], ticks[path], s = _timed_trace(n, path == "fused")
            done[path] = s["completed"]
        tps = {p: ticks[p] / max(wall[p] * 1e-6, 1e-12) for p in wall}
        us_tick = {p: wall[p] / max(ticks[p], 1) for p in wall}
        pcus = {p: us_tick[p] / n for p in wall}
        if base_pcus is None:
            base_pcus = pcus["fused"]
        speedup = tps["fused"] / max(tps["loop"], 1e-12)
        record = {
            "n_chips": n, "n_requests": n_requests, "steps": MAX_TICKS,
            "capacity": CAPACITY, "seed": sr.SEED,
            "base_chips": CHIPS[0],
            "ticks": dict(ticks),
            "completed": dict(done),
            "wall_time_us": {p: round(wall[p], 1) for p in wall},
            "ticks_per_sec": {p: round(tps[p], 2) for p in tps},
            "us_per_tick": {p: round(us_tick[p], 2) for p in us_tick},
            "us_per_tick_per_chip": {p: round(pcus[p], 4) for p in pcus},
            "fused_speedup": round(speedup, 3),
            "per_chip_us_ratio_vs_base": round(
                pcus["fused"] / max(base_pcus, 1e-12), 4),
        }
        rows.append({**row(
            f"serve_scale.{n}chips.fused_vs_loop",
            wall["fused"],
            f"x{speedup:.1f} fused "
            f"({tps['fused']:.0f}t/s vs {tps['loop']:.0f}t/s loop) "
            f"us/tick/chip={pcus['fused']:.2f}f/{pcus['loop']:.2f}l "
            f"ticks={ticks['fused']}f/{ticks['loop']}l "
            f"completed={done['fused']}f/{done['loop']}l/{n_requests}req"),
            "bench": "serve_scale",
            "record": record})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
