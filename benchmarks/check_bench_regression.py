"""CI bench-smoke regression gate for the fused learned control path.

Compares a fresh ``benchmarks/run.py --only fleet_frontier:run_learned
--json-out`` record against the committed baseline
(``reports/BENCH_smoke_baseline.json``) and fails if the learned path got
slower. Raw microseconds are machine-dependent — CI runners and dev boxes
differ by integer factors — so the gated quantity is the *learned/static
wall-time ratio* within the same run: static and learned rollouts share the
machine, the fleet, and the jit cache, so their ratio isolates what the
learned path adds (the thing PR 6's fused round collapsed). A >20% ratio
regression means someone un-fused the round or re-introduced the
every-step refit.

Usage::

    python benchmarks/check_bench_regression.py \
        reports/bench_smoke.json reports/BENCH_smoke_baseline.json
"""

from __future__ import annotations

import argparse
import json
import sys

TOLERANCE = 0.20    # allowed relative growth of the learned/static ratio


def load_record(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    recs = [r for r in data.get("records", []) if "wall_time_us" in r]
    if not recs:
        sys.exit(f"{path}: no learned-vs-static record (expected a "
                 f"fleet_frontier:run_learned --json-out file)")
    if len(recs) > 1:
        print(f"{path}: {len(recs)} records; gating on the first "
              f"({recs[0].get('name')})")
    return recs[0]


def ratio(rec: dict) -> float:
    wt = rec["wall_time_us"]
    return wt["learned"] / max(wt["static"], 1e-9)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh bench-smoke json")
    ap.add_argument("baseline", help="committed baseline json")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE,
                    help="allowed relative ratio growth (default 0.20)")
    args = ap.parse_args(argv)

    cur, base = load_record(args.current), load_record(args.baseline)
    for k in ("n_chips", "steps"):
        if cur.get(k) != base.get(k):
            sys.exit(f"config mismatch: current {k}={cur.get(k)} vs "
                     f"baseline {k}={base.get(k)} — the ratio gate only "
                     f"holds for identical sweep configs (set "
                     f"REPRO_BENCH_SOR_CHIPS/REPRO_BENCH_SOR_STEPS to the "
                     f"baseline's, or refresh the baseline)")

    r_cur, r_base = ratio(cur), ratio(base)
    limit = r_base * (1.0 + args.tolerance)
    print(f"learned/static wall-time ratio: current={r_cur:.3f} "
          f"baseline={r_base:.3f} limit={limit:.3f} "
          f"(n_chips={cur['n_chips']} steps={cur['steps']})")
    print(f"learned path: {cur['wall_time_us']['learned']:.0f}us "
          f"({cur['us_per_step']['learned']:.0f}us/step), "
          f"power_saving={cur.get('power_saving_pct', float('nan')):.1f}%")
    if r_cur > limit:
        sys.exit(f"REGRESSION: learned/static ratio {r_cur:.3f} exceeds "
                 f"{limit:.3f} (baseline {r_base:.3f} "
                 f"+{100 * args.tolerance:.0f}%) — the learned control "
                 f"path got slower relative to the static rollout")
    print("bench-smoke regression gate: OK")


if __name__ == "__main__":
    main()
