"""CI bench-smoke regression gate for the learned control path.

Compares a fresh ``benchmarks/run.py --json-out`` record file against a
committed baseline and fails if a gated ratio got worse. Raw microseconds
are machine-dependent — CI runners and dev boxes differ by integer factors
— so the gated quantities are *within-run ratios*:

* ``wall_time_us{learned,static}`` records (``fleet_frontier:run_learned``)
  gate the learned/static wall-time ratio — static and learned rollouts
  share the machine, the fleet, and the jit cache, so their ratio isolates
  what the learned path adds (the thing PR 6's fused round collapsed).
* ``ratio_vs_base`` records (``fleet_frontier:run_weak_scaling``) gate the
  sharded PER-CHIP µs/step against the same run's single-device anchor —
  the weak-scaling flatness the sharded control plane is for.
* ``tokens_per_joule{headroom,roundrobin}`` / ``p99_latency_s{...}``
  records (``serve_router``) gate the roundrobin/headroom tokens-per-joule
  ratio and the headroom/roundrobin p99 latency ratio — growth of either
  means the headroom router's serving win shrank.
* ``ticks_per_sec{fused,loop}`` / ``per_chip_us_ratio_vs_base`` records
  (``serve_scale``) gate the loop/fused tick-rate ratio (growth = the
  fused serve tick's speedup shrank) and the fused per-chip µs/tick
  against the same run's smallest-fleet anchor (growth = tick cost
  stopped amortizing with fleet size).
* ``tokens_per_joule{batched,unbatched}`` / ``p99_latency_s{...}`` /
  ``degraded_chip_ticks{migrate,drain}`` records (``serve_batching``)
  gate the unbatched/batched tokens-per-joule and batched/unbatched p99
  ratios (growth = the continuous-batching win shrank) and the
  migrate/drain degraded-chip-ticks ratio (growth toward 1.0 = migration
  stopped recovering degraded ticks).

Matching is by record ``name`` (and the files' ``bench`` tag): a record or
metric present in the BASELINE but missing from the new run fails with a
clear message (someone deleted or renamed a gated bench); a record present
only in the new run warns and passes (adding a bench never breaks the
gate). A >``--tolerance`` relative growth of any gated ratio fails.

Usage::

    python benchmarks/check_bench_regression.py \
        reports/bench_smoke.json reports/BENCH_smoke_baseline.json
"""

from __future__ import annotations

import argparse
import json
import sys

TOLERANCE = 0.20    # allowed relative growth of any gated ratio

# config keys that must match between baseline and current for a ratio
# comparison to mean anything (same sweep shape, different machine is fine)
CONFIG_KEYS = ("n_chips", "steps", "shards", "base_chips")


def load_records(path: str) -> tuple[str | None, dict[str, dict]]:
    """(bench tag, {record name: record}) of one --json-out file."""
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        sys.exit(f"{path}: no such file (run benchmarks/run.py --json-out "
                 f"first, or check the committed baseline path)")
    except json.JSONDecodeError as e:
        sys.exit(f"{path}: not valid JSON ({e})")
    records = data.get("records", [])
    if not records:
        sys.exit(f"{path}: no records (expected a benchmarks/run.py "
                 f"--json-out file)")
    by_name = {}
    for i, rec in enumerate(records):
        by_name[str(rec.get("name", f"record[{i}]"))] = rec
    return data.get("bench"), by_name


def gate_metrics(rec: dict) -> dict[str, float]:
    """The gateable within-run ratios a record carries (may be empty)."""
    out = {}
    wt = rec.get("wall_time_us")
    if isinstance(wt, dict) and "learned" in wt and "static" in wt:
        out["learned/static wall-time ratio"] = (
            wt["learned"] / max(wt["static"], 1e-9))
    if "ratio_vs_base" in rec:
        out["weak-scaling per-chip us/step ratio vs single-device base"] = (
            float(rec["ratio_vs_base"]))
    tpj = rec.get("tokens_per_joule")
    if isinstance(tpj, dict) and "headroom" in tpj and "roundrobin" in tpj:
        # growth of roundrobin/headroom = the headroom win shrank
        out["roundrobin/headroom tokens-per-joule ratio"] = (
            tpj["roundrobin"] / max(tpj["headroom"], 1e-9))
    p99 = rec.get("p99_latency_s")
    if isinstance(p99, dict) and "headroom" in p99 and "roundrobin" in p99:
        # growth of headroom/roundrobin p99 = headroom got slower at tail
        out["headroom/roundrobin p99 latency ratio"] = (
            p99["headroom"] / max(p99["roundrobin"], 1e-9))
    if isinstance(tpj, dict) and "batched" in tpj and "unbatched" in tpj:
        # growth of unbatched/batched = the continuous-batching win shrank
        out["unbatched/batched tokens-per-joule ratio"] = (
            tpj["unbatched"] / max(tpj["batched"], 1e-9))
    if isinstance(p99, dict) and "batched" in p99 and "unbatched" in p99:
        # growth of batched/unbatched p99 = batching got slower at tail
        out["batched/unbatched p99 latency ratio"] = (
            p99["batched"] / max(p99["unbatched"], 1e-9))
    dct = rec.get("degraded_chip_ticks")
    if isinstance(dct, dict) and "migrate" in dct and "drain" in dct:
        # growth of migrate/drain = migration recovers fewer degraded
        # chip-ticks than drain-pinned-only (1.0 = migration does nothing)
        out["migrate/drain degraded-chip-ticks ratio"] = (
            dct["migrate"] / max(dct["drain"], 1e-9))
    tps = rec.get("ticks_per_sec")
    if isinstance(tps, dict) and "fused" in tps and "loop" in tps:
        # growth of loop/fused = the fused serve tick's speedup shrank
        out["loop/fused ticks-per-second ratio"] = (
            tps["loop"] / max(tps["fused"], 1e-9))
    if "per_chip_us_ratio_vs_base" in rec:
        # growth = fused per-chip tick cost stopped amortizing with scale
        out["fused per-chip us/tick ratio vs smallest-fleet base"] = (
            float(rec["per_chip_us_ratio_vs_base"]))
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh bench-smoke json")
    ap.add_argument("baseline", help="committed baseline json")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE,
                    help="allowed relative ratio growth (default 0.20)")
    args = ap.parse_args(argv)

    cur_bench, cur = load_records(args.current)
    base_bench, base = load_records(args.baseline)
    if cur_bench and base_bench and cur_bench != base_bench:
        sys.exit(f"bench tag mismatch: {args.current} is bench "
                 f"{cur_bench!r} but {args.baseline} is bench "
                 f"{base_bench!r} — compare like with like (each bench "
                 f"group gets its own baseline file)")

    failures = []
    gated = 0
    for name, base_rec in base.items():
        base_metrics = gate_metrics(base_rec)
        if not base_metrics:
            print(f"WARNING: baseline record {name!r} has no gateable "
                  f"metric (wall_time_us{{learned,static}} or "
                  f"ratio_vs_base) — nothing to compare")
            continue
        cur_rec = cur.get(name)
        if cur_rec is None:
            failures.append(
                f"baseline record {name!r} is missing from {args.current} "
                f"(it has: {sorted(cur)}) — a gated bench was removed or "
                f"renamed; refresh the baseline if that was intentional")
            continue
        mismatched = [k for k in CONFIG_KEYS
                      if k in base_rec and k in cur_rec
                      and cur_rec[k] != base_rec[k]]
        if mismatched:
            failures.append(
                f"{name!r}: config mismatch on "
                f"{', '.join(f'{k}={cur_rec[k]} vs baseline {base_rec[k]}' for k in mismatched)}"
                f" — the ratio gate only holds for identical sweep configs "
                f"(set the REPRO_BENCH_SOR_* env knobs to the baseline's, "
                f"or refresh the baseline)")
            continue
        cur_metrics = gate_metrics(cur_rec)
        for metric, r_base in base_metrics.items():
            if metric not in cur_metrics:
                failures.append(
                    f"{name!r}: baseline gates {metric!r} but the new "
                    f"record lacks the keys that define it — the bench "
                    f"schema changed; refresh the baseline if intentional")
                continue
            r_cur = cur_metrics[metric]
            limit = r_base * (1.0 + args.tolerance)
            verdict = "OK" if r_cur <= limit else "REGRESSION"
            print(f"{name}: {metric}: current={r_cur:.3f} "
                  f"baseline={r_base:.3f} limit={limit:.3f} [{verdict}]")
            gated += 1
            if r_cur > limit:
                failures.append(
                    f"{name!r}: {metric} {r_cur:.3f} exceeds {limit:.3f} "
                    f"(baseline {r_base:.3f} +{100 * args.tolerance:.0f}%)")

    for name in sorted(set(cur) - set(base)):
        print(f"WARNING: record {name!r} is new (absent from the baseline) "
              f"— not gated; add it to {args.baseline} to gate it")

    if failures:
        sys.exit("bench regression gate FAILED:\n  - "
                 + "\n  - ".join(failures))
    if not gated:
        sys.exit("bench regression gate compared nothing — the baseline "
                 "has no gateable records matching the current run")
    print(f"bench regression gate: OK ({gated} metric(s) gated)")


if __name__ == "__main__":
    main()
