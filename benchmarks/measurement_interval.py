"""Paper Table VI: telemetry measurement interval per control path x PMBus
clock (0.2 / 0.6 / 0.8 / 1.0 ms), plus Fig 8's path comparison."""

from __future__ import annotations

from benchmarks.common import row, timed
from repro.core.power_manager import PowerManager

PAPER = {("hw", 400_000): 0.2, ("hw", 100_000): 0.6,
         ("sw", 400_000): 0.8, ("sw", 100_000): 1.0}


def run():
    rows = []
    for (path, hz), expect in PAPER.items():
        pm = PowerManager(path=path, clock_hz=hz)

        def sample():
            return pm.sample_trace(6, 2e-3)

        (ts, vs), us = timed(sample, repeats=1)
        meas = pm.measurement_interval_s() * 1e3
        emp = float(ts[1] - ts[0]) * 1e3 if len(ts) > 1 else float("nan")
        rows.append(row(f"tableVI.interval.{path}.{hz//1000}kHz", us,
                        f"interval={meas:.3f}ms empirical={emp:.3f}ms "
                        f"paper={expect}ms match={abs(meas-expect)<0.02}"))
    return rows
