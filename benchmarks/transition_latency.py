"""Paper Fig 7: voltage transition latency and dynamics (HW PMBus, 400 kHz).

Validates: 1.0 V -> 0.5 V end-to-end in 2.3 ms; transition time monotone in
the step size; full decrease/increase sweeps of Table V."""

from __future__ import annotations

from benchmarks.common import row, timed
from repro.core.power_manager import PowerManager

MGTAVCC = 6


def run():
    rows = []

    def fig7a():
        pm = PowerManager(path="hw", clock_hz=400_000)
        tr = pm.measure_transition(MGTAVCC, 0.5, duration_s=6e-3)
        return tr.end_to_end_latency_s()

    lat, us = timed(fig7a)
    rows.append(row("fig7a.transition_1.0->0.5V.hw400", us,
                    f"end_to_end={lat*1e3:.2f}ms paper=2.3ms "
                    f"match={abs(lat*1e3-2.3)<0.25}"))

    # Fig 7b + Table V sweeps
    for direction, targets in (("down", (0.9, 0.8, 0.7, 0.6, 0.5)),
                               ("up", (0.5, 0.6, 0.7, 0.8, 0.9))):
        lats = []
        for tgt in targets:
            pm = PowerManager(path="hw", clock_hz=400_000)
            if direction == "up":
                pm.set_voltage(MGTAVCC, tgt)
                pm.clock.advance(5e-3)
                tr = pm.measure_transition(MGTAVCC, 1.0, duration_s=6e-3)
            else:
                tr = pm.measure_transition(MGTAVCC, tgt, duration_s=6e-3)
            lats.append(tr.end_to_end_latency_s() * 1e3)
        if direction == "down":
            mono = all(b >= a for a, b in zip(lats, lats[1:]))
        else:
            mono = all(b <= a for a, b in zip(lats, lats[1:]))
        rows.append(row(f"fig7b.sweep_{direction}", 0.0,
                        f"latencies_ms={[round(x,2) for x in lats]} "
                        f"monotone_dV={mono}"))
    return rows
