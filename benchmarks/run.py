"""Benchmark harness (deliverable d): one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV and writes reports/bench_results.json.
"""

from __future__ import annotations

import importlib
import json
import os
import sys
import traceback

MODULES = [
    "benchmarks.transition_latency",    # Fig 7
    "benchmarks.measurement_interval",  # Table VI / Fig 8
    "benchmarks.settling_detection",    # Fig 9 / §V-D
    "benchmarks.controller_overhead",   # Tables VII-IX
    "benchmarks.ber_sweep",             # Fig 12
    "benchmarks.tx_rx_sensitivity",     # Fig 13 / Table XI
    "benchmarks.link_speed",            # Fig 14
    "benchmarks.latency_impact",        # Fig 15
    "benchmarks.power_reduction",       # Fig 16 / Table XII
    "benchmarks.ecollectives_frontier",  # beyond-paper (DESIGN.md §2.2)
    "benchmarks.fleet_frontier",        # beyond-paper: fleet size x policy
    # learned-vs-static safe-operating-region comparison (docs/sor.md):
    # per-chip recovered headroom below the shared static envelope
    "benchmarks.fleet_frontier:run_learned",
    "benchmarks.roofline_table",        # deliverable (g)
]


def main() -> None:
    all_rows = []
    failures = 0
    for name in MODULES:
        try:
            # "module" runs module.run(); "module:fn" runs module.fn()
            mod_name, _, fn_name = name.partition(":")
            mod = importlib.import_module(mod_name)
            rows = getattr(mod, fn_name or "run")()
            all_rows.extend(rows)
        except Exception:
            failures += 1
            traceback.print_exc()
            all_rows.append({"name": f"{name}.FAILED", "us_per_call": 0.0,
                             "derived": "see traceback"})
    print("\nname,us_per_call,derived")
    for r in all_rows:
        print(f"{r['name']},{r['us_per_call']},\"{r['derived']}\"")
    os.makedirs("reports", exist_ok=True)
    with open("reports/bench_results.json", "w") as f:
        json.dump(all_rows, f, indent=1)
    print(f"\n{len(all_rows)} rows, {failures} module failures "
          f"-> reports/bench_results.json")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
