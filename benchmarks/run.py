"""Benchmark harness (deliverable d): one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV and writes reports/bench_results.json.

``--only SUBSTR`` runs just the modules whose name contains SUBSTR.
``--json-out PATH`` additionally writes structured perf records, grouped by
each row's ``bench`` tag: the fleet-frontier learned-vs-static comparison
(rail-power saving %, per-rail floors, phase-split wall time) goes to PATH
itself — ``reports/BENCH_fleet_frontier.json`` by convention — and every
other tagged group (e.g. ``controller_overhead``'s fused-vs-unfused round)
to ``BENCH_<bench>.json`` next to it, so the bench trajectory accumulates
across PRs.
"""

from __future__ import annotations

import argparse
import datetime
import importlib
import json
import os
import subprocess
import sys
import time
import traceback

MODULES = [
    "benchmarks.transition_latency",    # Fig 7
    "benchmarks.measurement_interval",  # Table VI / Fig 8
    "benchmarks.settling_detection",    # Fig 9 / §V-D
    "benchmarks.controller_overhead",   # Tables VII-IX
    "benchmarks.ber_sweep",             # Fig 12
    "benchmarks.tx_rx_sensitivity",     # Fig 13 / Table XI
    "benchmarks.link_speed",            # Fig 14
    "benchmarks.latency_impact",        # Fig 15
    "benchmarks.power_reduction",       # Fig 16 / Table XII
    "benchmarks.ecollectives_frontier",  # beyond-paper (DESIGN.md §2.2)
    "benchmarks.fleet_frontier",        # beyond-paper: fleet size x policy
    # learned-vs-static safe-operating-region comparison (docs/sor.md):
    # per-chip recovered headroom below the shared static envelope
    "benchmarks.fleet_frontier:run_learned",
    # sharded-control-plane weak scaling (docs/fleet.md): learned µs/step
    # vs shard count, gated on the ratio to the single-device anchor
    # (runs on however many devices are visible; multi-device needs
    # XLA_FLAGS=--xla_force_host_platform_device_count=N at process start)
    "benchmarks.fleet_frontier:run_weak_scaling",
    # headroom-aware serving router vs round-robin (docs/serve.md): gated
    # on the roundrobin/headroom tokens-per-joule and headroom/roundrobin
    # p99 ratios
    "benchmarks.serve_router",
    # fused one-dispatch serve tick vs the per-tick host loop at fleet
    # scale (docs/serve.md "serving at fleet scale"): gated on the
    # loop/fused tick-rate ratio and the fused per-chip µs/tick scaling
    "benchmarks.serve_scale",
    # continuous batching vs one-request-per-slot, and in-flight migration
    # vs drain-pinned-only (docs/serve.md "continuous batching &
    # migration"): gated on the unbatched/batched tokens-per-joule,
    # batched/unbatched p99, and migrate/drain degraded-chip-ticks ratios
    "benchmarks.serve_batching",
    "benchmarks.roofline_table",        # deliverable (g)
]


def _git_commit() -> "str | None":
    """The commit the records were produced at (None outside a checkout
    or without git on PATH) — provenance for the cross-PR trajectory."""
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, timeout=10,
                             cwd=os.path.dirname(os.path.abspath(__file__)))
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except (OSError, subprocess.SubprocessError):
        return None


def _append_trajectory(out_dir: str, stamp: dict,
                       by_bench: "dict[str, list]") -> str:
    """Append ONE cumulative row per --json-out run to
    `<out_dir>/BENCH_trajectory.jsonl`: the commit/time stamp plus each
    bench's gated within-run ratios (`check_bench_regression.gate_metrics`
    — the same numbers CI gates, so the trajectory is comparable across
    machines). The BENCH_*.json files are overwritten per run; this file
    only grows, which is what makes the cross-PR story tellable."""
    from benchmarks.check_bench_regression import gate_metrics
    row_out = {**stamp, "benches": {
        bench: {rec["name"]: gate_metrics(rec) for rec in records}
        for bench, records in by_bench.items()}}
    path = os.path.join(out_dir, "BENCH_trajectory.jsonl")
    with open(path, "a") as f:
        f.write(json.dumps(row_out, sort_keys=True) + "\n")
    return path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None, metavar="SUBSTR",
                    help="run only modules whose name contains SUBSTR")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="write the fleet_frontier structured perf record "
                         "(e.g. reports/BENCH_fleet_frontier.json)")
    args = ap.parse_args(argv)

    modules = [m for m in MODULES if args.only is None or args.only in m]
    if not modules:
        sys.exit(f"no benchmark module matches {args.only!r}")
    all_rows = []
    failures = 0
    t0 = time.perf_counter()
    for name in modules:
        try:
            # "module" runs module.run(); "module:fn" runs module.fn()
            mod_name, _, fn_name = name.partition(":")
            mod = importlib.import_module(mod_name)
            rows = getattr(mod, fn_name or "run")()
            all_rows.extend(rows)
        except Exception:
            failures += 1
            traceback.print_exc()
            all_rows.append({"name": f"{name}.FAILED", "us_per_call": 0.0,
                             "derived": "see traceback"})
    wall_s = time.perf_counter() - t0
    print("\nname,us_per_call,derived")
    for r in all_rows:
        print(f"{r['name']},{r['us_per_call']},\"{r['derived']}\"")
    os.makedirs("reports", exist_ok=True)
    if args.only is None:
        # only a full run may overwrite the canonical results file — a
        # filtered run would clobber it with a subset
        with open("reports/bench_results.json", "w") as f:
            json.dump(all_rows, f, indent=1)
        print(f"\n{len(all_rows)} rows, {failures} module failures "
              f"-> reports/bench_results.json")
    else:
        print(f"\n{len(all_rows)} rows, {failures} module failures "
              f"(--only run: reports/bench_results.json left untouched)")

    if args.json_out:
        # structured perf records: every row that carries a machine-
        # readable `record` — the across-PR bench trajectory entries.
        # Rows are grouped by their `bench` tag (untagged rows are the
        # fleet_frontier learned-vs-static comparison, the original
        # emitter): the fleet_frontier group writes to --json-out itself
        # (e.g. reports/BENCH_fleet_frontier.json), every other group to
        # BENCH_<bench>.json next to it. Per-bench timing lives in each
        # record; run_wall_time_s covers whatever module set THIS
        # invocation ran (named, so runs with different --only selections
        # are not compared as if commensurate).
        by_bench: dict[str, list] = {}
        for r in all_rows:
            if "record" in r:
                by_bench.setdefault(r.get("bench", "fleet_frontier"),
                                    []).append(
                    {"name": r["name"], "us_per_call": r["us_per_call"],
                     **r["record"]})
        if by_bench:
            out_dir = os.path.dirname(args.json_out) or "."
            os.makedirs(out_dir, exist_ok=True)
            # commit/PR provenance: every record file carries the commit
            # it was produced at, and each --json-out run appends one row
            # to the cumulative cross-PR trajectory next to it
            stamp = {"commit": _git_commit(),
                     "generated_utc": datetime.datetime.now(
                         datetime.timezone.utc).isoformat(
                             timespec="seconds"),
                     "modules_run": modules}
            for bench, records in by_bench.items():
                path = (args.json_out if bench == "fleet_frontier"
                        else os.path.join(out_dir, f"BENCH_{bench}.json"))
                out = {"bench": bench, **stamp,
                       "run_wall_time_s": round(wall_s, 3),
                       "failures": failures, "records": records}
                with open(path, "w") as f:
                    json.dump(out, f, indent=1)
                print(f"perf record ({len(records)} entries) -> {path}")
            tpath = _append_trajectory(out_dir, stamp, by_bench)
            print(f"trajectory row appended -> {tpath}")
        else:
            # a selection that ran no record-emitting module must not
            # clobber the accumulated trajectory entry with an empty file
            print(f"no perf records produced; {args.json_out} left "
                  f"untouched")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
