"""Continuous batching + in-flight migration for compiled fleet serving
(docs/serve.md "continuous batching & migration").

Two questions, one committed record file:

**Does continuous batching pay?** The same weak-scaled bursty trace is
served twice on the serve_router bench world — once with `batch_cap=CAP`
(each chip a token-level decode batch over CAP resident lanes at the
shared-roofline per-lane rate) and once with `batch_cap=1` (one request
per chip at the full single-lane rate, the PR-9 semantics oracle). The
batched fleet holds CAP x the lanes, so the burst that drowns the
unbatched fleet's queue is absorbed; per-lane rate is sublinear in
occupancy (`power_plane.batched_lane_time_s`), so the throughput gain is
the roofline's shared fraction, not a free CAP x. Reported: tokens/joule,
goodput (decoded tokens per simulated second), p99 latency, both arms.

**Does migration recover degraded ticks?** A forced-pin scenario — the
same world at saturating load, where chips that accepted work before the
load-coupled onset shift re-cross the error bound and sit there serving
degraded — run with `migrate_after_ticks=K` vs `drain_pinned`-only
(migration off). Migration must STRICTLY reduce degraded chip-ticks: a
hot chip's decode lanes move to deep-headroom chips, its busy_frac drops,
its onset recedes, it recovers; drain-only leaves resident work degrading
to completion.

Both ratios are committed in reports/BENCH_serve_batching.json and gated
by check_bench_regression.py: unbatched/batched tokens-per-joule,
batched/unbatched p99, and migrate/drain degraded-chip-ticks (growth of
any = the win shrank). All simulated-time numbers are seed-deterministic;
the CI smoke runs a reduced config against its own committed baseline
(reports/BENCH_smoke_serve_batching_baseline.json).

Env knobs: REPRO_BENCH_SERVE_BATCHING_{CHIPS,REQ_PER_CHIP,TICKS,CAP} for
the batching arm, REPRO_BENCH_SERVE_BATCHING_{MIG_CHIPS,MIG_REQUESTS,
MIG_AFTER} for the migration scenario.
"""

from __future__ import annotations

import os
import time

import jax.numpy as jnp

from benchmarks import serve_router as sr
from benchmarks import serve_scale as ss
from benchmarks.common import row
from repro.core.power_plane import StepProfile, account_fleet_and_observe
from repro.serve.traffic import bursty_trace

N_CHIPS = int(os.environ.get("REPRO_BENCH_SERVE_BATCHING_CHIPS", "64"))
REQ_PER_CHIP = float(os.environ.get(
    "REPRO_BENCH_SERVE_BATCHING_REQ_PER_CHIP", "6"))
MAX_TICKS = int(os.environ.get("REPRO_BENCH_SERVE_BATCHING_TICKS", "4000"))
CAP = int(os.environ.get("REPRO_BENCH_SERVE_BATCHING_CAP", "8"))
BASE_CHIPS = 64   # weak-scaling anchor: rates scale with n/BASE_CHIPS

MIG_CHIPS = int(os.environ.get(
    "REPRO_BENCH_SERVE_BATCHING_MIG_CHIPS", "16"))
MIG_REQUESTS = int(os.environ.get(
    "REPRO_BENCH_SERVE_BATCHING_MIG_REQUESTS", "96"))
MIG_AFTER = int(os.environ.get(
    "REPRO_BENCH_SERVE_BATCHING_MIG_AFTER", "6"))

# sr.PROFILE is prefill/training-shaped: its FLOPs term sits at the memory
# roofline (t_comp ~ t_mem ~ 10ms), so once the controller's gradient
# compression collapses the collective term the world is COMPUTE-bound —
# and per-lane decode FLOPs don't share across a batch (BatchShares.flops
# = 0), so continuous batching would (correctly) buy nothing. Real decode
# is memory-bound: per-token FLOPs are ~2*params while the per-step HBM
# traffic is the full weights read, amortized over every resident lane —
# which is exactly WHY continuous batching pays. This bench serves with a
# decode-shaped profile: same HBM/ICI bytes as sr.PROFILE, FLOPs at the
# decode ratio (t_comp ~ 0.4ms << t_mem ~ 9.8ms).
DECODE_PROFILE = StepProfile(
    flops_per_chip=8e10,
    hbm_bytes_per_chip=sr.PROFILE.hbm_bytes_per_chip,
    ici_bytes_per_chip=sr.PROFILE.ici_bytes_per_chip,
    grad_bytes_per_chip=sr.PROFILE.grad_bytes_per_chip)


def _trace(n_chips: int, req_per_chip: float):
    """Weak-scaled seeded traffic anchored at BASE_CHIPS (the committed
    64-chip config): per-chip offered load is constant across fleet
    sizes, so the smoke config stresses each chip identically. Rates are
    16x the serve_scale trace's — a saturating burst: the offered token
    rate exceeds BOTH fleets' service rates, so each arm drains a backlog
    at its own fleet throughput and the goodput/p99 ratios measure exactly
    what continuous batching buys (an arrival-bound fleet never exercises
    the extra lanes — every arm just keeps up)."""
    scale = n_chips / BASE_CHIPS
    return bursty_trace(max(int(req_per_chip * n_chips), 1), seed=sr.SEED,
                        quiet_rate_hz=128.0 * scale,
                        burst_rate_hz=640.0 * scale, decode_mean=48.0)


def _warm(eng, observe, n_chips: int):
    """The serve_router idle warmup: envelopes converge before the trace
    routes, so placement (and migration) reads LEARNED margins."""
    idle = jnp.zeros((n_chips,), jnp.float32)
    for w in range(sr.WARMUP_ROUNDS):
        eng.plane, frame, _ = account_fleet_and_observe(
            eng.decode_profile, eng.plane, eng.fleet_spec)
        frame = observe(eng.plane, frame, 1_000_000 + w, idle)
        eng._control_tick(frame)


def _run(n_chips: int, trace, *, capacity: int, batch_cap: int,
         migrate_after_ticks: "int | None" = None):
    """(engine, ledger, wall_us) of one warmed traced run."""
    eng, observe = ss._engine(n_chips, capacity=capacity,
                              batch_cap=batch_cap,
                              decode_profile=DECODE_PROFILE)
    _warm(eng, observe, n_chips)
    t0 = time.perf_counter()
    ledger = eng.serve_trace(trace, observe=observe, max_ticks=MAX_TICKS,
                             error_bound=sr.ERROR_BOUND,
                             migrate_after_ticks=migrate_after_ticks)
    wall_us = (time.perf_counter() - t0) * 1e6
    return eng, ledger, wall_us


def run():
    rows = []

    # -- continuous batching vs batch_cap=1 on the weak-scaled trace ------
    trace = _trace(N_CHIPS, REQ_PER_CHIP)
    arms = {}
    for arm, (capacity, batch_cap) in (("batched", (CAP, CAP)),
                                       ("unbatched", (1, 1))):
        eng, ledger, wall_us = _run(N_CHIPS, trace, capacity=capacity,
                                    batch_cap=batch_cap)
        s = ledger.summary()
        sim_s = eng.last_trace["ticks"] * eng.last_trace["tick_s"]
        arms[arm] = {"summary": s, "trace": eng.last_trace,
                     "wall_us": wall_us,
                     "goodput_tok_per_s": s["tokens_out"] / max(sim_s,
                                                                1e-12)}
    b, u = arms["batched"]["summary"], arms["unbatched"]["summary"]
    tpj = {"batched": b["tokens_per_joule"],
           "unbatched": u["tokens_per_joule"]}
    p99 = {"batched": b["p99_latency_s"], "unbatched": u["p99_latency_s"]}
    goodput = {a: arms[a]["goodput_tok_per_s"] for a in arms}
    tpj_gain = tpj["batched"] / max(tpj["unbatched"], 1e-12)
    goodput_gain = goodput["batched"] / max(goodput["unbatched"], 1e-12)
    record = {
        "n_chips": N_CHIPS, "n_requests": len(trace), "steps": MAX_TICKS,
        "capacity": {"batched": CAP, "unbatched": 1},
        "batch_cap": CAP, "seed": sr.SEED, "base_chips": BASE_CHIPS,
        "req_per_chip": REQ_PER_CHIP,
        "tokens_per_joule": tpj,
        "tokens_per_joule_gain": round(tpj_gain, 3),
        "goodput_tok_per_s": {a: round(goodput[a], 2) for a in goodput},
        "goodput_gain": round(goodput_gain, 3),
        "p99_latency_s": p99,
        "p50_latency_s": {"batched": b["p50_latency_s"],
                          "unbatched": u["p50_latency_s"]},
        "completed": {"batched": b["completed"],
                      "unbatched": u["completed"]},
        "defers": {"batched": b["defers"], "unbatched": u["defers"]},
        "ticks": {"batched": arms["batched"]["trace"]["ticks"],
                  "unbatched": arms["unbatched"]["trace"]["ticks"]},
        "degraded_ticks": {
            "batched": arms["batched"]["trace"]["degraded_chip_ticks"],
            "unbatched": arms["unbatched"]["trace"]["degraded_chip_ticks"]},
    }
    rows.append({**row(
        f"serve_batching.{N_CHIPS}chips.batched_vs_unbatched",
        arms["batched"]["wall_us"],
        f"tok/J={tpj['batched']:.2f}b/{tpj['unbatched']:.2f}u "
        f"(x{tpj_gain:.2f}) goodput x{goodput_gain:.2f} "
        f"p99={p99['batched']:.2f}s/{p99['unbatched']:.2f}s "
        f"completed={b['completed']}b/{u['completed']}u/{len(trace)}req"),
        "bench": "serve_batching",
        "record": record})

    # -- migration vs drain-only in the forced-pin scenario ---------------
    mig_trace = bursty_trace(MIG_REQUESTS, seed=sr.SEED,
                             quiet_rate_hz=8.0 * MIG_CHIPS / BASE_CHIPS * 4,
                             burst_rate_hz=40.0 * MIG_CHIPS / BASE_CHIPS * 4,
                             decode_mean=96.0)
    mig = {}
    for arm, after in (("migrate", MIG_AFTER), ("drain", None)):
        eng, ledger, wall_us = _run(MIG_CHIPS, mig_trace, capacity=4,
                                    batch_cap=4,
                                    migrate_after_ticks=after)
        mig[arm] = {"summary": ledger.summary(), "trace": eng.last_trace,
                    "wall_us": wall_us,
                    "events": len(ledger.migration_events)}
    dct = {a: mig[a]["trace"]["degraded_chip_ticks"] for a in mig}
    rdt = {a: mig[a]["trace"]["resident_degraded_ticks"] for a in mig}
    n_migs = mig["migrate"]["summary"]["migrations"]
    mig_ratio = dct["migrate"] / max(dct["drain"], 1e-12)
    record = {
        "n_chips": MIG_CHIPS, "n_requests": MIG_REQUESTS,
        "steps": MAX_TICKS, "capacity": 4, "batch_cap": 4,
        "seed": sr.SEED, "migrate_after_ticks": MIG_AFTER,
        "migrations": n_migs,
        "migration_stall_s": mig["migrate"]["summary"][
            "migration_stall_s"],
        "degraded_chip_ticks": dct,
        "degraded_ratio": round(mig_ratio, 4),
        "resident_degraded_ticks": rdt,
        "completed": {a: mig[a]["summary"]["completed"] for a in mig},
        "tokens_per_joule_by_arm": {
            a: mig[a]["summary"]["tokens_per_joule"] for a in mig},
        "p99_latency_s_by_arm": {
            a: mig[a]["summary"]["p99_latency_s"] for a in mig},
    }
    rows.append({**row(
        f"serve_batching.{MIG_CHIPS}chips.migrate_vs_drain",
        mig["migrate"]["wall_us"],
        f"degraded_ticks={dct['migrate']}m/{dct['drain']}d "
        f"(x{mig_ratio:.2f}) migrations={n_migs} "
        f"completed={record['completed']['migrate']}m/"
        f"{record['completed']['drain']}d/{MIG_REQUESTS}req"),
        "bench": "serve_batching",
        "record": record})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
