"""Paper Fig 13 + Table XI: TX-only vs RX-only voltage scaling at 10 Gbps —
RX-dominant degradation; power savings localize to the swept side."""

from __future__ import annotations

from benchmarks.common import row, timed
from repro.core.transceiver import GtxLinkModel


def run():
    m = GtxLinkModel()
    rows = []
    sweeps = {}
    for mode in ("both", "tx", "rx"):
        sweeps[mode], us = timed(lambda mo=mode: m.sweep(10.0, mode=mo),
                                 repeats=1)
        sw = sweeps[mode]
        onset = next((r.v_tx if mode == "tx" else r.v_rx
                      for r in sw if r.ber > 0), None)
        recv_drop = next((min(r.v_tx, r.v_rx) for r in sw
                          if r.bytes_received < r.bytes_sent), None)
        rows.append(row(f"fig13.sweep.{mode}", us,
                        f"BER_onset={onset} recv_drop_at={recv_drop} "
                        f"(paper: rx-swept ~0.87/0.81, tx-only ~0.82/none)"))

    # Table XI power locality at 0.7 V
    t = m.run_link_test(0.7, 1.0, 10.0)
    r = m.run_link_test(1.0, 0.7, 10.0)
    rows.append(row("tableXI.tx_swept_rx_fixed", 0.0,
                    f"tx_power={t.tx_power_w:.3f}W (0.20->0.08) "
                    f"rx_power={t.rx_power_w:.3f}W (constant ~0.17)"))
    rows.append(row("tableXI.rx_swept_tx_fixed", 0.0,
                    f"tx_power={r.tx_power_w:.3f}W (constant ~0.20) "
                    f"rx_power={r.rx_power_w:.3f}W (0.17->0.07-0.08)"))
    return rows
