"""RWKV6 ("Finch") block: data-dependent-decay linear attention (time-mix)
plus squared-ReLU channel-mix. Attention-free: decode state is O(1) in
sequence length (one [H, Dh, Dh] matrix per layer), which is what makes the
long_500k cell runnable for this architecture (DESIGN.md §4)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common


@dataclasses.dataclass(frozen=True)
class Rwkv6Spec:
    d_model: int
    d_ff: int
    head_dim: int = 64
    lora_rank: int = 32

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim


def init_rwkv6(key, spec: Rwkv6Spec, dtype=jnp.float32):
    ks = jax.random.split(key, 12)
    D, H, Dh, R = spec.d_model, spec.n_heads, spec.head_dim, spec.lora_rank
    return {
        # time-mix (5 interpolation targets: w,k,v,r,g) — data-dependent lerp
        "mix_base": jnp.zeros((5, D), dtype),
        "mix_w1": common.dense_init(ks[0], (D, 5 * R), D, dtype),
        "mix_w2": common.dense_init(ks[1], (5, R, D), R, dtype),
        "w_r": common.dense_init(ks[2], (D, D), D, dtype),
        "w_k": common.dense_init(ks[3], (D, D), D, dtype),
        "w_v": common.dense_init(ks[4], (D, D), D, dtype),
        "w_g": common.dense_init(ks[5], (D, D), D, dtype),
        "w_o": common.dense_init(ks[6], (D, D), D, dtype),
        # decay: w = -exp(w0 + tanh(x W_a) W_b) (low-rank data dependence)
        "decay_base": jnp.full((D,), -2.0, jnp.float32),
        "decay_w1": common.dense_init(ks[7], (D, R), D, dtype),
        "decay_w2": common.dense_init(ks[8], (R, D), R, dtype),
        "bonus_u": jnp.full((H, Dh), 0.5, jnp.float32),
        "ln_x_w": jnp.ones((D,), dtype),
        "ln_x_b": jnp.zeros((D,), dtype),
        # channel-mix
        "cmix_k": jnp.zeros((D,), dtype),
        "cmix_r": jnp.zeros((D,), dtype),
        "cm_wk": common.dense_init(ks[9], (D, spec.d_ff), D, dtype),
        "cm_wv": common.dense_init(ks[10], (spec.d_ff, D), spec.d_ff, dtype),
        "cm_wr": common.dense_init(ks[11], (D, D), D, dtype),
    }


def _token_shift(x, last=None):
    """Shift sequence right by one: y[t] = x[t-1]; slot 0 takes `last`
    (decode continuation) or zeros."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def rwkv6_time_mix(params, x, spec: Rwkv6Spec, *, init_state=None, last_x=None):
    """x [B,T,D] -> (y, (wkv_state, last_token)). The recurrence itself runs
    in the Pallas kernel (chunked) or the jnp oracle."""
    from repro.kernels import ops as kops
    B, T, D = x.shape
    H, Dh, R = spec.n_heads, spec.head_dim, spec.lora_rank
    xs = _token_shift(x, last_x)
    dx = xs - x

    # data-dependent lerp (ddlerp): 5 mixed inputs
    lora = jnp.tanh(jnp.einsum("btd,dr->btr", x, params["mix_w1"])
                    .reshape(B, T, 5, R).astype(jnp.float32))
    dyn = jnp.einsum("btfr,frd->btfd", lora.astype(x.dtype), params["mix_w2"])
    mix = params["mix_base"][None, None] + dyn                   # [B,T,5,D]
    xw, xk, xv, xr, xg = [x + dx * mix[:, :, i] for i in range(5)]

    r = jnp.einsum("btd,de->bte", xr, params["w_r"]).reshape(B, T, H, Dh)
    k = jnp.einsum("btd,de->bte", xk, params["w_k"]).reshape(B, T, H, Dh)
    v = jnp.einsum("btd,de->bte", xv, params["w_v"]).reshape(B, T, H, Dh)
    g = jnp.einsum("btd,de->bte", xg, params["w_g"])

    dec = jnp.einsum("btr,rd->btd",
                     jnp.tanh(jnp.einsum("btd,dr->btr", xw, params["decay_w1"])
                              .astype(jnp.float32)).astype(x.dtype),
                     params["decay_w2"])
    w_log = -jnp.exp(params["decay_base"][None, None] + dec.astype(jnp.float32))
    w_log = w_log.reshape(B, T, H, Dh)

    y, state = kops.rwkv6_scan(r, k, v, w_log, params["bonus_u"],
                               init_state=init_state)
    y = y.reshape(B, T, D)
    y = common.layer_norm(y, params["ln_x_w"], params["ln_x_b"])
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("btd,de->bte", y, params["w_o"])
    return out, (state, x[:, -1:])


def rwkv6_channel_mix(params, x, *, last_x=None):
    xs = _token_shift(x, last_x)
    dx = xs - x
    xk = x + dx * params["cmix_k"][None, None]
    xr = x + dx * params["cmix_r"][None, None]
    k = jnp.einsum("btd,df->btf", xk, params["cm_wk"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, params["cm_wr"])
                       .astype(jnp.float32)).astype(x.dtype)
    return r * jnp.einsum("btf,fd->btd", k, params["cm_wv"]), x[:, -1:]


def init_rwkv6_state(batch: int, spec: Rwkv6Spec, dtype=jnp.bfloat16):
    """Per-layer decode state: (wkv [B,H,Dh,Dh] f32, tm_last [B,1,D],
    cm_last [B,1,D])."""
    return (
        jnp.zeros((batch, spec.n_heads, spec.head_dim, spec.head_dim), jnp.float32),
        jnp.zeros((batch, 1, spec.d_model), dtype),
        jnp.zeros((batch, 1, spec.d_model), dtype),
    )
