"""Mamba2 block (state-space dual form), used by the zamba2 hybrid
architecture. Train/prefill use the chunked SSD scan (Pallas kernel on TPU,
sequential oracle elsewhere); decode carries (conv_states, ssm_state) and
advances one token in O(1).

The input projection is kept as separate weights (w_z, w_x, w_B, w_C, w_dt)
rather than one fused matrix so each output dim can be TP-sharded exactly —
depthwise causal conv commutes with channel concatenation, so splitting the
conv into per-component convs is numerically identical to the fused layout.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common


@dataclasses.dataclass(frozen=True)
class Mamba2Spec:
    d_model: int
    d_state: int          # N
    head_dim: int = 64    # P
    expand: int = 2
    n_groups: int = 1     # B/C groups
    conv_width: int = 4

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init_mamba2(key, spec: Mamba2Spec, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    D, Din, H, N, G, W = (spec.d_model, spec.d_inner, spec.n_heads,
                          spec.d_state, spec.n_groups, spec.conv_width)
    return {
        "w_z": common.dense_init(ks[0], (D, Din), D, dtype),
        "w_x": common.dense_init(ks[1], (D, Din), D, dtype),
        "w_B": common.dense_init(ks[2], (D, G * N), D, dtype),
        "w_C": common.dense_init(ks[3], (D, G * N), D, dtype),
        "w_dt": common.dense_init(ks[4], (D, H), D, dtype),
        "conv_x_w": common.dense_init(ks[5], (W, Din), W, dtype),
        "conv_x_b": jnp.zeros((Din,), dtype),
        "conv_B_w": common.dense_init(ks[6], (W, G * N), W, dtype),
        "conv_B_b": jnp.zeros((G * N,), dtype),
        "conv_C_w": common.dense_init(jax.random.fold_in(key, 7), (W, G * N), W, dtype),
        "conv_C_b": jnp.zeros((G * N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))),
        "norm_w": jnp.ones((Din,), dtype),
        "w_out": common.dense_init(jax.random.fold_in(key, 8), (Din, D), Din, dtype),
    }


def _causal_conv(u, conv_w, conv_b, *, prev=None, silu=True):
    """Depthwise causal conv over time. u [B,T,C]; conv_w [W,C]; prev
    [B,W-1,C] prepends history (decode). Returns (y [B,T,C], new_prev)."""
    W = conv_w.shape[0]
    if prev is None:
        prev = jnp.zeros(u.shape[:1] + (W - 1, u.shape[-1]), u.dtype)
    xfull = jnp.concatenate([prev, u], axis=1)                # [B,T+W-1,C]
    out = sum(xfull[:, i:i + u.shape[1]] * conv_w[i] for i in range(W))
    out = out + conv_b
    if silu:
        out = jax.nn.silu(out.astype(jnp.float32)).astype(u.dtype)
    new_prev = xfull[:, -(W - 1):] if W > 1 else prev
    return out, new_prev


def init_mamba2_state(batch: int, spec: Mamba2Spec, dtype=jnp.bfloat16):
    W, GN = spec.conv_width, spec.n_groups * spec.d_state
    convs = (jnp.zeros((batch, W - 1, spec.d_inner), dtype),
             jnp.zeros((batch, W - 1, GN), dtype),
             jnp.zeros((batch, W - 1, GN), dtype))
    ssm = jnp.zeros((batch, spec.n_heads, spec.d_state, spec.head_dim),
                    jnp.float32)
    return (convs, ssm)


def mamba2_forward(params, x, spec: Mamba2Spec, *, init_state=None):
    """Train/prefill pass. x [B,T,D] -> (y [B,T,D], state)."""
    from repro.kernels import ops as kops
    B, T, D = x.shape
    H, N, G, P = spec.n_heads, spec.d_state, spec.n_groups, spec.head_dim
    convs_prev = (None, None, None) if init_state is None else init_state[0]
    ssm_prev = None if init_state is None else init_state[1]

    z = jnp.einsum("btd,de->bte", x, params["w_z"])
    xs = jnp.einsum("btd,de->bte", x, params["w_x"])
    Bm = jnp.einsum("btd,de->bte", x, params["w_B"])
    Cm = jnp.einsum("btd,de->bte", x, params["w_C"])
    dt = jnp.einsum("btd,dh->bth", x, params["w_dt"])

    xs, sx = _causal_conv(xs, params["conv_x_w"], params["conv_x_b"], prev=convs_prev[0])
    Bm, sB = _causal_conv(Bm, params["conv_B_w"], params["conv_B_b"], prev=convs_prev[1])
    Cm, sC = _causal_conv(Cm, params["conv_C_w"], params["conv_C_b"], prev=convs_prev[2])

    xh = xs.reshape(B, T, H, P)
    Bh = Bm.reshape(B, T, G, N)
    Ch = Cm.reshape(B, T, G, N)
    dts = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    y, ssm_state = kops.mamba2_scan(xh, dts, A, Bh, Ch, params["D"],
                                    init_state=ssm_prev)
    y = y.reshape(B, T, spec.d_inner)
    y = common.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                        params["norm_w"])
    out = jnp.einsum("bte,ed->btd", y, params["w_out"])
    return out, ((sx, sB, sC), ssm_state)


def mamba2_decode(params, x, state, spec: Mamba2Spec):
    """Single-token step: x [B,1,D]."""
    return mamba2_forward(params, x, spec, init_state=state)
