"""Whisper-style encoder-decoder backbone (audio family).

The conv frontend is a STUB per the assignment: `input_specs()` supplies
precomputed frame embeddings [B, enc_seq, D] (what the two conv1d layers
would produce). Encoder: pre-LN non-causal MHA + GELU MLP with learned
positions. Decoder: causal self-attn + cross-attn + GELU MLP.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import common, mlp
from repro.models.attention import AttnSpec
from repro.parallel.sharding import constrain


def enc_attn_spec(cfg: ModelConfig) -> AttnSpec:
    return AttnSpec(d_model=cfg.d_model, head_dim=cfg.head_dim_,
                    plan=cfg.head_plan(), qkv_bias=True, causal=False,
                    use_rotary=False)


def dec_attn_spec(cfg: ModelConfig) -> AttnSpec:
    return AttnSpec(d_model=cfg.d_model, head_dim=cfg.head_dim_,
                    plan=cfg.head_plan(), qkv_bias=True, causal=True,
                    use_rotary=False)


def _init_ln(dtype, d):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _ln(x, p, eps):
    return common.layer_norm(x, p["w"], p["b"], eps)


def _init_enc_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    D = cfg.d_model
    return {"ln1": _init_ln(dtype, D),
            "attn": attn.init_attention(k1, enc_attn_spec(cfg), dtype),
            "ln2": _init_ln(dtype, D),
            "mlp": mlp.init_gelu_mlp(k2, D, cfg.d_ff, dtype)}


def _init_dec_block(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    D = cfg.d_model
    return {"ln1": _init_ln(dtype, D),
            "self_attn": attn.init_attention(k1, dec_attn_spec(cfg), dtype),
            "ln2": _init_ln(dtype, D),
            "cross_attn": attn.init_attention(k2, enc_attn_spec(cfg), dtype),
            "ln3": _init_ln(dtype, D),
            "mlp": mlp.init_gelu_mlp(k3, D, cfg.d_ff, dtype)}


def init_encdec(key, cfg: ModelConfig):
    dtype = common.default_dtype(cfg.dtype)
    D, Vp = cfg.d_model, cfg.vocab_padded
    keys = jax.random.split(key, 8)
    ne = cfg.n_enc_layers or cfg.n_layers
    return {
        "enc_pos": common.embed_init(keys[0], (cfg.enc_seq_len, D), dtype),
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(
            jnp.stack(jax.random.split(keys[1], ne))),
        "enc_ln": _init_ln(dtype, D),
        "embed": common.embed_init(keys[2], (Vp, D), dtype),
        "dec_pos": common.embed_init(keys[3], (4 * 32768, D), dtype),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(
            jnp.stack(jax.random.split(keys[4], cfg.n_layers))),
        "dec_ln": _init_ln(dtype, D),
        "lm_head": common.dense_init(keys[5], (D, Vp), D, dtype),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames [B, enc_seq, D] (stub frontend output) -> encoder states."""
    x = frames.astype(common.default_dtype(cfg.dtype))
    x = x + params["enc_pos"][None, : x.shape[1]]
    x = constrain(x, "batch", "seq", "embed")
    spec = enc_attn_spec(cfg)

    def body(x, p):
        h = _ln(x, p["ln1"], cfg.norm_eps)
        a, _ = attn.attention_full(p["attn"], h, spec)
        x = x + a
        h = _ln(x, p["ln2"], cfg.norm_eps)
        return x + mlp.gelu_mlp(p["mlp"], h), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return _ln(x, params["enc_ln"], cfg.norm_eps)


def cross_kv(params, enc_states, cfg: ModelConfig):
    """Precompute per-decoder-layer cross-attention K/V (stacked [L,...])."""
    spec = enc_attn_spec(cfg)

    def body(_, p):
        k, v = attn.encode_kv(p["cross_attn"], enc_states, spec)
        return None, {"k": k, "v": v}

    _, kv = jax.lax.scan(body, None, params["dec_blocks"])
    return kv


def decode_train(params, enc_states, tokens, cfg: ModelConfig):
    """Teacher-forced decoder pass -> logits [B,T,Vp]."""
    B, T = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + params["dec_pos"][None, :T]
    x = constrain(x, "batch", "seq", "embed")
    sspec, cspec = dec_attn_spec(cfg), enc_attn_spec(cfg)

    def body(x, p):
        h = _ln(x, p["ln1"], cfg.norm_eps)
        a, _ = attn.attention_full(p["self_attn"], h, sspec)
        x = x + a
        h = _ln(x, p["ln2"], cfg.norm_eps)
        ckv = attn.encode_kv(p["cross_attn"], enc_states, cspec)
        a, _ = attn.attention_full(p["cross_attn"], h, cspec, cross_kv=ckv)
        x = x + a
        h = _ln(x, p["ln3"], cfg.norm_eps)
        return x + mlp.gelu_mlp(p["mlp"], h), None

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = _ln(x, params["dec_ln"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", x, params["lm_head"])
    if cfg.vocab_padded != cfg.vocab_size:
        mask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
        logits = jnp.where(mask[None, None], logits,
                           jnp.float32(-1e9).astype(logits.dtype))
    return constrain(logits, "batch", "seq", "vocab")


def forward_train(params, batch, cfg: ModelConfig, *, remat: str = "full"):
    enc = encode(params, batch["frames"], cfg)
    logits = decode_train(params, enc, batch["tokens"], cfg)
    loss = common.softmax_cross_entropy(logits, batch["labels"])
    return loss, {"ce_loss": loss, "moe_aux": jnp.zeros((), jnp.float32)}


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int):
    dtype = common.default_dtype(cfg.dtype)
    L = cfg.n_layers
    kv = attn.init_kv_cache(batch, max_len, dec_attn_spec(cfg), dtype)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), kv)


def decode_step(params, cache, xkv, tokens, cur_index, cfg: ModelConfig):
    """One serving step. xkv: stacked cross K/V from `cross_kv`."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = params["dec_pos"][cur_index][None, None]
    x = x + pos
    sspec, cspec = dec_attn_spec(cfg), enc_attn_spec(cfg)

    def body(x, xs):
        p, c, ck = xs
        h = _ln(x, p["ln1"], cfg.norm_eps)
        a, c = attn.attention_decode(p["self_attn"], h, c, cur_index, sspec)
        x = x + a
        h = _ln(x, p["ln2"], cfg.norm_eps)
        a, _ = attn.attention_decode(p["cross_attn"], h, None, cur_index,
                                     cspec, cross_kv=(ck["k"], ck["v"]))
        x = x + a
        h = _ln(x, p["ln3"], cfg.norm_eps)
        return x + mlp.gelu_mlp(p["mlp"], h), c

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache, xkv))
    x = _ln(x, params["dec_ln"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", x, params["lm_head"])
    return logits, new_cache
