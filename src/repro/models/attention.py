"""GQA attention with TP head padding, rotary embeddings, causal/windowed
masking, prefill and single-token decode paths.

The O(T^2) core dispatches to the Pallas flash kernel via repro.kernels.ops
(XLA reference fallback on non-TPU backends); this module owns projections,
rotary, KV-cache handling and sharding annotations.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import HeadPlan


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    head_dim: int
    plan: HeadPlan
    qkv_bias: bool = False
    rope_theta: float = 1e4
    causal: bool = True
    sliding_window: int = 0      # 0 = full attention
    use_rotary: bool = True      # False: learned/absolute positions upstream


def init_attention(key, spec: AttnSpec, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    D, Dh = spec.d_model, spec.head_dim
    nq, nkv = spec.plan.n_q_pad, spec.plan.n_kv_pad
    p = {
        "wq": common.dense_init(ks[0], (D, nq, Dh), D, dtype),
        "wk": common.dense_init(ks[1], (D, nkv, Dh), D, dtype),
        "wv": common.dense_init(ks[2], (D, nkv, Dh), D, dtype),
        "wo": common.dense_init(ks[3], (nq, Dh, D), nq * Dh, dtype),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((nq, Dh), dtype)
        p["bk"] = jnp.zeros((nkv, Dh), dtype)
        p["bv"] = jnp.zeros((nkv, Dh), dtype)
    # zero the padded q slots so padding stays numerically exact under training
    mask = jnp.asarray(spec.plan.q_pad_mask, dtype)
    p["wq"] = p["wq"] * mask[None, :, None]
    p["wo"] = p["wo"] * mask[:, None, None]
    return p


def _project_qkv(params, x, spec: AttnSpec, positions):
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    if spec.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if spec.use_rotary:
        sin, cos = common.rotary_angles(positions, spec.head_dim, spec.rope_theta)
        q = common.apply_rotary(q, sin, cos)
        k = common.apply_rotary(k, sin, cos)
    return q, k, v


def attention_full(params, x, spec: AttnSpec, positions=None, *,
                   cross_kv=None, use_flash: bool = True):
    """Training / prefill attention. x [B,T,D]; returns ([B,T,D], (k, v)).

    cross_kv: optional precomputed (k, v) for encoder-decoder cross-attention
    (no rotary applied on either side in that case)."""
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.arange(T, dtype=jnp.int32)[None, :]
    if cross_kv is None:
        q, k, v = _project_qkv(params, x, spec, positions)
    else:
        q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
        if spec.qkv_bias:
            q = q + params["bq"]
        k, v = cross_kv

    from repro.kernels import ops as kops
    out = kops.flash_attention(
        q, k, v,
        causal=spec.causal and cross_kv is None,
        group=spec.plan.group,
        sliding_window=spec.sliding_window,
        use_flash=use_flash,
    )
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, (k, v)


def encode_kv(params, x_enc, spec: AttnSpec):
    """Precompute cross-attention K/V from encoder output (enc-dec models)."""
    k = jnp.einsum("btd,dhk->bthk", x_enc, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x_enc, params["wv"])
    if spec.qkv_bias:
        k = k + params["bk"]
        v = v + params["bv"]
    return k, v


def init_kv_cache(batch: int, max_len: int, spec: AttnSpec, dtype=jnp.bfloat16):
    nkv, Dh = spec.plan.n_kv_pad, spec.head_dim
    window = spec.sliding_window or max_len
    size = min(max_len, window)
    return {
        "k": jnp.zeros((batch, size, nkv, Dh), dtype),
        "v": jnp.zeros((batch, size, nkv, Dh), dtype),
    }


def attention_decode(params, x, cache, cur_index, spec: AttnSpec, *,
                     cross_kv=None):
    """Single-token decode. x [B,1,D]; cache holds k/v [B,S,nkv,Dh];
    cur_index [] int32 — number of tokens already in the cache.

    Returns (y [B,1,D], new_cache). Sliding-window caches are rolling
    (position cur_index % window)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), cur_index, jnp.int32)
    if cross_kv is None:
        q, k, v = _project_qkv(params, x, spec, positions)
        S = cache["k"].shape[1]
        slot = cur_index % S
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, slot, 0, 0))
        cache = {"k": ck, "v": cv}
        kk, vv = ck, cv
        # valid positions: < cur_index+1 (non-window) or everything once wrapped
        n_valid = jnp.minimum(cur_index + 1, S)
        lengths = jnp.full((B,), n_valid, jnp.int32)
    else:
        q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
        if spec.qkv_bias:
            q = q + params["bq"]
        kk, vv = cross_kv
        lengths = jnp.full((B,), kk.shape[1], jnp.int32)

    from repro.kernels import ops as kops
    out = kops.decode_attention(q, kk, vv, lengths, group=spec.plan.group)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, cache
