"""Shared model building blocks: norms, rotary embeddings, initializers,
losses, and the TP head-padding planner.

Everything is functional: `init_*` builds parameter pytrees, `apply`-style
functions are pure. No framework dependency beyond jax.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np


def default_dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size, dtype=jnp.float32, scale=1.0):
    """Truncated-normal fan-in init (LLM standard)."""
    std = scale / math.sqrt(max(1, in_axis_size))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rotary_angles(positions, head_dim: int, theta: float = 1e4):
    """positions [*, T] int -> (sin, cos) each [*, T, head_dim//2] f32."""
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rotary(x, sin, cos):
    """x [..., T, H, Dh]; sin/cos [..., T, Dh//2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits, labels, z_loss: float = 1e-4):
    """Mean token cross-entropy with optional z-loss; logits [*, V] f32-cast.
    labels == -1 are masked out (padding)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    denom = jnp.maximum(jnp.sum(mask), 1)
    return jnp.sum(jnp.where(mask, nll, 0.0)) / denom


# ---------------------------------------------------------------------------
# Head-padding planner: make any (n_q, n_kv) GQA layout shard exactly on a
# tp-way model axis (DESIGN.md §5). Padded q heads have zeroed projections
# (their outputs are multiplied by zeroed W_o rows => numerically exact);
# kv heads are *duplicated* (gather of original rows => numerically exact).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HeadPlan:
    n_q: int                  # original query heads
    n_kv: int                 # original kv heads
    n_q_pad: int              # padded query heads (multiple of tp)
    n_kv_pad: int             # padded kv heads (multiple of tp)
    group: int                # n_q_pad // n_kv_pad
    kv_src: tuple[int, ...]   # len n_kv_pad: original kv head feeding each slot
    q_src: tuple[int, ...]    # len n_q_pad: original q head per slot, -1 = zero pad

    @property
    def q_pad_mask(self) -> np.ndarray:
        return np.asarray([s >= 0 for s in self.q_src])


def plan_head_padding(n_q: int, n_kv: int, tp: int) -> HeadPlan:
    """Construct an exact TP-shardable padded head layout.

    Invariants (property-tested):
      * n_q_pad % tp == 0 and n_kv_pad % tp == 0
      * uniform group size G = n_q_pad / n_kv_pad (integer)
      * q slot i attends kv slot i // G, whose source equals the original
        kv head of the original q head in slot i (when not a pad slot).
    """
    if n_q % n_kv != 0:
        raise ValueError(f"GQA requires n_kv | n_q, got {n_q=}, {n_kv=}")
    g_orig = n_q // n_kv

    if n_q == n_kv and n_kv % tp != 0:
        # MHA: zero-pad both q and kv to the same padded count
        n_kv_pad = tp * math.ceil(n_q / tp)
        n_q_pad = n_kv_pad
        kv_src = [k if k < n_kv else -1 for k in range(n_kv_pad)]
        q_src = [k if k < n_q else -1 for k in range(n_q_pad)]
    else:
        # GQA/MQA (or already-divisible MHA): duplicate kv heads to the
        # smallest multiple of both n_kv and tp, split q groups across copies
        n_kv_pad = n_kv if n_kv % tp == 0 else math.lcm(n_kv, tp)
        dup = n_kv_pad // n_kv
        g = max(1, math.ceil(g_orig / dup))
        kv_src, q_src = [], []
        for k in range(n_kv):
            qs = list(range(k * g_orig, (k + 1) * g_orig))
            for c in range(dup):
                kv_src.append(k)
                chunk = qs[c * g:(c + 1) * g]
                chunk += [-1] * (g - len(chunk))
                q_src.extend(chunk)
        n_q_pad = len(q_src)

    if n_q_pad % tp != 0 or n_kv_pad % tp != 0 or n_q_pad % n_kv_pad != 0:
        raise AssertionError(
            f"planner failed: q={n_q}->{n_q_pad} kv={n_kv}->{n_kv_pad} tp={tp}")
    return HeadPlan(n_q, n_kv, n_q_pad, n_kv_pad, n_q_pad // n_kv_pad,
                    tuple(kv_src), tuple(q_src))


def pad_heads_q(w: jnp.ndarray, plan: HeadPlan) -> jnp.ndarray:
    """w [..., n_q, Dh] -> [..., n_q_pad, Dh], zero rows at pad slots."""
    src = np.asarray(plan.q_src)
    gathered = jnp.take(w, jnp.asarray(np.maximum(src, 0)), axis=-2)
    mask = jnp.asarray((src >= 0), w.dtype)[..., :, None]
    return gathered * mask


def pad_heads_kv(w: jnp.ndarray, plan: HeadPlan) -> jnp.ndarray:
    """w [..., n_kv, Dh] -> [..., n_kv_pad, Dh] by duplication (or zero pad
    for MHA layouts where kv_src == -1)."""
    src = np.asarray(plan.kv_src)
    gathered = jnp.take(w, jnp.asarray(np.maximum(src, 0)), axis=-2)
    mask = jnp.asarray((src >= 0), w.dtype)[..., :, None]
    return gathered * mask
