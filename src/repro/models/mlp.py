"""Feed-forward layers: SwiGLU (LLaMA-family), GELU (whisper), and the MoE
layer (top-k routing, capacity-based dispatch, expert-TP sharding with an
optional true-EP all_to_all path in parallel/moe_ep.py)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common


# ---------------------------------------------------------------------------
# Dense MLPs
# ---------------------------------------------------------------------------

def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": common.dense_init(k1, (d_model, d_ff), d_model, dtype),
        "w_in": common.dense_init(k2, (d_model, d_ff), d_model, dtype),
        "w_out": common.dense_init(k3, (d_ff, d_model), d_ff, dtype),
    }


def swiglu(params, x):
    g = jnp.einsum("btd,df->btf", x, params["w_gate"])
    h = jnp.einsum("btd,df->btf", x, params["w_in"])
    act = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    return jnp.einsum("btf,fd->btd", act, params["w_out"])


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_in": common.dense_init(k1, (d_model, d_ff), d_model, dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": common.dense_init(k2, (d_ff, d_model), d_ff, dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params, x):
    h = jnp.einsum("btd,df->btf", x, params["w_in"]) + params["b_in"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("btf,fd->btd", h, params["w_out"]) + params["b_out"]


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    d_ff: int           # per-expert hidden size
    n_experts: int
    k: int              # experts per token
    capacity_factor: float = 2.0


def init_moe(key, spec: MoESpec, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    E, D, F = spec.n_experts, spec.d_model, spec.d_ff
    return {
        "router": common.dense_init(k1, (D, E), D, jnp.float32),
        "w_gate": common.dense_init(k2, (E, D, F), D, dtype),
        "w_in": common.dense_init(k3, (E, D, F), D, dtype),
        "w_out": common.dense_init(k4, (E, F, D), F, dtype),
    }


def moe_capacity(n_tokens: int, spec: MoESpec) -> int:
    cap = max(1, int(spec.capacity_factor * n_tokens * spec.k
                     / spec.n_experts))
    # round to 8 for TPU-friendly shapes, but never inflate tiny decode caps
    # (T=1: top-k experts are distinct, so rank-within-expert is always 0
    # and cap=1 suffices — a floor of 8 would cost 8x expert FLOPs)
    return -(-cap // 8) * 8 if cap >= 8 else cap


def moe_apply(params, x, spec: MoESpec):
    """Capacity-based top-k MoE with PER-BATCH-ROW routing.

    x [B,T,D] -> (y [B,T,D], aux).

    Routing ranks (position-within-expert) are computed with a cumsum over T
    *within each batch row only*, never across rows. This keeps the batch
    dim of every intermediate — including the [B, E, cap, D] dispatch
    buffer — shardable over the data axes under SPMD. (§Perf iteration 1:
    the original flat formulation cumsum'd across the whole global batch,
    which forced XLA to replicate a [E, cap_global, D] buffer on every
    device — 53 GB temp and ~20x FLOPs on grok-1-314b train_4k.)

    Tokens over capacity are dropped (the residual path carries them) —
    standard for capacity-based TPU MoE deployments.
    """
    Bsz, T, D = x.shape
    E, K = spec.n_experts, spec.k
    cap = moe_capacity(T, spec)                                # per row

    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)                   # [B,T,K]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # load-balance auxiliary loss (Switch Transformer eq. 4)
    me = jnp.mean(probs, axis=(0, 1))                          # [E]
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # rank of each (t, slot) within its expert, per batch row
    flat_e = idx.reshape(Bsz, T * K)                           # [B,TK]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # [B,TK,E]
    ranks = jnp.cumsum(onehot, axis=1) - onehot
    rank = jnp.sum(ranks * onehot, axis=-1)                    # [B,TK]
    keep = rank < cap

    # dispatch: per-row scatter into [B, E, cap, D]. vmap over the batch row
    # emits a scatter with *batching dims*, which SPMD partitions along B —
    # a raw 3-index .at[] scatter would replicate the buffer on every device.
    from repro.parallel.sharding import constrain
    xr = jnp.repeat(x, K, axis=1)                              # [B,TK,D]
    safe_rank = jnp.where(keep, rank, 0)
    contrib = jnp.where(keep[..., None], xr, 0).astype(x.dtype)

    def row_scatter(row_x, row_e, row_r):
        return jnp.zeros((E, cap, D), x.dtype).at[row_e, row_r].add(row_x)

    buf = jax.vmap(row_scatter)(contrib, flat_e, safe_rank)    # [B,E,cap,D]
    buf = constrain(buf, "batch", "experts", None, "embed")

    # expert computation (batched SwiGLU over E; F is TP-sharded)
    g = jnp.einsum("becd,edf->becf", buf, params["w_gate"])
    h = jnp.einsum("becd,edf->becf", buf, params["w_in"])
    act = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    act = constrain(act, "batch", "experts", None, "ff")
    out = jnp.einsum("becf,efd->becd", act, params["w_out"])
    out = constrain(out, "batch", "experts", None, "embed")

    # combine: per-row gather back and weight by gates
    def row_gather(row_out, row_e, row_r):
        return row_out[row_e, row_r]

    y_slots = jax.vmap(row_gather)(out, flat_e, safe_rank)     # [B,TK,D]
    y_slots = jnp.where(keep[..., None], y_slots, 0)
    w = gate_vals.reshape(Bsz, T * K)[..., None].astype(x.dtype)
    y = jnp.sum((y_slots * w).reshape(Bsz, T, K, D), axis=2)
    return y, {"moe_aux": aux}
