"""Unified model API over the two assembly families (decoder-only `lm` and
encoder-decoder `encdec`), plus input ShapeDtypeStructs for every assigned
(arch x shape) cell — the dry-run lowers against these (no allocation)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, lm


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss_fn: Callable[..., Any]           # (params, batch) -> (loss, metrics)
    init_decode_cache: Callable[..., Any]  # (batch, max_len) -> cache
    decode_fn: Callable[..., Any]          # (params, cache, batch) -> (logits, cache)
    prefill_fn: Callable[..., Any] | None


def build(cfg: ModelConfig, *, remat: str = "full") -> ModelApi:
    if cfg.family == "encdec":
        def loss_fn(params, batch):
            return encdec.forward_train(params, batch, cfg, remat=remat)

        def decode_fn(params, cache, batch):
            # batch: tokens [B,1], cur_index [], enc frame embeds -> xkv once
            xkv = batch["cross_kv"]
            return encdec.decode_step(params, cache, xkv, batch["tokens"],
                                      batch["cur_index"], cfg)

        return ModelApi(
            cfg=cfg,
            init=lambda key: encdec.init_encdec(key, cfg),
            loss_fn=loss_fn,
            init_decode_cache=lambda b, s: encdec.init_decode_cache(cfg, b, s),
            decode_fn=decode_fn,
            prefill_fn=None,
        )

    def loss_fn(params, batch):
        return lm.forward_train(params, batch, cfg, remat=remat)

    def decode_fn(params, cache, batch):
        return lm.decode_step(params, cache, batch["tokens"],
                              batch["cur_index"], cfg)

    def prefill_fn(params, tokens, max_len):
        return lm.prefill(params, tokens, cfg, max_len)

    return ModelApi(
        cfg=cfg,
        init=lambda key: lm.init_lm(key, cfg),
        loss_fn=loss_fn,
        init_decode_cache=lambda b, s: lm.init_decode_cache(cfg, b, s),
        decode_fn=decode_fn,
        prefill_fn=prefill_fn,
    )


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    api = build(cfg)
    return jax.eval_shape(api.init, jax.random.PRNGKey(0))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every step input of this cell.

    train/prefill: token batch (+ stub modality frontends).
    decode: one new token + cur_index; the KV cache is a separate argument
    (see launch/dryrun.py) sized to shape.seq_len."""
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch: dict[str, Any] = {
            "tokens": sds((B, T), i32), "labels": sds((B, T), i32)}
        if cfg.family == "vlm":
            batch["tokens"] = sds((B, T - cfg.n_img_tokens), i32)
            batch["labels"] = sds((B, T - cfg.n_img_tokens), i32)
            batch["img_embeds"] = sds((B, cfg.n_img_tokens, cfg.d_model), f32)
        if cfg.family == "encdec":
            batch["frames"] = sds((B, cfg.enc_seq_len, cfg.d_model), f32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((B, T), i32), "labels": sds((B, T), i32)}
        if cfg.family == "vlm":
            batch["tokens"] = sds((B, T - cfg.n_img_tokens), i32)
            batch["labels"] = sds((B, T - cfg.n_img_tokens), i32)
            batch["img_embeds"] = sds((B, cfg.n_img_tokens, cfg.d_model), f32)
        if cfg.family == "encdec":
            batch["frames"] = sds((B, cfg.enc_seq_len, cfg.d_model), f32)
        return batch
    # decode
    batch = {"tokens": sds((B, 1), i32),
             "cur_index": sds((), i32)}
    if cfg.family == "encdec":
        plan = cfg.head_plan()
        batch["cross_kv"] = {
            "k": sds((cfg.n_layers, B, cfg.enc_seq_len, plan.n_kv_pad,
                      cfg.head_dim_), jnp.bfloat16),
            "v": sds((cfg.n_layers, B, cfg.enc_seq_len, plan.n_kv_pad,
                      cfg.head_dim_), jnp.bfloat16),
        }
    return batch


def abstract_decode_cache(cfg: ModelConfig, shape: ShapeConfig):
    api = build(cfg)
    return jax.eval_shape(lambda: api.init_decode_cache(
        shape.global_batch, shape.seq_len))
