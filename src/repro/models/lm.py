"""Decoder-only LM assembly covering the dense / moe / hybrid (zamba2) /
ssm (rwkv6) / vlm families.

Layer stack runs as a two-level lax.scan over stacked parameters
(groups x layers-per-group) with configurable activation checkpointing:
the outer scan saves one residual per *group*, the inner scan is rematted,
giving O(L/G + G) live residuals instead of O(L) — the knob that makes
mistral-large-123b train_4k fit (DESIGN.md §5).

Decode paths carry per-layer caches stacked on a leading layer axis and
advance them through the same scan machinery (no remat).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import common, mamba2, mlp, rwkv6
from repro.models.attention import AttnSpec
from repro.models.mamba2 import Mamba2Spec
from repro.models.mlp import MoESpec
from repro.models.rwkv6 import Rwkv6Spec
from repro.parallel.sharding import constrain

MOE_AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# Specs from config
# ---------------------------------------------------------------------------

def attn_spec(cfg: ModelConfig, *, causal=True, sliding=False) -> AttnSpec:
    return AttnSpec(
        d_model=cfg.d_model, head_dim=cfg.head_dim_, plan=cfg.head_plan(),
        qkv_bias=cfg.qkv_bias, rope_theta=cfg.rope_theta, causal=causal,
        sliding_window=cfg.sliding_window if sliding else 0)


def moe_spec(cfg: ModelConfig) -> MoESpec:
    return MoESpec(d_model=cfg.d_model, d_ff=cfg.d_ff,
                   n_experts=cfg.n_experts, k=cfg.experts_per_token)


def mamba_spec(cfg: ModelConfig) -> Mamba2Spec:
    return Mamba2Spec(d_model=cfg.d_model, d_state=cfg.ssm_state)


def rwkv_spec(cfg: ModelConfig) -> Rwkv6Spec:
    return Rwkv6Spec(d_model=cfg.d_model, d_ff=cfg.d_ff)


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    if cfg.family in ("dense", "vlm"):
        return {"ln1_w": jnp.ones((cfg.d_model,), dtype),
                "attn": attn.init_attention(k1, attn_spec(cfg), dtype),
                "ln2_w": jnp.ones((cfg.d_model,), dtype),
                "mlp": mlp.init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype)}
    if cfg.family == "moe":
        return {"ln1_w": jnp.ones((cfg.d_model,), dtype),
                "attn": attn.init_attention(k1, attn_spec(cfg), dtype),
                "ln2_w": jnp.ones((cfg.d_model,), dtype),
                "moe": mlp.init_moe(k2, moe_spec(cfg), dtype)}
    if cfg.family == "hybrid":
        return {"ln1_w": jnp.ones((cfg.d_model,), dtype),
                "mamba": mamba2.init_mamba2(k1, mamba_spec(cfg), dtype)}
    if cfg.family == "ssm":
        return {"ln1_w": jnp.ones((cfg.d_model,), dtype),
                "ln1_b": jnp.zeros((cfg.d_model,), dtype),
                "rwkv_tm": rwkv6.init_rwkv6(k1, rwkv_spec(cfg), dtype),
                "ln2_w": jnp.ones((cfg.d_model,), dtype),
                "ln2_b": jnp.zeros((cfg.d_model,), dtype)}
    raise ValueError(f"family {cfg.family} not handled by lm.py")


def init_lm(key, cfg: ModelConfig):
    dtype = common.default_dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 4)
    Vp, D = cfg.vocab_padded, cfg.d_model
    params: dict[str, Any] = {
        "embed": common.embed_init(keys[0], (Vp, D), dtype),
        "final_norm_w": jnp.ones((D,), dtype),
        "lm_head": common.dense_init(keys[1], (D, Vp), D, dtype),
        "blocks": jax.vmap(lambda k: _init_block(k, cfg, dtype))(
            jnp.stack(keys[4:4 + cfg.n_layers])),
    }
    if cfg.family == "hybrid":
        # zamba2: one *shared* attention+mlp block reused every attn_every
        # mamba layers (arXiv:2411.15242)
        params["shared"] = {
            "ln1_w": jnp.ones((D,), dtype),
            "attn": attn.init_attention(keys[2], attn_spec(cfg, sliding=True), dtype),
            "ln2_w": jnp.ones((D,), dtype),
            "mlp": mlp.init_swiglu(keys[3], D, cfg.d_ff, dtype),
        }
    if cfg.family == "vlm":
        params["img_proj"] = common.dense_init(keys[2], (D, D), D, dtype)
    return params


# ---------------------------------------------------------------------------
# Per-layer forward (train/prefill)
# ---------------------------------------------------------------------------

def _apply_layer(cfg: ModelConfig, p, shared, x, positions, aux, layer_idx):
    if cfg.family in ("dense", "vlm", "moe"):
        h = common.rms_norm(x, p["ln1_w"], cfg.norm_eps)
        a, _ = attn.attention_full(p["attn"], h, attn_spec(cfg), positions)
        x = x + a
        x = constrain(x, "batch", "seq", "embed")
        h = common.rms_norm(x, p["ln2_w"], cfg.norm_eps)
        if cfg.family == "moe":
            m, am = mlp.moe_apply(p["moe"], h, moe_spec(cfg))
            aux = aux + am["moe_aux"]
        else:
            m = mlp.swiglu(p["mlp"], h)
        x = x + m
    elif cfg.family == "hybrid":
        h = common.rms_norm(x, p["ln1_w"], cfg.norm_eps)
        m, _ = mamba2.mamba2_forward(p["mamba"], h, mamba_spec(cfg))
        x = x + m

        def with_shared(x):
            h = common.rms_norm(x, shared["ln1_w"], cfg.norm_eps)
            a, _ = attn.attention_full(shared["attn"], h,
                                       attn_spec(cfg, sliding=True), positions)
            x = x + a
            h = common.rms_norm(x, shared["ln2_w"], cfg.norm_eps)
            return x + mlp.swiglu(shared["mlp"], h)

        x = jax.lax.cond((layer_idx + 1) % cfg.attn_every == 0,
                         with_shared, lambda y: y, x)
    elif cfg.family == "ssm":
        h = common.layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
        a, _ = rwkv6.rwkv6_time_mix(p["rwkv_tm"], h, rwkv_spec(cfg))
        x = x + a
        h = common.layer_norm(x, p["ln2_w"], p["ln2_b"], cfg.norm_eps)
        c, _ = rwkv6.rwkv6_channel_mix(p["rwkv_tm"], h)
        x = x + c
    else:
        raise ValueError(cfg.family)
    x = constrain(x, "batch", "seq", "embed")
    return x, aux


def _run_blocks(params, x, cfg: ModelConfig, positions, *, remat: str = "full"):
    """Two-level scan over stacked layers (see module docstring)."""
    L, G = cfg.n_layers, cfg.remat_group_
    n_groups = L // G
    shared = params.get("shared")
    stacked = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups, G) + a.shape[1:]), params["blocks"])
    idx = jnp.arange(L, dtype=jnp.int32).reshape(n_groups, G)

    def layer_body(carry, xs):
        x, aux = carry
        p, i = xs
        x, aux = _apply_layer(cfg, p, shared, x, positions, aux, i)
        return (x, aux), None

    if remat == "full":
        layer_body = jax.checkpoint(layer_body)

    def group_body(carry, xs):
        new_carry, _ = jax.lax.scan(layer_body, carry, xs)
        return new_carry, None

    if remat in ("full", "group"):
        group_body = jax.checkpoint(group_body)

    (x, aux), _ = jax.lax.scan(group_body, (x, jnp.zeros((), jnp.float32)),
                               (stacked, idx))
    return x, aux


# ---------------------------------------------------------------------------
# Full forward + loss
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    return constrain(x, "batch", "seq", "embed")


def logits_from(params, x, cfg: ModelConfig):
    x = common.rms_norm(x, params["final_norm_w"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", x, params["lm_head"])
    logits = constrain(logits, "batch", "seq", "vocab")
    # mask padded vocab slots out of the softmax
    if cfg.vocab_padded != cfg.vocab_size:
        neg = jnp.float32(-1e9).astype(logits.dtype)
        mask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
        logits = jnp.where(mask[None, None, :], logits, neg)
    return logits


def forward_train(params, batch, cfg: ModelConfig, *, remat: str = "full"):
    """batch: {'tokens': [B,T] i32, 'labels': [B,T] i32 (-1 = masked),
    optional 'img_embeds': [B,Ti,D]} -> (loss, metrics)."""
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg)
    labels = batch["labels"]
    if cfg.family == "vlm" and "img_embeds" in batch:
        img = jnp.einsum("btd,de->bte", batch["img_embeds"].astype(x.dtype),
                         params["img_proj"])
        x = jnp.concatenate([img, x], axis=1)
        labels = jnp.concatenate(
            [jnp.full(img.shape[:2], -1, labels.dtype), labels], axis=1)
    B, T = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x, aux = _run_blocks(params, x, cfg, positions, remat=remat)
    logits = logits_from(params, x, cfg)
    loss = common.softmax_cross_entropy(logits, labels)
    total = loss + MOE_AUX_COEF * aux / max(cfg.n_layers, 1)
    return total, {"ce_loss": loss, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Decode: caches + single-token step
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked per-layer cache matching the family."""
    dtype = common.default_dtype(cfg.dtype)
    L = cfg.n_layers

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), tree)

    if cfg.family in ("dense", "vlm", "moe"):
        return stack(attn.init_kv_cache(batch, max_len, attn_spec(cfg), dtype))
    if cfg.family == "hybrid":
        n_occ = cfg.n_layers // cfg.attn_every
        mamba_state = mamba2.init_mamba2_state(batch, mamba_spec(cfg), dtype)
        mamba_stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), mamba_state)
        kv = attn.init_kv_cache(batch, max_len, attn_spec(cfg, sliding=True), dtype)
        kv_stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_occ,) + a.shape).copy(), kv)
        return {"mamba": mamba_stacked, "shared_kv": kv_stacked}
    if cfg.family == "ssm":
        st = rwkv6.init_rwkv6_state(batch, rwkv_spec(cfg), dtype)
        return stack({"wkv": st[0], "tm_last": st[1], "cm_last": st[2]})
    raise ValueError(cfg.family)


def decode_step(params, cache, tokens, cur_index, cfg: ModelConfig):
    """One serving step: tokens [B,1] -> (logits [B,1,V], new_cache)."""
    x = embed_tokens(params, tokens, cfg)
    B = x.shape[0]
    shared = params.get("shared")

    if cfg.family in ("dense", "vlm", "moe"):
        def body(x, xs):
            p, c = xs
            h = common.rms_norm(x, p["ln1_w"], cfg.norm_eps)
            a, c = attn.attention_decode(p["attn"], h, c, cur_index, attn_spec(cfg))
            x = x + a
            h = common.rms_norm(x, p["ln2_w"], cfg.norm_eps)
            if cfg.family == "moe":
                m, _ = mlp.moe_apply(p["moe"], h, moe_spec(cfg))
            else:
                m = mlp.swiglu(p["mlp"], h)
            return x + m, c

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))

    elif cfg.family == "hybrid":
        def body(carry, xs):
            x, kv_all = carry
            p, ms, i = xs
            h = common.rms_norm(x, p["ln1_w"], cfg.norm_eps)
            m, ms = mamba2.mamba2_decode(p["mamba"], h, ms, mamba_spec(cfg))
            x = x + m

            occ = (i + 1) // cfg.attn_every - 1

            def with_shared(op):
                x, kv_all = op
                c = jax.tree_util.tree_map(lambda a: a[occ], kv_all)
                h = common.rms_norm(x, shared["ln1_w"], cfg.norm_eps)
                a, c = attn.attention_decode(shared["attn"], h, c, cur_index,
                                             attn_spec(cfg, sliding=True))
                x = x + a
                h = common.rms_norm(x, shared["ln2_w"], cfg.norm_eps)
                x = x + mlp.swiglu(shared["mlp"], h)
                kv_all = jax.tree_util.tree_map(
                    lambda buf, v: jax.lax.dynamic_update_index_in_dim(buf, v, occ, 0),
                    kv_all, c)
                return (x, kv_all)

            x, kv_all = jax.lax.cond((i + 1) % cfg.attn_every == 0,
                                     with_shared, lambda op: op, (x, kv_all))
            return (x, kv_all), ms

        idx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        (x, kv_new), mamba_new = jax.lax.scan(
            body, (x, cache["shared_kv"]), (params["blocks"], cache["mamba"], idx))
        new_cache = {"mamba": mamba_new, "shared_kv": kv_new}

    elif cfg.family == "ssm":
        def body(x, xs):
            p, c = xs
            h = common.layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
            a, (wkv, tm_last) = rwkv6.rwkv6_time_mix(
                p["rwkv_tm"], h, rwkv_spec(cfg),
                init_state=c["wkv"], last_x=c["tm_last"])
            x = x + a
            h = common.layer_norm(x, p["ln2_w"], p["ln2_b"], cfg.norm_eps)
            cmix, cm_last = rwkv6.rwkv6_channel_mix(p["rwkv_tm"], h,
                                                    last_x=c["cm_last"])
            x = x + cmix
            return x, {"wkv": wkv, "tm_last": tm_last, "cm_last": cm_last}

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    else:
        raise ValueError(cfg.family)

    return logits_from(params, x, cfg), new_cache


def prefill(params, tokens, cfg: ModelConfig, max_len: int):
    """Prefill pass: run the full prompt, return (last_logits, cache, T).
    Uses the train forward plus per-layer cache collection (no remat)."""
    B, T = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    shared = params.get("shared")
    dtype = common.default_dtype(cfg.dtype)

    if cfg.family in ("dense", "vlm", "moe"):
        spec = attn_spec(cfg)

        def body(x, p):
            h = common.rms_norm(x, p["ln1_w"], cfg.norm_eps)
            a, (k, v) = attn.attention_full(p["attn"], h, spec, positions)
            x = x + a
            h = common.rms_norm(x, p["ln2_w"], cfg.norm_eps)
            if cfg.family == "moe":
                m, _ = mlp.moe_apply(p["moe"], h, moe_spec(cfg))
            else:
                m = mlp.swiglu(p["mlp"], h)
            # write prompt K/V into a max_len cache buffer
            c = attn.init_kv_cache(B, max_len, spec, dtype)
            c["k"] = jax.lax.dynamic_update_slice(
                c["k"], k.astype(dtype), (0, 0, 0, 0))
            c["v"] = jax.lax.dynamic_update_slice(
                c["v"], v.astype(dtype), (0, 0, 0, 0))
            return x + m, c

        x, cache = jax.lax.scan(body, x, params["blocks"])
    elif cfg.family == "ssm":
        def body(x, p):
            h = common.layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
            a, (wkv, tm_last) = rwkv6.rwkv6_time_mix(p["rwkv_tm"], h, rwkv_spec(cfg))
            x = x + a
            h = common.layer_norm(x, p["ln2_w"], p["ln2_b"], cfg.norm_eps)
            cmix, cm_last = rwkv6.rwkv6_channel_mix(p["rwkv_tm"], h)
            x = x + cmix
            return x, {"wkv": wkv, "tm_last": tm_last, "cm_last": cm_last}

        x, cache = jax.lax.scan(body, x, params["blocks"])
    elif cfg.family == "hybrid":
        # interleaved mamba + shared attn: unrolled python loop (38 small
        # layers; prefill has no remat so HLO stays manageable)
        spec = attn_spec(cfg, sliding=True)
        mamba_states, kv_caches = [], []
        for i in range(cfg.n_layers):
            p = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            h = common.rms_norm(x, p["ln1_w"], cfg.norm_eps)
            m, ms = mamba2.mamba2_forward(p["mamba"], h, mamba_spec(cfg))
            x = x + m
            mamba_states.append(ms)
            if (i + 1) % cfg.attn_every == 0:
                h = common.rms_norm(x, shared["ln1_w"], cfg.norm_eps)
                a, (k, v) = attn.attention_full(shared["attn"], h, spec, positions)
                x = x + a
                h = common.rms_norm(x, shared["ln2_w"], cfg.norm_eps)
                x = x + mlp.swiglu(shared["mlp"], h)
                c = attn.init_kv_cache(B, max_len, spec, dtype)
                W = c["k"].shape[1]
                if T <= W:
                    c["k"] = jax.lax.dynamic_update_slice(
                        c["k"], k.astype(dtype), (0, 0, 0, 0))
                    c["v"] = jax.lax.dynamic_update_slice(
                        c["v"], v.astype(dtype), (0, 0, 0, 0))
                else:
                    # rolling window: position p lives at slot p % W
                    c["k"] = jnp.roll(k[:, -W:].astype(dtype), T % W, axis=1)
                    c["v"] = jnp.roll(v[:, -W:].astype(dtype), T % W, axis=1)
                kv_caches.append(c)

        def stack_trees(trees):
            return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)

        cache = {"mamba": stack_trees(mamba_states),
                 "shared_kv": stack_trees(kv_caches)}
    else:
        raise ValueError(cfg.family)

    logits = logits_from(params, x[:, -1:], cfg)
    return logits, cache, T
