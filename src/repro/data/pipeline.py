"""Deterministic synthetic token pipeline.

Production posture: the pipeline is *stateless given (seed, step)* — every
host can compute its own shard of any batch without coordination, restart
resumes mid-epoch exactly (the checkpoint stores only the step), and elastic
re-sharding needs no data-service rendezvous. Mixture of n-gram-ish Markov
streams + copy spans so the loss actually decreases during the e2e examples
(pure uniform tokens would pin CE at log V).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_order: int = 1
    copy_span: int = 32           # periodic copy task: repeat a window


class SyntheticLM:
    """Markov-chain token source with copy spans. Deterministic per
    (seed, step, row)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = min(cfg.vocab_size, 4096)  # transition table kept small
        self._v = v
        # sparse-ish row-stochastic transition logits
        self._trans = rng.dirichlet(np.full(64, 0.5), size=v)
        self._next = rng.integers(0, v, size=(v, 64))

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        B, T = cfg.global_batch, cfg.seq_len
        out = np.empty((B, T + 1), np.int32)
        for b in range(B):
            rng = np.random.default_rng(
                (cfg.seed * 0x9E3779B1 + step * 0x85EBCA77 + b) & 0xFFFFFFFF)
            toks = np.empty(T + 1, np.int32)
            toks[0] = rng.integers(0, self._v)
            i = 1
            while i < T + 1:
                if cfg.copy_span and i > cfg.copy_span and rng.random() < 0.05:
                    span = min(cfg.copy_span, T + 1 - i)
                    toks[i:i + span] = toks[i - cfg.copy_span:
                                            i - cfg.copy_span + span]
                    i += span
                else:
                    cur = toks[i - 1] % self._v
                    j = rng.choice(64, p=self._trans[cur])
                    toks[i] = self._next[cur, j]
                    i += 1
            out[b] = toks
        return {"tokens": out[:, :-1],
                "labels": out[:, 1:].astype(np.int32)}

    def jax_batch(self, step: int, extra: dict | None = None):
        host = self.batch(step)
        batch = {k: jnp.asarray(v) for k, v in host.items()}
        if extra:
            batch.update(extra)
        return batch


def stub_frontend_inputs(cfg, family: str, global_batch: int,
                         seed: int = 0) -> dict:
    """Stub modality frontends per the assignment: precomputed patch/frame
    embeddings, deterministic."""
    rng = np.random.default_rng(seed)
    if family == "vlm":
        x = rng.standard_normal((global_batch, cfg.n_img_tokens,
                                 cfg.d_model)).astype(np.float32) * 0.02
        return {"img_embeds": jnp.asarray(x)}
    if family == "encdec":
        x = rng.standard_normal((global_batch, cfg.enc_seq_len,
                                 cfg.d_model)).astype(np.float32) * 0.02
        return {"frames": jnp.asarray(x)}
    return {}
