"""Production mesh construction.

Function (not module-level constant) so importing never touches jax device
state. Single pod = 16x16 (256 chips of a v5e pod) over ('data', 'model');
multi-pod adds a leading 'pod' axis: (2, 16, 16) = 512 chips.
"""

from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devs)} — run under "
            f"launch/dryrun.py (which forces 512 host devices) or real hardware")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for tests (requires the test process to have forced enough
    host devices)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             devices=jax.devices()[: pod * data * model])
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
