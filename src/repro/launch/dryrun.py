import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run (deliverable e): lower + compile every assigned
(architecture x input shape) cell against the production meshes and record
memory/cost/collective analysis for the roofline (deliverable g).

The two lines above MUST stay first: jax locks the device count at first
init, and only the dry-run wants 512 placeholder CPU devices.

Usage:
    python -m repro.launch.dryrun --arch grok1_314b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out reports/
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, ShapeConfig,
                                cells, get_config)
from repro.core.power_plane import StepProfile
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import registry
from repro.optim import adamw
from repro.optim.schedule import cosine
from repro.parallel import sharding as shd
from repro.train.step import StepConfig, make_train_step

# Per-arch microbatch counts for train_4k (activation-memory control; the
# constraint is microbatches <= global_batch / dp_size). §Perf iteration:
# FSDP all-gathers scale with the microbatch count, so these sit at the
# smallest value whose activations still fit 16 GB/chip.
MICROBATCHES = {
    "mistral_large_123b": 8, "grok1_314b": 2, "granite_20b": 4,
    "qwen2p5_14b": 4, "qwen3_moe_30b_a3b": 2, "rwkv6_7b": 4,
    "zamba2_1p2b": 2, "minicpm_2b": 2, "internvl2_2b": 2, "whisper_base": 1,
}
# >=100B-param models use int8 optimizer moments (DESIGN.md §5)
INT8_OPT = {"mistral_large_123b", "grok1_314b"}

# §Perf iteration (sharding recipe per arch): sub-3B models pay more in TP
# activation all-reduces than they save, so they run wide-FSDP (params
# sharded over data x model, no TP; batch over data x model when divisible).
SHARDING_PROFILES = {
    "zamba2_1p2b": "fsdp_wide", "minicpm_2b": "fsdp_wide",
    "internvl2_2b": "fsdp_wide", "whisper_base": "fsdp_wide",
    # E=128 divides model=16 -> true expert parallelism (EP): experts over
    # 'model', full-width F per expert (F/16=48 was MXU-hostile)
    "qwen3_moe_30b_a3b": "moe_ep",
}


def _profile_settings(arch: str, mesh, shape: ShapeConfig):
    """Returns (rule_overrides, fsdp_axes, batch_axis_candidates, microbatches).

    fsdp_wide applies ONLY to training: inference batches (32/128/1) don't
    divide data x model, which would idle the model axis and turn FSDP
    gathers into per-token traffic (§Perf iteration 3: measured regression).
    Wide-FSDP training also forces microbatches=1 so each microbatch still
    divides the 256-way batch split (a 128-row microbatch on 256 devices
    compiles to 2x padded work — §Perf iteration 3a)."""
    base_dp = dp_axes(mesh)
    mb = MICROBATCHES.get(arch, 2) if shape.name == "train_4k" else 1
    if (SHARDING_PROFILES.get(arch) == "fsdp_wide"
            and shape.kind == "train"
            and shape.global_batch % _mesh_size(mesh, ("data", "model")) == 0):
        overrides = {"heads": None, "kv_heads": None, "ff": None,
                     "vocab": None, "ssm_heads": None, "experts": None}
        wide = tuple(mesh.axis_names)
        cands = [c for c in (wide, ("data", "model"))
                 if shape.global_batch % _mesh_size(mesh, c) == 0]
        return overrides, ("data", "model"), cands + [base_dp, None], 1
    if SHARDING_PROFILES.get(arch) == "moe_ep":
        return {"experts": "model", "ff": None}, "data", [base_dp, None], mb
    return {}, "data", [base_dp, None], mb


def analytic_profile(cfg: ModelConfig, shape: ShapeConfig, n_chips: int
                     ) -> StepProfile:
    """Coarse 6ND-based profile for the in-graph power plane (the precise
    numbers come back out of this dry-run; the plane only needs scale)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        flops = 6.0 * n_active * shape.tokens / n_chips
        grad_bytes = 2.0 * 2 * cfg.param_count() / n_chips
    elif shape.kind == "prefill":
        flops = 2.0 * n_active * shape.tokens / n_chips
        grad_bytes = 0.0
    else:
        flops = 2.0 * n_active * shape.global_batch / n_chips
        grad_bytes = 0.0
    hbm = 2.0 * cfg.param_count() / n_chips + 0.05 * flops / 100.0
    ici = grad_bytes
    return StepProfile(flops, hbm, ici, grad_bytes)


# ---------------------------------------------------------------------------
# Spec/shard construction per cell
# ---------------------------------------------------------------------------

def batch_pspecs(batch_tree, batch_axes):
    def one(path, leaf):
        keys = ".".join(str(getattr(k, "key", getattr(k, "idx", "")))
                        for k in path)
        if leaf.ndim == 0:
            return P()
        if "cross_kv" in keys:   # [L, B, S, H, Dh]: stacked layer dim leads
            return P(None, batch_axes, None, "model", None)
        return P(*((batch_axes,) + (None,) * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (step_fn, abstract_args, in_shardings) for one cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    api = registry.build(cfg)
    rule_overrides, fsdp_axes, batch_candidates, mb = _profile_settings(
        arch, mesh, shape)
    # first batch-axis candidate the global batch divides (long_500k: none)
    batch_axes = next(
        (c for c in batch_candidates
         if c is None or shape.global_batch % _mesh_size(mesh, c) == 0), None)

    moe_ep = SHARDING_PROFILES.get(arch) == "moe_ep"
    abstract_params = registry.abstract_params(cfg)
    pspecs = shd.param_pspecs(abstract_params, mesh, fsdp=fsdp_axes,
                              moe_ep=moe_ep)
    psh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)

    overrides = {"batch": batch_axes, **rule_overrides}
    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig(
            state_dtype="int8" if arch in INT8_OPT else "float32")
        abstract_opt = jax.eval_shape(
            lambda p: adamw.init_state(p, opt_cfg), abstract_params)
        ospecs = shd.param_pspecs(abstract_opt, mesh, fsdp=fsdp_axes,
                                  moe_ep=moe_ep)
        osh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), ospecs)

        profile = analytic_profile(cfg, shape, mesh.devices.size)
        step_cfg = StepConfig(microbatches=mb, grad_sync="auto")
        sched = lambda s: cosine(s, peak_lr=3e-4, warmup_steps=2000,
                                 total_steps=100_000)
        base_step = make_train_step(
            lambda p, b: api.loss_fn(p, b), opt_cfg, sched, profile, step_cfg)

        from repro.core.power_plane import PowerPlaneState
        abstract_plane = jax.eval_shape(PowerPlaneState.nominal)
        plane_sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), abstract_plane)

        batch = registry.input_specs(cfg, shape)
        bspecs = batch_pspecs(batch, batch_axes)
        bsh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), bspecs)

        def step(params, opt, plane, batch):
            with shd.mesh_context(mesh, overrides):
                return base_step(params, opt, plane, {}, batch)

        args = (abstract_params, abstract_opt, abstract_plane, batch)
        shardings = (psh, osh, plane_sh, bsh)
        donate = (0, 1, 2)
        return step, args, shardings, donate

    if shape.kind == "prefill":
        batch = registry.input_specs(cfg, shape)
        bspecs = batch_pspecs(batch, batch_axes)
        bsh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), bspecs)

        if cfg.family == "encdec":
            from repro.models import encdec

            def step(params, batch):
                with shd.mesh_context(mesh, overrides):
                    enc = encdec.encode(params, batch["frames"], cfg)
                    logits = encdec.decode_train(params, enc, batch["tokens"], cfg)
                    return logits[:, -1:], encdec.cross_kv(params, enc, cfg)
        else:
            def step(params, batch):
                with shd.mesh_context(mesh, overrides):
                    return api.prefill_fn(params, batch["tokens"], shape.seq_len)

        return step, (abstract_params, batch), (psh, bsh), ()

    # decode — §Perf note (blocked iteration, see EXPERIMENTS.md §Perf):
    # sharding the residual embed dim over 'data' would make FSDP weight
    # shards contract locally instead of moving expert weights to tokens,
    # but it collides with batch sharding on the same axis under automatic
    # SPMD (PartitionSpec('data', ..., 'data') is illegal). A manual
    # shard_map decode layer with a 2-D weight-stationary layout is the
    # production fix; left as documented future work.
    abstract_cache = registry.abstract_decode_cache(cfg, shape)
    cspecs = shd.cache_pspecs(abstract_cache, mesh, batch_axes=batch_axes)
    csh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), cspecs)
    batch = registry.input_specs(cfg, shape)
    bspecs = batch_pspecs(batch, batch_axes)
    bsh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), bspecs)

    def step(params, cache, batch):
        with shd.mesh_context(mesh, overrides):
            return api.decode_fn(params, cache, batch)

    return step, (abstract_params, abstract_cache, batch), (psh, csh, bsh), (1,)


def _mesh_size(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes[a]
    return max(n, 1)


# ---------------------------------------------------------------------------
# Collective-byte extraction from post-SPMD HLO
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n]*",
    re.M)

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|pred)"
                       r"\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}


def _group_size(line: str) -> int:
    m = _GROUP_IOTA_RE.search(line)       # iota format: [ngroups,group_size]
    if m:
        return max(1, int(m.group(2)))
    m = _GROUP_RE.search(line)            # explicit: {{0,1,...},{...}}
    if m:
        return max(1, m.group(1).count(",") + 1)
    return 2


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device wire bytes of every collective in post-optimization HLO.

    Output-shape bytes are converted to ring-algorithm wire traffic per
    participant (P = replica-group size):
      all-gather         out*(P-1)/P     (out = full gathered buffer)
      all-reduce         2*out*(P-1)/P   (reduce-scatter + all-gather phases)
      reduce-scatter     out*(P-1)       (out = the local shard)
      all-to-all         out*(P-1)/P
      collective-permute out
    """
    out: dict[str, float] = {}
    counts: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes_blob, kind, line = m.group(1), m.group(2), m.group(0)
        total = 0
        for sm in _SHAPE_RE.finditer(shapes_blob):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            total += n * _BYTES[dt]
        p = _group_size(line)
        factor = {"all-gather": (p - 1) / p,
                  "all-reduce": 2 * (p - 1) / p,
                  "reduce-scatter": float(p - 1),
                  "all-to-all": (p - 1) / p,
                  "collective-permute": 1.0}[kind]
        out[kind] = out.get(kind, 0) + total * factor
        counts[kind] = counts.get(kind, 0) + 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["op_counts"] = counts
    return out


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str,
             save_hlo_dir: str | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    step, args, shardings, donate = build_cell(arch, shape_name, mesh)
    jitted = jax.jit(step, in_shardings=shardings,
                     donate_argnums=donate or ())
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        # older jax returns one dict per device program
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    if save_hlo_dir:
        os.makedirs(save_hlo_dir, exist_ok=True)
        with open(os.path.join(save_hlo_dir,
                               f"{arch}.{shape_name}.{mesh_kind}.hlo"), "w") as f:
            f.write(hlo)

    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "utilization_ops": {k: v for k, v in cost.items()
                            if k.startswith("utilization")},
        "memory": mem_info,
        "collective_bytes": coll,
        "ok": True,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="reports")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    if args.all:
        todo = [(a, s) for a, s, runnable in cells() if runnable]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape or --all required")
        todo = [(args.arch, args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for mesh_kind in meshes:
        for arch, shape_name in todo:
            tag = f"{arch} x {shape_name} x {mesh_kind}"
            try:
                r = run_cell(arch, shape_name, mesh_kind,
                             save_hlo_dir=os.path.join(args.out, "hlo")
                             if args.save_hlo else None)
                print(f"[OK] {tag}: flops={r['flops']:.3e} "
                      f"coll={r['collective_bytes']['total']:.3e}B "
                      f"compile={r['compile_s']}s", flush=True)
            except Exception as e:
                traceback.print_exc()
                r = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                     "ok": False, "error": f"{type(e).__name__}: {e}"}
                print(f"[FAIL] {tag}: {r['error']}", flush=True)
            results.append(r)
            path = os.path.join(args.out, f"dryrun_{'_'.join(meshes)}.json")
            with open(path, "w") as f:
                json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells passed -> {path}")
    if n_ok != len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
