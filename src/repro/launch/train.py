"""Production training launcher.

    python -m repro.launch.train --arch minicpm_2b --tiny --steps 100
    python -m repro.launch.train --arch grok1_314b --dry-run   (lower only)

On real hardware the full configs train on the production mesh; on this CPU
container use --tiny (reduced same-family config) or --dry-run (AOT compile
check via launch/dryrun.py)."""

from __future__ import annotations

import argparse
import shutil

import jax

from repro.configs import ARCH_IDS, get_config
from repro.core.control_plane import HostRailController
from repro.core.policy import POLICIES
from repro.core.power_plane import StepProfile
from repro.data.pipeline import DataConfig, SyntheticLM, stub_frontend_inputs
from repro.models import registry
from repro.optim import adamw
from repro.optim.schedule import wsd
from repro.train.step import StepConfig, jit_train_step, make_train_step
from repro.train.trainer import (FaultConfig, Trainer, TrainerConfig,
                                 initial_plane_and_ef)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--dry-run", action="store_true",
                    help="AOT lower+compile on the production mesh instead")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--policy", choices=list(POLICIES), default="phase-aware")
    ap.add_argument("--control-path", choices=("in-graph", "host"),
                    default="in-graph",
                    help="in-graph = HW-path analogue (policy compiled into "
                         "the step); host = SW-path analogue (policy between "
                         "steps, actuated through simulated PMBus)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.dry_run:
        import subprocess
        import sys
        raise SystemExit(subprocess.call(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
             "--shape", "train_4k", "--mesh", "both"]))

    cfg = get_config(args.arch, tiny=args.tiny or True)
    api = registry.build(cfg, remat="none" if args.tiny else "full")
    params = api.init(jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params (tiny={args.tiny})")

    opt_cfg = adamw.AdamWConfig()
    opt = adamw.init_state(params, opt_cfg)
    plane, ef = initial_plane_and_ef(params)
    tokens = args.batch * args.seq
    profile = StepProfile(6.0 * n * tokens, 14.0 * n, 4.0 * n, 4.0 * n)
    sched = lambda s: wsd(s, peak_lr=3e-4, warmup_steps=10,
                          stable_steps=int(args.steps * 0.7),
                          decay_steps=int(args.steps * 0.2))
    policy = POLICIES[args.policy]
    in_graph = args.control_path == "in-graph"
    step = jit_train_step(make_train_step(
        lambda p, b: api.loss_fn(p, b), opt_cfg, sched, profile,
        StepConfig(policy=policy if in_graph else None)), donate=False)

    class _Data(SyntheticLM):
        def jax_batch(self, s, extra=None):
            return super().jax_batch(s, stub_frontend_inputs(
                cfg, cfg.family, args.batch))

    data = _Data(DataConfig(cfg.vocab_size, args.seq, args.batch))
    if not args.resume:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    controller = None if in_graph else HostRailController(policy)
    trainer = Trainer(step, data, TrainerConfig(
        total_steps=args.steps, ckpt_every=max(10, args.steps // 5),
        ckpt_dir=args.ckpt_dir, controller=controller),
        {"params": params, "opt": opt, "plane": plane, "ef": ef})
    if args.resume and trainer.maybe_restore():
        print(f"resumed from step {trainer.start_step}")
    log = trainer.run()
    rec = list(log.records)
    print(f"loss {rec[0].loss:.4f} -> {rec[-1].loss:.4f}; "
          f"summary: {trainer.summary()}")


if __name__ == "__main__":
    main()
