"""Serving launcher: batched prefill+decode with the power plane.

    python -m repro.launch.serve --arch qwen2p5_14b --tiny --max-new 32
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.control_plane import HostRailController, InGraphRailController
from repro.core.hwspec import FleetSpec
from repro.core.policy import POLICIES, WorstChipGate
from repro.core.power_plane import StepProfile
from repro.models import registry
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--policy", choices=list(POLICIES), default="phase-aware")
    ap.add_argument("--control-path", choices=("in-graph", "host"),
                    default="in-graph")
    ap.add_argument("--fleet-chips", type=int, default=0,
                    help="serve on an [n_chips] fleet plane with per-chip "
                         "process variation (0 = scalar single-chip)")
    ap.add_argument("--fleet-seed", type=int, default=0)
    ap.add_argument("--router", choices=("none", "headroom", "roundrobin"),
                    default="none",
                    help="route a seeded bursty traffic trace over the "
                         "fleet by per-rail voltage headroom (or the "
                         "round-robin baseline) instead of running "
                         "generate(); needs --fleet-chips")
    ap.add_argument("--trace-requests", type=int, default=48,
                    help="requests in the bursty trace (--router only)")
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--tick-path", choices=("auto", "fused", "loop"),
                    default="auto",
                    help="serve tick device path (--router only): 'fused' "
                         "forces the one-dispatch jitted tick, 'loop' the "
                         "historical per-tick host loop, 'auto' picks fused "
                         "for in-graph controllers (docs/serve.md)")
    ap.add_argument("--fast-forward", action="store_true",
                    help="skip idle tick gaps (empty queue, no resident "
                         "work) by jumping simulated time to the next "
                         "arrival — fused tick path only")
    ap.add_argument("--batch-cap", type=int, default=0,
                    help="continuous batching: each chip decodes a "
                         "token-level batch over up to BATCH_CAP resident "
                         "lanes at the shared-roofline per-lane rate "
                         "(0 = historical full-rate-per-slot model; "
                         "--router only — the cap becomes the router's "
                         "lane capacity)")
    ap.add_argument("--migrate-after-ticks", type=int, default=0,
                    help="in-flight migration: evacuate a chip's resident "
                         "decode lanes after its pinned/over-bound flag "
                         "held this many consecutive ticks (0 = off; "
                         "needs --router headroom — round-robin has no "
                         "migration planner)")
    args = ap.parse_args()
    if args.batch_cap < 0:
        ap.error(f"--batch-cap must be >= 0, got {args.batch_cap}")
    if args.migrate_after_ticks < 0:
        ap.error(f"--migrate-after-ticks must be >= 0, got "
                 f"{args.migrate_after_ticks}")
    if args.batch_cap and args.router == "none":
        ap.error("--batch-cap batches a router's lanes; pass --router "
                 "headroom (or roundrobin)")
    if args.migrate_after_ticks and args.router != "headroom":
        ap.error("--migrate-after-ticks needs the headroom router's "
                 "migration planner; pass --router headroom")

    cfg = get_config(args.arch, tiny=args.tiny or True)
    if cfg.family == "encdec":
        raise SystemExit("whisper serving uses cross-attention prefill; see "
                         "tests/test_models_smoke.py::test_arch_decode_step_smoke")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree_util.tree_leaves(params))

    policy = POLICIES[args.policy]
    fleet = (FleetSpec.sample(args.fleet_chips, seed=args.fleet_seed)
             if args.fleet_chips else None)
    if fleet is not None:
        # fleet serving: gate every chip's decision on the worst chip
        policy = WorstChipGate(policy)
    controller = (InGraphRailController(policy)
                  if args.control_path == "in-graph"
                  else HostRailController(policy,
                                          n_chips=max(args.fleet_chips, 1)))
    router = None
    if args.router != "none":
        if fleet is None:
            raise SystemExit("--router places work across a fleet; pass "
                             "--fleet-chips N")
        from repro.serve.router import HeadroomRouter, RoundRobinRouter
        # the launcher world has no error telemetry, so every chip walks
        # to its policy floor and reads as pinned — a drain-pinned router
        # would (correctly) shed the whole trace. Keep pinned chips
        # eligible here; benchmarks/serve_router.py and the tests
        # exercise the drain semantics against a frontier-error world.
        # --batch-cap sets the lane capacity (lanes ARE the router's
        # slots); without it the historical --batch slot count stands
        lanes = args.batch_cap or args.batch
        router = (HeadroomRouter(capacity=lanes, drain_pinned=False)
                  if args.router == "headroom"
                  else RoundRobinRouter(capacity=lanes))
    engine = ServeEngine(
        cfg, params, max_len=args.prompt_len + args.max_new + 8,
        batch_size=args.batch,
        prefill_profile=StepProfile(2.0 * n * args.batch * args.prompt_len,
                                    2.0 * n, 0.0),
        decode_profile=StepProfile(2.0 * n * args.batch, 2.0 * n, 0.0),
        controller=controller, fleet=fleet, router=router,
        batch_cap=args.batch_cap or None)
    if router is not None:
        # routed serving: place a seeded bursty trace by per-rail headroom
        # (docs/serve.md) and report the per-request SLO ledger
        from repro.serve.traffic import bursty_trace
        trace = bursty_trace(args.trace_requests, seed=args.trace_seed)
        # a tiny model's roofline step is microseconds — pin a serving-scale
        # tick so the seconds-scale trace spans hundreds of ticks, not 1e6;
        # bound the run to the trace span plus drain slack so a saturated
        # fleet reports unplaced work instead of spinning 20k ticks
        tick_s = 0.02
        span = trace.requests[-1].t_arrival_s if trace.requests else 0.0
        fused = {"auto": None, "fused": True, "loop": False}[args.tick_path]
        ledger = engine.serve_trace(trace, tick_s=tick_s,
                                    max_ticks=int(span / tick_s) + 400,
                                    fused=fused,
                                    fast_forward=args.fast_forward,
                                    migrate_after_ticks=(
                                        args.migrate_after_ticks or None))
        print(f"{cfg.name} ({n/1e6:.1f}M): routed {len(trace)} requests "
              f"over {engine.n_chips} chips ({args.router})")
        print("trace:", engine.last_trace)
        print("slo:", ledger.summary())
        print("summary:", engine.summary())
        return
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    out = engine.generate(prompts, max_new_tokens=args.max_new)
    print(f"{cfg.name} ({n/1e6:.1f}M): generated {out.shape} tokens")
    print("summary:", engine.summary())


if __name__ == "__main__":
    main()
