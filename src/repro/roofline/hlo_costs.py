"""Trip-count-aware static cost analysis of post-optimization HLO.

Why: XLA's `compiled.cost_analysis()` counts a `while` body ONCE regardless
of trip count (verified empirically: a scan of 10 matmuls reports 1 matmul
of FLOPs), so every scan-over-layers model is undercounted by ~L x M. This
walker parses `compiled.as_text()` and propagates loop multipliers:

  * computations are split on header lines (`%name (...) -> ... {`),
  * `while(...)` ops link to condition/body computations; the trip count is
    the s32 constant in the condition computation (scan-generated loops
    compare the induction variable against exactly one such constant),
  * `fusion ... calls=%f`, `call ... to_apply=%f` and conditional branches
    propagate the parent multiplier (x1),
  * FLOPs: every `dot(...)` contributes 2 * prod(output_dims) *
    prod(lhs_contracting_dims) * multiplier,
  * HBM bytes: for ops in non-fused computations (fusion interiors never
    touch HBM), output bytes + operand bytes (name -> shape symbol table),
    skipping bookkeeping ops (GTE/tuple/parameter/constant/bitcast/copy),
  * collective wire bytes: same ring-factor model as launch/dryrun.py but
    with loop multipliers applied.

Caveats (documented in EXPERIMENTS.md): `conditional` branches count once
each (zamba2's every-6th-layer shared-attention block therefore overcounts
its attention FLOPs ~6x — a conservative upper bound); elementwise FLOPs
are ignored (<2% for these models); bytes is a producer/consumer-boundary
model, an upper bound on HBM traffic.
"""

from __future__ import annotations

import dataclasses
import re

_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1, "c64": 8, "c128": 16}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->", re.M)
_OPLINE = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([\d,]*)\]")
_WHILE = re.compile(r"while\(.*condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply)=%([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUE_FALSE = re.compile(r"(?:true_computation|false_computation)=%([\w.\-]+)")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_COLL_KIND = re.compile(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
                        r"collective-permute)\(")
_GROUP_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST = re.compile(r"replica_groups=\{\{([\d,]+)\}")

_SKIP_OPS = ("get-tuple-element", "tuple(", "parameter(", "constant(",
             "bitcast(", "after-all(", "partition-id(", "iota(")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE.finditer(text):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _BYTES[m.group(1)]
    return total


def _first_shape_elems_bytes(text: str) -> tuple[list[int], int]:
    m = _SHAPE.search(text)
    if not m:
        return [], 0
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    n = 1
    for d in dims:
        n *= d
    return dims, n * _BYTES[m.group(1)]


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: dict[str, float] = dataclasses.field(default_factory=dict)
    n_whiles: int = 0
    max_mult: float = 1.0

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())


class HloModule:
    def __init__(self, text: str):
        self.comps: dict[str, list[str]] = {}
        self.entry: str | None = None
        self._split(text)
        self.shapes: dict[str, str] = {}     # op name -> defining line
        for name, lines in self.comps.items():
            for ln in lines:
                m = _OPLINE.match(ln)
                if m:
                    self.shapes[m.group(1)] = m.group(2)

    def _split(self, text: str) -> None:
        cur = None
        for line in text.splitlines():
            if line.startswith(("ENTRY", "%")) and "->" in line and line.rstrip().endswith("{"):
                m = _COMP_HDR.match(line)
                if m:
                    cur = m.group(1)
                    self.comps[cur] = []
                    if line.startswith("ENTRY"):
                        self.entry = cur
                    continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is not None:
                self.comps[cur].append(line)
        if self.entry is None:
            # fall back: the computation named like the module entry
            self.entry = next(iter(self.comps)) if self.comps else None

    # -- trip counts ---------------------------------------------------------
    def trip_count(self, cond_name: str) -> int:
        consts = [int(c) for ln in self.comps.get(cond_name, ())
                  for c in _CONST_S32.findall(ln)]
        consts = [c for c in consts if c > 0]
        return max(consts) if consts else 1

    # -- multiplier propagation -------------------------------------------------
    def multipliers(self) -> dict[str, float]:
        mult: dict[str, float] = {}

        def visit(name: str, m: float):
            if name not in self.comps:
                return
            mult[name] = mult.get(name, 0.0) + m
            for ln in self.comps[name]:
                w = _WHILE.search(ln)
                if w:
                    cond, body = w.group(1), w.group(2)
                    t = self.trip_count(cond)
                    visit(cond, m * (t + 1))
                    visit(body, m * t)
                    continue
                if "conditional(" in ln:
                    for b in _TRUE_FALSE.findall(ln):
                        visit(b, m)
                    bm = _BRANCHES.search(ln)
                    if bm:
                        for b in _OPERANDS.findall(bm.group(1)):
                            visit(b, m)
                    continue
                for c in _CALLS.findall(ln):
                    visit(c, m)

        if self.entry:
            visit(self.entry, 1.0)
        return mult

    # -- cost walk ------------------------------------------------------------------
    def costs(self) -> Costs:
        mult = self.multipliers()
        out = Costs()
        fused = {n for n in self.comps if n.startswith(("fused_computation",
                                                        "wrapped_"))}
        for name, lines in self.comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            out.max_mult = max(out.max_mult, m)
            in_fusion = name in fused
            for ln in lines:
                opm = _OPLINE.match(ln)
                if not opm:
                    continue
                rhs = opm.group(2)
                # FLOPs from dots (count inside fusions too)
                if " dot(" in rhs or rhs.startswith("dot("):
                    dims, _ = _first_shape_elems_bytes(rhs)
                    n_out = 1
                    for d in dims:
                        n_out *= d
                    lhs_c = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                    k = 1
                    if lhs_c:
                        ops = _OPERANDS.findall(rhs.split("dot(")[1])
                        lhs_name = ops[0] if ops else None
                        lhs_def = self.shapes.get(lhs_name, "")
                        ldims, _ = _first_shape_elems_bytes(lhs_def)
                        for idx in (lhs_c.group(1).split(",")
                                    if lhs_c.group(1) else []):
                            i = int(idx)
                            if i < len(ldims):
                                k *= ldims[i]
                    out.flops += 2.0 * n_out * k * m
                if "while(" in rhs:
                    out.n_whiles += 1
                # collectives (appear in non-fused comps)
                cm = _COLL_KIND.search(rhs)
                if cm and not in_fusion:
                    kind = cm.group(1)
                    _, obytes = _first_shape_elems_bytes(rhs)
                    # output may be a tuple: sum all shapes before the opcode
                    obytes = _shape_bytes(rhs.split(cm.group(1) + "(")[0])
                    p = self._group_size(rhs)
                    factor = {"all-gather": (p - 1) / p,
                              "all-reduce": 2 * (p - 1) / p,
                              "reduce-scatter": float(p - 1),
                              "all-to-all": (p - 1) / p,
                              "collective-permute": 1.0}[kind]
                    out.collective_bytes[kind] = (
                        out.collective_bytes.get(kind, 0.0)
                        + obytes * factor * m)
                # HBM traffic: non-fused boundaries only
                if not in_fusion and not any(s in rhs for s in _SKIP_OPS):
                    _, obytes = _first_shape_elems_bytes(rhs)
                    opnd_bytes = 0
                    paren = rhs.find("(")
                    if paren > 0:
                        args_blob = rhs[paren + 1:rhs.find(")", paren)]
                        for op_name in _OPERANDS.findall(args_blob):
                            opnd_bytes += _shape_bytes(
                                self.shapes.get(op_name, "").split(" ")[0])
                    out.hbm_bytes += (obytes + opnd_bytes) * m
        return out

    @staticmethod
    def _group_size(rhs: str) -> int:
        m = _GROUP_IOTA.search(rhs)
        if m:
            return max(1, int(m.group(2)))
        m = _GROUP_LIST.search(rhs)
        if m:
            return max(1, m.group(1).count(",") + 1)
        return 2


def analyze_hlo_text(text: str) -> Costs:
    return HloModule(text).costs()


def analyze_hlo_file(path: str) -> Costs:
    with open(path) as f:
        return analyze_hlo_text(f.read())
