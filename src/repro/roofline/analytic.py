"""Analytic HBM-traffic model for the roofline memory term.

The HLO producer/consumer byte walk (hlo_costs.py) is a faithful count of
*CPU*-HLO boundaries, but XLA:TPU fuses elementwise chains into VMEM, so it
overstates TPU HBM traffic ~5-10x. For the memory term we therefore use a
explicit traffic model of what a TPU execution actually moves per step
(documented in EXPERIMENTS.md §Roofline):

train (per device):
    2*(W + G + O)            weights/grads/optimizer, read+write once
  + M * L * A * C_ACT        residual-stream traffic per microbatch-layer:
                             fwd write + bwd read + remat re-write + the
                             attn/mlp internals that spill (C_ACT ~ 6)
prefill: W + L * A_pf * C_PF  (C_PF ~ 4; no grads/opt)
decode:  2N/devices + cache read+write (the classic decode bound)

W = 2N/devices (bf16), G = 4N/devices (f32 accum), O = 8N/devices f32
moments (2.1 for int8), A = tokens_local*d_model*2.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig

C_ACT_TRAIN = 6.0
C_ACT_PREFILL = 4.0


def hbm_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig, devices: int,
                         *, microbatches: int = 1, int8_opt: bool = False,
                         tp: int | None = None) -> float:
    N = cfg.param_count()
    L = max(cfg.n_layers + (cfg.n_enc_layers or 0), 1)
    D = cfg.d_model
    tp = cfg.tp if tp is None else tp
    if shape.kind == "train":
        W = 2.0 * N / devices
        G = 4.0 * N / devices
        O = (2.1 if int8_opt else 8.0) * N / devices
        tokens_local = shape.tokens / max(devices // tp, 1) / microbatches
        A = tokens_local * D * 2.0
        return 2.0 * (W + G + O) + microbatches * L * A * C_ACT_TRAIN
    if shape.kind == "prefill":
        W = 2.0 * N / devices
        tokens_local = shape.tokens / max(devices // tp, 1)
        A = tokens_local * D * 2.0
        return W + L * A * C_ACT_PREFILL
    # decode: every parameter is read once per token + cache traffic
    W = 2.0 * cfg.active_param_count() / devices
    cache = cache_bytes_per_device(cfg, shape, devices)
    return W + 2.0 * cache / max(shape.seq_len, 1) + cache_read_per_token(
        cfg, shape, devices)


def cache_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig,
                           devices: int) -> float:
    plan = cfg.head_plan()
    B_local = max(shape.global_batch / max(devices // cfg.tp, 1), 1)
    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        S = min(shape.seq_len, 10**9)
        kv = cfg.n_layers * B_local * S * (plan.n_kv_pad / cfg.tp) \
            * cfg.head_dim_ * 2 * 2
        return kv
    if cfg.family == "hybrid":
        window = cfg.sliding_window or shape.seq_len
        n_occ = cfg.n_layers // max(cfg.attn_every, 1)
        kv = n_occ * B_local * min(window, shape.seq_len) \
            * (plan.n_kv_pad / cfg.tp) * cfg.head_dim_ * 2 * 2
        ssm = cfg.n_layers * B_local * (2 * cfg.d_model / 64 / cfg.tp) \
            * cfg.ssm_state * 64 * 4
        return kv + ssm
    # ssm (rwkv6): [H, Dh, Dh] f32 per layer
    H = cfg.d_model // 64
    return cfg.n_layers * B_local * (H / cfg.tp) * 64 * 64 * 4


def cache_read_per_token(cfg: ModelConfig, shape: ShapeConfig,
                         devices: int) -> float:
    """Decode reads the whole (local) cache once per generated token."""
    return cache_bytes_per_device(cfg, shape, devices)
