"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
from the dry-run artifacts.

    compute term    = HLO_FLOPs / (peak_FLOP/s per chip)
    memory term     = HLO_bytes / (HBM bytes/s per chip)
    collective term = collective wire bytes / (ICI bytes/s per chip)

`compiled.cost_analysis()` on a partitioned module reports per-device FLOPs
and bytes; collective bytes come from the post-SPMD HLO parse in
launch/dryrun.py (already per-device wire traffic). MODEL_FLOPS uses
6*N*D (dense) / 6*N_active*D (MoE) for training, 2*N*D for inference, per
the assignment; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/padding
waste (values < 1 mean the compiled step does extra work — e.g. remat
recompute; values > 1 would mean XLA found algebraic savings).
"""

from __future__ import annotations

import dataclasses
import json
import os

from repro.configs.base import SHAPES, get_config
from repro.core.hwspec import V5E, ChipSpec


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    devices: int
    t_compute_s: float
    t_memory_s: float
    t_collective_s: float
    dominant: str
    model_flops_per_chip: float
    hlo_flops_per_chip: float
    useful_ratio: float          # MODEL_FLOPS / HLO_FLOPs
    roofline_fraction: float     # t_compute / max(all terms) — MFU-like bound
    note: str = ""

    @property
    def t_step_bound_s(self) -> float:
        return max(self.t_compute_s, self.t_memory_s, self.t_collective_s)


def model_flops_per_chip(arch: str, shape_name: str, devices: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        total = 6.0 * n_active * shape.tokens
    elif shape.kind == "prefill":
        total = 2.0 * n_active * shape.tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / devices


def analyze_cell(rec: dict, spec: ChipSpec = V5E,
                 hlo_dir: str | None = None) -> RooflineRow | None:
    if not rec.get("ok"):
        return None
    # corrected costs (trip-count-aware walker over saved HLO) when available
    corrected = rec.get("corrected")
    if corrected is None and hlo_dir:
        path = os.path.join(hlo_dir,
                            f"{rec['arch']}.{rec['shape']}.{rec['mesh']}.hlo")
        if os.path.exists(path):
            from repro.roofline.hlo_costs import analyze_hlo_file
            c = analyze_hlo_file(path)
            corrected = {"flops": c.flops,
                         "collective_bytes": c.collective_total,
                         "by_kind": c.collective_bytes}
            rec["corrected"] = corrected
    if corrected:
        flops = float(corrected["flops"])
        coll = float(corrected["collective_bytes"])
    else:
        flops = float(rec["flops"] or 0.0)
        coll = float(rec["collective_bytes"]["total"])
    # memory term: analytic TPU HBM-traffic model (see roofline/analytic.py)
    from repro.launch.dryrun import INT8_OPT, MICROBATCHES, SHARDING_PROFILES
    from repro.roofline.analytic import hbm_bytes_per_device
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    wide = (SHARDING_PROFILES.get(rec["arch"]) == "fsdp_wide"
            and rec["shape"] == "train_4k")
    mb = 1 if wide else (
        MICROBATCHES.get(rec["arch"], 2) if rec["shape"] == "train_4k" else 1)
    tp_eff = 1 if wide else cfg.tp
    hbm_bytes = hbm_bytes_per_device(cfg, shape, rec["devices"],
                                     microbatches=mb, tp=tp_eff,
                                     int8_opt=rec["arch"] in INT8_OPT)
    t_comp = flops / spec.peak_bf16_flops
    t_mem = hbm_bytes / spec.hbm_bandwidth
    t_coll = coll / (spec.ici_link_bandwidth * spec.ici_links_per_chip)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_chip(rec["arch"], rec["shape"], rec["devices"])
    t_bound = max(terms.values()) or 1e-30
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        devices=rec["devices"],
        t_compute_s=t_comp, t_memory_s=t_mem, t_collective_s=t_coll,
        dominant=dominant,
        model_flops_per_chip=mf, hlo_flops_per_chip=flops,
        useful_ratio=mf / flops if flops else 0.0,
        roofline_fraction=(mf / spec.peak_bf16_flops) / t_bound,
    )


def load_report(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)


def analyze_report(path: str, mesh: str | None = "single",
                   hlo_dir: str | None = None) -> list[RooflineRow]:
    if hlo_dir is None:
        cand = os.path.join(os.path.dirname(path), "hlo")
        hlo_dir = cand if os.path.isdir(cand) else None
    rows = []
    recs = load_report(path)
    for rec in recs:
        if mesh and rec.get("mesh") != mesh:
            continue
        row = analyze_cell(rec, hlo_dir=hlo_dir)
        if row:
            rows.append(row)
    # persist corrected costs back into the report (cache for benchmarks)
    if any("corrected" in r for r in recs):
        with open(path, "w") as f:
            json.dump(recs, f, indent=1)
    return rows


def format_table(rows: list[RooflineRow]) -> str:
    hdr = (f"{'arch':<20} {'shape':<12} {'mesh':<6} "
           f"{'t_comp(ms)':>10} {'t_mem(ms)':>10} {'t_coll(ms)':>10} "
           f"{'dominant':>10} {'useful':>7} {'roofl%':>7}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:<20} {r.shape:<12} {r.mesh:<6} "
            f"{r.t_compute_s*1e3:>10.3f} {r.t_memory_s*1e3:>10.3f} "
            f"{r.t_collective_s*1e3:>10.3f} {r.dominant:>10} "
            f"{r.useful_ratio:>7.2f} {100*r.roofline_fraction:>6.1f}%")
    return "\n".join(lines)


def pick_hillclimb_cells(rows: list[RooflineRow]) -> dict[str, RooflineRow]:
    """The three §Perf targets: worst roofline fraction, most collective-
    bound, most representative of the paper's technique (largest
    gradient-sync collective share in training = the 'transceiver link')."""
    train = [r for r in rows if r.shape == "train_4k"]
    worst = min(rows, key=lambda r: r.roofline_fraction)
    coll = max(rows, key=lambda r: r.t_collective_s / (r.t_step_bound_s or 1))
    paper = max(train, key=lambda r: r.t_collective_s) if train else coll
    return {"worst_roofline": worst, "most_collective_bound": coll,
            "paper_representative": paper}


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", default="reports/dryrun_single_multi.json")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = analyze_report(args.report, args.mesh)
    print(format_table(rows))
    picks = pick_hillclimb_cells(rows)
    print("\nHillclimb candidates:")
    for k, r in picks.items():
        print(f"  {k}: {r.arch} x {r.shape} (dominant={r.dominant}, "
              f"roofline={100*r.roofline_fraction:.1f}%)")


if __name__ == "__main__":
    main()
