"""Trainer: the production loop — checkpoint/restart, simulated node-failure
recovery, deadline-based straggler mitigation, host-path power control, and
telemetry.

Fault-tolerance posture for 1000+ nodes (DESIGN.md §5): the *mechanisms*
(step-atomic checkpoints, elastic restore onto a different mesh, stateless
data pipeline keyed by step) are fully real and tested; node failures and
stragglers themselves are *injected* (this container is one host), driving
the same recovery code paths a real deployment would take.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.core.control_plane import RailController, as_controller
from repro.core.hwspec import FleetSpec
from repro.core.power_plane import PowerPlaneState
from repro.core.telemetry import TelemetryLog
from repro.core import ecollectives
from repro.core import sor as sor_mod
from repro.checkpoint.ckpt import CheckpointManager, remap_plane, remap_sor


class SimulatedNodeFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FaultConfig:
    fail_prob: float = 0.0           # per-step probability of a node loss
    straggler_prob: float = 0.0      # per-step probability of a slow node
    straggler_factor: float = 4.0    # slow node runs this much slower
    grace: float = 1.5               # deadline = grace * median step time
    seed: int = 0


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    async_ckpt: bool = True
    # Host-path (SW analogue) control plane: a RailController, or a bare
    # Policy (wrapped so its decision runs between steps, decide-only; pass a
    # HostRailController to also pay PMBus actuation — and decide_from="poll"
    # to close the loop on its own READ_VOUT sampling). The in-graph (HW
    # analogue) path is configured on the step (train.step.StepConfig.policy).
    controller: RailController | Any = None
    faults: FaultConfig = dataclasses.field(default_factory=FaultConfig)
    # Fleet provenance: checkpointed alongside the plane so elastic restarts
    # onto a different fleet size remap per-chip state explicitly.
    fleet: FleetSpec | None = None
    # In-graph learned safe operating regions: the SorConfig the train step
    # was built with (train.step.FleetStepConfig.sor). When set — and
    # init_state carries a "sor" entry — the trainer threads the functional
    # SorState through the (6-arg) step, checkpoints it next to the plane,
    # remaps it across fleet sizes on elastic restore, and folds the learned
    # per-rail view into summary()["sor"].
    sor: Any = None
    # Sharded control plane (train.step.FleetStepConfig.mesh/shard_control):
    # when set, restored per-chip state (plane + SorState) is re-placed onto
    # this mesh after restore/remap — `ckpt.save` gathers transparently to
    # host arrays, restore lands on the default device, and `remap_plane`/
    # `remap_sor` run on the gathered view, so `shard_fleet_state` scatters
    # the result back before the next sharded step. Checkpoint files and
    # remap semantics are identical to the unsharded trainer.
    mesh: Any = None
    shard_axis: str = "chips"

    def __post_init__(self):
        self.controller = as_controller(self.controller, host=True)


class Trainer:
    def __init__(self, train_step: Callable, data, cfg: TrainerConfig,
                 init_state: dict[str, Any]):
        """init_state: {'params','opt','plane','ef'} pytrees."""
        self.train_step = train_step
        self.data = data
        self.cfg = cfg
        self.state = dict(init_state)
        self.ckpt = CheckpointManager(cfg.ckpt_dir, async_save=cfg.async_ckpt)
        self.log = TelemetryLog()
        self.start_step = 0
        self.restarts = 0
        self.straggler_events = 0
        self.ckpt_writes = 0
        self._rng = np.random.default_rng(cfg.faults.seed)
        self._step_times: list[float] = []
        # fail fast on SOR misconfiguration — otherwise it only surfaces as
        # an opaque step-arity TypeError on the first training step (the
        # 6-arg SOR step and the "sor" state entry must come together), or
        # as a summary() error after the whole run (rails mismatch)
        ss = self.state.get("sor")
        if (cfg.sor is None) != (ss is None):
            raise ValueError(
                "TrainerConfig.sor and init_state['sor'] must be set "
                "together: the SOR train step (FleetStepConfig.sor) takes "
                "the 6-arg signature and threads the state the trainer "
                "carries — configure both or neither")
        if ss is not None and ss.history.rails != cfg.sor.rails:
            raise ValueError(
                f"TrainerConfig.sor declares rails "
                f"{[s.rail for s in cfg.sor.rails]} but init_state['sor'] "
                f"was built with {[s.rail for s in ss.history.rails]}; "
                f"pass the same SorConfig as FleetStepConfig.sor")

    # -- checkpoint/restart ----------------------------------------------------
    def maybe_restore(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        step, restored = self.ckpt.restore(self.state, optional=("sor",))
        self.state.update(restored)
        self._remap_restored_plane()
        self.start_step = step
        return True

    def _remap_restored_plane(self) -> None:
        """Elastic fleet restore: when this run's FleetSpec differs in size
        from the checkpoint's, remap the restored `[n_old]` plane onto the
        current fleet explicitly (surviving chips keep their per-chip state,
        joiners start at their own nominal point). A restored SorState is
        remapped the same way — survivors keep their learned regions,
        joiners start at the cold-start static pin."""
        if self.cfg.fleet is None:
            return
        n_target = self.cfg.fleet.n_chips
        plane = self.state["plane"]
        if not (plane.is_fleet and plane.n_chips == n_target):
            self.state["plane"] = remap_plane(plane, self.cfg.fleet)
        ss = self.state.get("sor")
        if ss is not None and ss.history.chip_shape \
                and ss.history.chip_shape[0] != n_target:
            self.state["sor"] = remap_sor(ss, self.cfg.fleet)
        if self.cfg.mesh is not None:
            # scatter the (gathered, remapped) per-chip state back onto the
            # chips mesh so the next sharded step starts shard-resident
            from repro.train.step import shard_fleet_state
            self.state = shard_fleet_state(self.state, self.cfg.mesh,
                                           self.cfg.shard_axis)

    def _save(self, step: int):
        self.ckpt.save(step, self.state, fleet=self.cfg.fleet)
        self.ckpt_writes += 1

    # -- fault injection ---------------------------------------------------------
    def _inject_faults(self, step: int, t_step: float) -> float:
        f = self.cfg.faults
        if f.fail_prob and self._rng.random() < f.fail_prob:
            raise SimulatedNodeFailure(f"node lost at step {step}")
        if f.straggler_prob and self._rng.random() < f.straggler_prob:
            # a straggling node would stretch the step by straggler_factor;
            # deadline-based mitigation caps the damage at grace * median.
            # Median excludes the first (compile) step and uses a recent
            # window so warmup outliers don't inflate the deadline.
            recent = self._step_times[1:][-20:]
            med = float(np.median(recent)) if recent else t_step
            slow = t_step * f.straggler_factor
            mitigated = min(slow, med * f.grace)
            self.straggler_events += 1
            return mitigated
        return t_step

    # -- the loop -----------------------------------------------------------------
    def run(self) -> TelemetryLog:
        cfg = self.cfg
        step = self.start_step
        while step < cfg.total_steps:
            try:
                step = self._run_span(step)
            except SimulatedNodeFailure:
                # recovery path: reload last complete checkpoint and resume —
                # the data pipeline is stateless in step, so no drift
                self.restarts += 1
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is not None:
                    s, restored = self.ckpt.restore(self.state,
                                                    optional=("sor",))
                    self.state.update(restored)
                    self._remap_restored_plane()
                    step = s
                # else: restart from the in-memory state (step unchanged)
        self.ckpt.wait()
        return self.log

    def _run_span(self, step: int) -> int:
        cfg = self.cfg
        while step < cfg.total_steps:
            batch = self.data.jax_batch(step)
            t0 = time.perf_counter()
            if "sor" in self.state:
                # in-graph SOR step: the functional SorState rides the
                # trainer state like any other carry (and checkpoints)
                params, opt, plane, ef, sor_state, metrics = self.train_step(
                    self.state["params"], self.state["opt"],
                    self.state["plane"], self.state["ef"],
                    self.state["sor"], batch)
            else:
                sor_state = None
                params, opt, plane, ef, metrics = self.train_step(
                    self.state["params"], self.state["opt"],
                    self.state["plane"], self.state["ef"], batch)
            jax.block_until_ready(metrics["loss"])
            wall = time.perf_counter() - t0
            wall = self._inject_faults(step, wall)
            self._step_times.append(wall)

            self.state.update(params=params, opt=opt, plane=plane, ef=ef)
            if sor_state is not None:
                self.state["sor"] = sor_state

            # host-path control (SW analogue): one control_step through the
            # unified rail control plane (decide + PMBus-actuate)
            if cfg.controller is not None:
                self.state["plane"] = cfg.controller.control_step(plane, metrics)
                metrics = self._with_sor_metrics(metrics)

            self.log.append_from(step, metrics["loss"], metrics,
                                 self.state["plane"])
            step += 1
            if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
                self._save(step)
        return step

    def _with_sor_metrics(self, metrics: dict[str, Any]) -> dict[str, Any]:
        """Fold the controller's learned safe-operating-region view into the
        step telemetry (`sor/...` scalar keys) so the TelemetryLog records
        how the fleet's learned envelope evolves over training."""
        summarize = getattr(self.cfg.controller, "sor_summary", None)
        s = summarize() if callable(summarize) else None
        if not s:
            return metrics
        return {**metrics,
                **{f"sor/{k}": float(v) for k, v in s.items()
                   if np.isfinite(v)}}

    # -- reporting -------------------------------------------------------------
    def summary(self) -> dict[str, Any]:
        t = self.log.totals()
        ctrl = (self.cfg.controller.stats() if self.cfg.controller is not None
                else None)
        out = {
            **t,
            "restarts": self.restarts,
            "straggler_events": self.straggler_events,
            "ckpt_writes": self.ckpt_writes,
            "host_actuations": ctrl.actuations if ctrl else 0,
            "host_actuation_s": ctrl.actuation_seconds if ctrl else 0.0,
            # writes the deadband scheduler held back from the bus (steady-
            # state lanes pinned at a learned floor) — saved transactions
            "host_skipped_actuations": ctrl.skipped_actuations if ctrl else 0,
            "mean_wall_step_s": float(np.mean(self._step_times))
            if self._step_times else 0.0,
        }
        if self.log.records:
            last = self.log.records[-1]
            out["n_chips"] = last.n_chips
            if last.fleet:   # fleet run: surface the gating worst-chip view
                out["fleet_last"] = dict(last.fleet)
        summarize = getattr(self.cfg.controller, "sor_summary", None)
        sor = summarize() if callable(summarize) else None
        if sor is None and self.cfg.sor is not None \
                and self.state.get("sor") is not None:
            # in-graph learner: summarize the state threaded through the step
            sor = sor_mod.summary(self.state["sor"].estimate, self.cfg.sor)
        if sor:              # learned safe-operating-region state, if any
            out["sor"] = sor
        return out


def initial_plane_and_ef(params, fleet: FleetSpec | None = None
                         ) -> tuple[PowerPlaneState, Any]:
    """Initial (plane, error-feedback residuals). With a `FleetSpec`, the
    plane is `[n_chips]` with every chip at its own process-varied nominal
    point (pair with train.step.make_fleet_train_step)."""
    plane = (PowerPlaneState.from_fleet(fleet) if fleet is not None
             else PowerPlaneState.nominal())
    return plane, ecollectives.zeros_like_residuals(params)
