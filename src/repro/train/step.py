"""Train-step factory: microbatch gradient accumulation, gradient sync
(XLA-auto or error-feedback-compressed — the paper-adapted bounded-error
link), AdamW update, and the power plane woven through the step.

Two control paths, mirroring the paper (DESIGN.md §2.2):
  * in-graph controller: observation (TelemetryFrame) → policy.decide →
    arbitrate composed INTO the jitted step (HW path analogue —
    deterministic, no host round trip);
  * host controller: the trainer runs a control_plane.HostRailController
    between steps, actuating through the PMBus-simulated fleet bus (SW
    analogue — optionally deciding from its own READ_VOUT polling,
    `decide_from="poll"`). Both paths implement control_plane.RailController.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import ecollectives
from repro.core.control_plane import as_controller
from repro.core.hwspec import FleetSpec
from repro.core.power_plane import (PowerPlaneState, StepProfile,
                                    account_and_observe,
                                    account_fleet_and_observe)
from repro.kernels import ops
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class StepConfig:
    microbatches: int = 1
    grad_sync: str = "auto"          # auto | ef_int8 | ef_int8_topk
    k_fraction: float = 0.25
    policy: Any = None               # in-graph policy/RailController or None
    dp_axes: tuple[str, ...] = ("data",)  # manual axes for ef sync


@dataclasses.dataclass(frozen=True)
class FleetStepConfig:
    """Fleet-native extension of StepConfig: one jitted step drives a
    `[n_chips]` power plane whose chips carry per-chip process variation
    (`FleetSpec`), with in-graph per-chip straggler/fault injection coupled
    to each chip's voltage margin. At `FleetSpec.uniform(1)` the fleet step
    is numerically equivalent to the scalar step as long as the
    margin-coupled error feedback is inactive — uncompressed grad sync or
    `error_gain=0` (pinned by tests/test_fleet_native.py). With ef_int8*
    sync AND a nonzero `error_gain`, the fleet step intentionally models
    margin-amplified measured error that the scalar step cannot, so the
    trajectories diverge once a policy undervolts VDD_IO."""
    spec: FleetSpec
    # per-chip measured-error telemetry: how fast a chip's gradient-domain
    # error grows as it digs below its own nominal VDD_IO, scaled by the
    # chip's BER-curve offset (FleetSpec.error_sensitivity)
    error_gain: float = 12.0
    link_ber_floor: float = 0.0      # intrinsic link error floor (no compression)
    telemetry_noise: float = 0.0     # relative noise on measured error
    # per-chip stragglers: base per-step probability, amplified by the chip's
    # VDD_CORE undervolt margin — weak chips at fleet setpoints straggle first
    straggler_prob: float = 0.0
    straggler_factor: float = 4.0
    straggler_margin_gain: float = 8.0
    # margin-coupled HBM interface error rate (the VDD_HBM failure
    # observable): base rate amplified by the chip's VDD_HBM undervolt
    # margin. Base 0.0 (default) records a zero observable — inert for
    # control, but honest telemetry.
    hbm_error_base: float = 0.0
    hbm_error_gain: float = 24.0
    # fleet reductions on a sharded `chips` mesh axis: when `mesh` spans
    # more than one device, the per-chip telemetry matrix never gathers —
    # each device reduces its local shard through the Pallas/XLA
    # fleet_reduce hot path and the partials combine via pmax/pmin/psum
    # (ops.sharded_fleet_reduce). On a single-device (CPU) mesh, or with
    # mesh=None, the step falls back to the plain vmap-path fleet_reduce —
    # identical results, no shard_map.
    mesh: Any = None
    shard_axis: str = "chips"
    # shard the learned control round itself (control_plane.
    # sharded_control_round): the SorState history ring, ingest, refit,
    # envelopes, and decide/arbitrate all run per shard inside shard_map —
    # only the fleet reductions and the confidence summary scalars cross
    # shards. None (default) auto-enables when `mesh` spans more than one
    # device; True forces the shard_map path even on a 1-device mesh (the
    # bit-equality testing knob, mirroring sharded_fleet_reduce's
    # use_shard_map); False keeps the control round unsharded. Requires
    # `sor` and an elementwise (not cross_chip) policy.
    shard_control: "bool | None" = None
    # in-graph safe-operating-region learning (core/sor.py): when set, the
    # step threads a functional `sor.SorState` through its signature —
    # train_step(params, opt, plane, ef, sor_state, batch) -> (..., sor_state',
    # metrics) — so per-rail frontiers are learned DURING training, not just
    # by the host controller, and the state checkpoints next to the plane
    # (ckpt.save / ckpt.remap_sor). Requires an in-graph policy
    # (StepConfig.policy) and ingest="frames".
    sor: Any = None
    seed: int = 0


def _accumulate_grads(loss_fn, params, batch, microbatches: int):
    """Returns (mean_loss, metrics, mean_grads)."""
    if microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, metrics, grads

    def reshape(a):
        b = a.shape[0]
        return a.reshape((microbatches, b // microbatches) + a.shape[1:])

    mbatch = jax.tree_util.tree_map(reshape, batch)

    def body(acc, mb):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb)
        acc_loss, acc_grads = acc
        acc_grads = jax.tree_util.tree_map(jnp.add, acc_grads, grads)
        return (acc_loss + loss, acc_grads), metrics

    zero_grads = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, grads_sum), metrics = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zero_grads), mbatch)
    inv = 1.0 / microbatches
    grads = jax.tree_util.tree_map(lambda g: g * inv, grads_sum)
    metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
    return loss_sum * inv, metrics, grads


def _grads_and_update(loss_fn, opt_cfg, schedule_fn, step_cfg,
                      params, opt_state, ef_resid, batch):
    """The model side of a train step, shared by the scalar and fleet step
    factories: microbatched grads, optional error-feedback compressed sync,
    AdamW update. Returns (params', opt_state', ef_resid', loss, metrics,
    opt_metrics, grad_error)."""
    loss, metrics, grads = _accumulate_grads(
        loss_fn, params, batch, step_cfg.microbatches)

    grad_error = jnp.zeros((), jnp.float32)
    if step_cfg.grad_sync.startswith("ef_int8"):
        # error-feedback compression BEFORE the cross-replica reduction
        level = (ecollectives.LEVEL_INT8_TOPK
                 if step_cfg.grad_sync == "ef_int8_topk"
                 else ecollectives.LEVEL_INT8)
        raw = grads
        grads, ef_resid = ecollectives.ef_compress(
            grads, ef_resid, level, step_cfg.k_fraction)
        grad_error = ecollectives.compression_error_norm(raw, grads)
        axis = step_cfg.dp_axes[0]
        grads = ecollectives.reduce_gradients(
            grads, axis, level=ecollectives.LEVEL_INT8
            if level >= ecollectives.LEVEL_INT8 else 0)
        loss = jax.lax.pmean(loss, axis)

    lr = schedule_fn(opt_state["step"])
    params, opt_state, opt_metrics = adamw.apply_updates(
        params, grads, opt_state, lr, opt_cfg)
    return params, opt_state, ef_resid, loss, metrics, opt_metrics, grad_error


def make_train_step(loss_fn: Callable, opt_cfg: adamw.AdamWConfig,
                    schedule_fn: Callable, profile: StepProfile,
                    step_cfg: StepConfig):
    """Returns train_step(params, opt_state, plane, ef_resid, batch) ->
    (params', opt_state', plane', ef_resid', metrics)."""
    # HW-path analogue: the in-graph controller is compiled INTO the step,
    # behind the same RailController interface the host path uses.
    controller = as_controller(step_cfg.policy)

    def train_step(params, opt_state, plane: PowerPlaneState, ef_resid, batch):
        (params, opt_state, ef_resid, loss, metrics, opt_metrics,
         grad_error) = _grads_and_update(loss_fn, opt_cfg, schedule_fn,
                                         step_cfg, params, opt_state,
                                         ef_resid, batch)

        # observation → decision → arbitration, all in-graph: the typed
        # EXACT frame is what the controller's policy sees
        plane, frame, power_metrics = account_and_observe(profile, plane)
        frame = dataclasses.replace(frame, grad_error=grad_error)
        if controller is not None:
            plane = controller.control_step(plane, frame)

        telemetry = {**power_metrics, "grad_error": grad_error}
        out_metrics = {"loss": loss, **metrics, **opt_metrics, **telemetry}
        return params, opt_state, plane, ef_resid, out_metrics

    return train_step


def make_fleet_train_step(loss_fn: Callable, opt_cfg: adamw.AdamWConfig,
                          schedule_fn: Callable, profile: StepProfile,
                          step_cfg: StepConfig, fleet_cfg: FleetStepConfig):
    """Fleet-native train step: same model/optimizer math as the scalar
    step, but the power plane is `[n_chips]` with per-chip process
    variation, per-chip margin-coupled fault/straggler injection, and fleet
    reductions (worst/mean/p95) computed in-graph through the Pallas
    `ops.fleet_reduce` hot path.

    The model itself is SPMD-replicated (every chip computes the same
    grads); what varies per chip is the *power/telemetry* world: measured
    gradient-domain error scales with the chip's BER-curve offset and its
    VDD_IO undervolt margin, stragglers fire preferentially on chips whose
    VDD_CORE margin is thinnest, and the HBM interface error rate grows with
    each chip's VDD_HBM margin. Per-step randomness derives from
    `fold_in(seed, plane.step)` so the trainer's call signature — and
    checkpoint/restart determinism — are unchanged.

    With `fleet_cfg.sor` set, the returned step instead has the signature
    train_step(params, opt_state, plane, ef_resid, sor_state, batch) ->
    (params', opt_state', plane', ef_resid', sor_state', metrics): the
    in-graph controller pushes every step's frame (per-rail voltages + the
    margin-coupled failure observables above) into the `sor.SorState`
    threaded through the carry, refreshes the per-rail frontier estimates on
    the configured cadence, and decides/arbitrates under the learned
    envelopes — learning happens DURING training, and the state persists
    through `ckpt.save` like any other group (the Trainer does this when its
    init_state carries a "sor" entry)."""
    controller = as_controller(step_cfg.policy)
    sor_cfg = fleet_cfg.sor
    if sor_cfg is not None:
        from repro.core.control_plane import with_sor
        if controller is None:
            raise ValueError("FleetStepConfig.sor needs an in-graph policy "
                             "(StepConfig.policy) to consume the learned "
                             "envelopes")
        controller = with_sor(controller, sor_cfg)

    # resolve the sharded-control-round knob once, at factory time: the mesh
    # is static, so the shard_map'd round is built here and closed over
    shard_control = fleet_cfg.shard_control
    if shard_control is None:
        shard_control = (fleet_cfg.mesh is not None
                         and fleet_cfg.mesh.devices.size > 1
                         and sor_cfg is not None)
    sharded_round = None
    if shard_control:
        from repro.core.control_plane import sharded_control_round
        if fleet_cfg.mesh is None:
            raise ValueError("FleetStepConfig.shard_control=True needs a mesh")
        if sor_cfg is None:
            raise ValueError("FleetStepConfig.shard_control shards the "
                             "learned (SOR) control round — set "
                             "FleetStepConfig.sor, or leave shard_control "
                             "off (the reduction still shards via mesh=)")
        sharded_round = sharded_control_round(
            controller, fleet_cfg.mesh, fleet_cfg.shard_axis)
    fs = fleet_cfg.spec
    n = fs.n_chips
    v_nom_core = jnp.asarray(fs.v_core_nominal, jnp.float32)
    v_nom_hbm = jnp.asarray(fs.v_hbm_nominal, jnp.float32)
    v_nom_io = jnp.asarray(fs.v_io_nominal, jnp.float32)
    sens = jnp.asarray(fs.error_sensitivity, jnp.float32)

    def _step_body(params, opt_state, plane: PowerPlaneState, ef_resid,
                   sor_state, batch):
        (params, opt_state, ef_resid, loss, metrics, opt_metrics,
         grad_error) = _grads_and_update(loss_fn, opt_cfg, schedule_fn,
                                         step_cfg, params, opt_state,
                                         ef_resid, batch)

        plane, frame, power_metrics = account_fleet_and_observe(
            profile, plane, fs)
        key = jax.random.fold_in(jax.random.PRNGKey(fleet_cfg.seed),
                                 plane.step[0])
        k_err, k_straggle = jax.random.split(key)

        # per-chip measured error: the shared compression error (plus any
        # intrinsic link floor) seen through each chip's own BER curve —
        # offset by process variation, amplified by ITS undervolt margin
        margin_io = jnp.maximum(0.0, v_nom_io - plane.v_io) / v_nom_io
        noise = 1.0 + fleet_cfg.telemetry_noise * jax.random.normal(
            k_err, (n,))
        err = ((grad_error + fleet_cfg.link_ber_floor) * sens * noise
               * (1.0 + fleet_cfg.error_gain * margin_io))

        # per-chip stragglers: thin VDD_CORE margin -> higher odds. The
        # margin-coupled *rate* is the VDD_CORE failure observable the SOR
        # learner fits (the realized 0/1 draw is far too noisy to regress).
        margin_core = jnp.maximum(0.0, v_nom_core - plane.v_core) / v_nom_core
        p_straggle = jnp.clip(
            fleet_cfg.straggler_prob
            * (1.0 + fleet_cfg.straggler_margin_gain * margin_core), 0.0, 1.0)
        straggle = jax.random.uniform(k_straggle, (n,)) < p_straggle
        t_chip = power_metrics["t_step_s"] * jnp.where(
            straggle, fleet_cfg.straggler_factor, 1.0)

        # per-chip HBM interface errors: thin VDD_HBM margin -> higher rate
        # (the VDD_HBM failure observable)
        margin_hbm = jnp.maximum(0.0, v_nom_hbm - plane.v_hbm) / v_nom_hbm
        hbm_rate = (jnp.float32(fleet_cfg.hbm_error_base) * sens
                    * (1.0 + fleet_cfg.hbm_error_gain * margin_hbm))

        # the frame is already anchored to the FleetSpec per-chip nominals;
        # overlay the per-chip measured error + straggler-stretched times +
        # the per-rail failure observables (telemetry.RAIL_OBSERVABLE_KEYS)
        frame = dataclasses.replace(
            frame, grad_error=err,
            extras={**frame.extras, "t_chip_s": t_chip,
                    "straggle_rate": p_straggle, "hbm_error_rate": hbm_rate})
        telemetry = {**power_metrics, "grad_error": err, "t_chip_s": t_chip,
                     "straggle_rate": p_straggle, "hbm_error_rate": hbm_rate,
                     "v_nom_core": v_nom_core, "v_nom_hbm": v_nom_hbm,
                     "v_nom_io": v_nom_io}
        sor_conf = None
        if sharded_round is not None:
            # per-shard resident control round: the frame slice lands in the
            # shard's own history ring, refit/envelopes/decide/arbitrate run
            # elementwise on-shard, and only the confidence summary scalars
            # cross shards (bit-equal trajectories — the RNG observables
            # above were drawn on global shapes, outside the shard_map)
            plane, sor_state, conf_sum, conf_min = sharded_round(
                plane, frame, sor_state)
            sor_conf = (conf_sum / sor_state.estimate.confidence.size,
                        conf_min)
        elif sor_cfg is not None:
            plane, sor_state = controller.control_step_sor(
                plane, frame, sor_state)
        elif controller is not None:
            plane = controller.control_step(plane, frame)

        # fleet reductions through the Pallas telemetry-reduction hot path:
        # [n_chips, n_fields] -> per-field worst/mean (+ p95 where it gates).
        # With a multi-device mesh the reduction runs sharded over the
        # chips axis (local kernel reduce + pmax/pmin/psum collectives).
        stacked = jnp.stack([power_metrics["power_w"], t_chip, err,
                             power_metrics["energy_step_j"], plane.v_io],
                            axis=1)
        if fleet_cfg.mesh is not None:
            mx, mn, sm = ops.sharded_fleet_reduce(
                stacked, mesh=fleet_cfg.mesh,
                axis_name=fleet_cfg.shard_axis,
                # a forced-on-1-device sharded control round forces the
                # reduction through shard_map too, so tests exercise the
                # whole sharded graph on any device count
                use_shard_map=True if shard_control else None)
        else:
            mx, mn, sm = ops.fleet_reduce(stacked)
        fleet_metrics = {}
        # for these, the worst chip is the max; for a voltage rail it is the
        # MIN (thinnest margin), so v_io gets min/mean instead
        for i, name in enumerate(("power_w", "t_chip_s", "grad_error",
                                  "energy_step_j")):
            fleet_metrics[f"fleet/{name}_worst"] = mx[i]
            fleet_metrics[f"fleet/{name}_mean"] = sm[i] / n
        fleet_metrics["fleet/v_io_min"] = mn[4]
        fleet_metrics["fleet/v_io_mean"] = sm[4] / n
        # a synchronous fleet steps at its slowest chip
        fleet_metrics["fleet/t_fleet_s"] = mx[1]
        # p95 tails through the kernels-layer seam (sort-bound — the [n]
        # stat vectors are the only cross-shard traffic on the sharded path)
        fleet_metrics["fleet/t_chip_p95_s"] = ops.fleet_percentile(
            t_chip, 95.0)
        fleet_metrics["fleet/grad_error_p95"] = ops.fleet_percentile(
            err, 95.0)
        fleet_metrics["fleet/straggler_frac"] = jnp.mean(
            straggle.astype(jnp.float32))

        if sor_conf is not None:
            # learned-region telemetry from the in-round collectives (one
            # psum + one pmin scalar — the SorState itself never gathers)
            fleet_metrics["fleet/sor_conf_mean"] = sor_conf[0]
            fleet_metrics["fleet/sor_conf_min"] = sor_conf[1]
        elif sor_cfg is not None:
            # learned-region telemetry: how much of the fleet trusts a fit
            fleet_metrics["fleet/sor_conf_mean"] = jnp.mean(
                sor_state.estimate.confidence)
            fleet_metrics["fleet/sor_conf_min"] = jnp.min(
                sor_state.estimate.confidence)

        # v_nom_* are static per-run FleetSpec constants — policy inputs,
        # not telemetry worth logging every step
        logged = {k: v for k, v in telemetry.items()
                  if not k.startswith("v_nom_")}
        out_metrics = {"loss": loss, **metrics, **opt_metrics, **logged,
                       **fleet_metrics}
        return params, opt_state, plane, ef_resid, sor_state, out_metrics

    if sor_cfg is not None:
        def train_step(params, opt_state, plane, ef_resid, sor_state, batch):
            return _step_body(params, opt_state, plane, ef_resid, sor_state,
                              batch)
    else:
        def train_step(params, opt_state, plane, ef_resid, batch):
            out = _step_body(params, opt_state, plane, ef_resid, None, batch)
            return out[:4] + (out[5],)

    return train_step


def jit_train_step(train_step, *, donate=True):
    """jit a train step with its carry buffers donated: params, opt state,
    plane, ef residual — and, for the 6-arg SOR step, the `SorState` too,
    so the O(capacity x rails x chips) history ring is updated in place
    instead of copied every step. Donated inputs are invalidated: callers
    must rebind to the returned state (the trainer's carry loop already
    does) and never reuse the objects they passed in."""
    if not donate:
        return jax.jit(train_step)
    try:
        import inspect
        n_args = len(inspect.signature(train_step).parameters)
    except (TypeError, ValueError):
        n_args = 5
    donate_argnums = (0, 1, 2, 3, 4) if n_args >= 6 else (0, 1, 2, 3)
    return jax.jit(train_step, donate_argnums=donate_argnums)


def shard_fleet_state(state: dict, mesh, axis_name: str = "chips") -> dict:
    """Place the per-chip groups of a trainer state dict (`plane`, `sor`)
    onto `mesh` with their trailing chip axis sharded over `axis_name`
    (ops.chip_specs layout: ring [capacity, n_rails, n] and estimate
    [n_rails, n] shard, scalars replicate). Model groups pass through
    untouched — the fleet step is SPMD-replicated over the model. Use after
    building (or restoring) the initial state, before the first sharded
    step; `ckpt.save` gathers transparently on the way back out."""
    out = dict(state)
    plane = state.get("plane")
    n_chips = None
    if plane is not None and jnp.ndim(plane.v_core) == 1:
        n_chips = plane.v_core.shape[0]
    for group in ("plane", "sor"):
        tree = state.get(group)
        if tree is None or n_chips is None:
            continue
        out[group] = ops.shard_chip_tree(tree, mesh, n_chips, axis_name)
    return out


def shard_map_ef_step(train_step, mesh, dp_axes=("data",)):
    """Wrap a train step for error-feedback compressed data parallelism:
    manual over the DP axes (so the int8 collective is ours), params/opt
    replicated, batch sharded. Used by the e2e examples and the ecollectives
    case-study benchmark (DESIGN.md §2.2)."""
    batch_spec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0])
    rep = P()

    def mapped(params, opt_state, plane, ef_resid, batch):
        return train_step(params, opt_state, plane, ef_resid, batch)

    in_specs = (rep, rep, rep, rep, batch_spec)
    out_specs = (rep, rep, rep, rep, rep)
    # version shim shared with the sharded fleet reduction (ops._shard_map)
    return ops._shard_map(mapped, mesh, in_specs, out_specs)
