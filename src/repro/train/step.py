"""Train-step factory: microbatch gradient accumulation, gradient sync
(XLA-auto or error-feedback-compressed — the paper-adapted bounded-error
link), AdamW update, and the power plane woven through the step.

Two control paths, mirroring the paper (DESIGN.md §2.2):
  * in-graph controller: policy.update_jax composed INTO the jitted step
    (HW path analogue — deterministic, no host round trip);
  * host controller: the trainer runs a control_plane.HostRailController
    between steps, actuating through the PMBus-simulated fleet bus (SW
    analogue). Both paths implement control_plane.RailController.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import ecollectives
from repro.core.control_plane import as_controller
from repro.core.power_plane import PowerPlaneState, StepProfile, account_step
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class StepConfig:
    microbatches: int = 1
    grad_sync: str = "auto"          # auto | ef_int8 | ef_int8_topk
    k_fraction: float = 0.25
    policy: Any = None               # in-graph policy/RailController or None
    dp_axes: tuple[str, ...] = ("data",)  # manual axes for ef sync


def _accumulate_grads(loss_fn, params, batch, microbatches: int):
    """Returns (mean_loss, metrics, mean_grads)."""
    if microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, metrics, grads

    def reshape(a):
        b = a.shape[0]
        return a.reshape((microbatches, b // microbatches) + a.shape[1:])

    mbatch = jax.tree_util.tree_map(reshape, batch)

    def body(acc, mb):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb)
        acc_loss, acc_grads = acc
        acc_grads = jax.tree_util.tree_map(jnp.add, acc_grads, grads)
        return (acc_loss + loss, acc_grads), metrics

    zero_grads = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, grads_sum), metrics = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zero_grads), mbatch)
    inv = 1.0 / microbatches
    grads = jax.tree_util.tree_map(lambda g: g * inv, grads_sum)
    metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
    return loss_sum * inv, metrics, grads


def make_train_step(loss_fn: Callable, opt_cfg: adamw.AdamWConfig,
                    schedule_fn: Callable, profile: StepProfile,
                    step_cfg: StepConfig):
    """Returns train_step(params, opt_state, plane, ef_resid, batch) ->
    (params', opt_state', plane', ef_resid', metrics)."""
    # HW-path analogue: the in-graph controller is compiled INTO the step,
    # behind the same RailController interface the host path uses.
    controller = as_controller(step_cfg.policy)

    def train_step(params, opt_state, plane: PowerPlaneState, ef_resid, batch):
        loss, metrics, grads = _accumulate_grads(
            loss_fn, params, batch, step_cfg.microbatches)

        grad_error = jnp.zeros((), jnp.float32)
        if step_cfg.grad_sync.startswith("ef_int8"):
            # error-feedback compression BEFORE the cross-replica reduction
            level = (ecollectives.LEVEL_INT8_TOPK
                     if step_cfg.grad_sync == "ef_int8_topk"
                     else ecollectives.LEVEL_INT8)
            raw = grads
            grads, ef_resid = ecollectives.ef_compress(
                grads, ef_resid, level, step_cfg.k_fraction)
            grad_error = ecollectives.compression_error_norm(raw, grads)
            axis = step_cfg.dp_axes[0]
            grads = ecollectives.reduce_gradients(
                grads, axis, level=ecollectives.LEVEL_INT8
                if level >= ecollectives.LEVEL_INT8 else 0)
            loss = jax.lax.pmean(loss, axis)

        lr = schedule_fn(opt_state["step"])
        params, opt_state, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, lr, opt_cfg)

        plane, power_metrics = account_step(profile, plane)
        telemetry = {**power_metrics, "grad_error": grad_error}
        if controller is not None:
            plane = controller.control_step(plane, telemetry)

        out_metrics = {"loss": loss, **metrics, **opt_metrics, **telemetry}
        return params, opt_state, plane, ef_resid, out_metrics

    return train_step


def jit_train_step(train_step, *, donate=True):
    return jax.jit(train_step,
                   donate_argnums=(0, 1, 2, 3) if donate else ())


def shard_map_ef_step(train_step, mesh, dp_axes=("data",)):
    """Wrap a train step for error-feedback compressed data parallelism:
    manual over the DP axes (so the int8 collective is ours), params/opt
    replicated, batch sharded. Used by the e2e examples and the ecollectives
    case-study benchmark (DESIGN.md §2.2)."""
    batch_spec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0])
    rep = P()

    def mapped(params, opt_state, plane, ef_resid, batch):
        return train_step(params, opt_state, plane, ef_resid, batch)

    in_specs = (rep, rep, rep, rep, batch_spec)
    out_specs = (rep, rep, rep, rep, rep)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(mapped, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(dp_axes),
                             check_vma=False)
    # jax < 0.5: shard_map lives in jax.experimental (check_rep, no axis_names)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(mapped, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)
