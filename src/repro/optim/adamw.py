"""AdamW built from scratch (no optax in this environment), with an optional
int8 block-quantized first/second-moment representation (8-bit-Adam-style)
that cuts optimizer HBM from 8 to ~2.1 bytes/param — what lets
grok-1-314b / mistral-large-123b train_4k fit 16 GB/chip at 256-way sharding
(DESIGN.md §5)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Q_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    state_dtype: str = "float32"      # float32 | int8


# -- int8 moment codec --------------------------------------------------------

def _q_encode(x):
    flat = jnp.ravel(x)
    pad = (-flat.size) % Q_BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(-1, Q_BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _q_decode(enc, shape):
    flat = (enc["q"].astype(jnp.float32) * enc["scale"]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


# -- init / update --------------------------------------------------------------

def init_state(params, cfg: AdamWConfig):
    def zero_moment(p):
        if cfg.state_dtype == "int8":
            return _q_encode(jnp.zeros_like(p, jnp.float32))
        return jnp.zeros_like(p, jnp.float32)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zero_moment, params),
        "v": jax.tree_util.tree_map(zero_moment, params),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def apply_updates(params, grads, state, lr, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))

    quant = cfg.state_dtype == "int8"

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        g = g.astype(jnp.float32) * clip
        m_f = _q_decode(m, p.shape) if quant else m
        v_f = _q_decode(v, p.shape) if quant else v
        m_f = b1 * m_f + (1 - b1) * g
        v_f = b2 * v_f + (1 - b2) * jnp.square(g)
        upd = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
        # decoupled weight decay (skip 1-D params: norms, biases, scalars)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        p_new = (p.astype(jnp.float32)
                 - lr * (upd + wd * p.astype(jnp.float32))).astype(p.dtype)
        new_p.append(p_new)
        new_m.append(_q_encode(m_f) if quant else m_f)
        new_v.append(_q_encode(v_f) if quant else v_f)

    return (tdef.unflatten(new_p),
            {"step": step, "m": tdef.unflatten(new_m),
             "v": tdef.unflatten(new_v)},
            {"grad_norm": gnorm, "lr": lr})
