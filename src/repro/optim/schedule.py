"""LR schedules. WSD (Warmup-Stable-Decay) is included because minicpm-2b is
trained with it (arXiv:2404.06395): linear warmup, long stable plateau, then
a short sharp decay — the schedule that makes continuous pretraining cheap."""

from __future__ import annotations

import jax.numpy as jnp


def wsd(step, *, peak_lr: float, warmup_steps: int, stable_steps: int,
        decay_steps: int, final_frac: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = peak_lr * s / jnp.maximum(warmup_steps, 1)
    stable = jnp.float32(peak_lr)
    d = (s - warmup_steps - stable_steps) / jnp.maximum(decay_steps, 1)
    decay = peak_lr * (final_frac ** jnp.clip(d, 0.0, 1.0))
    lr = jnp.where(s < warmup_steps, warm,
                   jnp.where(s < warmup_steps + stable_steps, stable, decay))
    return lr


def cosine(step, *, peak_lr: float, warmup_steps: int, total_steps: int,
           final_frac: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = peak_lr * s / jnp.maximum(warmup_steps, 1)
    t = jnp.clip((s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
                 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(s < warmup_steps, warm, peak_lr * cos)


SCHEDULES = {"wsd": wsd, "cosine": cosine}
