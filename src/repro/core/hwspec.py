"""Target hardware constants (TPU v5e) shared by the roofline analysis and
the energy model.

Roofline constants are the ones mandated for this reproduction:
197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s per ICI link.

Chip power constants are stated assumptions (vendor does not publish a rail
breakdown); they only set the *scale* of the energy numbers — all paper-
validation claims are expressed as ratios, which are insensitive to them.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12        # FLOP/s per chip
    hbm_bandwidth: float = 819e9           # bytes/s per chip
    ici_link_bandwidth: float = 50e9       # bytes/s per link (per direction)
    ici_links_per_chip: int = 4            # 2-D torus on a 16x16 pod
    hbm_bytes: float = 16e9                # 16 GB HBM per chip
    vmem_bytes: float = 128 * 2**20        # ~128 MiB VMEM

    # --- power model assumptions (documented in DESIGN.md) -----------------
    nominal_v_core: float = 0.90
    nominal_v_hbm: float = 1.10
    nominal_v_io: float = 0.95
    p_core_dynamic_w: float = 90.0   # at 100% MXU utilization, nominal V/f
    p_core_static_w: float = 25.0
    p_hbm_w: float = 30.0            # at 100% bandwidth utilization
    p_ici_w: float = 15.0            # at 100% link utilization (all links)
    p_other_w: float = 10.0          # fans/host share/uncore, not scalable

    def idle_power_w(self) -> float:
        return self.p_core_static_w + self.p_other_w


V5E = ChipSpec()


# ---------------------------------------------------------------------------
# Fleet-scale process variation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Per-chip process variation over an `n_chips` fleet.

    The VolTune case study shows each board has its own safe operating
    region, so fleet-level control must track per-chip margins, not fleet
    means. A `FleetSpec` is the vectorized `ChipSpec`: `[n_chips]` arrays of
    per-chip nominal rail voltages (a weak chip *needs* more voltage for the
    same frequency), leakage spread (static power multiplier), and a
    BER-curve offset (how fast the measured link/gradient error grows as the
    chip digs below its own nominal — the worst chip's curve is the one that
    gates a worst-chip-bounded fleet policy).

    Sampling is seeded and reproducible: the same (n_chips, seed, sigmas)
    always yields the same fleet, so fleet experiments are replayable and
    checkpoint/restart sees an identical fleet.
    """
    base: ChipSpec
    seed: int
    v_core_nominal: np.ndarray      # f32 [n_chips]
    v_hbm_nominal: np.ndarray       # f32 [n_chips]
    v_io_nominal: np.ndarray        # f32 [n_chips]
    leakage_scale: np.ndarray       # f32 [n_chips] — multiplies static power
    error_sensitivity: np.ndarray   # f32 [n_chips] — BER-curve offset (>=0)

    @property
    def n_chips(self) -> int:
        return int(self.v_core_nominal.shape[0])

    @staticmethod
    def sample(
        n_chips: int,
        seed: int = 0,
        spec: ChipSpec = V5E,
        *,
        sigma_v: float = 0.01,         # relative σ of per-chip nominal voltage
        sigma_leakage: float = 0.08,   # σ of log leakage multiplier
        error_spread: float = 1.2,     # worst chip ≈ (1 + spread)× the best
    ) -> "FleetSpec":
        """Draw a reproducible fleet. Voltage spread is truncated at ±3σ so
        every chip's nominal stays inside the platform rail envelope."""
        if n_chips < 1:
            raise ValueError(f"n_chips must be >= 1, got {n_chips}")
        rng = np.random.default_rng(seed)

        def nominal(v_nom: float) -> np.ndarray:
            z = np.clip(rng.standard_normal(n_chips), -3.0, 3.0)
            return (v_nom * (1.0 + sigma_v * z)).astype(np.float32)

        leak = np.exp(sigma_leakage * np.clip(
            rng.standard_normal(n_chips), -3.0, 3.0)).astype(np.float32)
        sens = (1.0 + error_spread * rng.uniform(size=n_chips)).astype(np.float32)
        return FleetSpec(
            base=spec, seed=seed,
            v_core_nominal=nominal(spec.nominal_v_core),
            v_hbm_nominal=nominal(spec.nominal_v_hbm),
            v_io_nominal=nominal(spec.nominal_v_io),
            leakage_scale=leak,
            error_sensitivity=sens,
        )

    @staticmethod
    def uniform(n_chips: int, spec: ChipSpec = V5E) -> "FleetSpec":
        """Zero-spread fleet: every chip exactly at the base spec. At
        n_chips=1 this makes the fleet code paths numerically equivalent to
        the scalar ones (pinned by tests)."""
        ones = np.ones((n_chips,), np.float32)
        return FleetSpec(
            base=spec, seed=0,
            v_core_nominal=ones * np.float32(spec.nominal_v_core),
            v_hbm_nominal=ones * np.float32(spec.nominal_v_hbm),
            v_io_nominal=ones * np.float32(spec.nominal_v_io),
            leakage_scale=ones.copy(),
            error_sensitivity=ones.copy(),
        )

    def chip(self, i: int) -> ChipSpec:
        """Scalar `ChipSpec` view of chip `i` (host-side consumers)."""
        return dataclasses.replace(
            self.base,
            nominal_v_core=float(self.v_core_nominal[i]),
            nominal_v_hbm=float(self.v_hbm_nominal[i]),
            nominal_v_io=float(self.v_io_nominal[i]),
            p_core_static_w=float(self.base.p_core_static_w
                                  * self.leakage_scale[i]),
        )

    def variation(self) -> dict[str, np.ndarray]:
        """The `[n_chips]` arrays consumed (via vmap) by the power-plane
        accounting — see power_plane.account_step's `variation` argument."""
        return {
            "v_core_nom": self.v_core_nominal,
            "v_hbm_nom": self.v_hbm_nominal,
            "v_io_nom": self.v_io_nominal,
            "leak_scale": self.leakage_scale,
        }


def core_frequency_scale(v_core: float, spec: ChipSpec = V5E) -> float:
    """Linear DVFS approximation: f ∝ v (clamped at 40% floor)."""
    return max(0.4, v_core / spec.nominal_v_core)


def chip_power_w(
    *,
    v_core: float,
    v_hbm: float,
    v_io: float,
    mxu_utilization: float,
    hbm_utilization: float,
    ici_utilization: float,
    spec: ChipSpec = V5E,
) -> float:
    """Rail-resolved chip power.

    Dynamic power ∝ v^2 * f with f ∝ v (=> v^3); static ∝ v^2 (leakage is
    super-linear in v; quadratic is the standard compact model). Utilizations
    come from the compiled-step roofline terms.
    """
    sv_core = v_core / spec.nominal_v_core
    sv_hbm = v_hbm / spec.nominal_v_hbm
    sv_io = v_io / spec.nominal_v_io
    p_core = (spec.p_core_dynamic_w * mxu_utilization * sv_core**3
              + spec.p_core_static_w * sv_core**2)
    p_hbm = spec.p_hbm_w * (0.3 + 0.7 * hbm_utilization) * sv_hbm**2
    p_ici = spec.p_ici_w * (0.15 + 0.85 * ici_utilization) * sv_io**2
    return p_core + p_hbm + p_ici + spec.p_other_w
