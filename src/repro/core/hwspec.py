"""Target hardware constants (TPU v5e) shared by the roofline analysis and
the energy model.

Roofline constants are the ones mandated for this reproduction:
197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s per ICI link.

Chip power constants are stated assumptions (vendor does not publish a rail
breakdown); they only set the *scale* of the energy numbers — all paper-
validation claims are expressed as ratios, which are insensitive to them.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12        # FLOP/s per chip
    hbm_bandwidth: float = 819e9           # bytes/s per chip
    ici_link_bandwidth: float = 50e9       # bytes/s per link (per direction)
    ici_links_per_chip: int = 4            # 2-D torus on a 16x16 pod
    hbm_bytes: float = 16e9                # 16 GB HBM per chip
    vmem_bytes: float = 128 * 2**20        # ~128 MiB VMEM

    # --- power model assumptions (documented in DESIGN.md) -----------------
    nominal_v_core: float = 0.90
    nominal_v_hbm: float = 1.10
    nominal_v_io: float = 0.95
    p_core_dynamic_w: float = 90.0   # at 100% MXU utilization, nominal V/f
    p_core_static_w: float = 25.0
    p_hbm_w: float = 30.0            # at 100% bandwidth utilization
    p_ici_w: float = 15.0            # at 100% link utilization (all links)
    p_other_w: float = 10.0          # fans/host share/uncore, not scalable

    def idle_power_w(self) -> float:
        return self.p_core_static_w + self.p_other_w


V5E = ChipSpec()


def core_frequency_scale(v_core: float, spec: ChipSpec = V5E) -> float:
    """Linear DVFS approximation: f ∝ v (clamped at 40% floor)."""
    return max(0.4, v_core / spec.nominal_v_core)


def chip_power_w(
    *,
    v_core: float,
    v_hbm: float,
    v_io: float,
    mxu_utilization: float,
    hbm_utilization: float,
    ici_utilization: float,
    spec: ChipSpec = V5E,
) -> float:
    """Rail-resolved chip power.

    Dynamic power ∝ v^2 * f with f ∝ v (=> v^3); static ∝ v^2 (leakage is
    super-linear in v; quadratic is the standard compact model). Utilizations
    come from the compiled-step roofline terms.
    """
    sv_core = v_core / spec.nominal_v_core
    sv_hbm = v_hbm / spec.nominal_v_hbm
    sv_io = v_io / spec.nominal_v_io
    p_core = (spec.p_core_dynamic_w * mxu_utilization * sv_core**3
              + spec.p_core_static_w * sv_core**2)
    p_hbm = spec.p_hbm_w * (0.3 + 0.7 * hbm_utilization) * sv_hbm**2
    p_ici = spec.p_ici_w * (0.15 + 0.85 * ici_utilization) * sv_io**2
    return p_core + p_hbm + p_ici + spec.p_other_w
