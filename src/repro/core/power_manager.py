"""The VolTune PowerManager subsystem (paper §III, §IV-D, Table III).

Accepts structured requests — (VolTune opcode, target lane, value) — and
converts them into PMBus command sequences per the three-step conversion path
of §IV-D:

  1. resolve lane -> (PMBus device address, PAGE) via the rail map,
  2. select the transaction primitive (Write Word for programming,
     Read Word for readback),
  3. pack the PMBus command byte + LINEAR16 payload into the request stream.

Two control paths are modelled, with per-(path, clock) controller overheads
calibrated so the telemetry measurement interval reproduces paper Table VI
exactly (HW: 0.2/0.6 ms, SW: 0.8/1.0 ms at 400/100 kHz), and so that a full
HW-path/400 kHz voltage-update sequence + regulator settling for a
1.0 V -> 0.5 V step completes end-to-end in 2.3 ms (paper Fig 7a).

Opcode map (paper Table III):
  0x0 CLEAR_STATUS         controller-internal reset, no PMBus transaction
  0x1 SET_UNDER_VOLTAGE    PAGE (on lane change) + VOUT_UV_WARN + VOUT_UV_FAULT
  0x2 SET_POWER_GOOD_ON    POWER_GOOD_ON
  0x3 SET_POWER_GOOD_OFF   POWER_GOOD_OFF
  0x4 SET_VOLTAGE          VOUT_COMMAND
  0x5 GET_VOLTAGE          READ_VOUT
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable

import numpy as np

from repro.core import codecs
from repro.core.pmbus import (
    Cmd, Completion, PmBus, Primitive, SimClock, Transaction, build_board,
    transaction_seconds,
)
from repro.core.rails import KC705_RAIL_MAP, RailMap


class Opcode(enum.IntEnum):
    CLEAR_STATUS = 0x0
    SET_UNDER_VOLTAGE = 0x1
    SET_POWER_GOOD_ON = 0x2
    SET_POWER_GOOD_OFF = 0x3
    SET_VOLTAGE = 0x4
    GET_VOLTAGE = 0x5


class ControlPath(str, enum.Enum):
    HARDWARE = "hw"   # RTL FSM: deterministic, low-latency (paper §III-B)
    SOFTWARE = "sw"   # MicroBlaze: flexible, higher per-transaction cost (§III-C)


# Controller-side time added around each PMBus wire transaction, calibrated to
# paper Table VI / Fig 7 (see module docstring). "write gap" models FSM /
# driver sequencing between write transactions; "read overhead" additionally
# covers ADC sample scheduling + result handling for telemetry reads.
_WRITE_GAP_S: dict[tuple[ControlPath, int], float] = {
    (ControlPath.HARDWARE, 400_000): 10e-6,
    (ControlPath.HARDWARE, 100_000): 15e-6,
    (ControlPath.SOFTWARE, 400_000): 310e-6,
    (ControlPath.SOFTWARE, 100_000): 330e-6,
}
_READ_OVERHEAD_S: dict[tuple[ControlPath, int], float] = {
    (ControlPath.HARDWARE, 400_000): 80e-6,
    (ControlPath.HARDWARE, 100_000): 120e-6,
    (ControlPath.SOFTWARE, 400_000): 680e-6,
    (ControlPath.SOFTWARE, 100_000): 520e-6,
}


@dataclasses.dataclass
class RequestResult:
    ok: bool
    opcode: Opcode
    lane: int
    value: float | None = None
    completions: tuple[Completion, ...] = ()
    t_issue: float = 0.0
    t_done: float = 0.0
    error: str | None = None

    @property
    def elapsed_s(self) -> float:
        return self.t_done - self.t_issue


@dataclasses.dataclass
class Thresholds:
    """Protection/monitoring limits programmed before VOUT_COMMAND in the
    prototype measurement workflow (paper §IV-E, Fig 5). Expressed as factors
    of the requested setpoint."""
    uv_warn: float = 0.90
    uv_fault: float = 0.85
    pg_on: float = 0.92
    pg_off: float = 0.88


class PowerManager:
    """FPGA-resident voltage-control subsystem (hardware or software path)."""

    def __init__(
        self,
        rail_map: RailMap = KC705_RAIL_MAP,
        *,
        path: ControlPath | str = ControlPath.HARDWARE,
        clock_hz: int = 400_000,
        loads: dict[str, Callable[[float, float], float]] | None = None,
        clock: SimClock | None = None,
        seed: int = 0,
    ):
        self.rail_map = rail_map
        self.path = ControlPath(path)
        self.clock_hz = clock_hz
        self.clock, self.bus, self.channels = build_board(
            rail_map, clock=clock, clock_hz=clock_hz, loads=loads, seed=seed)
        # PAGE cache per device address: PAGE is written only when the target
        # lane changes (paper §IV-C).
        self._page_cache: dict[int, int] = {}
        self.request_log: list[RequestResult] = []
        self.status_fault = False

    # -- controller timing ---------------------------------------------------
    def _write_gap(self) -> float:
        return _WRITE_GAP_S[(self.path, self.clock_hz)]

    def _read_overhead(self) -> float:
        return _READ_OVERHEAD_S[(self.path, self.clock_hz)]

    def measurement_interval_s(self) -> float:
        """Telemetry sampling interval for this (path, clock) configuration —
        reproduces paper Table VI."""
        return transaction_seconds(Primitive.READ_WORD, self.clock_hz) + self._read_overhead()

    # -- PMBus issue helpers ---------------------------------------------------
    def _issue(self, txn: Transaction, *, is_read: bool) -> Completion:
        comp = self.bus.execute(txn)
        self.clock.advance(self._read_overhead() if is_read else self._write_gap())
        return comp

    def _page_txn_if_needed(self, lane: int) -> list[Completion]:
        rail = self.rail_map.by_lane(lane)
        comps: list[Completion] = []
        if self._page_cache.get(rail.pmbus_address) != rail.page:
            comps.append(self._issue(Transaction(
                Primitive.WRITE_BYTE, rail.pmbus_address, Cmd.PAGE, (rail.page,)),
                is_read=False))
            if comps[-1].ok:
                self._page_cache[rail.pmbus_address] = rail.page
        return comps

    def _write_word(self, lane: int, cmd: Cmd, volts: float) -> Completion:
        rail = self.rail_map.by_lane(lane)
        payload = codecs.word_to_bytes_le(codecs.linear16_encode(volts))
        return self._issue(Transaction(Primitive.WRITE_WORD, rail.pmbus_address, cmd, payload),
                           is_read=False)

    def _read_word(self, lane: int, cmd: Cmd) -> Completion:
        rail = self.rail_map.by_lane(lane)
        return self._issue(Transaction(Primitive.READ_WORD, rail.pmbus_address, cmd),
                           is_read=True)

    # Opcodes whose conversion path consumes `value` (Table III); a missing
    # value must come back as a structured error, not a TypeError mid-sequence.
    _VALUE_REQUIRED = frozenset({
        Opcode.SET_UNDER_VOLTAGE, Opcode.SET_POWER_GOOD_ON,
        Opcode.SET_POWER_GOOD_OFF, Opcode.SET_VOLTAGE,
    })

    # -- the opcode interface (Table III) -------------------------------------
    def execute(self, opcode: Opcode | int, lane: int = 0,
                value: float | None = None) -> RequestResult:
        opcode = Opcode(opcode)
        t0 = self.clock.now
        comps: list[Completion] = []
        out_value: float | None = None
        err: str | None = None

        if opcode in self._VALUE_REQUIRED and value is None:
            self.status_fault = True
            res = RequestResult(False, opcode, lane, None, (), t0, t0,
                                f"opcode {opcode.name} requires a value")
            self.request_log.append(res)
            return res

        if opcode == Opcode.CLEAR_STATUS:
            # Controller-internal reset only — no PMBus transaction (Table III).
            self.status_fault = False
        elif opcode == Opcode.SET_UNDER_VOLTAGE:
            # Table III: one opcode expands to both UV limit registers
            # (warn at the requested threshold, fault slightly below it).
            comps += self._page_txn_if_needed(lane)
            comps.append(self._write_word(lane, Cmd.VOUT_UV_WARN_LIMIT, value))
            comps.append(self._write_word(lane, Cmd.VOUT_UV_FAULT_LIMIT, value * 0.95))
        elif opcode == Opcode.SET_POWER_GOOD_ON:
            comps += self._page_txn_if_needed(lane)
            comps.append(self._write_word(lane, Cmd.POWER_GOOD_ON, value))
        elif opcode == Opcode.SET_POWER_GOOD_OFF:
            comps += self._page_txn_if_needed(lane)
            comps.append(self._write_word(lane, Cmd.POWER_GOOD_OFF, value))
        elif opcode == Opcode.SET_VOLTAGE:
            comps += self._page_txn_if_needed(lane)
            comps.append(self._write_word(lane, Cmd.VOUT_COMMAND, value))
        elif opcode == Opcode.GET_VOLTAGE:
            comps += self._page_txn_if_needed(lane)
            comp = self._read_word(lane, Cmd.READ_VOUT)
            comps.append(comp)
            if comp.ok:
                out_value = codecs.linear16_decode(codecs.bytes_le_to_word(*comp.data))
        else:  # pragma: no cover
            err = f"unknown opcode {opcode}"

        ok = err is None and all(c.ok for c in comps)
        if not ok:
            self.status_fault = True
            err = err or "; ".join(c.error for c in comps if c.error)
        res = RequestResult(ok, opcode, lane, out_value, tuple(comps),
                            t0, self.clock.now, err)
        self.request_log.append(res)
        return res

    # -- composite workflows ---------------------------------------------------
    def set_voltage(self, lane: int, volts: float,
                    thresholds: Thresholds | None = None) -> RequestResult:
        """The full prototype voltage-update workflow (paper Fig 5 / §IV-E):
        threshold-register configuration, then the VOUT_COMMAND setpoint.
        Expands to PAGE + 4 Write Words + VOUT_COMMAND = 6 PMBus transactions
        when the lane changed, 5 otherwise."""
        rail = self.rail_map.by_lane(lane)
        # Mechanism-level envelope check; policy owns the smart limits. The
        # epsilon admits float32-rounded policy outputs sitting exactly on the
        # envelope edge (e.g. f32(0.65) < 0.65), which are then clamped in.
        eps = 1e-6
        if not (rail.v_min - eps <= volts <= rail.v_max + eps):
            return RequestResult(False, Opcode.SET_VOLTAGE, lane, volts,
                                 t_issue=self.clock.now, t_done=self.clock.now,
                                 error=f"{volts} V outside [{rail.v_min}, {rail.v_max}] "
                                       f"for {rail.name}")
        volts = min(max(volts, rail.v_min), rail.v_max)
        th = thresholds or Thresholds()
        t0 = self.clock.now
        r1 = self.execute(Opcode.SET_UNDER_VOLTAGE, lane, volts * th.uv_warn)
        r2 = self.execute(Opcode.SET_POWER_GOOD_ON, lane, volts * th.pg_on)
        r3 = self.execute(Opcode.SET_POWER_GOOD_OFF, lane, volts * th.pg_off)
        r4 = self.execute(Opcode.SET_VOLTAGE, lane, volts)
        ok = all(r.ok for r in (r1, r2, r3, r4))
        comps = r1.completions + r2.completions + r3.completions + r4.completions
        res = RequestResult(ok, Opcode.SET_VOLTAGE, lane, volts, comps,
                            t0, self.clock.now,
                            None if ok else "sequence failure")
        return res

    def get_voltage(self, lane: int) -> float:
        res = self.execute(Opcode.GET_VOLTAGE, lane)
        if not res.ok:
            raise RuntimeError(f"GET_VOLTAGE failed: {res.error}")
        return res.value

    def rail_voltage_now(self, lane: int) -> float:
        """Instantaneous true rail voltage (oscilloscope view, paper §V-E) —
        bypasses PMBus sampling; for validation only."""
        return self.channels[lane].voltage_at(self.clock.now)

    def sample_trace(self, lane: int, duration_s: float) -> tuple[np.ndarray, np.ndarray]:
        """Periodic READ_VOUT sampling for `duration_s` of simulated time.
        The achievable sample interval is set by the control path and PMBus
        clock (paper Table VI); returns (times_s, volts)."""
        t_stop = self.clock.now + duration_s
        ts, vs = [], []
        while self.clock.now < t_stop:
            res = self.execute(Opcode.GET_VOLTAGE, lane)
            if res.ok:
                ts.append(res.t_done)
                vs.append(res.value)
        return np.asarray(ts), np.asarray(vs)

    def measure_transition(self, lane: int, target_v: float,
                           duration_s: float = 6e-3) -> "TransitionTrace":
        """Issue a full voltage-update workflow, then sample the rail until
        `duration_s` after the request (the paper Fig 7 experiment). t=0 is
        the request issue time at the PowerManager interface."""
        t0 = self.clock.now
        v_from = self.rail_voltage_now(lane)
        res = self.set_voltage(lane, target_v)
        if not res.ok:
            raise RuntimeError(f"set_voltage failed: {res.error}")
        remaining_s = duration_s - (self.clock.now - t0)
        if remaining_s <= 0.0:
            # Slow configurations (SW path / 100 kHz) can spend the whole
            # window on the command sequence itself; an empty trace yields a
            # NaN latency rather than a silently-bogus settling estimate.
            remaining_s = 0.0
        ts, vs = self.sample_trace(lane, remaining_s)
        return TransitionTrace(lane=lane, v_from=v_from, v_target=target_v,
                               t_request=t0, times=ts - t0, volts=vs,
                               command_time_s=res.elapsed_s)

    # -- bookkeeping -----------------------------------------------------------
    def stats(self) -> dict[str, float]:
        return {
            "transactions": self.bus.transaction_count,
            "bus_busy_s": self.bus.busy_seconds,
            "sim_time_s": self.clock.now,
            "requests": len(self.request_log),
        }


@dataclasses.dataclass
class TransitionTrace:
    """A sampled voltage transition, times relative to request issue."""
    lane: int
    v_from: float
    v_target: float
    t_request: float
    times: np.ndarray
    volts: np.ndarray
    command_time_s: float

    def end_to_end_latency_s(self, *, n: int = 8, band_pct: float = 1.0) -> float:
        """Paper §V-A metric: elapsed time from issuing the voltage-update
        request at the PowerManager interface until the measured rail voltage
        reaches and remains within the stable band — i.e. the §V-D settling
        index measured on the sampled trace, offset by the first-sample time
        (samples only begin once the command sequence left the bus)."""
        from repro.core.settling import settling_time
        if self.times.size == 0:
            # command sequence consumed the whole measurement window
            return float("nan")
        res = settling_time(self.times, self.volts, n=n, band_pct=band_pct)
        if not res.settled:
            return float("nan")
        return float(self.times[res.t_s_index])
