"""Controller overhead accounting (paper §V-F, Tables VII-IX).

Two halves:

1. The paper's measured Vivado numbers for the KC705 prototype, kept as
   structured reference data. The benchmarks regenerate the paper's headline
   ratios from these (SW/HW BRAM 31.96x, static power 5.60x, HW total ~2% of
   the subsystem static budget) and the tests pin them.

2. The analogous accounting for *this* system's controller: the in-graph
   (HW-path analogue) controller adds FLOPs/bytes to the compiled step and
   the host (SW-path analogue) controller adds host milliseconds between
   steps. `controller_budget_fraction` asserts the paper's design goal —
   the control plane must stay a <2% add-on to the application budget.
"""

from __future__ import annotations

import dataclasses

# Device totals on the KC705 (XC7K325T), from the Table VII/VIII headers.
KC705_TOTALS = {
    "slice_luts": 203_800,
    "slice_regs": 407_600,
    "slices": 50_950,
    "lut_logic": 203_800,
    "lut_mem": 64_000,
    "bram_tiles": 445,
    "dsps": 840,
}

# Table VII: hardware-based implementation (percent of device totals).
HW_UTILIZATION_PCT = {
    "counter": {"slice_luts": 0.01, "slice_regs": 0.02, "slices": 0.03,
                "lut_logic": 0.01, "lut_mem": 0.00, "bram_tiles": 0.00, "dsps": 0.00},
    "power_manager": {"slice_luts": 0.31, "slice_regs": 0.46, "slices": 1.19,
                      "lut_logic": 0.31, "lut_mem": 0.02, "bram_tiles": 0.00, "dsps": 0.24},
    "pmbus": {"slice_luts": 0.12, "slice_regs": 0.03, "slices": 0.15,
              "lut_logic": 0.12, "lut_mem": 0.00, "bram_tiles": 0.00, "dsps": 0.00},
    "total": {"slice_luts": 1.45, "slice_regs": 1.30, "slices": 3.48,
              "lut_logic": 1.22, "lut_mem": 0.72, "bram_tiles": 1.80, "dsps": 0.24},
}

# Table VIII: software-based implementation (percent of device totals).
SW_UTILIZATION_PCT = {
    "axi_gpio": {"slice_luts": 0.03, "slice_regs": 0.02, "slices": 0.05, "bram_tiles": 0.00, "dsps": 0.00},
    "axi_timer": {"slice_luts": 0.10, "slice_regs": 0.04, "slices": 0.16, "bram_tiles": 0.00, "dsps": 0.00},
    "axi_uartlite": {"slice_luts": 0.05, "slice_regs": 0.03, "slices": 0.09, "bram_tiles": 0.00, "dsps": 0.00},
    "axis_dwidth_converter": {"slice_luts": 0.01, "slice_regs": 0.06, "slices": 0.11, "bram_tiles": 0.00, "dsps": 0.00},
    "mdm_1": {"slice_luts": 0.05, "slice_regs": 0.03, "slices": 0.08, "bram_tiles": 0.00, "dsps": 0.00},
    "microblaze": {"slice_luts": 0.76, "slice_regs": 0.31, "slices": 1.12, "bram_tiles": 0.00, "dsps": 0.36},
    "microblaze_local_memory": {"slice_luts": 0.36, "slice_regs": 0.32, "slices": 0.98, "bram_tiles": 57.53, "dsps": 0.00},
    "pmbus_io": {"slice_luts": 0.00, "slice_regs": 0.00, "slices": 0.00, "bram_tiles": 0.00, "dsps": 0.00},
    "smartconnect": {"slice_luts": 0.19, "slice_regs": 0.09, "slices": 0.36, "bram_tiles": 0.00, "dsps": 0.00},
    "util_vector_logic": {"slice_luts": 0.01, "slice_regs": 0.00, "slices": 0.01, "bram_tiles": 0.00, "dsps": 0.00},
    "total": {"slice_luts": 1.53, "slice_regs": 0.90, "slices": 2.81,
              "lut_logic": 1.34, "lut_mem": 0.62, "bram_tiles": 57.52, "dsps": 0.36},
}

# Table IX: static power breakdown (watts).
HW_STATIC_POWER_W = {"power_manager": 0.011, "pmbus": 0.003, "counter": 0.001}
SW_STATIC_POWER_W = {
    "microblaze": 0.052, "microblaze_local_memory": 0.023, "smartconnect": 0.003,
    "axi_timer": 0.002, "axis_dwidth_converter": 0.001, "axi_uartlite": 0.001,
    "mdm_1": 0.001, "axi_gpio": 0.001,
}

HW_STATIC_TOTAL_W = round(sum(HW_STATIC_POWER_W.values()), 4)   # 0.015 W (2% share)
SW_STATIC_TOTAL_W = round(sum(SW_STATIC_POWER_W.values()), 4)   # 0.084 W (9% share)
HW_STATIC_SHARE = 0.02
SW_STATIC_SHARE = 0.09


def static_power_ratio() -> float:
    """Paper §V-F: SW path increases static power 5.60x."""
    return SW_STATIC_TOTAL_W / HW_STATIC_TOTAL_W


def bram_ratio() -> float:
    """Paper §V-F: SW path trades a 31.96x BRAM increase for programmability."""
    return SW_UTILIZATION_PCT["total"]["bram_tiles"] / HW_UTILIZATION_PCT["total"]["bram_tiles"]


# ---------------------------------------------------------------------------
# This system's controller overhead (the TPU-adaptation analogue)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ControllerOverheadReport:
    """Overhead of the power-control plane relative to the training step —
    the analogue of 'percent of the KC705 device' for our deployment."""
    path: str                      # 'in_graph' (HW analogue) | 'host' (SW analogue)
    controller_flops_per_step: float
    model_flops_per_step: float
    controller_bytes_per_step: float
    model_bytes_per_step: float
    host_seconds_per_step: float
    step_seconds: float

    @property
    def flops_fraction(self) -> float:
        return self.controller_flops_per_step / max(self.model_flops_per_step, 1.0)

    @property
    def bytes_fraction(self) -> float:
        return self.controller_bytes_per_step / max(self.model_bytes_per_step, 1.0)

    @property
    def time_fraction(self) -> float:
        return self.host_seconds_per_step / max(self.step_seconds, 1e-12)

    def within_budget(self, budget: float = 0.02) -> bool:
        """The paper's integration-cost goal: control plane <2% of budget."""
        return (self.flops_fraction <= budget and self.bytes_fraction <= budget
                and self.time_fraction <= budget)
