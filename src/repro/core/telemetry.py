"""Telemetry plumbing: the typed per-step observation (`TelemetryFrame`),
per-step records (the READ_VOUT/READ_IOUT analogue of the training system)
and a host-side ring log used by host controllers, benchmarks and the
trainer.

Decision-as-data control API, stage 1 — observation (docs/control_api.md):
a `TelemetryFrame` is what a policy is allowed to see. Every field is either
a scalar (one chip / SPMD-replicated) or a `[n_chips]` array (per-chip fleet
state), and the frame says where its rail voltages came from:

  * `Provenance.EXACT`  — in-graph accounting values (the oracle state the
    HW-path analogue acts on), `age_s == 0`;
  * `Provenance.POLLED` — PMBus READ_VOUT samples off the fleet bus, with
    `age_s` carrying how stale each chip's sample is in fleet-clock seconds
    (the SW path closes its loop on *these*, sampling delay included).

Frames are built by `power_plane.account_and_observe[_fleet]` (EXACT), by
`fleet.FleetPowerManager.poll_frame` (POLLED), and by the back-compat
`TelemetryFrame.from_dict` shim over the historical string-keyed metrics
dict.

Scalar→fleet convention (docs/fleet.md): every metric is either a scalar
(one chip / SPMD-replicated) or a `[n_chips]` array (per-chip fleet state).
`append_from` accepts both: scalars record as before; `[n_chips]` arrays
record the full per-chip vector in `StepRecord.per_chip` plus fleet
reductions (worst/best/mean/p95) in `StepRecord.fleet`, with the legacy
scalar field holding the fleet mean so downstream consumers (`totals`,
benchmark report code) keep working unchanged. Keys prefixed `fleet/` are
in-graph reductions computed by the fleet train step through the Pallas
`ops.fleet_reduce` hot path and land in `StepRecord.fleet` verbatim.
"""

from __future__ import annotations

import collections
import dataclasses
import enum
import json
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


class Provenance(enum.Enum):
    """Where a frame's rail-voltage observations came from."""
    EXACT = "exact"      # in-graph accounting state (oracle, age 0)
    POLLED = "polled"    # PMBus READ_VOUT samples (quantized + aged)


# metrics dict keys with first-class TelemetryFrame fields
_FRAME_METRIC_KEYS = ("grad_error", "t_step_s", "t_comp_s", "t_mem_s",
                      "t_coll_s", "power_w", "energy_step_j")
_FRAME_RAIL_KEYS = ("v_core", "v_hbm", "v_io")
_FRAME_NOM_KEYS = ("v_nom_core", "v_nom_hbm", "v_nom_io")


def _zf32():
    return jnp.float32(0.0)


@partial(jax.tree_util.register_dataclass,
         data_fields=["grad_error", "t_step_s", "t_comp_s", "t_mem_s",
                      "t_coll_s", "power_w", "energy_step_j",
                      "v_core", "v_hbm", "v_io",
                      "v_nom_core", "v_nom_hbm", "v_nom_io",
                      "age_s", "extras"],
         meta_fields=["provenance"])
@dataclasses.dataclass(frozen=True)
class TelemetryFrame:
    """One typed observation of a chip (or `[n_chips]` fleet): what a policy
    decides from. Frozen pytree — jit/vmap/scan-safe.

    Voltage observations (`v_core`/`v_hbm`/`v_io`) may be None when the
    builder had no view of the rails (pure-metrics legacy dicts); policies
    fall back to the plane state then. Nominal anchors (`v_nom_*`) are the
    per-chip process-varied nominal voltages from `hwspec.FleetSpec`, or
    None on the scalar path (policies fall back to their spec scalar).
    `age_s` is how stale the voltage observations are — 0 for EXACT frames,
    fleet-clock seconds since each chip's READ_VOUT sample for POLLED ones.
    """
    # step measurements (what the old metrics dict carried)
    grad_error: Any = dataclasses.field(default_factory=_zf32)
    t_step_s: Any = dataclasses.field(default_factory=_zf32)
    t_comp_s: Any = dataclasses.field(default_factory=_zf32)
    t_mem_s: Any = dataclasses.field(default_factory=_zf32)
    t_coll_s: Any = dataclasses.field(default_factory=_zf32)
    power_w: Any = dataclasses.field(default_factory=_zf32)
    energy_step_j: Any = dataclasses.field(default_factory=_zf32)
    # rail-voltage observations + provenance metadata
    v_core: Any = None
    v_hbm: Any = None
    v_io: Any = None
    v_nom_core: Any = None
    v_nom_hbm: Any = None
    v_nom_io: Any = None
    age_s: Any = dataclasses.field(default_factory=_zf32)
    extras: dict[str, Any] = dataclasses.field(default_factory=dict)
    provenance: Provenance = Provenance.EXACT

    # -- constructors ---------------------------------------------------------
    @staticmethod
    def from_dict(telemetry: dict[str, Any], *, state=None,
                  age_s: Any = None,
                  provenance: Provenance = Provenance.EXACT
                  ) -> "TelemetryFrame":
        """Back-compat shim: build a frame from the historical string-keyed
        metrics dict. Known keys land in typed fields, everything else in
        `extras`; rail-voltage observations come from `state` (the plane the
        caller is controlling) so legacy dict-driven trajectories are
        bit-identical to the old state-reading policies."""
        if provenance is Provenance.POLLED and age_s is None:
            # a POLLED observation with a silently zero-filled age would
            # masquerade as fresh to every age-aware consumer (StalenessGuard,
            # SOR ingestion); demand an explicit staleness — math.nan is the
            # honest sentinel when the caller genuinely does not know
            raise ValueError(
                "POLLED frames must carry age_s (fleet-clock staleness of "
                "the READ_VOUT samples); pass age_s=math.nan if unknown "
                "rather than letting a stale sample masquerade as fresh")
        t = dict(telemetry)
        kw: dict[str, Any] = {}
        for k in _FRAME_METRIC_KEYS:
            v = t.pop(k, None)
            if v is not None:
                kw[k] = v
        for k in _FRAME_NOM_KEYS:
            v = t.pop(k, None)
            if v is not None:
                kw[k] = jnp.asarray(v, jnp.float32)
        for k in _FRAME_RAIL_KEYS:
            v = t.pop(k, None)
            if v is not None:
                kw[k] = jnp.asarray(v, jnp.float32)
            elif state is not None:
                kw[k] = getattr(state, k)
        if age_s is not None:
            kw["age_s"] = age_s
        return TelemetryFrame(extras=t, provenance=provenance, **kw)

    @staticmethod
    def from_account(state, metrics: dict[str, Any], *,
                     nominals: dict[str, Any] | None = None
                     ) -> "TelemetryFrame":
        """EXACT frame from an `account_step[_fleet]` result: voltages are
        the oracle plane state, `age_s` is 0. `nominals` optionally carries
        the per-chip `v_nom_*` anchors of a `FleetSpec`."""
        kw = {k: metrics[k] for k in _FRAME_METRIC_KEYS if k in metrics}
        if nominals:
            for k in _FRAME_NOM_KEYS:
                if k in nominals:
                    kw[k] = jnp.asarray(nominals[k], jnp.float32)
        extras = {k: v for k, v in metrics.items()
                  if k not in _FRAME_METRIC_KEYS and k not in _FRAME_NOM_KEYS}
        return TelemetryFrame(v_core=state.v_core, v_hbm=state.v_hbm,
                              v_io=state.v_io, extras=extras,
                              provenance=Provenance.EXACT, **kw)

    # -- views ----------------------------------------------------------------
    def to_dict(self) -> dict[str, Any]:
        """The legacy metrics-dict view (for legacy `update_*` policies and
        logging). Non-None typed fields plus extras."""
        out = dict(self.extras)
        for k in _FRAME_METRIC_KEYS + _FRAME_NOM_KEYS + _FRAME_RAIL_KEYS:
            v = getattr(self, k)
            if v is not None:
                out[k] = v
        return out

    def get(self, key: str, default: Any = None) -> Any:
        """dict-style access over typed fields + extras (migration aid)."""
        if key in self.extras:
            return self.extras[key]
        v = getattr(self, key, None)
        return v if v is not None else default

    def reduce_worst(self, keys: tuple[str, ...]) -> "TelemetryFrame":
        """Broadcast the fleet-worst (max) value of each named observation to
        every chip — the WorstChipGate reduction, now a frame transform.
        NaN lanes mean "not measured this round" (the per-rail observable
        convention), so the worst is taken over *measured* lanes only —
        one unmeasured chip must not NaN-poison the reduction and mask a
        genuinely over-bound chip; all-NaN stays NaN (nothing measured)."""
        def worst(v):
            masked = jnp.where(jnp.isnan(v), -jnp.inf, v)
            m = jnp.max(masked)
            return jnp.where(jnp.isneginf(m), jnp.nan, m)

        kw: dict[str, Any] = {}
        extras = dict(self.extras)
        for k in keys:
            if k in extras:
                v = extras[k]
                if jnp.ndim(v) >= 1:
                    extras[k] = jnp.broadcast_to(worst(v), v.shape)
                continue
            v = getattr(self, k, None)
            if v is not None and jnp.ndim(v) >= 1:
                kw[k] = jnp.broadcast_to(worst(v), v.shape)
        return dataclasses.replace(self, extras=extras, **kw)


def as_frame(telemetry, *, state=None) -> TelemetryFrame:
    """Normalize a controller input: a TelemetryFrame passes through (rail
    observations filled from `state` when the frame has none); a legacy dict
    goes through `TelemetryFrame.from_dict`."""
    if isinstance(telemetry, TelemetryFrame):
        if state is not None and telemetry.v_core is None:
            return dataclasses.replace(
                telemetry, v_core=state.v_core, v_hbm=state.v_hbm,
                v_io=state.v_io)
        return telemetry
    return TelemetryFrame.from_dict(telemetry, state=state)


# ---------------------------------------------------------------------------
# RailObservable + FrameHistory: the jit/vmap-safe per-rail x per-chip
# telemetry window (SOR stage 0)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RailObservable:
    """Declarative spec of what a safe-operating-region learner fits for one
    rail: which frame field carries the rail's voltage observation and which
    field/extras key carries the failure observable whose log10 is regressed
    against it. `error_bound`/`guard_v` optionally override the SorConfig
    globals for this rail (each rail's failure mode has its own bound: BER
    for the SerDes rail, straggler rate for the core rail)."""
    rail: str                        # rail name ("VDD_IO", ...)
    voltage: str                     # TelemetryFrame field with the voltage
    key: str                         # frame field/extras key of the observable
    error_bound: "float | None" = None   # None -> SorConfig.error_bound
    guard_v: "float | None" = None       # None -> SorConfig.guard_v


# The three TPU logical rails with their paper-grounded failure observables:
# VDD_IO keeps the BER-frontier analogue (measured gradient-domain error);
# VDD_CORE/VDD_HBM fit the fleet step's margin-coupled injection observables
# (straggler rate, HBM error rate) against their own rails.
VDD_IO_BER = RailObservable("VDD_IO", "v_io", "grad_error")
VDD_CORE_STRAGGLE = RailObservable("VDD_CORE", "v_core", "straggle_rate")
VDD_HBM_ERROR = RailObservable("VDD_HBM", "v_hbm", "hbm_error_rate")

DEFAULT_RAIL_OBSERVABLES = (VDD_IO_BER,)
ALL_RAIL_OBSERVABLES = (VDD_CORE_STRAGGLE, VDD_HBM_ERROR, VDD_IO_BER)

# rail name -> canonical observable key (fleet.poll_frame uses this to place
# per-rail error telemetry supplied as a {rail: value} dict)
RAIL_OBSERVABLE_KEYS = {s.rail: s.key for s in ALL_RAIL_OBSERVABLES}


def validate_rails(rails) -> tuple:
    """Shared validation of a RailObservable tuple (FrameHistory and
    SorConfig both declare one — ONE rule set): non-empty, unique names."""
    rails = tuple(rails)
    if not rails:
        raise ValueError("need at least one RailObservable")
    names = [s.rail for s in rails]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate rails in {names}")
    return rails


def rail_index(rails, name: str) -> int:
    """Index of a rail name within a RailObservable tuple."""
    for i, s in enumerate(rails):
        if s.rail == name:
            return i
    raise KeyError(f"rail {name!r} not tracked; "
                   f"have {[s.rail for s in rails]}")


@partial(jax.tree_util.register_dataclass,
         data_fields=["v", "obs", "age_s", "polled",
                      "valid", "cursor", "count"],
         meta_fields=["capacity", "rails"])
@dataclasses.dataclass(frozen=True)
class FrameHistory:
    """Fixed-capacity ring buffer of `TelemetryFrame` samples, stored as
    stacked jnp arrays `[capacity, n_rails, *chip_shape]` so the whole store
    jits, vmaps, and rides a `lax.scan` carry (the in-graph SOR path needs
    exactly that — see core/sor.py and docs/sor.md).

    The rail axis is declared by `rails` (a tuple of `RailObservable`): per
    sample, per rail and per chip it keeps the rail-voltage observation and
    the rail's failure observable (the BER analogue for VDD_IO, straggler /
    HBM error rates for the margin-coupled rails), plus the observation
    staleness (`age_s` — down-weighted by the fit when
    `SorConfig.age_halflife_s` is set) and a POLLED/EXACT provenance flag
    (an observability record of where each sample came from; the fit itself
    weighs samples by recency and `age_s` only).
    `valid` masks (rail, chip) lanes whose voltage or observable was NaN at
    push time (e.g. a `FleetPowerManager.poll_frame` lane that was never
    sampled, or a rail whose observable the caller did not report) — cold
    start therefore records *nothing*, which is what pins learned-envelope
    controllers to static behavior until real telemetry arrives."""
    v: Any            # f32 [capacity, n_rails, *chip] — voltage observations
    obs: Any          # f32 [capacity, n_rails, *chip] — failure observables
    age_s: Any        # f32 [capacity, *chip] — staleness at observation time
    polled: Any       # f32 [capacity, *chip] — 1.0 POLLED, 0.0 EXACT
    valid: Any        # bool [capacity, n_rails, *chip]
    cursor: Any       # i32 [] — next slot to write
    count: Any        # i32 [] — total pushes (not capped)
    capacity: int
    rails: tuple = DEFAULT_RAIL_OBSERVABLES

    @staticmethod
    def create(capacity: int, n_chips: int | None = None,
               rails: tuple = DEFAULT_RAIL_OBSERVABLES) -> "FrameHistory":
        """Empty history. `n_chips=None` -> scalar (single-chip) samples;
        `rails` declares the fitted rails (default: the VDD_IO BER frontier
        alone — the single-rail learner)."""
        if capacity < 2:
            raise ValueError(f"capacity must be >= 2, got {capacity}")
        rails = validate_rails(rails)
        chip = () if n_chips is None else (n_chips,)
        zr = jnp.zeros((capacity, len(rails)) + chip, jnp.float32)
        zc = jnp.zeros((capacity,) + chip, jnp.float32)
        return FrameHistory(
            v=zr, obs=zr, age_s=zc, polled=zc,
            valid=jnp.zeros(zr.shape, bool),
            cursor=jnp.int32(0), count=jnp.int32(0), capacity=capacity,
            rails=rails)

    @property
    def chip_shape(self) -> tuple[int, ...]:
        return self.v.shape[2:]

    @property
    def n_rails(self) -> int:
        return len(self.rails)

    def rail_index(self, name: str) -> int:
        return rail_index(self.rails, name)

    # back-compat single-rail views (the PR-4 layout's field names)
    @property
    def v_io(self):
        return self.v[:, self.rail_index("VDD_IO")]

    @property
    def error(self):
        return self.obs[:, self.rail_index("VDD_IO")]

    def push(self, frame: TelemetryFrame) -> "FrameHistory":
        """Functional append of one observation (pure jnp: jit/vmap/scan
        safe). (rail, chip) lanes whose voltage or observable is non-finite
        record as invalid — they carry no weight in any downstream fit, so a
        rail the frame says nothing about simply records nothing."""
        shape = self.chip_shape

        def val(x, default=None):
            if x is None:
                x = jnp.nan if default is None else default
            return jnp.broadcast_to(jnp.asarray(x, jnp.float32), shape)

        v = jnp.stack([val(frame.get(s.voltage)) for s in self.rails])
        obs = jnp.stack([val(frame.get(s.key)) for s in self.rails])
        age = val(frame.age_s, default=0.0)
        ok = jnp.isfinite(v) & jnp.isfinite(obs)
        polled = jnp.broadcast_to(
            jnp.float32(frame.provenance is Provenance.POLLED), shape)

        def put(buf, x):
            return jax.lax.dynamic_update_index_in_dim(buf, x, self.cursor, 0)

        # unknown staleness (the documented NaN sentinel) records as +inf —
        # under SorConfig.age_halflife_s that is ZERO fit weight (the
        # conservative reading, matching StalenessGuard's maximally-stale
        # treatment), not the perfectly-fresh 0.0 a silent coercion would
        # claim; staleness-blind configs ignore age entirely
        return dataclasses.replace(
            self,
            v=put(self.v, v),
            obs=put(self.obs, obs),
            age_s=put(self.age_s, jnp.where(jnp.isfinite(age), age,
                                            jnp.inf)),
            polled=put(self.polled, polled),
            valid=put(self.valid, ok),
            cursor=(self.cursor + 1) % self.capacity,
            count=self.count + 1)

    def partition_specs(self, axis_name: str = "chips"):
        """Exact `PartitionSpec` pytree for this ring on a 1-D chip mesh:
        the `[capacity, n_rails, n]` data leaves shard their trailing chip
        axis over `axis_name`, the `cursor`/`count` scalars replicate —
        the in/out specs a shard_map'd control round uses so the history
        window itself never gathers. Non-fleet stores (scalar or multi-dim
        chip shapes) replicate every leaf."""
        from jax.sharding import PartitionSpec as P
        fleet = len(self.chip_shape) == 1

        def spec(leaf):
            nd = jnp.ndim(leaf)
            if fleet and nd >= 1 and jnp.shape(leaf)[-1] == self.chip_shape[0]:
                return P(*((None,) * (nd - 1)), axis_name)
            return P()

        return jax.tree_util.tree_map(spec, self)

    def recency_weights(self, decay: float) -> jnp.ndarray:
        """`[capacity, n_rails, *chip]` exponential recency weights: the
        newest valid sample weighs 1, each older slot `decay`x less, invalid
        (rail, chip) lanes 0 — the weighting of the SOR exponentially-
        weighted least squares."""
        slots = jnp.arange(self.capacity)
        rank = (self.cursor - 1 - slots) % self.capacity   # 0 == newest
        w = jnp.asarray(decay, jnp.float32) ** rank
        w = w.reshape((self.capacity,) + (1,) * (1 + len(self.chip_shape)))
        return w * self.valid.astype(jnp.float32)


def scalar_view(x) -> float:
    """Array-aware scalar reduction: a scalar metric passes through, a
    `[n_chips]` metric reports the fleet mean (the same convention
    `TelemetryLog.append_from` records)."""
    a = np.asarray(jax.device_get(x), dtype=np.float64)
    return float(a.mean()) if a.ndim else float(a)

# metrics with first-class StepRecord fields
_CORE_KEYS = ("grad_error", "t_step_s", "power_w", "energy_step_j")


@dataclasses.dataclass
class StepRecord:
    step: int
    loss: float
    grad_error: float
    t_step_s: float
    power_w: float
    energy_step_j: float
    comp_level: int
    v_core: float
    v_hbm: float
    v_io: float
    n_chips: int = 1
    extras: dict[str, float] = dataclasses.field(default_factory=dict)
    # fleet-shaped state only: per-chip vectors + host-side reductions
    per_chip: dict[str, list[float]] = dataclasses.field(default_factory=dict)
    fleet: dict[str, float] = dataclasses.field(default_factory=dict)


class TelemetryLog:
    """Bounded host-side telemetry store (ring buffer)."""

    def __init__(self, capacity: int = 100_000):
        self.records: collections.deque[StepRecord] = collections.deque(maxlen=capacity)

    def append_from(self, step: int, loss, metrics: dict[str, Any], state) -> StepRecord:
        per_chip: dict[str, list[float]] = {}
        fleet: dict[str, float] = {}

        # one host round-trip for everything this record needs (append_from
        # is on the trainer hot loop; per-key device_get syncs add up)
        loss, metrics, state_v = jax.device_get(
            (loss, dict(metrics),
             {f: getattr(state, f)
              for f in ("v_core", "v_hbm", "v_io", "comp_level")}))

        v_core_a = np.asarray(state_v["v_core"])
        n_chips = int(v_core_a.shape[0]) if v_core_a.ndim else 1

        def record(key: str, x) -> float | None:
            """Scalar -> float. [n_chips] -> per-chip list + max/min/mean/p95
            reductions, returning the fleet mean as the scalar view. The
            suffixes are direction-neutral on purpose — which extreme is the
            *worst* chip depends on the metric (max power, but MIN voltage);
            directional `_worst` keys come from the fleet step's in-graph
            reductions. Arrays that are not `[n_chips]`-shaped are not
            per-chip telemetry -> None (the scalar-or-fleet convention)."""
            a = np.asarray(x)
            if a.ndim == 0:
                return float(a)
            if a.ndim == 1 and a.shape[0] == n_chips:
                af = a.astype(np.float64)
                per_chip[key] = [float(v) for v in af]
                fleet[f"{key}_max"] = float(af.max())
                fleet[f"{key}_min"] = float(af.min())
                fleet[f"{key}_mean"] = float(af.mean())
                fleet[f"{key}_p95"] = float(np.percentile(af, 95.0))
                return float(af.mean())
            return None

        core = {k: record(k, metrics.get(k, 0.0)) or 0.0 for k in _CORE_KEYS}
        rails = {f: record(f, state_v[f]) or 0.0
                 for f in ("v_core", "v_hbm", "v_io")}
        comp = np.asarray(state_v["comp_level"])
        if comp.ndim:
            per_chip["comp_level"] = [float(c) for c in comp]
            comp_level = int(comp.min())   # fleet view: most conservative chip
        else:
            comp_level = int(comp)

        extras: dict[str, float] = {}
        for k, v in metrics.items():
            if k in _CORE_KEYS or k == "loss":
                continue
            if k.startswith("fleet/"):
                fleet[k.split("/", 1)[1]] = float(np.asarray(v))
                continue
            s = record(k, v)
            if s is not None and k not in per_chip:
                extras[k] = s

        rec = StepRecord(
            step=step,
            loss=float(np.mean(np.asarray(loss))),
            grad_error=core["grad_error"],
            t_step_s=core["t_step_s"],
            power_w=core["power_w"],
            energy_step_j=core["energy_step_j"],
            comp_level=comp_level,
            v_core=rails["v_core"], v_hbm=rails["v_hbm"], v_io=rails["v_io"],
            n_chips=n_chips,
            extras=extras, per_chip=per_chip, fleet=fleet,
        )
        self.records.append(rec)
        return rec

    def totals(self) -> dict[str, float]:
        if not self.records:
            return {"steps": 0, "energy_j": 0.0, "mean_power_w": 0.0,
                    "time_s": 0.0, "fleet_energy_j": 0.0}
        # scalar fields are per-chip means, so these are per-chip totals;
        # fleet_energy_j is the whole fleet's energy (mean x n_chips).
        e = sum(r.energy_step_j for r in self.records)
        t = sum(r.t_step_s for r in self.records)
        ef = sum(r.energy_step_j * r.n_chips for r in self.records)
        return {"steps": len(self.records), "energy_j": e,
                "mean_power_w": e / max(t, 1e-12), "time_s": t,
                "fleet_energy_j": ef}

    def per_chip_series(self, key: str) -> np.ndarray:
        """[steps, n_chips] history of one per-chip metric (records lacking
        the key are skipped)."""
        rows = [r.per_chip[key] for r in self.records if key in r.per_chip]
        if not rows:
            raise KeyError(f"no per-chip telemetry recorded for {key!r}")
        return np.asarray(rows)

    def dump_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for r in self.records:
                f.write(json.dumps(dataclasses.asdict(r)) + "\n")
