"""Telemetry plumbing: per-step records (the READ_VOUT/READ_IOUT analogue of
the training system) and a host-side ring log used by host controllers,
benchmarks and the trainer."""

from __future__ import annotations

import collections
import dataclasses
import json
from typing import Any

import jax
import numpy as np


@dataclasses.dataclass
class StepRecord:
    step: int
    loss: float
    grad_error: float
    t_step_s: float
    power_w: float
    energy_step_j: float
    comp_level: int
    v_core: float
    v_hbm: float
    v_io: float
    extras: dict[str, float] = dataclasses.field(default_factory=dict)


class TelemetryLog:
    """Bounded host-side telemetry store (ring buffer)."""

    def __init__(self, capacity: int = 100_000):
        self.records: collections.deque[StepRecord] = collections.deque(maxlen=capacity)

    def append_from(self, step: int, loss, metrics: dict[str, Any], state) -> StepRecord:
        get = lambda x: float(jax.device_get(x))
        rec = StepRecord(
            step=step,
            loss=get(loss),
            grad_error=get(metrics.get("grad_error", 0.0)),
            t_step_s=get(metrics.get("t_step_s", 0.0)),
            power_w=get(metrics.get("power_w", 0.0)),
            energy_step_j=get(metrics.get("energy_step_j", 0.0)),
            comp_level=int(jax.device_get(state.comp_level)),
            v_core=get(state.v_core), v_hbm=get(state.v_hbm), v_io=get(state.v_io),
            extras={k: get(v) for k, v in metrics.items()
                    if k not in ("grad_error", "t_step_s", "power_w", "energy_step_j")
                    and np.ndim(jax.device_get(v)) == 0},
        )
        self.records.append(rec)
        return rec

    def totals(self) -> dict[str, float]:
        if not self.records:
            return {"steps": 0, "energy_j": 0.0, "mean_power_w": 0.0, "time_s": 0.0}
        e = sum(r.energy_step_j for r in self.records)
        t = sum(r.t_step_s for r in self.records)
        return {"steps": len(self.records), "energy_j": e,
                "mean_power_w": e / max(t, 1e-12), "time_s": t}

    def dump_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for r in self.records:
                f.write(json.dumps(dataclasses.asdict(r)) + "\n")
