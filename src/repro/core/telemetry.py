"""Telemetry plumbing: per-step records (the READ_VOUT/READ_IOUT analogue of
the training system) and a host-side ring log used by host controllers,
benchmarks and the trainer.

Scalar→fleet convention (docs/fleet.md): every metric is either a scalar
(one chip / SPMD-replicated) or a `[n_chips]` array (per-chip fleet state).
`append_from` accepts both: scalars record as before; `[n_chips]` arrays
record the full per-chip vector in `StepRecord.per_chip` plus fleet
reductions (worst/best/mean/p95) in `StepRecord.fleet`, with the legacy
scalar field holding the fleet mean so downstream consumers (`totals`,
benchmark report code) keep working unchanged. Keys prefixed `fleet/` are
in-graph reductions computed by the fleet train step through the Pallas
`ops.fleet_reduce` hot path and land in `StepRecord.fleet` verbatim.
"""

from __future__ import annotations

import collections
import dataclasses
import json
from typing import Any

import jax
import numpy as np

# metrics with first-class StepRecord fields
_CORE_KEYS = ("grad_error", "t_step_s", "power_w", "energy_step_j")


@dataclasses.dataclass
class StepRecord:
    step: int
    loss: float
    grad_error: float
    t_step_s: float
    power_w: float
    energy_step_j: float
    comp_level: int
    v_core: float
    v_hbm: float
    v_io: float
    n_chips: int = 1
    extras: dict[str, float] = dataclasses.field(default_factory=dict)
    # fleet-shaped state only: per-chip vectors + host-side reductions
    per_chip: dict[str, list[float]] = dataclasses.field(default_factory=dict)
    fleet: dict[str, float] = dataclasses.field(default_factory=dict)


class TelemetryLog:
    """Bounded host-side telemetry store (ring buffer)."""

    def __init__(self, capacity: int = 100_000):
        self.records: collections.deque[StepRecord] = collections.deque(maxlen=capacity)

    def append_from(self, step: int, loss, metrics: dict[str, Any], state) -> StepRecord:
        per_chip: dict[str, list[float]] = {}
        fleet: dict[str, float] = {}

        # one host round-trip for everything this record needs (append_from
        # is on the trainer hot loop; per-key device_get syncs add up)
        loss, metrics, state_v = jax.device_get(
            (loss, dict(metrics),
             {f: getattr(state, f)
              for f in ("v_core", "v_hbm", "v_io", "comp_level")}))

        v_core_a = np.asarray(state_v["v_core"])
        n_chips = int(v_core_a.shape[0]) if v_core_a.ndim else 1

        def record(key: str, x) -> float | None:
            """Scalar -> float. [n_chips] -> per-chip list + max/min/mean/p95
            reductions, returning the fleet mean as the scalar view. The
            suffixes are direction-neutral on purpose — which extreme is the
            *worst* chip depends on the metric (max power, but MIN voltage);
            directional `_worst` keys come from the fleet step's in-graph
            reductions. Arrays that are not `[n_chips]`-shaped are not
            per-chip telemetry -> None (the scalar-or-fleet convention)."""
            a = np.asarray(x)
            if a.ndim == 0:
                return float(a)
            if a.ndim == 1 and a.shape[0] == n_chips:
                af = a.astype(np.float64)
                per_chip[key] = [float(v) for v in af]
                fleet[f"{key}_max"] = float(af.max())
                fleet[f"{key}_min"] = float(af.min())
                fleet[f"{key}_mean"] = float(af.mean())
                fleet[f"{key}_p95"] = float(np.percentile(af, 95.0))
                return float(af.mean())
            return None

        core = {k: record(k, metrics.get(k, 0.0)) or 0.0 for k in _CORE_KEYS}
        rails = {f: record(f, state_v[f]) or 0.0
                 for f in ("v_core", "v_hbm", "v_io")}
        comp = np.asarray(state_v["comp_level"])
        if comp.ndim:
            per_chip["comp_level"] = [float(c) for c in comp]
            comp_level = int(comp.min())   # fleet view: most conservative chip
        else:
            comp_level = int(comp)

        extras: dict[str, float] = {}
        for k, v in metrics.items():
            if k in _CORE_KEYS or k == "loss":
                continue
            if k.startswith("fleet/"):
                fleet[k.split("/", 1)[1]] = float(np.asarray(v))
                continue
            s = record(k, v)
            if s is not None and k not in per_chip:
                extras[k] = s

        rec = StepRecord(
            step=step,
            loss=float(np.mean(np.asarray(loss))),
            grad_error=core["grad_error"],
            t_step_s=core["t_step_s"],
            power_w=core["power_w"],
            energy_step_j=core["energy_step_j"],
            comp_level=comp_level,
            v_core=rails["v_core"], v_hbm=rails["v_hbm"], v_io=rails["v_io"],
            n_chips=n_chips,
            extras=extras, per_chip=per_chip, fleet=fleet,
        )
        self.records.append(rec)
        return rec

    def totals(self) -> dict[str, float]:
        if not self.records:
            return {"steps": 0, "energy_j": 0.0, "mean_power_w": 0.0,
                    "time_s": 0.0, "fleet_energy_j": 0.0}
        # scalar fields are per-chip means, so these are per-chip totals;
        # fleet_energy_j is the whole fleet's energy (mean x n_chips).
        e = sum(r.energy_step_j for r in self.records)
        t = sum(r.t_step_s for r in self.records)
        ef = sum(r.energy_step_j * r.n_chips for r in self.records)
        return {"steps": len(self.records), "energy_j": e,
                "mean_power_w": e / max(t, 1e-12), "time_s": t,
                "fleet_energy_j": ef}

    def per_chip_series(self, key: str) -> np.ndarray:
        """[steps, n_chips] history of one per-chip metric (records lacking
        the key are skipped)."""
        rows = [r.per_chip[key] for r in self.records if key in r.per_chip]
        if not rows:
            raise KeyError(f"no per-chip telemetry recorded for {key!r}")
        return np.asarray(rows)

    def dump_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for r in self.records:
                f.write(json.dumps(dataclasses.asdict(r)) + "\n")
