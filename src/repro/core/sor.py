"""Learned per-chip, per-rail safe operating regions (paper §VIII future
work, at fleet scale).

VolTune's headline result is a *bounded operating region*: undervolt the
transceiver rail as far as the measured BER frontier allows (≈29.3% rail
power at 10 Gbps with BER <= 1e-6) — and its future-work section asks for
learning that region at runtime instead of hard-coding it. The paper's
architecture is explicitly per-rail: every PMBus-addressable supply gets the
same control path, and the bounded region exists on each rail with a
different failure mode (BER on the SerDes rail, stragglers on the core rail,
memory errors on the HBM rail). This module is that subsystem for the TPU
adaptation (docs/sor.md):

    FrameHistory  ->  SorEstimate  ->  SafeEnvelope  ->  arbitration
    (telemetry)       (fitted frontiers)  (per-rail v_min)   (control_plane)

* `telemetry.FrameHistory` — fixed-capacity ring of (voltage, observable,
  age, provenance) samples per rail x chip, stacked jnp arrays so the whole
  store jits/vmaps and rides a scan carry. Which rails are fitted — and
  which telemetry field each rail's failure observable comes from — is a
  declarative `telemetry.RailObservable` tuple (`SorConfig.rails`).
* `SorEstimate` — each (rail, chip)'s fitted log10(observable)-vs-voltage
  frontier: slope + intercept from exponentially-weighted least squares over
  the history window, the frontier voltage where the modeled observable
  meets the rail's bound, and a confidence in [0, 1] that gates everything
  downstream. All math is elementwise jnp over `[n_rails, *chip]`; the
  per-chip x per-rail x per-window weighted sums run through the fused
  streaming reduction `ops.sor_accumulate` (Pallas on TPU, the identical
  jnp reference elsewhere).
* `SafeEnvelope` — per-chip v_min/v_max for ONE rail, derived from the fit
  at that rail's bound, *blended with the caller's static envelope by
  confidence*: at zero confidence the envelope IS the static one (bit-exact
  — the cold-start no-behavior-change pin), and the learned floor may extend
  below the static floor by at most `max_extension_v` (bounded
  exploration). `rail_envelopes` maps a multi-rail estimate to the
  {rail: SafeEnvelope} dict `control_plane.arbitrate(envelopes=)` consumes.

Consumers: `policy.BERBounded/ClosedLoop/WorstChipGate/MultiRailClosedLoop`
warm-start their decisions from the envelopes (`decide_env`),
`control_plane.arbitrate` clamps requests against per-chip envelopes instead
of the shared rail envelopes, and both controllers maintain the
history/estimate on a configurable cadence (`SorConfig.refresh_every`).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import telemetry as _telemetry
from repro.core.telemetry import (ALL_RAIL_OBSERVABLES,
                                  DEFAULT_RAIL_OBSERVABLES, FrameHistory,
                                  RailObservable, TelemetryFrame)
from repro.kernels import ops

LOG10_ERR_FLOOR = -8.0   # zero-error samples clamp here (detection floor)
LOG10_ERR_CEIL = 2.0


@dataclasses.dataclass(frozen=True)
class SorConfig:
    """Knobs of the safe-operating-region learner.

    `rails` declares the fitted rails and their observables (default: the
    VDD_IO BER frontier alone — the single-rail learner; pass
    `telemetry.ALL_RAIL_OBSERVABLES` for the full three-rail fit).
    `error_bound` is the measured-observable bound each frontier is cut at
    (the gradient-domain analogue of the paper's BER <= 1e-6), overridable
    per rail via `RailObservable.error_bound`; `guard_v` is the guard band
    added above the fitted frontier voltage (per-rail override:
    `RailObservable.guard_v`); `max_extension_v` bounds how far below a
    consumer's *static* floor the learned floor may reach (confidence-gated
    exploration, never a free fall)."""
    capacity: int = 32           # history window (samples per chip)
    refresh_every: int = 4       # observations between estimate refreshes
    error_bound: float = 5e-3    # frontier cut: modeled observable == this
    guard_v: float = 0.010       # volts of guard band above the frontier
    decay: float = 0.92          # per-slot recency decay of the EWLS weights
    update_gain: float = 1.0     # EW blend of a refit into the running fit
    min_slope: float = 0.5       # |d log10(err)/dV| below this -> no trust
    min_spread_v: float = 2e-3   # required voltage stddev in the window
    conf_samples: float = 8.0    # effective samples to ~63% confidence
    age_halflife_s: "float | None" = None  # None: staleness-blind weights;
    #                              else a sample's weight halves per this
    #                              many seconds of observation age
    max_extension_v: float = 0.05  # max reach below a consumer's static floor
    ingest: str = "polled"       # "polled": learn only from READ_VOUT
    #                              samples; "frames": learn from whatever
    #                              frame the decision consumed (EXACT ok)
    rails: tuple = DEFAULT_RAIL_OBSERVABLES   # RailObservable per fitted rail

    def __post_init__(self):
        if self.ingest not in ("polled", "frames"):
            raise ValueError(f"ingest must be 'polled' or 'frames', "
                             f"got {self.ingest!r}")
        if not 0.0 < self.decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {self.decay}")
        if self.refresh_every < 1:
            raise ValueError("refresh_every must be >= 1")
        object.__setattr__(self, "rails", _telemetry.validate_rails(self.rails))

    @property
    def n_rails(self) -> int:
        return len(self.rails)

    def rail_index(self, name: str) -> int:
        return _telemetry.rail_index(self.rails, name)


@partial(jax.tree_util.register_dataclass,
         data_fields=["intercept", "slope", "v_frontier", "confidence",
                      "n_eff"],
         meta_fields=[])
@dataclasses.dataclass(frozen=True)
class SorEstimate:
    """The fitted frontiers, `[n_rails]` or `[n_rails, n_chips]`:
    log10(observable)(v) ~= intercept + slope * v per (rail, chip), with
    `v_frontier` the voltage where the model meets the rail's configured
    bound and `confidence` in [0, 1] gating every consumer. Zero confidence
    == no opinion (cold start)."""
    intercept: Any    # f32 [n_rails, *chip]
    slope: Any        # f32 — d log10(obs)/dV, negative when healthy
    v_frontier: Any   # f32 — modeled log10(obs) == log10(bound) here
    confidence: Any   # f32 in [0, 1]
    n_eff: Any        # f32 — effective (decayed) sample count behind the fit

    @staticmethod
    def init(n_chips: int | None = None, n_rails: int = 1) -> "SorEstimate":
        shape = (n_rails,) if n_chips is None else (n_rails, n_chips)
        z = jnp.zeros(shape, jnp.float32)
        return SorEstimate(intercept=z, slope=z, v_frontier=z,
                           confidence=z, n_eff=z)

    @property
    def n_rails(self) -> int:
        return self.confidence.shape[0]

    def rail(self, i: int) -> "SorEstimate":
        """One rail's estimate (fields shaped [*chip])."""
        return jax.tree_util.tree_map(lambda a: a[i], self)

    def log10_error_at(self, v) -> jnp.ndarray:
        """Modeled log10(observable) at voltage `v` (elementwise)."""
        return self.intercept + self.slope * jnp.asarray(v, jnp.float32)


def _rail_bounds(cfg: SorConfig, chip_ndim: int) -> jnp.ndarray:
    """[n_rails, 1...] log10 frontier bounds, per-rail overrides applied."""
    b = np.log10([s.error_bound if s.error_bound is not None
                  else cfg.error_bound for s in cfg.rails])
    return jnp.asarray(b, jnp.float32).reshape(
        (len(cfg.rails),) + (1,) * chip_ndim)


def _rail_guards(cfg: SorConfig, chip_ndim: int) -> jnp.ndarray:
    """[n_rails, 1...] guard bands, per-rail overrides applied — the +guard
    the fused kernel adds onto v_frontier to emit the envelope floor."""
    g = [s.guard_v if s.guard_v is not None else cfg.guard_v
         for s in cfg.rails]
    return jnp.asarray(g, jnp.float32).reshape(
        (len(cfg.rails),) + (1,) * chip_ndim)


def _fit_inputs(history: FrameHistory, cfg: SorConfig):
    """The (x, y, w) EWLS inputs of the window: masked voltages, clipped
    log10 observables, recency (x optional staleness) weights."""
    w = history.recency_weights(cfg.decay)
    if cfg.age_halflife_s is not None:
        # POLLED samples that were already stale when observed carry less
        # weight (halving per age_halflife_s of recorded staleness)
        w = w * 0.5 ** (history.age_s[:, None]
                        / jnp.float32(cfg.age_halflife_s))
    x = jnp.where(history.valid, history.v, 0.0)
    y = jnp.clip(
        jnp.log10(jnp.maximum(history.obs, 10.0 ** LOG10_ERR_FLOOR)),
        LOG10_ERR_FLOOR, LOG10_ERR_CEIL)
    y = jnp.where(history.valid, y, 0.0)
    return x, y, w


def fit_history(history: FrameHistory, cfg: SorConfig,
                fused: "bool | None" = None) -> SorEstimate:
    """Exponentially-weighted least squares of log10(observable) against the
    rail-voltage observation over the history window — elementwise per
    (rail, chip), pure jnp (jit/vmap/scan safe).

    `fused=True`: the accumulation AND the per-lane solve (plus the
    envelope floor) are carried out of ONE streaming pass over the window
    (`ops.sor_fit` — the fused Pallas fleet-telemetry kernel on TPU; the
    composed jnp reference elsewhere). `fused=False` is the historical
    two-stage split — `ops.sor_accumulate` then a host-graph solve. Under
    a trace the two compile to the same optimized graph, so trajectories
    are bit-equal (pinned by tests/test_fused_control_round.py).

    `fused=None` (default) resolves by context: fused under a trace (where
    every hot path lives and the two are bit-identical anyway), the
    historical split on eager host calls — an eagerly-dispatched fused op
    would see different XLA contraction (FMA) choices than the op-by-op
    eager solve, and the PR-4 eager fit pin is bit-exact.

    Confidence gates on three things at once: enough effective samples
    (`conf_samples` ramp), enough voltage spread to identify a slope
    (`min_spread_v`), and a frontier with the right sign and steepness
    (`min_slope`; the observable must *grow* as voltage drops)."""
    if fused is None:
        fused = any(isinstance(leaf, jax.core.Tracer)
                    for leaf in jax.tree_util.tree_leaves(history))
    x, y, w = _fit_inputs(history, cfg)
    shape = x.shape[1:]                      # [n_rails, *chip]
    chip_ndim = len(history.chip_shape)
    flat = lambda a: a.reshape(history.capacity, -1)

    if fused:
        full = lambda a: jnp.broadcast_to(a, shape).reshape(-1)
        intercept, slope, v_frontier, confidence, n_eff, _floor = (
            s.reshape(shape) for s in ops.sor_fit(
                flat(x), flat(y), flat(w),
                full(_rail_bounds(cfg, chip_ndim)),
                full(_rail_guards(cfg, chip_ndim)),
                min_slope=cfg.min_slope, min_spread_v=cfg.min_spread_v,
                conf_samples=cfg.conf_samples))
        # the fused pass also emits the envelope floor (v_frontier + guard);
        # SorEstimate keeps its 5-field checkpoint layout and
        # `rail_envelopes` re-derives the identical f32 add
        return SorEstimate(intercept=intercept, slope=slope,
                           v_frontier=v_frontier, confidence=confidence,
                           n_eff=n_eff)

    eps = jnp.float32(1e-9)
    sw, sx, sy, sxx, sxy = (s.reshape(shape) for s in ops.sor_accumulate(
        flat(x), flat(y), flat(w)))

    denom = sw * sxx - sx * sx
    slope = (sw * sxy - sx * sy) / jnp.maximum(denom, eps)
    intercept = (sy - slope * sx) / jnp.maximum(sw, eps)
    var_x = jnp.maximum(sxx / jnp.maximum(sw, eps)
                        - (sx / jnp.maximum(sw, eps)) ** 2, 0.0)

    steep = slope < -jnp.float32(cfg.min_slope)
    spread = var_x > jnp.float32(cfg.min_spread_v) ** 2
    usable = steep & spread & (denom > eps)

    log10_bound = _rail_bounds(cfg, chip_ndim)
    v_frontier = jnp.where(
        usable, (log10_bound - intercept) / jnp.where(usable, slope, -1.0),
        0.0)
    v_frontier = jnp.clip(v_frontier, 0.0, 2.0)   # sanity, conf gates anyway
    confidence = jnp.where(
        usable, 1.0 - jnp.exp(-sw / jnp.float32(cfg.conf_samples)), 0.0)
    return SorEstimate(
        intercept=jnp.where(usable, intercept, 0.0).astype(jnp.float32),
        slope=jnp.where(usable, slope, 0.0).astype(jnp.float32),
        v_frontier=v_frontier.astype(jnp.float32),
        confidence=confidence.astype(jnp.float32),
        n_eff=sw.astype(jnp.float32))


def update_estimate(old: SorEstimate, history: FrameHistory,
                    cfg: SorConfig,
                    fused: "bool | None" = None) -> SorEstimate:
    """Online refresh: refit the window, then blend into the running
    estimate with `update_gain` (1.0 == adopt the refit). A (rail, chip)
    lane that yields no usable fit keeps the previous estimate — a chip
    whose polls stopped does not forget its learned region, and a cold lane
    stays at zero confidence."""
    fit = fit_history(history, cfg, fused=fused)
    gain = jnp.where(old.confidence > 0.0, jnp.float32(cfg.update_gain), 1.0)
    return jax.tree_util.tree_map(
        lambda o, f: jnp.where(fit.confidence > 0.0, o + gain * (f - o),
                               jnp.where(old.confidence > 0.0, o, f)),
        old, fit)


# ---------------------------------------------------------------------------
# SafeEnvelope: the fit, expressed as per-chip operating limits per rail
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=["v_min", "v_max", "confidence"],
         meta_fields=["max_extension_v", "rail"])
@dataclasses.dataclass(frozen=True)
class SafeEnvelope:
    """Per-chip learned operating limits for one rail, confidence-blended
    against whatever *static* limit the consumer holds (a policy's
    `v_io_floor`, arbitration's rail `v_min`): at zero confidence the
    blended limit is bit-exactly the static one, at full confidence it is
    the learned frontier. The learned floor may reach below the static one
    by at most `max_extension_v` — conservative, bounded exploration.
    `rail` records which rail the fit belongs to, so a bare envelope handed
    around outside the {rail: env} dict can never be silently applied to a
    different rail's voltage levels (`envelope_for` checks it)."""
    v_min: Any          # f32 [] or [n_chips] — learned minimum safe voltage
    v_max: Any = None   # f32 or None — learned ceiling (None: static only)
    confidence: Any = 0.0
    max_extension_v: float = 0.05
    rail: str = "VDD_IO"

    def floor(self, static_v_min) -> jnp.ndarray:
        s = jnp.asarray(static_v_min, jnp.float32)
        blended = s + jnp.asarray(self.confidence, jnp.float32) \
            * (jnp.asarray(self.v_min, jnp.float32) - s)
        return jnp.maximum(blended, s - jnp.float32(self.max_extension_v))

    def ceil(self, static_v_max) -> jnp.ndarray:
        s = jnp.asarray(static_v_max, jnp.float32)
        if self.v_max is None:
            return s
        blended = s + jnp.asarray(self.confidence, jnp.float32) \
            * (jnp.asarray(self.v_max, jnp.float32) - s)
        return jnp.minimum(blended, s + jnp.float32(self.max_extension_v))


def rail_envelopes(est: SorEstimate, cfg: SorConfig
                   ) -> dict[str, SafeEnvelope]:
    """The estimate as {rail name: SafeEnvelope} — the shape
    `control_plane.arbitrate(envelopes=)` and `policy.decide_env` consume:
    each rail's floor is its fitted frontier plus that rail's guard band,
    ceiling left to the consumer's static limit."""
    out = {}
    for i, spec in enumerate(cfg.rails):
        guard = spec.guard_v if spec.guard_v is not None else cfg.guard_v
        out[spec.rail] = SafeEnvelope(
            v_min=est.v_frontier[i] + jnp.float32(guard),
            v_max=None, confidence=est.confidence[i],
            max_extension_v=cfg.max_extension_v, rail=spec.rail)
    return out


def safe_envelope(est: SorEstimate, cfg: SorConfig) -> SafeEnvelope:
    """Back-compat single-envelope view: the VDD_IO rail's envelope (or the
    sole fitted rail's, for a 1-rail config on another rail)."""
    envs = rail_envelopes(est, cfg)
    if "VDD_IO" in envs:
        return envs["VDD_IO"]
    if len(envs) == 1:
        return next(iter(envs.values()))
    raise KeyError("safe_envelope needs a VDD_IO (or single) rail; "
                   "use rail_envelopes for multi-rail estimates")


def envelope_for(envelope, rail: str = "VDD_IO"):
    """Normalize an envelope argument: a {rail: SafeEnvelope} dict yields
    that rail's envelope (None if unfitted); a bare SafeEnvelope applies
    only to the rail its `rail` tag names (the historical bare spelling
    defaults to VDD_IO — an envelope fitted on another rail is never
    silently blended into a different rail's voltage levels); None passes
    through."""
    if envelope is None:
        return None
    if isinstance(envelope, dict):
        return envelope.get(rail)
    return envelope if getattr(envelope, "rail", "VDD_IO") == rail else None


def as_envelopes(envelope) -> "dict[str, SafeEnvelope] | None":
    """Normalize to the {rail: SafeEnvelope} dict arbitration consumes."""
    if envelope is None or isinstance(envelope, dict):
        return envelope
    return {getattr(envelope, "rail", "VDD_IO"): envelope}


# ---------------------------------------------------------------------------
# SorState: the functional bundle controllers carry
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=["history", "estimate", "tick"],
         meta_fields=[])
@dataclasses.dataclass(frozen=True)
class SorState:
    """(history, estimate, tick): what a controller threads through its
    loop. `InGraphRailController.control_step_sor` carries it through the
    jitted scan (and `make_fleet_train_step` through the trainer state);
    `HostRailController` holds it between decisions. A registered pytree, so
    `ckpt.save` persists it like any other state group and learned regions
    survive restarts (`ckpt.remap_sor` resizes it across fleets)."""
    history: FrameHistory
    estimate: SorEstimate
    tick: Any   # i32 [] — observations seen


def init_state(cfg: SorConfig, n_chips: int | None = None) -> SorState:
    return SorState(
        history=FrameHistory.create(cfg.capacity, n_chips, rails=cfg.rails),
        estimate=SorEstimate.init(n_chips, n_rails=cfg.n_rails),
        tick=jnp.int32(0))


def partition_specs(state: SorState, axis_name: str = "chips"):
    """Exact `PartitionSpec` pytree for a fleet `SorState` on a 1-D
    `axis_name` mesh: the history ring `[capacity, n_rails, n]` and the
    estimate `[n_rails, n]` shard their trailing chip axis — per-shard
    resident, never gathered — while `tick` replicates (it drives the
    refresh-cadence `lax.cond`, so every shard must take the same branch).
    Raises for non-fleet states: there is no chip axis to shard."""
    chip_shape = state.history.chip_shape
    if len(chip_shape) != 1:
        raise ValueError(
            "partition_specs needs a fleet SorState with a 1-D chip axis, "
            f"got chip_shape={chip_shape!r}")
    return ops.chip_specs(state, chip_shape[0], axis_name)


def observe(state: SorState, frame: TelemetryFrame,
            cfg: SorConfig, fused: "bool | None" = None) -> SorState:
    """Push one observation and refresh the estimate on the configured
    cadence. On the eager host path the off-cadence refits are skipped
    outright. Under a trace, the default batches the refits: one
    `lax.cond` per round means the refit graph executes only on every
    `refresh_every`-th round instead of being computed every step and
    discarded — the amortization that closes the learned-control-path gap
    (docs/sor.md "fused control round"). `fused=False` keeps the historical
    compute-always + select-by-tick graph as the bit-equivalence oracle:
    on-cadence rounds adopt the identical refit, off-cadence rounds keep
    the identical prior, so the two compiled trajectories are bit-equal
    (pinned by tests/test_fused_control_round.py)."""
    hist = state.history.push(frame)
    tick = state.tick + 1
    if isinstance(tick, jax.core.Tracer):
        do = (tick % cfg.refresh_every) == 0
        if fused is not False:
            est = jax.lax.cond(
                do,
                lambda est_h: update_estimate(est_h[0], est_h[1], cfg,
                                              fused=True),
                lambda est_h: est_h[0],
                (state.estimate, hist))
        else:
            refreshed = update_estimate(state.estimate, hist, cfg,
                                        fused=False)
            est = jax.tree_util.tree_map(
                lambda a, b: jnp.where(do, b, a), state.estimate, refreshed)
    elif int(tick) % cfg.refresh_every == 0:
        est = update_estimate(state.estimate, hist, cfg, fused=fused)
    else:
        est = state.estimate
    return SorState(history=hist, estimate=est, tick=tick)


def merge_observables(sample: TelemetryFrame, src: TelemetryFrame,
                      cfg: SorConfig) -> TelemetryFrame:
    """Overlay the per-rail failure observables the fit needs (named by
    `cfg.rails`) from `src` (the frame the decision consumed) onto `sample`
    (e.g. a raw `poll_frame` sweep). A rail whose observable `src` does not
    carry records NaN — that rail's lane is simply invalid for this sample,
    instead of silently attributing another rail's error to it."""
    kw: dict[str, Any] = {}
    extras = dict(sample.extras)
    for spec in cfg.rails:
        v = src.get(spec.key)
        v = jnp.nan if v is None else v
        if spec.key in TelemetryFrame.__dataclass_fields__:
            kw[spec.key] = v
        else:
            extras[spec.key] = v
    return dataclasses.replace(sample, extras=extras, **kw)


def summary(est: SorEstimate, cfg: SorConfig) -> dict[str, float]:
    """Host-side telemetry view of an estimate (trainer/serve summaries).
    Single-rail configs keep the historical flat keys; multi-rail configs
    additionally emit per-rail `<RAIL>/...` keys (all values numeric)."""
    if est.n_rails != cfg.n_rails:
        # a mismatched config would silently fold rails into the chip axis
        # below — refuse instead (e.g. TrainerConfig.sor disagreeing with
        # the FleetStepConfig.sor the state was actually learned under)
        raise ValueError(
            f"estimate carries {est.n_rails} rail(s) but the SorConfig "
            f"declares {cfg.n_rails} ({[s.rail for s in cfg.rails]}); "
            f"summarize with the config the state was learned under")
    conf = np.asarray(jax.device_get(est.confidence), np.float64)
    front = np.asarray(jax.device_get(est.v_frontier), np.float64)
    n_eff = np.asarray(jax.device_get(est.n_eff), np.float64)
    # [n_rails] (scalar chip) and [n_rails, n_chips] both -> [n_rails, chips]
    conf, front, n_eff = (a.reshape(cfg.n_rails, -1)
                          for a in (conf, front, n_eff))

    def rail_stats(i: int, spec: RailObservable) -> dict[str, float]:
        c, f, n = conf[i], front[i], n_eff[i]
        learned = c > 0.0
        guard = spec.guard_v if spec.guard_v is not None else cfg.guard_v
        floor = f + guard
        out = {
            "n_chips": int(c.size),
            "chips_learned": int(learned.sum()),
            "confidence_mean": float(c.mean()),
            "confidence_min": float(c.min()),
            "n_eff_mean": float(n.mean()),
        }
        if learned.any():
            out["floor_min_v"] = float(floor[learned].min())
            out["floor_max_v"] = float(floor[learned].max())
            out["floor_mean_v"] = float(floor[learned].mean())
        return out

    if cfg.n_rails == 1:
        return rail_stats(0, cfg.rails[0])
    out: dict[str, float] = {
        "n_chips": int(conf.shape[1]),
        "n_rails": cfg.n_rails,
        "chips_learned": int((conf > 0.0).any(axis=0).sum()),
        "confidence_mean": float(conf.mean()),
    }
    for i, spec in enumerate(cfg.rails):
        for k, v in rail_stats(i, spec).items():
            if k != "n_chips":
                out[f"{spec.rail}/{k}"] = v
    return out


# re-exported for consumers that configure rails through this module
__all__ = [
    "ALL_RAIL_OBSERVABLES", "DEFAULT_RAIL_OBSERVABLES", "RailObservable",
    "SorConfig", "SorEstimate", "SafeEnvelope", "SorState",
    "fit_history", "update_estimate", "rail_envelopes", "safe_envelope",
    "envelope_for", "as_envelopes", "init_state", "observe",
    "merge_observables", "summary",
]
