"""Learned per-chip safe operating regions (paper §VIII future work, at
fleet scale).

VolTune's headline result is a *bounded operating region*: undervolt the
transceiver rail as far as the measured BER frontier allows (≈29.3% rail
power at 10 Gbps with BER <= 1e-6) — and its future-work section asks for
learning that region at runtime instead of hard-coding it. This module is
that subsystem for the TPU adaptation (docs/sor.md):

    FrameHistory  ->  SorEstimate  ->  SafeEnvelope  ->  arbitration
    (telemetry)       (fitted frontier)  (per-chip v_min)   (control_plane)

* `telemetry.FrameHistory` — fixed-capacity ring of (voltage, measured
  error, age, provenance) samples per chip, stacked jnp arrays so the whole
  store jits/vmaps and rides a scan carry.
* `SorEstimate` — each chip's fitted log10(error)-vs-voltage frontier:
  slope + intercept from exponentially-weighted least squares over the
  history window, the frontier voltage where the modeled error meets a
  caller-chosen bound, and a confidence in [0, 1] that gates everything
  downstream. All math is elementwise jnp over `[n_chips]` (Pallas-friendly:
  the same streaming-reduction shape as kernels/fleet_telemetry.py).
* `SafeEnvelope` — per-chip v_min/v_max derived from the fit at the bound,
  *blended with the caller's static envelope by confidence*: at zero
  confidence the envelope IS the static one (bit-exact — the cold-start
  no-behavior-change pin), and the learned floor may extend below the static
  floor by at most `max_extension_v` (bounded exploration).

Consumers: `policy.BERBounded/ClosedLoop/WorstChipGate` warm-start their
decisions from the envelope (`decide_env`), `control_plane.arbitrate` clamps
requests against per-chip envelopes instead of the one shared rail envelope,
and both controllers maintain the history/estimate on a configurable cadence
(`SorConfig.refresh_every`).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.telemetry import FrameHistory, TelemetryFrame

LOG10_ERR_FLOOR = -8.0   # zero-error samples clamp here (detection floor)
LOG10_ERR_CEIL = 2.0


@dataclasses.dataclass(frozen=True)
class SorConfig:
    """Knobs of the safe-operating-region learner.

    `error_bound` is the measured-error bound the frontier is cut at (the
    gradient-domain analogue of the paper's BER <= 1e-6); `guard_v` is the
    guard band added above the fitted frontier voltage; `max_extension_v`
    bounds how far below a consumer's *static* floor the learned floor may
    reach (confidence-gated exploration, never a free fall)."""
    capacity: int = 32           # history window (samples per chip)
    refresh_every: int = 4       # observations between estimate refreshes
    error_bound: float = 5e-3    # frontier cut: modeled error == this bound
    guard_v: float = 0.010       # volts of guard band above the frontier
    decay: float = 0.92          # per-slot recency decay of the EWLS weights
    update_gain: float = 1.0     # EW blend of a refit into the running fit
    min_slope: float = 0.5       # |d log10(err)/dV| below this -> no trust
    min_spread_v: float = 2e-3   # required voltage stddev in the window
    conf_samples: float = 8.0    # effective samples to ~63% confidence
    age_halflife_s: "float | None" = None  # None: staleness-blind weights;
    #                              else a sample's weight halves per this
    #                              many seconds of observation age
    max_extension_v: float = 0.05  # max reach below a consumer's static floor
    ingest: str = "polled"       # "polled": learn only from READ_VOUT
    #                              samples; "frames": learn from whatever
    #                              frame the decision consumed (EXACT ok)

    def __post_init__(self):
        if self.ingest not in ("polled", "frames"):
            raise ValueError(f"ingest must be 'polled' or 'frames', "
                             f"got {self.ingest!r}")
        if not 0.0 < self.decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {self.decay}")
        if self.refresh_every < 1:
            raise ValueError("refresh_every must be >= 1")


@partial(jax.tree_util.register_dataclass,
         data_fields=["intercept", "slope", "v_frontier", "confidence",
                      "n_eff"],
         meta_fields=[])
@dataclasses.dataclass(frozen=True)
class SorEstimate:
    """One chip's (or `[n_chips]`-batched) fitted BER frontier:
    log10(error)(v) ~= intercept + slope * v, with `v_frontier` the voltage
    where the model meets the configured bound and `confidence` in [0, 1]
    gating every consumer. Zero confidence == no opinion (cold start)."""
    intercept: Any    # f32 [] or [n_chips]
    slope: Any        # f32 — d log10(err)/dV, negative when healthy
    v_frontier: Any   # f32 — modeled log10(err) == log10(bound) here
    confidence: Any   # f32 in [0, 1]
    n_eff: Any        # f32 — effective (decayed) sample count behind the fit

    @staticmethod
    def init(n_chips: int | None = None) -> "SorEstimate":
        shape = () if n_chips is None else (n_chips,)
        z = jnp.zeros(shape, jnp.float32)
        return SorEstimate(intercept=z, slope=z, v_frontier=z,
                           confidence=z, n_eff=z)

    def log10_error_at(self, v) -> jnp.ndarray:
        """Modeled log10(error) at rail voltage `v` (elementwise)."""
        return self.intercept + self.slope * jnp.asarray(v, jnp.float32)


def fit_history(history: FrameHistory, cfg: SorConfig) -> SorEstimate:
    """Exponentially-weighted least squares of log10(error) against the
    VDD_IO observation over the history window — elementwise per chip, pure
    jnp (jit/vmap/scan safe; the same [window, n_chips] streaming-reduction
    shape the Pallas fleet-telemetry kernel handles at scale).

    Confidence gates on three things at once: enough effective samples
    (`conf_samples` ramp), enough voltage spread to identify a slope
    (`min_spread_v`), and a frontier with the right sign and steepness
    (`min_slope`; error must *grow* as voltage drops)."""
    eps = jnp.float32(1e-9)
    w = history.recency_weights(cfg.decay)
    if cfg.age_halflife_s is not None:
        # POLLED samples that were already stale when observed carry less
        # weight (halving per age_halflife_s of recorded staleness)
        w = w * 0.5 ** (history.age_s / jnp.float32(cfg.age_halflife_s))
    x = jnp.where(history.valid, history.v_io, 0.0)
    y = jnp.clip(
        jnp.log10(jnp.maximum(history.error, 10.0 ** LOG10_ERR_FLOOR)),
        LOG10_ERR_FLOOR, LOG10_ERR_CEIL)
    y = jnp.where(history.valid, y, 0.0)

    sw = jnp.sum(w, axis=0)
    sx = jnp.sum(w * x, axis=0)
    sy = jnp.sum(w * y, axis=0)
    sxx = jnp.sum(w * x * x, axis=0)
    sxy = jnp.sum(w * x * y, axis=0)

    denom = sw * sxx - sx * sx
    slope = (sw * sxy - sx * sy) / jnp.maximum(denom, eps)
    intercept = (sy - slope * sx) / jnp.maximum(sw, eps)
    var_x = jnp.maximum(sxx / jnp.maximum(sw, eps)
                        - (sx / jnp.maximum(sw, eps)) ** 2, 0.0)

    steep = slope < -jnp.float32(cfg.min_slope)
    spread = var_x > jnp.float32(cfg.min_spread_v) ** 2
    usable = steep & spread & (denom > eps)

    log10_bound = jnp.float32(np.log10(cfg.error_bound))
    v_frontier = jnp.where(
        usable, (log10_bound - intercept) / jnp.where(usable, slope, -1.0),
        0.0)
    v_frontier = jnp.clip(v_frontier, 0.0, 2.0)   # sanity, conf gates anyway
    confidence = jnp.where(
        usable, 1.0 - jnp.exp(-sw / jnp.float32(cfg.conf_samples)), 0.0)
    return SorEstimate(
        intercept=jnp.where(usable, intercept, 0.0).astype(jnp.float32),
        slope=jnp.where(usable, slope, 0.0).astype(jnp.float32),
        v_frontier=v_frontier.astype(jnp.float32),
        confidence=confidence.astype(jnp.float32),
        n_eff=sw.astype(jnp.float32))


def update_estimate(old: SorEstimate, history: FrameHistory,
                    cfg: SorConfig) -> SorEstimate:
    """Online refresh: refit the window, then blend into the running
    estimate with `update_gain` (1.0 == adopt the refit). A window that
    yields no usable fit keeps the previous estimate — a chip whose polls
    stopped does not forget its learned region, and a cold chip stays at
    zero confidence."""
    fit = fit_history(history, cfg)
    gain = jnp.where(old.confidence > 0.0, jnp.float32(cfg.update_gain), 1.0)
    return jax.tree_util.tree_map(
        lambda o, f: jnp.where(fit.confidence > 0.0, o + gain * (f - o),
                               jnp.where(old.confidence > 0.0, o, f)),
        old, fit)


# ---------------------------------------------------------------------------
# SafeEnvelope: the fit, expressed as per-chip operating limits
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=["v_min", "v_max", "confidence"],
         meta_fields=["max_extension_v"])
@dataclasses.dataclass(frozen=True)
class SafeEnvelope:
    """Per-chip learned operating limits for one rail, confidence-blended
    against whatever *static* limit the consumer holds (a policy's
    `v_io_floor`, arbitration's rail `v_min`): at zero confidence the
    blended limit is bit-exactly the static one, at full confidence it is
    the learned frontier. The learned floor may reach below the static one
    by at most `max_extension_v` — conservative, bounded exploration."""
    v_min: Any          # f32 [] or [n_chips] — learned minimum safe voltage
    v_max: Any = None   # f32 or None — learned ceiling (None: static only)
    confidence: Any = 0.0
    max_extension_v: float = 0.05

    def floor(self, static_v_min) -> jnp.ndarray:
        s = jnp.asarray(static_v_min, jnp.float32)
        blended = s + jnp.asarray(self.confidence, jnp.float32) \
            * (jnp.asarray(self.v_min, jnp.float32) - s)
        return jnp.maximum(blended, s - jnp.float32(self.max_extension_v))

    def ceil(self, static_v_max) -> jnp.ndarray:
        s = jnp.asarray(static_v_max, jnp.float32)
        if self.v_max is None:
            return s
        blended = s + jnp.asarray(self.confidence, jnp.float32) \
            * (jnp.asarray(self.v_max, jnp.float32) - s)
        return jnp.minimum(blended, s + jnp.float32(self.max_extension_v))


def safe_envelope(est: SorEstimate, cfg: SorConfig) -> SafeEnvelope:
    """The estimate as a rail envelope: floor at the fitted frontier plus
    the guard band, ceiling left to the consumer's static limit."""
    return SafeEnvelope(v_min=est.v_frontier + jnp.float32(cfg.guard_v),
                        v_max=None, confidence=est.confidence,
                        max_extension_v=cfg.max_extension_v)


# ---------------------------------------------------------------------------
# SorState: the functional bundle controllers carry
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=["history", "estimate", "tick"],
         meta_fields=[])
@dataclasses.dataclass(frozen=True)
class SorState:
    """(history, estimate, tick): what a controller threads through its
    loop. `InGraphRailController.control_step_sor` carries it through the
    jitted scan; `HostRailController` holds it between decisions."""
    history: FrameHistory
    estimate: SorEstimate
    tick: Any   # i32 [] — observations seen


def init_state(cfg: SorConfig, n_chips: int | None = None) -> SorState:
    return SorState(history=FrameHistory.create(cfg.capacity, n_chips),
                    estimate=SorEstimate.init(n_chips),
                    tick=jnp.int32(0))


def observe(state: SorState, frame: TelemetryFrame,
            cfg: SorConfig) -> SorState:
    """Push one observation and refresh the estimate on the configured
    cadence. Under a trace the refresh is computed every step and selected
    by tick (one graph serves every step of a scan); on the eager host path
    the off-cadence refits are skipped outright instead of computed and
    discarded."""
    hist = state.history.push(frame)
    tick = state.tick + 1
    if isinstance(tick, jax.core.Tracer):
        refreshed = update_estimate(state.estimate, hist, cfg)
        do = (tick % cfg.refresh_every) == 0
        est = jax.tree_util.tree_map(
            lambda a, b: jnp.where(do, b, a), state.estimate, refreshed)
    elif int(tick) % cfg.refresh_every == 0:
        est = update_estimate(state.estimate, hist, cfg)
    else:
        est = state.estimate
    return SorState(history=hist, estimate=est, tick=tick)


def summary(est: SorEstimate, cfg: SorConfig) -> dict[str, float]:
    """Host-side telemetry view of an estimate (trainer/serve summaries)."""
    conf = np.atleast_1d(np.asarray(jax.device_get(est.confidence),
                                    np.float64))
    front = np.atleast_1d(np.asarray(jax.device_get(est.v_frontier),
                                     np.float64))
    n_eff = np.atleast_1d(np.asarray(jax.device_get(est.n_eff), np.float64))
    learned = conf > 0.0
    floor = front + cfg.guard_v
    out = {
        "n_chips": int(conf.size),
        "chips_learned": int(learned.sum()),
        "confidence_mean": float(conf.mean()),
        "confidence_min": float(conf.min()),
        "n_eff_mean": float(n_eff.mean()),
    }
    if learned.any():
        out["floor_min_v"] = float(floor[learned].min())
        out["floor_max_v"] = float(floor[learned].max())
        out["floor_mean_v"] = float(floor[learned].mean())
    return out
