"""UCD9248 regulator-channel model (paper Fig 6 + §V-B dynamics).

The UCD9248 does not apply VOUT_COMMAND directly to the DAC: the programmed
value passes through calibration offset, limit clamping, and scaling before
driving the DAC reference (paper Fig 6), and the rail then slews toward the
new reference with finite regulator response ("voltage adjustment must be
treated as a regulator-level operation with finite response and settling
time, not as an instantaneous rail change").

Dynamics model: slew-rate-limited first-order response,

    dv/dt = clip((v_ref - v) / tau, -slew, +slew)

which has a closed-form piecewise solution (linear ramp while the error
exceeds slew*tau, exponential tail inside). The (slew, tau) defaults are
calibrated so that the full HW-path/400 kHz voltage-update sequence
(PAGE + 4 threshold writes + VOUT_COMMAND, paper §IV-E) plus settling for a
1.0 V -> 0.5 V step completes end-to-end in 2.3 ms (paper Fig 7a), with
transition time monotone in the step size |dV| (paper Fig 7b).
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import codecs

# Calibrated dynamics (see module docstring + EXPERIMENTS.md validation).
DEFAULT_SLEW_V_PER_S = 350.0      # 0.35 V/ms slew limit
DEFAULT_TAU_S = 0.17e-3           # first-order tail time constant
DEFAULT_ADC_NOISE_V = 0.3e-3      # telemetry readback noise sigma (V)


@dataclasses.dataclass
class _Segment:
    """One commanded transition: closed-form v(t) for t >= t0."""
    t0: float
    v_start: float
    v_target: float
    slew: float
    tau: float

    def voltage_at(self, t: float) -> float:
        dt = max(0.0, t - self.t0)
        err0 = self.v_target - self.v_start
        sgn = 1.0 if err0 >= 0 else -1.0
        knee = self.slew * self.tau  # error magnitude where ramp -> exponential
        if abs(err0) > knee:
            t_lin = (abs(err0) - knee) / self.slew
            if dt <= t_lin:
                return self.v_start + sgn * self.slew * dt
            # exponential tail from error = knee
            return self.v_target - sgn * knee * math.exp(-(dt - t_lin) / self.tau)
        # small step: pure first-order response
        return self.v_target - err0 * math.exp(-dt / self.tau)

    def time_to_band(self, band_v: float) -> float:
        """Time after t0 until |v - v_target| <= band_v (stays inside after)."""
        err0 = abs(self.v_target - self.v_start)
        if err0 <= band_v:
            return 0.0
        knee = self.slew * self.tau
        if err0 > knee:
            t_lin = (err0 - knee) / self.slew
            if band_v >= knee:
                return (err0 - band_v) / self.slew
            return t_lin + self.tau * math.log(knee / band_v)
        return self.tau * math.log(err0 / band_v)


class RegulatorChannel:
    """One output channel (= one PAGE) of a UCD9248-like regulator."""

    def __init__(
        self,
        nominal_v: float,
        v_min: float,
        v_max: float,
        *,
        cal_offset_v: float = 0.0,
        dac_gain: float = 1.0,
        slew_v_per_s: float = DEFAULT_SLEW_V_PER_S,
        tau_s: float = DEFAULT_TAU_S,
        adc_noise_v: float = DEFAULT_ADC_NOISE_V,
        seed: int = 0,
    ):
        self.nominal_v = nominal_v
        self.v_min = v_min
        self.v_max = v_max
        self.cal_offset_v = cal_offset_v
        self.dac_gain = dac_gain
        self.slew = slew_v_per_s
        self.tau = tau_s
        self.adc_noise_v = adc_noise_v
        self._seed = seed
        self._segment = _Segment(0.0, nominal_v, nominal_v, self.slew, self.tau)
        # Protection/monitoring registers (written via PMBus; paper §IV-E).
        self.uv_warn_limit_v = nominal_v * 0.9
        self.uv_fault_limit_v = nominal_v * 0.85
        self.power_good_on_v = nominal_v * 0.92
        self.power_good_off_v = nominal_v * 0.88
        self.fault_latched = False

    # -- Fig 6 control path ------------------------------------------------
    def _reference_from_command(self, commanded_v: float) -> float:
        """VOUT_COMMAND -> cal offset -> limit clamp -> scale -> DAC ref."""
        v = commanded_v + self.cal_offset_v
        v = min(max(v, self.v_min), self.v_max)
        return v * self.dac_gain

    def command_voltage(self, commanded_v: float, t_now: float) -> float:
        """Apply a VOUT_COMMAND at simulated time `t_now` (end of the PMBus
        transaction). Returns the post-clamp DAC reference actually used."""
        v_now = self.voltage_at(t_now)
        ref = self._reference_from_command(commanded_v)
        self._segment = _Segment(t_now, v_now, ref, self.slew, self.tau)
        return ref

    # -- observation --------------------------------------------------------
    def voltage_at(self, t: float) -> float:
        return self._segment.voltage_at(t)

    def telemetry_voltage(self, t: float) -> float:
        """ADC-sampled readback: true rail voltage + deterministic noise,
        quantized to LINEAR16 resolution (what READ_VOUT returns)."""
        v = self.voltage_at(t)
        # Deterministic noise: hash of (seed, quantized time) -> ~N(0, sigma).
        h = hash((self._seed, round(t * 1e7))) & 0xFFFFFFFF
        u1 = ((h & 0xFFFF) + 0.5) / 65536.0
        u2 = (((h >> 16) & 0xFFFF) + 0.5) / 65536.0
        gauss = math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)
        v_noisy = v + gauss * self.adc_noise_v
        word = codecs.linear16_encode(max(0.0, v_noisy))
        return codecs.linear16_decode(word)

    def update_faults(self, t: float) -> None:
        if self.voltage_at(t) < self.uv_fault_limit_v:
            self.fault_latched = True

    def power_good(self, t: float) -> bool:
        v = self.voltage_at(t)
        return v >= self.power_good_off_v

    def settle_time_to_band(self, band_v: float) -> float:
        """Analytic time (s) from the last command until the rail is inside
        +/- band_v of its target. Used for calibration tests; the benchmarks
        measure the same thing from sampled telemetry via §V-D detection."""
        return self._segment.time_to_band(band_v)

    @property
    def target_v(self) -> float:
        return self._segment.v_target
