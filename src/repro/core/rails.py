"""Rail maps: lane -> (PMBus address, PAGE) (paper Table II) plus the TPU
logical-rail map used by the adaptation layer (DESIGN.md §2.2).

The lane number is a VolTune-specific identifier, not part of the PMBus
standard (paper §IV-C). Porting to another platform only requires providing
this mapping (paper §VII-D) — which is exactly what `TPU_V5E_RAILS` does for
the simulated TPU power plane.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Rail:
    lane: int
    name: str
    pmbus_address: int
    page: int
    nominal_v: float
    # Safe runtime envelope (paper §VII-B: per-rail safety envelopes are
    # platform-specific and enforced by the policy layer, not the mechanism).
    v_min: float
    v_max: float


# Paper Table II, with nominal voltages from the KC705 user guide (UG810).
KC705_RAILS: tuple[Rail, ...] = (
    Rail(0, "VCCINT", 52, 0, 1.00, 0.50, 1.10),
    Rail(1, "VCCAUX", 52, 1, 1.80, 1.50, 1.98),
    Rail(2, "VCC3V3", 52, 2, 3.30, 3.00, 3.60),
    Rail(3, "VADJ", 52, 3, 2.50, 1.80, 3.30),
    Rail(4, "VCC2V5", 53, 0, 2.50, 2.20, 2.75),
    Rail(5, "VCC1V5", 53, 1, 1.50, 1.30, 1.65),
    Rail(6, "MGTAVCC", 53, 2, 1.00, 0.50, 1.10),
    Rail(7, "MGTAVTT", 53, 3, 1.20, 1.00, 1.32),
    Rail(8, "VCCAUX_IO", 54, 0, 1.80, 1.60, 1.98),
    Rail(9, "VCCBRAM", 54, 1, 1.00, 0.70, 1.10),
    Rail(10, "MGTVCCAUX", 54, 2, 1.80, 1.60, 1.98),
)


# TPU v5e logical rails (DESIGN.md §2.2). One UCD9248-like simulated regulator
# device per chip; lanes follow the same lane->(address,page) discipline so the
# whole PowerManager/PMBus stack is reused unchanged.
TPU_V5E_RAILS: tuple[Rail, ...] = (
    Rail(0, "VDD_CORE", 96, 0, 0.90, 0.60, 0.99),   # MXU/VPU/scalar core
    Rail(1, "VDD_HBM", 96, 1, 1.10, 0.90, 1.21),    # HBM2e interface + stacks
    Rail(2, "VDD_IO", 96, 2, 0.95, 0.65, 1.05),     # ICI SerDes (the MGTAVCC analogue)
)


class RailMap:
    """Lane-indexed rail lookup used by the PowerManager conversion path
    (paper §IV-D step 1: resolve lane -> (address, PAGE))."""

    def __init__(self, rails: tuple[Rail, ...]):
        self._by_lane = {r.lane: r for r in rails}
        self._by_name = {r.name: r for r in rails}
        if len(self._by_lane) != len(rails):
            raise ValueError("duplicate lane numbers in rail map")

    def by_lane(self, lane: int) -> Rail:
        try:
            return self._by_lane[lane]
        except KeyError:
            raise KeyError(f"unknown lane {lane}; known: {sorted(self._by_lane)}") from None

    def by_name(self, name: str) -> Rail:
        try:
            return self._by_name[name]
        except KeyError:
            raise KeyError(f"unknown rail {name!r}; known: {sorted(self._by_name)}") from None

    def lanes(self) -> list[int]:
        return sorted(self._by_lane)

    def devices(self) -> list[int]:
        """Distinct PMBus device addresses in this map."""
        return sorted({r.pmbus_address for r in self._by_lane.values()})

    def pages_for_device(self, address: int) -> dict[int, Rail]:
        return {r.page: r for r in self._by_lane.values() if r.pmbus_address == address}

    def __iter__(self):
        return iter(sorted(self._by_lane.values(), key=lambda r: r.lane))

    def __len__(self) -> int:
        return len(self._by_lane)


KC705_RAIL_MAP = RailMap(KC705_RAILS)
TPU_V5E_RAIL_MAP = RailMap(TPU_V5E_RAILS)
