"""Fleet-scale PMBus: N boards, each with its own serialized bus segment,
sharing one fleet timeline through an event queue.

The single-board model (pmbus.PmBus) serializes every transaction on one
global clock, so actuating a fleet of N chips would cost N x the single-board
latency in simulated time — physically wrong (each board has its own two-wire
bus) and computationally hopeless for 1000-chip sweeps. Here each board is a
`BusSegment`: a full PowerManager stack (UCD9248 model + regulator dynamics +
per-path controller overheads) on its *own local clock*. Fleet-level
operations schedule per-segment work as events on the shared timeline
(pmbus.EventQueue), let every segment run ahead independently, then advance
fleet time to the max over segments — fleet actuations overlap in simulated
time exactly as N independent buses would.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core.pmbus import EventQueue, SimClock
from repro.core.power_manager import ControlPath, PowerManager
from repro.core.rails import TPU_V5E_RAIL_MAP, RailMap


@dataclasses.dataclass
class FleetActuationReport:
    """Timing + outcome of one fleet-wide actuation round."""
    boards_touched: int
    lane_writes: int            # command sequences that completed on a bus
    elapsed_s: float            # fleet-time cost (max over segments)
    serialized_s: float         # what one shared bus would have cost (sum)
    failed_writes: int = 0      # rejected requests (e.g. outside envelope)
    errors: tuple[str, ...] = ()

    @property
    def ok(self) -> bool:
        return self.failed_writes == 0

    @property
    def overlap_speedup(self) -> float:
        return self.serialized_s / self.elapsed_s if self.elapsed_s > 0 else 1.0


class BusSegment:
    """One board's serialized PMBus + regulators on a local timeline.

    The local clock may run ahead of fleet time while an actuation is in
    flight; `catch_up` models the segment sitting idle until fleet time
    passes it again."""

    def __init__(self, board_id: int, pm: PowerManager):
        self.board_id = board_id
        self.pm = pm
        self.busy_seconds = 0.0

    @property
    def local_now(self) -> float:
        return self.pm.clock.now

    def catch_up(self, t: float) -> None:
        self.pm.clock.advance_to(t)

    def set_voltage_settled(self, lane: int, volts: float,
                            settle_band_frac: float = 0.01
                            ) -> tuple[float, str | None]:
        """Full voltage-update workflow + wait for regulator settling on this
        segment's local clock; returns (achieved rail voltage, error) where
        error is None on success and the rejection reason otherwise."""
        t0 = self.pm.clock.now
        res = self.pm.set_voltage(lane, volts)
        if res.ok:
            ch = self.pm.channels[lane]
            self.pm.clock.advance(
                ch.settle_time_to_band(abs(volts) * settle_band_frac))
        self.busy_seconds += self.pm.clock.now - t0
        return self.pm.rail_voltage_now(lane), (None if res.ok else res.error)

    def rail_voltage(self, lane: int) -> float:
        return self.pm.rail_voltage_now(lane)


class FleetPowerManager:
    """Event-scheduled multi-segment bus: one PowerManager per board, one
    shared fleet clock, actuation rounds that cost max-over-segments.

    `apply_setpoints` is the fleet analogue of the old single-board
    HostPowerController.apply: push per-chip rail setpoints, pay the
    characterized PMBus + settling cost *concurrently across boards*, and
    read back what each regulator actually achieved."""

    def __init__(
        self,
        n_boards: int,
        rail_map: RailMap = TPU_V5E_RAIL_MAP,
        *,
        path: ControlPath | str = ControlPath.SOFTWARE,
        clock_hz: int = 400_000,
        seed: int = 0,
        loads: dict[str, Callable[[float, float], float]] | None = None,
    ):
        if n_boards < 1:
            raise ValueError(f"n_boards must be >= 1, got {n_boards}")
        self.rail_map = rail_map
        self.clock = SimClock()            # fleet (global) time
        self.events = EventQueue()
        self.segments = [
            BusSegment(i, PowerManager(rail_map, path=path, clock_hz=clock_hz,
                                       loads=loads, seed=seed * 8191 + i))
            for i in range(n_boards)
        ]
        self.actuation_rounds = 0
        self.actuation_seconds = 0.0       # fleet-time total
        self.serialized_seconds = 0.0      # sum-over-segments total
        self.lane_writes = 0
        self.failed_writes = 0

    @property
    def n_boards(self) -> int:
        return len(self.segments)

    # -- timeline management ---------------------------------------------------
    def _barrier(self) -> float:
        """Drain due events and advance fleet time to the max segment time."""
        t = max((s.local_now for s in self.segments), default=self.clock.now)
        t = max(t, self.clock.now)
        self.events.run_until(t)
        return self.clock.advance_to(t)

    def sync(self) -> None:
        """Bring every idle segment up to fleet time."""
        for s in self.segments:
            s.catch_up(self.clock.now)

    def idle(self, dt: float) -> None:
        """Let simulated fleet time pass with no bus traffic (e.g. the
        training step between host-path control rounds)."""
        if dt < 0:
            raise ValueError("time cannot go backwards")
        self.clock.advance(dt)
        self.events.run_until(self.clock.now)
        self.sync()

    # -- fleet actuation --------------------------------------------------------
    def apply_setpoints(
        self,
        setpoints: Sequence[dict[int, float]],
        *,
        settle_band_frac: float = 0.01,
        deadband_v: float = 1e-4,
    ) -> tuple[list[dict[int, float]], FleetActuationReport]:
        """Push per-board {lane: volts} setpoints through every segment.

        Per board: skip lanes already within `deadband_v` of the request;
        otherwise run the full Fig-5 command sequence + settling on that
        board's local clock. All touched boards proceed concurrently in
        simulated time; fleet time advances by the slowest board's cost.
        Returns (per-board achieved {lane: volts}, timing report)."""
        if len(setpoints) != self.n_boards:
            raise ValueError(
                f"expected {self.n_boards} setpoint dicts, got {len(setpoints)}")
        self.sync()
        t0 = self.clock.now
        achieved: list[dict[int, float]] = [dict() for _ in self.segments]
        touched = 0
        writes = 0
        errors: list[str] = []

        def make_actuation(seg: BusSegment, wanted: dict[int, float]):
            def fire(t_fire: float, seg=seg, wanted=wanted):
                nonlocal writes
                seg.catch_up(t_fire)
                for lane, volts in sorted(wanted.items()):
                    if abs(seg.rail_voltage(lane) - volts) > deadband_v:
                        v, err = seg.set_voltage_settled(
                            lane, volts, settle_band_frac)
                        achieved[seg.board_id][lane] = v
                        if err is None:
                            writes += 1
                        else:
                            errors.append(
                                f"board {seg.board_id} lane {lane}: {err}")
                    else:
                        achieved[seg.board_id][lane] = seg.rail_voltage(lane)
            return fire

        for seg, wanted in zip(self.segments, setpoints):
            if not wanted:
                continue
            need = any(abs(seg.rail_voltage(l) - v) > deadband_v
                       for l, v in wanted.items())
            if need:
                touched += 1
            # schedule even deadband-only boards so readback is time-consistent
            self.events.schedule(t0, make_actuation(seg, dict(wanted)))

        self.events.run_until(t0)          # fire this round's actuations
        self._barrier()
        elapsed = self.clock.now - t0
        serialized = sum(s.local_now - t0 for s in self.segments
                         if s.local_now > t0)
        self.actuation_rounds += 1
        self.actuation_seconds += elapsed
        self.serialized_seconds += serialized
        self.lane_writes += writes
        self.failed_writes += len(errors)
        return achieved, FleetActuationReport(touched, writes, elapsed,
                                              serialized, len(errors),
                                              tuple(errors))

    # -- telemetry --------------------------------------------------------------
    def readback(self, lanes: Iterable[int] | None = None) -> np.ndarray:
        """Instantaneous true rail voltages, [n_boards, n_lanes] (oscilloscope
        view; PMBus-sampled telemetry goes through each segment's PowerManager)."""
        lanes = list(lanes) if lanes is not None else self.rail_map.lanes()
        self.sync()
        return np.array([[s.rail_voltage(l) for l in lanes]
                         for s in self.segments])

    def stats(self) -> dict[str, float]:
        return {
            "boards": self.n_boards,
            "actuation_rounds": self.actuation_rounds,
            "actuation_s": self.actuation_seconds,
            "serialized_s": self.serialized_seconds,
            "lane_writes": self.lane_writes,
            "failed_writes": self.failed_writes,
            "events_processed": self.events.processed,
            "fleet_time_s": self.clock.now,
            "transactions": sum(s.pm.bus.transaction_count for s in self.segments),
        }
