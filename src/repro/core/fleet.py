"""Fleet-scale PMBus: N boards, each with its own serialized bus segment,
sharing one fleet timeline through an event queue.

The single-board model (pmbus.PmBus) serializes every transaction on one
global clock, so actuating a fleet of N chips would cost N x the single-board
latency in simulated time — physically wrong (each board has its own two-wire
bus) and computationally hopeless for 1000-chip sweeps. Here each board is a
`BusSegment`: a full PowerManager stack (UCD9248 model + regulator dynamics +
per-path controller overheads) on its *own local clock*. Fleet-level
operations schedule per-segment work as events on the shared timeline
(pmbus.EventQueue), let every segment run ahead independently, then advance
fleet time to the max over segments — fleet actuations overlap in simulated
time exactly as N independent buses would.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core.pmbus import EventQueue, SimClock
from repro.core.power_manager import ControlPath, Opcode, PowerManager
from repro.core.rails import TPU_V5E_RAIL_MAP, RailMap


@dataclasses.dataclass
class FleetActuationReport:
    """Timing + outcome of one fleet-wide actuation round."""
    boards_touched: int
    lane_writes: int            # command sequences that completed on a bus
    elapsed_s: float            # fleet-time cost (max over segments)
    serialized_s: float         # what one shared bus would have cost (sum)
    failed_writes: int = 0      # rejected requests (e.g. outside envelope)
    errors: tuple[str, ...] = ()
    deadband_skipped: int = 0   # lanes already within deadband_v (no write)

    @property
    def ok(self) -> bool:
        return self.failed_writes == 0

    @property
    def overlap_speedup(self) -> float:
        return self.serialized_s / self.elapsed_s if self.elapsed_s > 0 else 1.0


class BusSegment:
    """One board's serialized PMBus + regulators on a local timeline.

    The local clock may run ahead of fleet time while an actuation is in
    flight; `catch_up` models the segment sitting idle until fleet time
    passes it again."""

    def __init__(self, board_id: int, pm: PowerManager):
        self.board_id = board_id
        self.pm = pm
        self.busy_seconds = 0.0

    @property
    def local_now(self) -> float:
        return self.pm.clock.now

    def catch_up(self, t: float) -> None:
        self.pm.clock.advance_to(t)

    def set_voltage_settled(self, lane: int, volts: float,
                            settle_band_frac: float = 0.01
                            ) -> tuple[float, str | None]:
        """Full voltage-update workflow + wait for regulator settling on this
        segment's local clock; returns (achieved rail voltage, error) where
        error is None on success and the rejection reason otherwise."""
        t0 = self.pm.clock.now
        res = self.pm.set_voltage(lane, volts)
        if res.ok:
            ch = self.pm.channels[lane]
            self.pm.clock.advance(
                ch.settle_time_to_band(abs(volts) * settle_band_frac))
        self.busy_seconds += self.pm.clock.now - t0
        return self.pm.rail_voltage_now(lane), (None if res.ok else res.error)

    def rail_voltage(self, lane: int) -> float:
        return self.pm.rail_voltage_now(lane)


@dataclasses.dataclass
class SegmentPollStats:
    """Outcome of one segment's periodic READ_VOUT telemetry polling.

    `requested_interval_s` is what the operator asked for (defaults to the
    segment's Table VI measurement interval x lanes); `achieved_interval_s`
    is what the bus actually delivered. When a segment's poll rate exceeds
    its serialized two-wire capacity — or actuation traffic occupies the bus
    — polls slip (`deferred`) and the achieved interval degrades; polls are
    *paced*, never queued into a backlog, and actuations are never dropped.

    Deadband back-pressure (`set_poll_relax`): a segment whose lanes all sit
    steady inside their confidence-scaled deadband at a learned floor is
    polled at `relax_factor` x the requested interval — `relaxed_lanes`
    records how many lanes pinned it there and `relaxed_polls` counts the
    rounds fired at the relaxed rate."""
    board_id: int
    requested_interval_s: float
    polls: int = 0              # poll rounds completed
    samples: int = 0            # successful per-lane READ_VOUT samples
    deferred: int = 0           # rounds that slipped past their deadline
    busy_s: float = 0.0         # bus time spent polling
    relax_factor: float = 1.0   # current READ_VOUT interval multiplier
    relaxed_lanes: int = 0      # deadband-pinned lanes behind the relax
    relaxed_polls: int = 0      # poll rounds fired at a relaxed interval
    _last_done: float = math.nan
    _interval_sum_s: float = 0.0
    _intervals: int = 0

    @property
    def achieved_interval_s(self) -> float:
        return (self._interval_sum_s / self._intervals if self._intervals
                else math.nan)

    @property
    def backpressure(self) -> float:
        """achieved / requested interval; > 1 means the segment is
        oversubscribed and polling degraded to what the bus can carry."""
        a = self.achieved_interval_s
        return a / self.requested_interval_s if not math.isnan(a) else 1.0


class FleetPowerManager:
    """Event-scheduled multi-segment bus: one PowerManager per board, one
    shared fleet clock, actuation rounds that cost max-over-segments.

    `apply_setpoints` is the fleet analogue of the old single-board
    HostPowerController.apply: push per-chip rail setpoints, pay the
    characterized PMBus + settling cost *concurrently across boards*, and
    read back what each regulator actually achieved."""

    def __init__(
        self,
        n_boards: int,
        rail_map: RailMap = TPU_V5E_RAIL_MAP,
        *,
        path: ControlPath | str = ControlPath.SOFTWARE,
        clock_hz: int = 400_000,
        seed: int = 0,
        loads: dict[str, Callable[[float, float], float]] | None = None,
    ):
        if n_boards < 1:
            raise ValueError(f"n_boards must be >= 1, got {n_boards}")
        self.rail_map = rail_map
        self.clock = SimClock()            # fleet (global) time
        self.events = EventQueue()
        self.segments = [
            BusSegment(i, PowerManager(rail_map, path=path, clock_hz=clock_hz,
                                       loads=loads, seed=seed * 8191 + i))
            for i in range(n_boards)
        ]
        self.actuation_rounds = 0
        self.actuation_seconds = 0.0       # fleet-time total
        self.serialized_seconds = 0.0      # sum-over-segments total
        self.lane_writes = 0
        self.failed_writes = 0
        self.deadband_skips = 0            # lanes held by the write deadband
        # periodic READ_VOUT telemetry polling (paper Table VI intervals)
        self._polling = False
        self._poll_gen = 0   # invalidates stale periodic events on restart
        self.poll_stats: dict[int, SegmentPollStats] = {}
        self.last_poll: dict[int, dict[int, tuple[float, float]]] = {}

    @property
    def n_boards(self) -> int:
        return len(self.segments)

    # -- timeline management ---------------------------------------------------
    def _barrier(self) -> float:
        """Drain due events and advance fleet time to the max segment time."""
        t = max((s.local_now for s in self.segments), default=self.clock.now)
        t = max(t, self.clock.now)
        self.events.run_until(t)
        return self.clock.advance_to(t)

    def sync(self) -> None:
        """Bring every idle segment up to fleet time."""
        for s in self.segments:
            s.catch_up(self.clock.now)

    def idle(self, dt: float) -> None:
        """Let simulated fleet time pass with no bus traffic (e.g. the
        training step between host-path control rounds)."""
        if dt < 0:
            raise ValueError("time cannot go backwards")
        self.clock.advance(dt)
        self.events.run_until(self.clock.now)
        self.sync()

    # -- fleet actuation --------------------------------------------------------
    def apply_setpoints(
        self,
        setpoints: Sequence[dict[int, float]],
        *,
        settle_band_frac: float = 0.01,
        deadband_v: float = 1e-4,
    ) -> tuple[list[dict[int, float]], FleetActuationReport]:
        """Push per-board {lane: volts} setpoints through every segment.

        Per board: skip lanes already within `deadband_v` of the request;
        otherwise run the full Fig-5 command sequence + settling on that
        board's local clock. All touched boards proceed concurrently in
        simulated time; fleet time advances by the slowest board's cost.
        Returns (per-board achieved {lane: volts}, timing report)."""
        if len(setpoints) != self.n_boards:
            raise ValueError(
                f"expected {self.n_boards} setpoint dicts, got {len(setpoints)}")
        self.sync()
        t0 = self.clock.now
        achieved: list[dict[int, float]] = [dict() for _ in self.segments]
        touched = 0
        writes = 0
        skipped = 0
        errors: list[str] = []

        def make_actuation(seg: BusSegment, wanted: dict[int, float]):
            def fire(t_fire: float, seg=seg, wanted=wanted):
                nonlocal writes, skipped
                seg.catch_up(t_fire)
                for lane, volts in sorted(wanted.items()):
                    if abs(seg.rail_voltage(lane) - volts) > deadband_v:
                        v, err = seg.set_voltage_settled(
                            lane, volts, settle_band_frac)
                        achieved[seg.board_id][lane] = v
                        if err is None:
                            writes += 1
                        else:
                            errors.append(
                                f"board {seg.board_id} lane {lane}: {err}")
                    else:
                        skipped += 1
                        achieved[seg.board_id][lane] = seg.rail_voltage(lane)
            return fire

        for seg, wanted in zip(self.segments, setpoints):
            if not wanted:
                continue
            need = any(abs(seg.rail_voltage(l) - v) > deadband_v
                       for l, v in wanted.items())
            if need:
                touched += 1
            # schedule even deadband-only boards so readback is time-consistent
            self.events.schedule(t0, make_actuation(seg, dict(wanted)))

        self.events.run_until(t0)          # fire this round's actuations
        self._barrier()
        elapsed = self.clock.now - t0
        serialized = sum(s.local_now - t0 for s in self.segments
                         if s.local_now > t0)
        self.actuation_rounds += 1
        self.actuation_seconds += elapsed
        self.serialized_seconds += serialized
        self.lane_writes += writes
        self.failed_writes += len(errors)
        self.deadband_skips += skipped
        return achieved, FleetActuationReport(touched, writes, elapsed,
                                              serialized, len(errors),
                                              tuple(errors),
                                              deadband_skipped=skipped)

    # -- periodic telemetry polling ---------------------------------------------
    def start_polling(self, interval_s: float | None = None,
                      lanes: Iterable[int] | None = None) -> None:
        """Start periodic per-segment READ_VOUT polling on the fleet
        timeline, interleaved with actuations.

        Every segment samples each polled lane through its own PowerManager
        (paying the full Read Word + controller overhead of paper Table VI)
        at the requested interval. `interval_s=None` asks for the fastest
        the configuration supports: the segment's measurement interval times
        the number of polled lanes. Polls fire whenever fleet time advances
        (`idle`, actuation barriers), so telemetry and actuation traffic
        share each segment's serialized bus.

        Back-pressure: a poll that finds its bus still busy (actuation in
        flight, or the previous poll still draining) slips to when the bus
        frees up, and the *next* poll is scheduled from its completion — the
        effective interval degrades to what the segment can carry instead of
        building a backlog, and pending actuations are never dropped."""
        if self._polling:
            raise RuntimeError("polling already active; stop_polling() first")
        lanes = list(lanes) if lanes is not None else self.rail_map.lanes()
        if not lanes:
            raise ValueError("need at least one lane to poll")
        self._polling = True
        self._poll_gen += 1
        self.poll_stats = {}
        self.last_poll = {s.board_id: {} for s in self.segments}
        for seg in self.segments:
            req = (interval_s if interval_s is not None
                   else seg.pm.measurement_interval_s() * len(lanes))
            if req <= 0:
                raise ValueError(f"poll interval must be > 0, got {req}")
            st = SegmentPollStats(seg.board_id, req)
            self.poll_stats[seg.board_id] = st
            self.events.schedule_periodic(
                self.clock.now + req, self._make_poll(seg, st, lanes))

    def stop_polling(self) -> None:
        """Stop polling; in-flight periodic events unschedule themselves on
        their next firing."""
        self._polling = False

    def set_poll_relax(self, board_id: int, factor: float,
                       lanes_pinned: int = 0) -> None:
        """Deadband-paired poll back-pressure: when every governed lane on a
        segment sits inside its confidence-scaled deadband at a learned
        floor, its READ_VOUT samples carry no new information at the full
        Table VI rate — relax the segment's poll interval by `factor`
        (>= 1.0; 1.0 restores the requested rate). Takes effect from the
        segment's next firing: the periodic event reads the factor live, so
        entering/leaving the deadband needs no reschedule and never drops an
        in-flight poll. `lanes_pinned` records how many lanes justified the
        relax (SegmentPollStats.relaxed_lanes). No-op when the segment is
        not polling."""
        if factor < 1.0:
            raise ValueError(f"relax factor must be >= 1.0, got {factor}")
        st = self.poll_stats.get(board_id)
        if st is None:
            return
        st.relax_factor = factor
        st.relaxed_lanes = lanes_pinned if factor > 1.0 else 0

    def _make_poll(self, seg: BusSegment, st: SegmentPollStats,
                   lanes: list[int]):
        gen = self._poll_gen
        def poll(t_fire: float) -> float | None:
            # gen check kills events of a stopped run even if polling has
            # been restarted since (else a stop/start revives the old
            # periodic events and the segment polls at double rate)
            if not self._polling or gen != self._poll_gen:
                return None
            start = max(t_fire, seg.local_now)
            slipped = start - t_fire > 1e-12
            seg.catch_up(start)
            for lane in lanes:
                res = seg.pm.execute(Opcode.GET_VOLTAGE, lane)
                if res.ok:
                    self.last_poll[seg.board_id][lane] = (res.t_done, res.value)
                    st.samples += 1
            done = seg.local_now
            st.polls += 1
            st.busy_s += done - start
            # deadband back-pressure: the effective interval is the request
            # stretched by the live relax factor (read per firing, so the
            # controller flips it between rounds with no reschedule)
            interval = st.requested_interval_s * max(st.relax_factor, 1.0)
            if st.relax_factor > 1.0:
                st.relaxed_polls += 1
            if slipped or done > t_fire + interval:
                st.deferred += 1
            if not math.isnan(st._last_done):
                st._interval_sum_s += done - st._last_done
                st._intervals += 1
            st._last_done = done
            # degrade, don't backlog: next poll no earlier than completion
            return max(t_fire + interval, done)
        return poll

    def poll_readback(self, lanes: Iterable[int] | None = None) -> np.ndarray:
        """Latest PMBus-*sampled* rail voltages, [n_boards, n_lanes] (NaN
        where a lane was never polled) — the telemetry-path counterpart of
        `readback`'s oscilloscope view."""
        return self.poll_observation(lanes)[0]

    def poll_observation(self, lanes: Iterable[int] | None = None
                         ) -> tuple[np.ndarray, np.ndarray]:
        """(values, ages): the latest READ_VOUT sample of each lane and how
        stale it is, both [n_boards, n_lanes] (NaN where never polled). Ages
        are fleet-clock seconds since each sample completed on its segment's
        bus — the sampling delay a poll-driven host policy decides under."""
        lanes = list(lanes) if lanes is not None else self.rail_map.lanes()
        vals = np.full((self.n_boards, len(lanes)), np.nan)
        ages = np.full((self.n_boards, len(lanes)), np.nan)
        for s in self.segments:
            got = self.last_poll.get(s.board_id, {})
            for j, lane in enumerate(lanes):
                if lane in got:
                    t_done, v = got[lane]
                    vals[s.board_id, j] = v
                    ages[s.board_id, j] = self.clock.age(t_done)
        return vals, ages

    def poll_frame(self, *, grad_error=None) -> "object":
        """The latest polled observation as a typed `TelemetryFrame`
        (Provenance.POLLED): per-board sampled rail voltages keyed by the
        rail map's VDD_CORE/VDD_HBM/VDD_IO names, `age_s` = each board's
        *stalest* sampled lane (a decision is only as fresh as its oldest
        input). NaN where a lane was never polled — the consumer decides the
        fallback (HostRailController uses the oracle plane value at age 0;
        the SOR learner records the chip as having no sample).

        `grad_error` optionally merges the caller's measured-error telemetry
        (the non-electrical inputs the frontier fits need) onto the sampled
        frame — this is how `poll_frame` feeds `telemetry.FrameHistory`
        without pretending the error came off the bus. It is either the
        historical scalar/array (the VDD_IO measured error, recorded under
        the `grad_error` field alone) or a dict keyed by RAIL NAME mapping
        each rail to its own failure observable
        (`telemetry.RAIL_OBSERVABLE_KEYS` places them: VDD_IO ->
        `grad_error`, VDD_CORE -> `straggle_rate`, VDD_HBM ->
        `hbm_error_rate`). Rails missing from the dict record NaN — an
        invalid sample for that rail's fit — instead of silently attributing
        another rail's error to it."""
        from repro.core.telemetry import (RAIL_OBSERVABLE_KEYS, Provenance,
                                          TelemetryFrame)
        fields = {"VDD_CORE": "v_core", "VDD_HBM": "v_hbm", "VDD_IO": "v_io"}
        lanes, names = [], []
        for rail in self.rail_map:
            if rail.name in fields:
                lanes.append(rail.lane)
                names.append(fields[rail.name])
        vals, ages = self.poll_observation(lanes)
        kw = {name: vals[:, j].astype(np.float32)
              for j, name in enumerate(names)}
        extras: dict = {}
        if isinstance(grad_error, dict):
            unknown = set(grad_error) - set(RAIL_OBSERVABLE_KEYS)
            if unknown:
                raise ValueError(
                    f"unknown rail(s) {sorted(unknown)} in grad_error dict; "
                    f"known: {sorted(RAIL_OBSERVABLE_KEYS)}")
            # missing rails record NaN -> an invalid sample for that rail
            kw["grad_error"] = grad_error.get("VDD_IO", math.nan)
            for rail, key in RAIL_OBSERVABLE_KEYS.items():
                if rail != "VDD_IO":
                    extras[key] = grad_error.get(rail, math.nan)
        elif grad_error is not None:
            kw["grad_error"] = grad_error
        # max over lanes, NaN-aware without the all-NaN-slice warning
        masked = np.where(np.isnan(ages), -np.inf, ages)
        age = masked.max(axis=1, initial=-np.inf)
        age = np.where(np.isinf(age), np.nan, age)
        return TelemetryFrame(age_s=age.astype(np.float32), extras=extras,
                              provenance=Provenance.POLLED, **kw)

    # -- telemetry --------------------------------------------------------------
    def readback(self, lanes: Iterable[int] | None = None) -> np.ndarray:
        """Instantaneous true rail voltages, [n_boards, n_lanes] (oscilloscope
        view; PMBus-sampled telemetry goes through each segment's PowerManager)."""
        lanes = list(lanes) if lanes is not None else self.rail_map.lanes()
        self.sync()
        return np.array([[s.rail_voltage(l) for l in lanes]
                         for s in self.segments])

    def stats(self) -> dict[str, float]:
        return {
            "boards": self.n_boards,
            "actuation_rounds": self.actuation_rounds,
            "actuation_s": self.actuation_seconds,
            "serialized_s": self.serialized_seconds,
            "lane_writes": self.lane_writes,
            "failed_writes": self.failed_writes,
            "events_processed": self.events.processed,
            "fleet_time_s": self.clock.now,
            "transactions": sum(s.pm.bus.transaction_count for s in self.segments),
            "polls": sum(st.polls for st in self.poll_stats.values()),
            "poll_samples": sum(st.samples for st in self.poll_stats.values()),
            "polls_deferred": sum(st.deferred
                                  for st in self.poll_stats.values()),
            "polls_relaxed": sum(st.relaxed_polls
                                 for st in self.poll_stats.values()),
            "relaxed_lanes": sum(st.relaxed_lanes
                                 for st in self.poll_stats.values()),
        }
