"""The paper's primary contribution: the VolTune runtime voltage-control
architecture — faithful KC705/UCD9248 simulation (codecs, pmbus, regulator,
power_manager, settling, transceiver, overhead) plus its TPU-native
adaptation (power_plane, ecollectives, policy, energy accounting).
See DESIGN.md §2 for the mapping."""

from repro.core.codecs import (
    linear11_decode, linear11_encode, linear16_decode, linear16_encode,
)
from repro.core.control_plane import (
    HostDecisionController, HostPowerController, HostRailController,
    InGraphRailController, RailController, as_controller, pinned_chip_mask,
    pinned_rails, worst_chip_pinned,
)
from repro.core.sor import (
    SafeEnvelope, SorConfig, SorEstimate, SorState, rail_envelopes,
    safe_envelope,
)
from repro.core.telemetry import (
    ALL_RAIL_OBSERVABLES, FrameHistory, RailObservable, TelemetryFrame,
)
from repro.core.fleet import FleetPowerManager, SegmentPollStats
from repro.core.hwspec import V5E, ChipSpec, FleetSpec
from repro.core.power_manager import ControlPath, Opcode, PowerManager, Thresholds
from repro.core.power_plane import (
    PowerPlaneState, StepProfile, account_step, account_step_fleet,
    fleet_summary,
)
from repro.core.rails import KC705_RAIL_MAP, TPU_V5E_RAIL_MAP, RailMap
from repro.core.settling import settling_time
from repro.core.transceiver import GtxLinkModel

__all__ = [
    "ALL_RAIL_OBSERVABLES", "ChipSpec", "ControlPath", "FleetPowerManager",
    "FleetSpec", "FrameHistory", "GtxLinkModel", "HostDecisionController",
    "HostPowerController", "HostRailController", "InGraphRailController",
    "KC705_RAIL_MAP", "Opcode", "PowerManager", "PowerPlaneState",
    "RailController", "RailMap", "RailObservable", "SafeEnvelope",
    "SegmentPollStats", "SorConfig", "SorEstimate", "SorState",
    "StepProfile", "TPU_V5E_RAIL_MAP", "TelemetryFrame", "Thresholds",
    "V5E", "account_step", "account_step_fleet", "as_controller",
    "fleet_summary", "linear11_decode", "linear11_encode",
    "linear16_decode", "linear16_encode", "pinned_chip_mask", "pinned_rails",
    "rail_envelopes", "safe_envelope", "settling_time", "worst_chip_pinned",
]
