"""PMBus fixed-point payload codecs (paper §IV-B).

VolTune encodes voltage programming/readback payloads in LINEAR16 and some
telemetry (e.g. READ_IOUT) in LINEAR11, matching the UCD9248 configuration on
KC705 [paper Table I, §IV-B]. These are exact bit-level implementations of the
PMBus Part II formats:

  LINEAR16:  value = mantissa * 2**exponent
             mantissa: unsigned 16-bit word; exponent: signed 5-bit from
             VOUT_MODE (UCD9248 uses -12 => ~0.2441 mV resolution).
  LINEAR11:  one 16-bit word: [15:11] signed 5-bit exponent N,
             [10:0] signed 11-bit mantissa Y; value = Y * 2**N.
"""

from __future__ import annotations

# UCD9248 VOUT_MODE exponent used on KC705 (2^-12 V per LSB).
VOUT_MODE_EXPONENT = -12


def _twos_complement(value: int, bits: int) -> int:
    """Interpret the low `bits` of `value` as a signed two's-complement int."""
    mask = (1 << bits) - 1
    value &= mask
    if value & (1 << (bits - 1)):
        value -= 1 << bits
    return value


def _to_twos_complement(value: int, bits: int) -> int:
    """Encode a signed int into `bits`-wide two's complement (raises on overflow)."""
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    if not lo <= value <= hi:
        raise ValueError(f"{value} does not fit in {bits}-bit two's complement")
    return value & ((1 << bits) - 1)


# ---------------------------------------------------------------------------
# LINEAR16 (voltage programming / readback: VOUT_COMMAND, READ_VOUT, limits)
# ---------------------------------------------------------------------------

def linear16_encode(volts: float, exponent: int = VOUT_MODE_EXPONENT) -> int:
    """Encode a voltage into a LINEAR16 mantissa word for the given VOUT_MODE
    exponent. Clamps to the representable [0, 0xFFFF * 2**exp] range, which is
    what the UCD9248 limit stage does before the DAC (paper Fig 6)."""
    if exponent > 0:
        lsb = float(1 << exponent)
    else:
        lsb = 1.0 / float(1 << (-exponent))
    mantissa = int(round(volts / lsb))
    return max(0, min(0xFFFF, mantissa))


def linear16_decode(mantissa: int, exponent: int = VOUT_MODE_EXPONENT) -> float:
    """Decode a LINEAR16 mantissa word into volts."""
    if not 0 <= mantissa <= 0xFFFF:
        raise ValueError(f"LINEAR16 mantissa out of range: {mantissa}")
    if exponent > 0:
        return float(mantissa << exponent)
    return mantissa / float(1 << (-exponent))


def linear16_resolution(exponent: int = VOUT_MODE_EXPONENT) -> float:
    """Volts per LSB — the regulator resolution limit (paper §I: 'fine-grained
    voltage adjustment within regulator resolution limits')."""
    return linear16_decode(1, exponent)


# ---------------------------------------------------------------------------
# LINEAR11 (telemetry: READ_IOUT and friends)
# ---------------------------------------------------------------------------

def linear11_encode(value: float, exponent: int | None = None) -> int:
    """Encode a real value into a LINEAR11 word.

    If `exponent` is None, picks the smallest exponent that fits the value in
    the 11-bit signed mantissa with maximum precision (the strategy PMBus
    devices use for telemetry).
    """
    if exponent is None:
        exponent = -16
        while exponent < 15:
            mant = round(value / (2.0 ** exponent))
            if -1024 <= mant <= 1023:
                break
            exponent += 1
        else:
            raise ValueError(f"value {value} not representable in LINEAR11")
    mantissa = int(round(value / (2.0 ** exponent)))
    if not -1024 <= mantissa <= 1023:
        raise ValueError(f"mantissa {mantissa} out of 11-bit range (exp={exponent})")
    return (_to_twos_complement(exponent, 5) << 11) | _to_twos_complement(mantissa, 11)


def linear11_decode(word: int) -> float:
    """Decode a LINEAR11 word into a real value."""
    if not 0 <= word <= 0xFFFF:
        raise ValueError(f"LINEAR11 word out of range: {word}")
    exponent = _twos_complement(word >> 11, 5)
    mantissa = _twos_complement(word & 0x7FF, 11)
    return mantissa * (2.0 ** exponent)


def word_to_bytes_le(word: int) -> tuple[int, int]:
    """PMBus words are transmitted low byte first (SMBus convention)."""
    return (word & 0xFF, (word >> 8) & 0xFF)


def bytes_le_to_word(lo: int, hi: int) -> int:
    return ((hi & 0xFF) << 8) | (lo & 0xFF)
