"""Voltage/compression selection policies (paper §VII-B: 'VolTune is designed
as a control mechanism rather than as a fixed automatic optimizer').

Decision-as-data control API, stage 2 — decision (docs/control_api.md). The
mechanism layer (power_plane / power_manager / ecollectives) never decides
operating points; these policies do, through one primary hook:

    decide(state, frame) -> RailRequest

A policy looks at a typed `telemetry.TelemetryFrame` observation (exact
in-graph values or aged PMBus samples — the policy cannot tell except by
checking `frame.provenance`/`frame.age_s`, which is the point) and returns a
declarative `RailRequest`: the rail voltages / compression level it *wants*,
per-chip or broadcast, with an optional `reason` code. It never mutates
`PowerPlaneState`. Arbitration against the per-rail safety envelopes and
actuation live in one place, `control_plane.arbitrate` — the same merge for
the in-graph (HW-path) and host (SW-path) controllers.

Policies anchor to per-chip nominal voltages when the frame carries them
(`frame.v_nom_*`, from hwspec.FleetSpec), so process variation flows through
every operating-point decision; absent those, the spec scalars apply (scalar
path unchanged). All decision arithmetic is elementwise jnp, so one decide()
serves scalar states and `[n_chips]` fleets alike.

The pre-redesign API — `update_jax/update_host/update_fleet(state, telemetry
dict) -> state` — survives as thin deprecated shims over decide() (warning:
`ControlAPIDeprecationWarning`, an error for in-repo callers via pytest).
"""

from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import ecollectives
from repro.core.hwspec import V5E, ChipSpec
from repro.core.power_plane import PowerPlaneState
from repro.core.telemetry import RAIL_OBSERVABLE_KEYS, TelemetryFrame


class ControlAPIDeprecationWarning(DeprecationWarning):
    """Raised by the legacy `Policy.update_*` shims. pytest.ini turns this
    into an error so in-repo code cannot regress onto the dict interface."""


def _warn_legacy(name: str) -> None:
    warnings.warn(
        f"Policy.{name}(state, telemetry_dict) is deprecated; implement/call "
        f"decide(state, frame) -> RailRequest and actuate through a "
        f"RailController (see docs/control_api.md)",
        ControlAPIDeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# Decision as data
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=["v_core", "v_hbm", "v_io", "comp_level"],
         meta_fields=["reason"])
@dataclasses.dataclass(frozen=True)
class RailRequest:
    """A declarative operating-point request. None fields mean 'leave this
    rail alone'. Values may be scalar (broadcast over a fleet) or `[n_chips]`
    (per-chip setpoints). `reason` is a static policy-assigned code for
    logs/traces — not data, so it never forces a retrace."""
    v_core: Any = None
    v_hbm: Any = None
    v_io: Any = None
    comp_level: Any = None
    reason: str = ""

    def is_empty(self) -> bool:
        return (self.v_core is None and self.v_hbm is None
                and self.v_io is None and self.comp_level is None)


def apply_request(state: PowerPlaneState, request: RailRequest
                  ) -> PowerPlaneState:
    """Raw merge of a request into a plane state — NO envelope clamping (that
    is `control_plane.arbitrate`'s job). Scalar request fields broadcast over
    a `[n_chips]` state. This is the legacy-shim semantics: exactly what the
    old state-mutating `update_*` methods did."""
    fleet_shape = (jnp.shape(state.v_core)
                   if jnp.ndim(state.v_core) >= 1 else None)

    def merge(cur, want, dtype):
        if want is None:
            return cur
        v = jnp.asarray(want, dtype)
        if fleet_shape is not None and jnp.ndim(v) == 0:
            v = jnp.broadcast_to(v, fleet_shape)
        return v

    return dataclasses.replace(
        state,
        v_core=merge(state.v_core, request.v_core, jnp.float32),
        v_hbm=merge(state.v_hbm, request.v_hbm, jnp.float32),
        v_io=merge(state.v_io, request.v_io, jnp.float32),
        comp_level=merge(state.comp_level, request.comp_level, jnp.int32),
    )


def _nom(anchor, fallback: float):
    """Per-chip nominal voltage from the frame (fleet path) or the spec
    scalar (scalar path)."""
    return (jnp.float32(fallback) if anchor is None
            else jnp.asarray(anchor, jnp.float32))


def _rail_env(envelope, rail: str):
    """Normalize a `decide_env` envelope argument: controllers pass either
    the historical single VDD_IO `sor.SafeEnvelope` or the multi-rail
    {rail name: SafeEnvelope} dict; policies read one rail's envelope (None
    when that rail is unfitted). One implementation — sor.envelope_for —
    so policies and arbitration can never disagree on the spelling."""
    from repro.core.sor import envelope_for
    return envelope_for(envelope, rail)


def _obs(observed, state_value):
    """A rail observation from the frame, falling back to the oracle state
    when the frame carries none (pure-metrics legacy dicts)."""
    return state_value if observed is None else observed


class Policy:
    name = "base"
    # True on policies whose decide reduces *across* chips (e.g. a fleet-wide
    # worst-of gate). Inside the sharded control round such a policy would
    # silently reduce over its local shard only, so the sharded path rejects
    # cross-chip policies up front. Elementwise per-chip policies keep the
    # default False.
    cross_chip = False

    # -- the API --------------------------------------------------------------
    def decide(self, state: PowerPlaneState,
               frame: TelemetryFrame) -> RailRequest:
        """Observation in, request out. Pure jnp — compiled into the step by
        the in-graph controller, evaluated between steps by host ones."""
        raise NotImplementedError(
            f"{type(self).__name__} defines no decide(); implement it "
            f"(the legacy update_* API is deprecated)")

    def decide_env(self, state: PowerPlaneState, frame: TelemetryFrame,
                   envelope=None) -> RailRequest:
        """decide() under learned per-chip `sor.SafeEnvelope`s — a single
        VDD_IO envelope (historical spelling) or a {rail name: SafeEnvelope}
        dict covering every fitted rail. Controllers with a live SOR
        estimate call this; envelope-aware policies override it to
        warm-start from the fitted frontiers (confidence-blended so zero
        confidence is bit-identical to decide()). The base simply ignores
        the envelope, so every policy stays callable either way."""
        return self.decide(state, frame)

    def _decides(self) -> bool:
        """True when this policy implements its own decide() (vs a legacy
        subclass that only overrode the update_* methods)."""
        return type(self).decide is not Policy.decide

    # -- deprecated dict-interface shims --------------------------------------
    # Pre-redesign base-class semantics are preserved for legacy subclasses
    # that only override update_jax: update_host delegates to it, and
    # update_fleet broadcasts + vmaps it — exactly the old defaults.
    def update_jax(self, state: PowerPlaneState, telemetry) -> PowerPlaneState:
        _warn_legacy("update_jax")
        frame = TelemetryFrame.from_dict(telemetry, state=state)
        return apply_request(state, self.decide(state, frame))

    def update_host(self, state: PowerPlaneState, telemetry) -> PowerPlaneState:
        _warn_legacy("update_host")
        if not self._decides():
            # old default: same decision logic, evaluated host-side
            return self.update_jax(state, telemetry)
        frame = TelemetryFrame.from_dict(telemetry, state=state)
        return apply_request(state, self.decide(state, frame))

    def update_fleet(self, state: PowerPlaneState, telemetry) -> PowerPlaneState:
        _warn_legacy("update_fleet")
        n = state.v_core.shape[0]
        telem = {k: jnp.broadcast_to(jnp.asarray(v), (n,))
                 if jnp.ndim(v) == 0 else v for k, v in telemetry.items()}
        if not self._decides():
            # old default: per-chip vmap of the legacy scalar update
            return jax.vmap(self.update_jax)(state, telem)

        def per_chip(s, t):
            return apply_request(
                s, self.decide(s, TelemetryFrame.from_dict(t, state=s)))

        return jax.vmap(per_chip)(state, telem)


@dataclasses.dataclass
class StaticNominal(Policy):
    """Fixed worst-case margins — the design-time status quo the paper argues
    against (§I). Baseline for all energy comparisons."""
    spec: ChipSpec = V5E
    name: str = "static-nominal"

    def decide(self, state, frame):
        return RailRequest(
            v_core=_nom(frame.v_nom_core, self.spec.nominal_v_core),
            v_hbm=_nom(frame.v_nom_hbm, self.spec.nominal_v_hbm),
            v_io=_nom(frame.v_nom_io, self.spec.nominal_v_io),
            comp_level=jnp.int32(ecollectives.LEVEL_LOSSLESS),
            reason="static-nominal-margins",
        )


@dataclasses.dataclass
class BERBounded(Policy):
    """The paper's case-study policy, gradient-domain: pick the most
    aggressive compression level whose measured relative gradient error stays
    below `error_bound` (the BER <= 1e-6 analogue), and undervolt VDD_IO in
    proportion to the wire-byte savings (lower effective link utilization ->
    lower safe operating point on the same curve)."""
    error_bound: float = 5e-3
    v_io_floor: float = 0.80
    spec: ChipSpec = V5E
    name: str = "ber-bounded"
    # learned per-chip SOR envelope (core/sor.py). None -> static floor only.
    envelope: Any = None

    def decide(self, state, frame):
        return self.decide_env(state, frame, self.envelope)

    def decide_env(self, state, frame, envelope=None):
        envelope = _rail_env(envelope, "VDD_IO")
        err = frame.grad_error
        # hysteresis: escalate when comfortably under bound, retreat when over
        lvl = state.comp_level
        lvl = jnp.where(err < 0.5 * self.error_bound,
                        jnp.minimum(lvl + 1, ecollectives.LEVEL_INT8_TOPK), lvl)
        lvl = jnp.where(err > self.error_bound, jnp.maximum(lvl - 1, 0), lvl)
        v_nom_io = _nom(frame.v_nom_io, self.spec.nominal_v_io)
        base = v_nom_io * 0.9
        if envelope is None:
            v_low = jnp.maximum(jnp.float32(self.v_io_floor), base)
        else:
            # warm start from the fitted frontier: the undervolt target pulls
            # from the fixed 10% margin toward each chip's learned floor as
            # confidence accrues (zero confidence == the static expression)
            floor_eff = envelope.floor(self.v_io_floor)
            c = jnp.asarray(envelope.confidence, jnp.float32)
            v_low = jnp.maximum(floor_eff, base + c * (floor_eff - base))
        v_io = jnp.where(lvl > 0, v_low, v_nom_io)
        return RailRequest(v_io=v_io, comp_level=lvl.astype(jnp.int32),
                           reason="ber-bounded-hysteresis")


@dataclasses.dataclass
class PhaseAware(Policy):
    """Exploit temporal slack (paper §I: 'during low-utilization or
    communication-light phases, operating all rails at worst-case margins
    results in unnecessary power'): whichever roofline term is NOT dominant
    has slack — undervolt its rail until the terms balance."""
    margin: float = 0.10          # keep 10% headroom below the dominant term
    spec: ChipSpec = V5E
    name: str = "phase-aware"

    def decide(self, state, frame):
        return self.decide_env(state, frame, None)

    def decide_env(self, state, frame, envelope=None):
        t_comp = frame.t_comp_s
        t_mem = frame.t_mem_s
        t_coll = frame.t_coll_s
        t_dom = jnp.maximum(t_comp, jnp.maximum(t_mem, t_coll))
        target = t_dom * (1.0 - self.margin)

        def scaled(rail, v_nom, v_min, t_mine):
            # f ∝ v: slowing this rail by t_mine/target keeps it under the
            # dominant term; clamp to the rail's safety envelope — the
            # platform constant (paper §VII-B), or that rail's learned
            # per-chip floor when the controller carries a fitted one
            # (confidence-blended: zero confidence == the static clamp).
            env = _rail_env(envelope, rail)
            lo = jnp.float32(v_min) if env is None else env.floor(v_min)
            s = jnp.clip(t_mine / target, 0.0, 1.0)
            return jnp.maximum(jnp.asarray(v_nom, jnp.float32) * s, lo)

        from repro.core.rails import TPU_V5E_RAIL_MAP as rm
        return RailRequest(
            v_core=scaled("VDD_CORE",
                          _nom(frame.v_nom_core, self.spec.nominal_v_core),
                          rm.by_name("VDD_CORE").v_min, t_comp),
            v_hbm=scaled("VDD_HBM",
                         _nom(frame.v_nom_hbm, self.spec.nominal_v_hbm),
                         rm.by_name("VDD_HBM").v_min, t_mem),
            v_io=scaled("VDD_IO",
                        _nom(frame.v_nom_io, self.spec.nominal_v_io),
                        rm.by_name("VDD_IO").v_min, t_coll),
            reason="phase-slack",
        )


@dataclasses.dataclass
class ClosedLoop(Policy):
    """The paper's explicit future work (§VIII): feedback control on
    telemetry. A conservative integral controller that walks VDD_IO down
    while the gradient-error telemetry stays under the bound and backs off
    multiplicatively on violation (AIMD — stable under noisy telemetry).

    Decides from the frame's *observed* VDD_IO — the exact in-graph value on
    the HW path, the aged READ_VOUT sample on a poll-driven host controller
    (`decide_from="poll"`) — so the SW loop genuinely closes on sampled
    telemetry, sampling delay included."""
    error_bound: float = 5e-3
    step_v: float = 0.005
    backoff: float = 1.05
    v_io_floor: float = 0.75
    spec: ChipSpec = V5E
    name: str = "closed-loop"
    # learned per-chip SOR envelope (core/sor.py). None -> static floor only.
    envelope: Any = None

    def decide(self, state, frame):
        return self.decide_env(state, frame, self.envelope)

    def decide_env(self, state, frame, envelope=None):
        envelope = _rail_env(envelope, "VDD_IO")
        err = frame.grad_error
        v_io_obs = _obs(frame.v_io, state.v_io)
        ok = err <= self.error_bound
        if envelope is None:
            v_down = jnp.maximum(v_io_obs - self.step_v, self.v_io_floor)
        else:
            # warm start: a confident fitted frontier pulls the 5 mV walk
            # straight to each chip's learned floor (and lifts chips already
            # *below* it back up); zero confidence == the static walk
            floor_eff = envelope.floor(self.v_io_floor)
            c = jnp.asarray(envelope.confidence, jnp.float32)
            walk = v_io_obs - self.step_v
            v_down = jnp.maximum(walk + c * (floor_eff - walk), floor_eff)
        v_up = jnp.minimum(v_io_obs * self.backoff,
                           _nom(frame.v_nom_io, self.spec.nominal_v_io))
        v_io = jnp.where(ok, v_down, v_up)
        lvl = jnp.where(ok, jnp.minimum(state.comp_level + 1,
                                        ecollectives.LEVEL_INT8),
                        jnp.int32(ecollectives.LEVEL_LOSSLESS))
        return RailRequest(v_io=v_io, comp_level=lvl.astype(jnp.int32),
                           reason="aimd-feedback")


@dataclasses.dataclass
class MultiRailClosedLoop(Policy):
    """The AIMD feedback walk generalized to every PMBus-addressable rail —
    the paper's per-rail architecture as one policy. Each rail walks on its
    *own* failure observable (the `telemetry.RAIL_OBSERVABLE_KEYS` canon:
    measured gradient-domain error for VDD_IO, straggler rate for VDD_CORE,
    HBM error rate for VDD_HBM): under the bound the rail steps down
    (warm-started to the rail's learned per-chip floor as SOR confidence
    accrues), over the bound it backs off multiplicatively toward nominal.
    A rail whose observable the frame does not carry — or carries as NaN —
    *holds position*: no blind walking on missing telemetry, and no
    attributing another rail's error to it. Caveat: VDD_IO's observable is
    the first-class `grad_error` field, which defaults to 0.0 rather than
    absent — a frame built with no error telemetry therefore walks VDD_IO
    down exactly as `ClosedLoop` always has (zero measured error == zero
    measured error); pass `grad_error=nan` (what
    `poll_frame(grad_error={...})` records for a missing VDD_IO entry) to
    hold that rail too."""
    error_bound: float = 5e-3
    step_v: float = 0.005
    backoff: float = 1.05
    spec: ChipSpec = V5E
    name: str = "multi-rail-closed-loop"
    # per-rail static floors the walks stop at without a learned envelope;
    # rails omitted from this dict are never walked (scoped control)
    floors: dict = dataclasses.field(default_factory=lambda: {
        "VDD_CORE": 0.65, "VDD_HBM": 0.95, "VDD_IO": 0.75})

    def decide(self, state, frame):
        return self.decide_env(state, frame, None)

    def decide_env(self, state, frame, envelope=None):
        # traces inside InGraphRailController.control_round: everything here
        # must stay jnp-only so the fused jitted round (observe + refit +
        # decide + arbitrate) compiles as one program
        rails = (
            ("VDD_CORE", "v_core",
             _nom(frame.v_nom_core, self.spec.nominal_v_core)),
            ("VDD_HBM", "v_hbm",
             _nom(frame.v_nom_hbm, self.spec.nominal_v_hbm)),
            ("VDD_IO", "v_io",
             _nom(frame.v_nom_io, self.spec.nominal_v_io)),
        )
        kw: dict[str, Any] = {}
        for rail, field, v_nom in rails:
            obs = frame.get(RAIL_OBSERVABLE_KEYS[rail])
            if obs is None or rail not in self.floors:
                # no observable, or the caller scoped `floors` to a subset
                # of rails ("only walk VDD_IO"): hold this rail
                continue
            err = jnp.asarray(obs, jnp.float32)
            v_obs = jnp.asarray(
                _obs(getattr(frame, field), getattr(state, field)),
                jnp.float32)
            floor = jnp.float32(self.floors[rail])
            env = _rail_env(envelope, rail)
            if env is None:
                v_down = jnp.maximum(v_obs - self.step_v, floor)
            else:
                floor_eff = env.floor(floor)
                c = jnp.asarray(env.confidence, jnp.float32)
                walk = v_obs - self.step_v
                v_down = jnp.maximum(walk + c * (floor_eff - walk), floor_eff)
            v_up = jnp.minimum(v_obs * self.backoff, v_nom)
            v = jnp.where(err <= self.error_bound, v_down, v_up)
            # NaN observable == "not measured this round": hold, don't walk
            kw[field] = jnp.where(jnp.isnan(err), v_obs, v)
        # compression escalates on the link observable, like ClosedLoop —
        # and holds (not resets) when that observable is NaN/unmeasured,
        # matching the voltage walks' hold-on-missing-telemetry contract
        io_err = jnp.asarray(frame.grad_error, jnp.float32)
        lvl = jnp.where(io_err <= self.error_bound,
                        jnp.minimum(state.comp_level + 1,
                                    ecollectives.LEVEL_INT8),
                        jnp.int32(ecollectives.LEVEL_LOSSLESS))
        lvl = jnp.where(jnp.isnan(io_err), state.comp_level, lvl)
        return RailRequest(comp_level=lvl.astype(jnp.int32),
                           reason="multi-rail-aimd", **kw)


@dataclasses.dataclass
class WorstChipGate(Policy):
    """Fleet-level reduction wrapper: every chip's decision is gated on the
    *worst* chip's error telemetry (the fleet version of the paper's bounded-
    BER rule — a link is only as safe as its worst lane). With per-chip
    margins this is the conservative fleet policy: no chip undervolts past
    what the worst chip's measured error allows."""
    cross_chip = True
    inner: Policy = dataclasses.field(default_factory=lambda: BERBounded())
    # every canonical rail observable reduces (keys absent from the frame
    # are skipped, so single-rail telemetry behaves exactly as before)
    reduce_keys: tuple[str, ...] = ("grad_error", "straggle_rate",
                                    "hbm_error_rate")
    name: str = "worst-chip"
    # learned per-chip SOR envelope, forwarded to the inner policy: the
    # worst chip's *telemetry* gates everyone, but each chip keeps its own
    # learned floor — the conservative fleet policy with per-chip margins.
    envelope: Any = None

    def __post_init__(self):
        self.name = f"worst-chip[{self.inner.name}]"

    def decide(self, state, frame):
        return self.decide_env(state, frame, self.envelope)

    def decide_env(self, state, frame, envelope=None):
        # scalar state: one chip IS the worst chip
        if jnp.ndim(state.v_core) >= 1:
            frame = frame.reduce_worst(self.reduce_keys)
        if envelope is None:
            return self.inner.decide(state, frame)
        return self.inner.decide_env(state, frame, envelope)

    def update_fleet(self, state, telemetry):
        # legacy shim kept override-for-override with the old API: reduce the
        # dict, then delegate to the inner policy's (deprecated) fleet shim
        _warn_legacy("update_fleet")
        telem = dict(telemetry)
        for k in self.reduce_keys:
            if k in telem and jnp.ndim(telem[k]) >= 1:
                telem[k] = jnp.broadcast_to(jnp.max(telem[k]),
                                            telem[k].shape)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", ControlAPIDeprecationWarning)
            return self.inner.update_fleet(state, telem)


@dataclasses.dataclass
class StalenessGuard(Policy):
    """Age-aware margin widening: the first policy to actually act on
    `frame.age_s`. Wraps any decision policy and *widens the requested
    margin* in proportion to how stale the observations are — when
    back-pressure degrades the poll interval (fleet.SegmentPollStats), the
    loop is flying on old samples and should not hold an aggressive
    operating point it can no longer verify.

    Mechanics: staleness beyond `grace_s` lifts every requested rail voltage
    by `widen_v_per_s` volts per second of excess age, capped at
    `max_widen_v` (arbitration still clamps to the rail/SOR envelope above).
    Fresh frames (age <= grace, including every EXACT frame at age 0) pass
    the inner request through numerically unchanged."""
    inner: Policy = dataclasses.field(default_factory=lambda: ClosedLoop())
    grace_s: float = 0.050       # staleness the loop tolerates for free
    widen_v_per_s: float = 0.5   # volts of margin per second of excess age
    max_widen_v: float = 0.05    # never widen past this
    name: str = "staleness-guard"

    def __post_init__(self):
        self.name = f"staleness-guard[{self.inner.name}]"

    def decide(self, state, frame):
        return self.decide_env(state, frame, None)

    def decide_env(self, state, frame, envelope=None):
        req = (self.inner.decide_env(state, frame, envelope)
               if envelope is not None else self.inner.decide(state, frame))
        age = jnp.asarray(frame.age_s, jnp.float32)
        widen = jnp.clip((age - self.grace_s) * self.widen_v_per_s,
                         0.0, self.max_widen_v)
        # NaN age is the documented "staleness unknown" sentinel (telemetry.
        # from_dict, poll_frame before the first sample): treat it as
        # maximally stale — widen fully rather than poisoning the rails
        widen = jnp.where(jnp.isnan(age), jnp.float32(self.max_widen_v),
                          widen)

        def lift(v):
            return None if v is None else jnp.asarray(v, jnp.float32) + widen

        return dataclasses.replace(
            req, v_core=lift(req.v_core), v_hbm=lift(req.v_hbm),
            v_io=lift(req.v_io),
            reason=f"{req.reason}+staleness-guard" if req.reason
            else "staleness-guard")


POLICIES = {p.name: p for p in
            (StaticNominal(), BERBounded(), PhaseAware(), ClosedLoop(),
             MultiRailClosedLoop(), WorstChipGate(BERBounded()),
             StalenessGuard(ClosedLoop()))}
