"""Voltage/compression selection policies (paper §VII-B: 'VolTune is designed
as a control mechanism rather than as a fixed automatic optimizer').

The mechanism layer (power_plane / power_manager / ecollectives) never decides
operating points; these policies do. Each policy exists in two forms matching
the paper's control paths:

  * `update_jax(state, telemetry) -> state` — pure jnp, compiled into the
    step (in-graph / HW-path analogue);
  * `update_host(state, telemetry) -> state` — plain Python between steps
    (host / SW-path analogue), pushed through control_plane.HostRailController;

plus `update_fleet(state, telemetry) -> state` for `[n_chips]`-batched fleet
states (per-chip vmap with optional fleet-level reductions).

Telemetry is a dict with (at least) the keys produced by
power_plane.account_step plus 'grad_error' (the gradient-domain BER) when
error-bounded collectives are active. Fleet-native consumers (the fleet
train step, fleet_frontier) additionally provide per-chip nominal voltages
('v_nom_core'/'v_nom_hbm'/'v_nom_io', from hwspec.FleetSpec): policies
anchor their decisions to *that chip's* nominal point instead of the shared
spec scalar, so process variation flows through every operating-point
decision. Absent those keys, the spec scalars apply (scalar path unchanged).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import ecollectives
from repro.core.hwspec import V5E, ChipSpec
from repro.core.power_plane import PowerPlaneState


def _nom(telemetry, key: str, fallback: float):
    """Per-chip nominal voltage from telemetry (fleet path) or the spec
    scalar (scalar path)."""
    v = telemetry.get(key)
    return jnp.float32(fallback) if v is None else jnp.asarray(v, jnp.float32)


class Policy:
    name = "base"

    def update_jax(self, state: PowerPlaneState, telemetry) -> PowerPlaneState:
        raise NotImplementedError

    def update_host(self, state: PowerPlaneState, telemetry) -> PowerPlaneState:
        # default: same decision logic, evaluated host-side between steps
        return self.update_jax(state, telemetry)

    def update_fleet(self, state: PowerPlaneState, telemetry) -> PowerPlaneState:
        """Per-chip decision vectorized over a `[n_chips]`-batched state via
        `jax.vmap`. Scalar telemetry entries broadcast to the fleet; policies
        with fleet-level reductions (e.g. worst-chip gating) override this."""
        n = state.v_core.shape[0]
        telem = {k: jnp.broadcast_to(jnp.asarray(v), (n,))
                 if jnp.ndim(v) == 0 else v for k, v in telemetry.items()}
        return jax.vmap(self.update_jax)(state, telem)


@dataclasses.dataclass
class StaticNominal(Policy):
    """Fixed worst-case margins — the design-time status quo the paper argues
    against (§I). Baseline for all energy comparisons."""
    spec: ChipSpec = V5E
    name: str = "static-nominal"

    def update_jax(self, state, telemetry):
        return dataclasses.replace(
            state,
            v_core=_nom(telemetry, "v_nom_core", self.spec.nominal_v_core),
            v_hbm=_nom(telemetry, "v_nom_hbm", self.spec.nominal_v_hbm),
            v_io=_nom(telemetry, "v_nom_io", self.spec.nominal_v_io),
            comp_level=jnp.int32(ecollectives.LEVEL_LOSSLESS),
        )


@dataclasses.dataclass
class BERBounded(Policy):
    """The paper's case-study policy, gradient-domain: pick the most
    aggressive compression level whose measured relative gradient error stays
    below `error_bound` (the BER <= 1e-6 analogue), and undervolt VDD_IO in
    proportion to the wire-byte savings (lower effective link utilization ->
    lower safe operating point on the same curve)."""
    error_bound: float = 5e-3
    v_io_floor: float = 0.80
    spec: ChipSpec = V5E
    name: str = "ber-bounded"

    def update_jax(self, state, telemetry):
        err = telemetry.get("grad_error", jnp.float32(0.0))
        # hysteresis: escalate when comfortably under bound, retreat when over
        lvl = state.comp_level
        lvl = jnp.where(err < 0.5 * self.error_bound,
                        jnp.minimum(lvl + 1, ecollectives.LEVEL_INT8_TOPK), lvl)
        lvl = jnp.where(err > self.error_bound, jnp.maximum(lvl - 1, 0), lvl)
        v_nom_io = _nom(telemetry, "v_nom_io", self.spec.nominal_v_io)
        v_io = jnp.where(lvl > 0,
                         jnp.maximum(jnp.float32(self.v_io_floor),
                                     v_nom_io * 0.9),
                         v_nom_io)
        return dataclasses.replace(state, comp_level=lvl.astype(jnp.int32),
                                   v_io=v_io)


@dataclasses.dataclass
class PhaseAware(Policy):
    """Exploit temporal slack (paper §I: 'during low-utilization or
    communication-light phases, operating all rails at worst-case margins
    results in unnecessary power'): whichever roofline term is NOT dominant
    has slack — undervolt its rail until the terms balance."""
    margin: float = 0.10          # keep 10% headroom below the dominant term
    spec: ChipSpec = V5E
    name: str = "phase-aware"

    def update_jax(self, state, telemetry):
        t_comp = telemetry["t_comp_s"]
        t_mem = telemetry["t_mem_s"]
        t_coll = telemetry["t_coll_s"]
        t_dom = jnp.maximum(t_comp, jnp.maximum(t_mem, t_coll))
        target = t_dom * (1.0 - self.margin)

        def scaled(v_nom, v_min, t_mine):
            # f ∝ v: slowing this rail by t_mine/target keeps it under the
            # dominant term; clamp to the rail's platform safety envelope
            # (paper §VII-B: per-rail envelopes are platform-defined).
            s = jnp.clip(t_mine / target, 0.0, 1.0)
            return jnp.maximum(jnp.asarray(v_nom, jnp.float32) * s,
                               jnp.float32(v_min))

        from repro.core.rails import TPU_V5E_RAIL_MAP as rm
        return dataclasses.replace(
            state,
            v_core=scaled(_nom(telemetry, "v_nom_core", self.spec.nominal_v_core),
                          rm.by_name("VDD_CORE").v_min, t_comp),
            v_hbm=scaled(_nom(telemetry, "v_nom_hbm", self.spec.nominal_v_hbm),
                         rm.by_name("VDD_HBM").v_min, t_mem),
            v_io=scaled(_nom(telemetry, "v_nom_io", self.spec.nominal_v_io),
                        rm.by_name("VDD_IO").v_min, t_coll),
        )


@dataclasses.dataclass
class ClosedLoop(Policy):
    """The paper's explicit future work (§VIII): feedback control on
    telemetry. A conservative integral controller that walks VDD_IO down
    while the gradient-error telemetry stays under the bound and backs off
    multiplicatively on violation (AIMD — stable under noisy telemetry)."""
    error_bound: float = 5e-3
    step_v: float = 0.005
    backoff: float = 1.05
    v_io_floor: float = 0.75
    spec: ChipSpec = V5E
    name: str = "closed-loop"

    def update_jax(self, state, telemetry):
        err = telemetry.get("grad_error", jnp.float32(0.0))
        ok = err <= self.error_bound
        v_down = jnp.maximum(state.v_io - self.step_v, self.v_io_floor)
        v_up = jnp.minimum(state.v_io * self.backoff,
                           _nom(telemetry, "v_nom_io", self.spec.nominal_v_io))
        v_io = jnp.where(ok, v_down, v_up)
        lvl = jnp.where(ok, jnp.minimum(state.comp_level + 1,
                                        ecollectives.LEVEL_INT8),
                        jnp.int32(ecollectives.LEVEL_LOSSLESS))
        return dataclasses.replace(state, v_io=v_io, comp_level=lvl.astype(jnp.int32))


@dataclasses.dataclass
class WorstChipGate(Policy):
    """Fleet-level reduction wrapper: every chip's decision is gated on the
    *worst* chip's error telemetry (the fleet version of the paper's bounded-
    BER rule — a link is only as safe as its worst lane). With per-chip
    margins this is the conservative fleet policy: no chip undervolts past
    what the worst chip's measured error allows."""
    inner: Policy = dataclasses.field(default_factory=lambda: BERBounded())
    reduce_keys: tuple[str, ...] = ("grad_error",)
    name: str = "worst-chip"

    def __post_init__(self):
        self.name = f"worst-chip[{self.inner.name}]"

    def update_jax(self, state, telemetry):
        # scalar state: one chip IS the worst chip
        return self.inner.update_jax(state, telemetry)

    def update_host(self, state, telemetry):
        return self.inner.update_host(state, telemetry)

    def update_fleet(self, state, telemetry):
        telem = dict(telemetry)
        for k in self.reduce_keys:
            if k in telem and jnp.ndim(telem[k]) >= 1:
                telem[k] = jnp.broadcast_to(jnp.max(telem[k]),
                                            telem[k].shape)
        return self.inner.update_fleet(state, telem)


POLICIES = {p.name: p for p in
            (StaticNominal(), BERBounded(), PhaseAware(), ClosedLoop(),
             WorstChipGate(BERBounded()))}
