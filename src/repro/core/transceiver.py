"""GTX transceiver link model for the representative case study (paper §VI).

Models the KC705 back-to-back GTX link as a function of the MGTAVCC analog
supply voltage applied per side (TX / RX) and the line rate. All curve
anchors are taken from the paper's measurements:

  RX-side BER onset voltages (Fig 12/14):  10.0 Gbps: 0.869 V,
      7.5 Gbps: 0.787 V, 5.0 Gbps: 0.745 V, 2.5 Gbps: 0.744 V.
  BER ramp at 10 Gbps (Fig 12c): 1e-10..1e-9 near 0.869-0.868 V,
      ~1e-7 near 0.866 V, ~1e-6 near 0.864 V.
  Throughput collapse (Fig 12a/14a): ~0.80 V @10 G, ~0.72 V @5 G
      (7.5/2.5 G collapse below the 0.70 V sweep floor, as observed).
  TX-only sensitivity (Fig 13): BER onset ~0.82 V @10 G, no received-size
      collapse down to 0.70 V.
  Latency (Fig 15): baselines ~100/130/200/410 ns for 10/7.5/5/2.5 Gbps,
      excursion onsets ~0.86/0.76/0.745/0.74 V.
  Rail power (Tables XI/XII, Fig 16): TX 0.20 W -> 0.1432 W at the
      near-zero-BER boundary (28.4% saving), 0.1415 W at BER<=1e-6 (29.3%).

The voltage->power shape is a Fritsch-Carlson monotone cubic (PCHIP) through
the paper's anchor points, shared across speeds/sides except the 2.5 Gbps RX
rail whose measured reduction is shallower (paper §VI-G: ~25-30%).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

SPEEDS_GBPS = (2.5, 5.0, 7.5, 10.0)
PAYLOAD_BYTES_DEFAULT = 10 * 10**9  # 10 GByte count-up stream (paper §VI-B)
NOMINAL_V = 1.0

# Reference clocks (paper Table X): 125 MHz except 117.188 MHz for 7.5 Gbps.
REFCLK_MHZ = {2.5: 125.000, 5.0: 125.000, 7.5: 117.188, 10.0: 125.000}

RX_BER_ONSET_V = {10.0: 0.869, 7.5: 0.787, 5.0: 0.745, 2.5: 0.744}
TX_BER_ONSET_V = {10.0: 0.820, 7.5: 0.745, 5.0: 0.708, 2.5: 0.706}
RX_COLLAPSE_V = {10.0: 0.800, 7.5: 0.695, 5.0: 0.720, 2.5: 0.688}
LATENCY_BASE_NS = {10.0: 100.0, 7.5: 130.0, 5.0: 200.0, 2.5: 410.0}
LATENCY_EXCURSION_ONSET_V = {10.0: 0.860, 7.5: 0.760, 5.0: 0.745, 2.5: 0.740}

TX_POWER_1V0_W = {10.0: 0.200, 7.5: 0.180, 5.0: 0.140, 2.5: 0.120}
RX_POWER_1V0_W = {10.0: 0.170, 7.5: 0.155, 5.0: 0.120, 2.5: 0.095}

# Shared normalized power-vs-voltage shape (anchored to Fig 16 / Table XII).
_POWER_SHAPE_ANCHORS = (
    (0.700, 0.400), (0.800, 0.648), (0.864, 0.7075), (0.866, 0.7100),
    (0.869, 0.7160), (0.900, 0.785), (1.000, 1.000),
)
# 2.5 Gbps RX: shallower reduction (~25-30% at 0.8 V; paper §VI-G).
_POWER_SHAPE_ANCHORS_25RX = (
    (0.700, 0.520), (0.800, 0.720), (0.869, 0.800), (0.900, 0.840), (1.000, 1.000),
)

BER_FLOOR_LOG10 = -12.0  # "effectively zero" — below detection for 8e10 bits


class Pchip:
    """Fritsch-Carlson monotone piecewise-cubic Hermite interpolator."""

    def __init__(self, x, y):
        x = np.asarray(x, np.float64)
        y = np.asarray(y, np.float64)
        if x.ndim != 1 or x.shape != y.shape or x.shape[0] < 2:
            raise ValueError("need matching 1-D arrays with >= 2 points")
        if np.any(np.diff(x) <= 0):
            raise ValueError("x must be strictly increasing")
        h = np.diff(x)
        delta = np.diff(y) / h
        m = np.empty_like(x)
        m[0], m[-1] = delta[0], delta[-1]
        for i in range(1, len(x) - 1):
            if delta[i - 1] * delta[i] <= 0:
                m[i] = 0.0
            else:
                w1 = 2 * h[i] + h[i - 1]
                w2 = h[i] + 2 * h[i - 1]
                m[i] = (w1 + w2) / (w1 / delta[i - 1] + w2 / delta[i])
        self.x, self.y, self.m, self.h = x, y, m, h

    def __call__(self, xq):
        xq = np.asarray(xq, np.float64)
        scalar = xq.ndim == 0
        xq = np.atleast_1d(xq)
        # clamp to the fitted domain (model is only defined on the sweep range)
        xq = np.clip(xq, self.x[0], self.x[-1])
        i = np.clip(np.searchsorted(self.x, xq, side="right") - 1, 0, len(self.x) - 2)
        t = (xq - self.x[i]) / self.h[i]
        h00 = (1 + 2 * t) * (1 - t) ** 2
        h10 = t * (1 - t) ** 2
        h01 = t * t * (3 - 2 * t)
        h11 = t * t * (t - 1)
        out = (h00 * self.y[i] + h10 * self.h[i] * self.m[i]
               + h01 * self.y[i + 1] + h11 * self.h[i] * self.m[i + 1])
        return float(out[0]) if scalar else out


_POWER_SHAPE = Pchip(*zip(*_POWER_SHAPE_ANCHORS))
_POWER_SHAPE_25RX = Pchip(*zip(*_POWER_SHAPE_ANCHORS_25RX))


def _det_uniform(seed: int, *keys: float) -> float:
    """Deterministic pseudo-uniform in (0,1) from a seed + float keys."""
    h = hash((seed,) + tuple(round(k * 1e6) for k in keys)) & 0xFFFFFFFF
    return (h + 0.5) / 4294967296.0


@dataclasses.dataclass
class LinkTestResult:
    """One voltage point of the sweep (paper §VI-B workload)."""
    speed_gbps: float
    v_tx: float
    v_rx: float
    bytes_sent: int
    bytes_received: int
    bit_errors: float
    ber: float                # measured BER (0.0 when below detection)
    ber_true: float           # model ground truth (for validation tests)
    latency_ns: float
    tx_power_w: float
    rx_power_w: float
    link_up: bool


class GtxLinkModel:
    """Voltage-sensitive serial-link behavioural model (see module docstring)."""

    def __init__(self, seed: int = 0):
        self.seed = seed

    # -- reliability ---------------------------------------------------------
    def _log10_ber_side(self, v: float, onset: float, collapse: float,
                        hard_floor: bool) -> float:
        """log10(BER) contributed by one side at supply voltage v.

        Piecewise: detection floor above `onset`; a steep ramp
        -9.5 -> -6.0 over the 5 mV transition band (paper Fig 12c); then a
        gradual rise toward -3 at the collapse voltage."""
        if v >= onset:
            return BER_FLOOR_LOG10
        d = onset - v
        if d <= 0.005:  # the 5 mV transition band (Fig 12c anchor offsets)
            ramp = ((0.000, -9.5), (0.001, -9.0), (0.003, -7.0), (0.005, -6.0))
            for (d0, y0), (d1, y1) in zip(ramp, ramp[1:]):
                if d <= d1:
                    return y0 + (d - d0) / (d1 - d0) * (y1 - y0)
        lo = -6.0
        span = max(1e-4, onset - 0.005 - collapse)
        frac = min(1.0, (d - 0.005) / span)
        return lo + frac * 3.0 if hard_floor else lo + frac * 1.5

    def log10_ber(self, v_tx: float, v_rx: float, speed_gbps: float) -> float:
        rx = self._log10_ber_side(v_rx, RX_BER_ONSET_V[speed_gbps],
                                  RX_COLLAPSE_V[speed_gbps], hard_floor=True)
        tx = self._log10_ber_side(v_tx, TX_BER_ONSET_V[speed_gbps],
                                  RX_COLLAPSE_V[speed_gbps] - 0.05, hard_floor=False)
        # independent error sources: BER ~ ber_tx + ber_rx
        return math.log10(10.0 ** rx + 10.0 ** tx)

    def received_fraction(self, v_rx: float, speed_gbps: float) -> float:
        """Received-data-size model: full payload above the collapse voltage,
        sharp noisy drop below it (paper Fig 12a: 'the received data size
        drops sharply'). Only the RX side collapses (Fig 13a)."""
        collapse = RX_COLLAPSE_V[speed_gbps]
        if v_rx >= collapse:
            return 1.0
        depth = (collapse - v_rx) / 0.008
        frac = math.exp(-depth)
        jitter = 0.2 + 0.8 * _det_uniform(self.seed, v_rx, speed_gbps, 1.0)
        return max(0.0, min(1.0, frac * jitter))

    # -- performance -----------------------------------------------------------
    def latency_ns(self, v_tx: float, v_rx: float, speed_gbps: float) -> float:
        base = LATENCY_BASE_NS[speed_gbps]
        onset = LATENCY_EXCURSION_ONSET_V[speed_gbps]
        v_eff = min(v_rx, v_tx + 0.05)  # RX-dominant (paper §VI-D)
        if v_eff >= onset:
            return base
        # Below the excursion onset: frequent large spikes (paper Fig 15).
        depth = (onset - v_eff) / max(1e-6, onset - 0.70)
        p_spike = min(0.9, 0.15 + 0.8 * depth)
        u = _det_uniform(self.seed, v_tx, v_rx, speed_gbps)
        if u < p_spike:
            mag = 10.0 ** (1.0 + 2.0 * _det_uniform(self.seed + 1, v_tx, v_rx, speed_gbps))
            return base + mag * 100.0  # spikes up to ~100x baseline
        return base

    # -- power ------------------------------------------------------------------
    def rail_power_w(self, side: str, v: float, speed_gbps: float) -> float:
        if side not in ("tx", "rx"):
            raise ValueError(f"side must be tx|rx, got {side}")
        base = (TX_POWER_1V0_W if side == "tx" else RX_POWER_1V0_W)[speed_gbps]
        shape = _POWER_SHAPE_25RX if (side == "rx" and speed_gbps == 2.5) else _POWER_SHAPE
        return base * shape(v)

    def current_a(self, side: str, v: float, speed_gbps: float) -> float:
        """Rail current for READ_IOUT telemetry."""
        return self.rail_power_w(side, v, speed_gbps) / max(v, 1e-6)

    # -- the full link test -------------------------------------------------------
    def run_link_test(self, v_tx: float, v_rx: float, speed_gbps: float,
                      payload_bytes: int = PAYLOAD_BYTES_DEFAULT) -> LinkTestResult:
        """Simulate one test point: TX sends `payload_bytes` of count-up data,
        RX checks correctness (paper §VI-B)."""
        if speed_gbps not in SPEEDS_GBPS:
            raise ValueError(f"speed {speed_gbps} not in {SPEEDS_GBPS}")
        frac = self.received_fraction(v_rx, speed_gbps)
        bytes_received = int(payload_bytes * frac)
        bits_received = bytes_received * 8
        ber_true = 10.0 ** self.log10_ber(v_tx, v_rx, speed_gbps)
        expected_errors = ber_true * bits_received
        # Detection floor: with < ~0.5 expected errors the counter reads zero.
        if expected_errors < 0.5:
            bit_errors = 0.0
        else:
            # deterministic Poisson-ish jitter around the expectation
            jitter = 0.7 + 0.6 * _det_uniform(self.seed, v_tx, v_rx, speed_gbps, 2.0)
            bit_errors = expected_errors * jitter
        ber_meas = bit_errors / bits_received if bits_received else 1.0
        return LinkTestResult(
            speed_gbps=speed_gbps, v_tx=v_tx, v_rx=v_rx,
            bytes_sent=int(payload_bytes), bytes_received=bytes_received,
            bit_errors=bit_errors, ber=ber_meas, ber_true=ber_true,
            latency_ns=self.latency_ns(v_tx, v_rx, speed_gbps),
            tx_power_w=self.rail_power_w("tx", v_tx, speed_gbps),
            rx_power_w=self.rail_power_w("rx", v_rx, speed_gbps),
            link_up=frac > 0.5,
        )

    # -- sweep helper (the §VI-B procedure) ----------------------------------------
    def sweep(self, speed_gbps: float, mode: str = "both",
              v_start: float = 1.0, v_stop: float = 0.70, step: float = 0.001,
              payload_bytes: int = PAYLOAD_BYTES_DEFAULT) -> list[LinkTestResult]:
        """Voltage sweep 1.0 -> 0.7 V at 1 mV steps (paper Table X).

        mode: 'both' (TX=RX swept), 'tx' (RX fixed 1.0 V), 'rx' (TX fixed 1.0 V).
        """
        if mode not in ("both", "tx", "rx"):
            raise ValueError(f"bad mode {mode}")
        out = []
        n = int(round((v_start - v_stop) / step)) + 1
        for i in range(n):
            v = round(v_start - i * step, 6)
            v_tx = v if mode in ("both", "tx") else NOMINAL_V
            v_rx = v if mode in ("both", "rx") else NOMINAL_V
            out.append(self.run_link_test(v_tx, v_rx, speed_gbps, payload_bytes))
        return out
