"""Error-bounded collectives — the TPU-native analogue of the paper's
bounded-BER transceiver operation (DESIGN.md §2.2).

The paper undervolts the GTX rail and accepts BER <= 1e-6 for ~29.3% link
power savings (paper §VI-G). On a TPU pod, the ICI SerDes is the same kind
of multi-Gb/s link; the workload-visible equivalent of "bounded link error"
is a *bounded-error gradient collective*: compress the gradient on the wire
(int8 block quantization, optionally top-k sparsification), carry the
compression residual forward with error feedback so the error stays bounded
over training, and bank the ICI bytes/energy.

Compression levels (the "voltage knob" of the ICI rail):
    0  lossless     : bf16/f32 psum                    (the >= onset region)
    1  int8 + EF    : blockwise int8 quantized         (bounded-error region)
    2  int8+topk+EF : additionally top-k sparsified    (aggressive region)

Collective wire-byte accounting per level is exposed for the roofline
analysis and the energy model. The quantization hot loop has a Pallas TPU
kernel (repro.kernels.quant_codec); this module uses the jnp reference path
so it stays differentiable-free and shard_map-safe everywhere, and swaps in
the kernel through repro.kernels.ops when on TPU.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

DEFAULT_BLOCK = 256
LEVEL_LOSSLESS, LEVEL_INT8, LEVEL_INT8_TOPK = 0, 1, 2


# ---------------------------------------------------------------------------
# Blockwise int8 quantization (the codec; LINEAR16 analogue for gradients)
# ---------------------------------------------------------------------------

def _pad_to_block(x: jnp.ndarray, block: int) -> tuple[jnp.ndarray, int]:
    n = x.size
    pad = (-n) % block
    flat = jnp.ravel(x)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, pad


def quantize_int8(x: jnp.ndarray, block: int = DEFAULT_BLOCK
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8 quantization. Returns (q[int8], scales[f32])
    with one scale per `block` contiguous elements."""
    flat, _ = _pad_to_block(x, block)
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape: tuple[int, ...],
                    dtype=jnp.float32) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def topk_mask(x: jnp.ndarray, k_fraction: float, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Keep the top ceil(k_fraction*block) magnitudes per block, zero the rest."""
    flat, pad = _pad_to_block(x, block)
    blocks = flat.reshape(-1, block)
    k = max(1, int(round(k_fraction * block)))
    thresh = -jnp.sort(-jnp.abs(blocks), axis=1)[:, k - 1:k]
    masked = jnp.where(jnp.abs(blocks) >= thresh, blocks, 0.0)
    out = masked.reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(x.shape)


# ---------------------------------------------------------------------------
# Compressed cross-device reduction (for use inside shard_map)
# ---------------------------------------------------------------------------

def psum_lossless(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    return jax.lax.psum(x, axis_name)


def psum_int8(x: jnp.ndarray, axis_name, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Bounded-error sum over `axis_name`: quantize locally to int8, exchange
    the int8 payload + scales (all-gather), dequantize-and-sum locally.

    Ring all-gather moves ~1 byte/element/device-hop vs ~4 bytes for a bf16
    ring all-reduce (2 passes x 2 bytes) => ~4x ICI byte reduction, at the
    cost of a bounded quantization error (the "BER") that the caller bounds
    with error feedback."""
    q, s = quantize_int8(x, block)
    qg = jax.lax.all_gather(q, axis_name)            # [P, nblk, block] int8
    sg = jax.lax.all_gather(s, axis_name)            # [P, nblk, 1] f32
    total = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
    return dequantize_like(total, x)


def dequantize_like(blocks_sum: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    flat = blocks_sum.reshape(-1)[: x.size]
    return flat.reshape(x.shape).astype(x.dtype)


def psum_int8_topk(x: jnp.ndarray, axis_name, k_fraction: float = 0.25,
                   block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Level-2: top-k sparsify then int8-quantize. Wire bytes scale with the
    kept fraction (indices are implicit in the blockwise dense-mask layout)."""
    return psum_int8(topk_mask(x, k_fraction, block), axis_name, block)


def reduce_gradients(grads, axis_name, level: int, k_fraction: float = 0.25,
                     mean: bool = True):
    """Reduce a gradient pytree across `axis_name` at a compression level."""
    size = jax.lax.psum(1, axis_name)

    def red(g):
        if level == LEVEL_LOSSLESS:
            out = psum_lossless(g, axis_name)
        elif level == LEVEL_INT8:
            out = psum_int8(g, axis_name)
        elif level == LEVEL_INT8_TOPK:
            out = psum_int8_topk(g, axis_name, k_fraction)
        else:
            raise ValueError(f"unknown compression level {level}")
        return out / size if mean else out

    return jax.tree_util.tree_map(red, grads)


# ---------------------------------------------------------------------------
# Error feedback (keeps the compression error bounded over training)
# ---------------------------------------------------------------------------

def ef_compress(grads, residuals, level: int, k_fraction: float = 0.25,
                block: int = DEFAULT_BLOCK):
    """Error-feedback transform: g' = compress(g + r); r' = (g + r) - g'.

    With EF the *accumulated* compression error stays O(one-step error)
    instead of growing with steps (Karimireddy et al. 2019) — this is what
    makes the bounded-error region usable, exactly like the paper's
    bounded-BER region is usable because the payload tolerates rare flips."""
    if level == LEVEL_LOSSLESS:
        return grads, residuals

    def comp(g, r):
        corrected = g + r
        if level == LEVEL_INT8_TOPK:
            kept = topk_mask(corrected, k_fraction, block)
        else:
            kept = corrected
        q, s = quantize_int8(kept, block)
        g_hat = dequantize_int8(q, s, corrected.shape, corrected.dtype)
        return g_hat, corrected - g_hat

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    out = [comp(g, r) for g, r in zip(flat_g, flat_r)]
    gs = tdef.unflatten([o[0] for o in out])
    rs = tdef.unflatten([o[1] for o in out])
    return gs, rs


def zeros_like_residuals(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


# ---------------------------------------------------------------------------
# Wire-byte accounting (feeds the roofline collective term + energy model)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WireCost:
    bytes_per_element: float     # on-wire bytes per gradient element per device
    description: str


def wire_cost(level: int, k_fraction: float = 0.25,
              elem_bytes: int = 2, block: int = DEFAULT_BLOCK) -> WireCost:
    """Ring-collective wire bytes per gradient element (per device).

    Lossless ring all-reduce: 2 passes x elem_bytes. int8 all-gather +
    local reduce: 1 byte + scales overhead. top-k: fraction kept + scales."""
    scale_overhead = 4.0 / block
    if level == LEVEL_LOSSLESS:
        return WireCost(2.0 * elem_bytes, "ring all-reduce bf16")
    if level == LEVEL_INT8:
        return WireCost(1.0 + scale_overhead, "int8 all-gather + local reduce")
    if level == LEVEL_INT8_TOPK:
        return WireCost(k_fraction * 1.0 + scale_overhead + 0.25,
                        "top-k int8 (+index bitmap) all-gather + local reduce")
    raise ValueError(f"unknown level {level}")


def compression_error_norm(grads, grads_hat) -> jnp.ndarray:
    """Relative L2 error — the gradient-domain 'BER' telemetry channel."""
    num = sum(jnp.sum((a - b) ** 2) for a, b in
              zip(jax.tree_util.tree_leaves(grads), jax.tree_util.tree_leaves(grads_hat)))
    den = sum(jnp.sum(a ** 2) for a in jax.tree_util.tree_leaves(grads))
    return jnp.sqrt(num / jnp.maximum(den, 1e-30))
