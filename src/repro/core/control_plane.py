"""The unified rail control plane (paper §III): one `RailController`
interface serving both of VolTune's control paths.

Decision-as-data control API, stage 3 — arbitration + actuation
(docs/control_api.md). Policies return declarative `RailRequest`s
(policy.decide); this module is the single place where requests meet the
hardware: `arbitrate` clamps/merges a request into the plane state under the
per-rail safety envelopes (paper §VII-B), and the controllers actuate the
arbitrated state:

  * `InGraphRailController` (HW-path analogue): observation → decision →
    arbitration are pure jnp and compile into the jitted step — deterministic,
    zero host round-trip, and the arbitrated operating point takes effect
    immediately (the RTL FSM analogue). One elementwise decide() serves
    scalar states and `[n_chips]` fleets.

  * `HostRailController` (SW-path analogue): the policy runs host-side
    between steps and every actuation is pushed through the simulated
    PMBus/regulator stack — per-board `PowerManager`s over the
    event-scheduled multi-segment `FleetPowerManager` bus — paying the
    paper-characterized millisecond-scale command-sequence + settling cost,
    with achieved voltages (clamp + LINEAR16 quantization + settling band)
    written back into the state. With `decide_from="poll"` it closes the
    loop on its *own* READ_VOUT polling telemetry (`Provenance.POLLED`
    frames with nonzero `age_s`) instead of trainer-supplied oracle state —
    the paper's SW path acting on sampled readbacks, sampling delay included.

Both controllers run the *same* decide()+arbitrate() logic, so on the same
telemetry stream they produce the same rail trajectory up to actuation
quantization — the two-paths-one-behavior property pinned by
tests/test_control_plane.py and tests/test_control_api.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ecollectives
from repro.core.fleet import FleetPowerManager
from repro.core.hwspec import V5E, ChipSpec
from repro.core.policy import Policy, RailRequest, apply_request
from repro.core.power_manager import ControlPath
from repro.core.power_plane import PowerPlaneState
from repro.core.rails import TPU_V5E_RAIL_MAP, RailMap
from repro.core.telemetry import Provenance, TelemetryFrame, as_frame

# a controller accepts the typed observation or the legacy metrics dict
Telemetry = TelemetryFrame | dict[str, Any]

# TPU logical rails in PowerPlaneState field order.
RAIL_LANES = {"VDD_CORE": 0, "VDD_HBM": 1, "VDD_IO": 2}
_LANE_FIELDS = {"VDD_CORE": "v_core", "VDD_HBM": "v_hbm", "VDD_IO": "v_io"}


# ---------------------------------------------------------------------------
# Arbitration: requests meet the safety envelopes in exactly one place
# ---------------------------------------------------------------------------

def arbitrate(plane: PowerPlaneState, request: RailRequest,
              rail_map: RailMap = TPU_V5E_RAIL_MAP,
              envelopes: dict | None = None) -> PowerPlaneState:
    """Merge a `RailRequest` into the plane state under the per-rail safety
    envelopes: None fields keep the current value, scalar fields broadcast
    over a `[n_chips]` fleet, voltages clamp into [v_min, v_max] of their
    rail, compression levels clamp into the codec range. Pure jnp —
    identical under jit/vmap and on the host. The None-skip/broadcast merge
    itself is `policy.apply_request` (one implementation); arbitration adds
    only the clamping.

    `envelopes` optionally maps rail names to learned per-chip
    `sor.SafeEnvelope`s: a rail with an envelope clamps into
    [env.floor(v_min), env.ceil(v_max)] instead of the one shared static
    pair — weak chips get a *tighter* floor than the platform constant,
    strong chips a confidence-gated extension below it (bounded by the
    envelope's `max_extension_v`). At zero confidence the blend is bit-exact
    the static envelope, so cold start arbitrates exactly as before."""
    def clamp(want, name):
        if want is None:
            return None
        r = rail_map.by_name(name)
        env = envelopes.get(name) if envelopes else None
        if env is None:
            lo, hi = jnp.float32(r.v_min), jnp.float32(r.v_max)
        else:
            lo, hi = env.floor(r.v_min), env.ceil(r.v_max)
        return jnp.clip(jnp.asarray(want, jnp.float32), lo, hi)

    comp = request.comp_level
    if comp is not None:
        comp = jnp.clip(jnp.asarray(comp, jnp.int32),
                        ecollectives.LEVEL_LOSSLESS,
                        ecollectives.LEVEL_INT8_TOPK)

    clamped = RailRequest(v_core=clamp(request.v_core, "VDD_CORE"),
                          v_hbm=clamp(request.v_hbm, "VDD_HBM"),
                          v_io=clamp(request.v_io, "VDD_IO"),
                          comp_level=comp, reason=request.reason)
    return apply_request(plane, clamped)


def rail_floors(plane: PowerPlaneState, envelope: Any = None,
                rail_map: RailMap = TPU_V5E_RAIL_MAP) -> jnp.ndarray:
    """`[n_rails, n_chips]` float32 of per-rail arbitration floors in
    `RAIL_LANES` order: the confidence-blended learned floor
    (`SafeEnvelope.floor(static v_min)`) where a rail carries a fitted
    envelope, the platform static `Rail.v_min` where it does not. Pure
    jnp — the fused serve tick packs these rows (and the headroom rows
    derived from them) into its single host bundle, so routing reads
    floors with zero extra device syncs."""
    from repro.core.sor import envelope_for
    n = plane.n_chips
    rows = []
    for name in RAIL_LANES:
        r = rail_map.by_name(name)
        env = envelope_for(envelope, name)
        floor = (env.floor(r.v_min) if env is not None
                 else jnp.float32(r.v_min))
        rows.append(jnp.broadcast_to(
            jnp.atleast_1d(jnp.asarray(floor, jnp.float32)), (n,)))
    return jnp.stack(rows)


def _pinned_lane(plane: PowerPlaneState, request: RailRequest | None,
                 name: str, envelope: Any, rail_map: RailMap,
                 atol: float):
    """Pure-jnp pinned mask for one rail, or None when the request left it
    alone — the shared arithmetic behind the host (`pinned_rails`) and
    in-graph (`pinned_lane_masks`) spellings."""
    if request is None:
        return None
    want = getattr(request, _LANE_FIELDS[name])
    if want is None:
        return None
    from repro.core.sor import envelope_for
    env = envelope_for(envelope, name)   # dict or single spelling
    r = rail_map.by_name(name)
    floor = (env.floor(r.v_min) if env is not None
             else jnp.float32(r.v_min))
    wantv = jnp.asarray(want, jnp.float32)
    held = jnp.asarray(getattr(plane, _LANE_FIELDS[name]), jnp.float32)
    return (wantv <= floor + atol) & (held <= floor + atol)


def pinned_rails(plane: PowerPlaneState, request: RailRequest | None,
                 rail_map: RailMap = TPU_V5E_RAIL_MAP,
                 envelope: Any = None, atol: float = 1e-4
                 ) -> dict[str, np.ndarray]:
    """Host-side per-rail pinning breakdown: {rail name: [n_chips] bool}
    for every rail the request actually asked for. A chip is pinned on a
    rail when the latest decision *wanted* a voltage at/below the floor
    arbitration holds it to AND the plane is already held there — the chip
    is operating at its envelope limit with the policy still pushing
    against it. `envelope` is the learned state in either spelling (a
    {rail: SafeEnvelope} dict or the historical bare VDD_IO envelope);
    rails without one pin against the platform static floor. Rails the
    request left alone (None) are absent from the result — no request, no
    pinning claim. All requested rails come back in ONE stacked device
    transfer (the historical spelling paid one blocking `device_get` per
    rail)."""
    out: dict[str, np.ndarray] = {}
    if request is None:
        return out
    n = plane.n_chips
    names, lanes = [], []
    for name in _LANE_FIELDS:
        pinned = _pinned_lane(plane, request, name, envelope, rail_map,
                              atol)
        if pinned is None:
            continue
        names.append(name)
        lanes.append(jnp.broadcast_to(jnp.atleast_1d(pinned), (n,)))
    if not names:
        return out
    masks = np.asarray(jax.device_get(jnp.stack(lanes)), bool)
    return {name: masks[i].copy() for i, name in enumerate(names)}


def pinned_lane_masks(plane: PowerPlaneState, request: RailRequest | None,
                      rail_map: RailMap = TPU_V5E_RAIL_MAP,
                      envelope: Any = None, atol: float = 1e-4
                      ) -> jnp.ndarray:
    """`[n_rails, n_chips]` bool in `RAIL_LANES` order, pure jnp: the
    `pinned_rails` masks with all-False rows for rails the request left
    alone (an absent rail makes no pinning claim, matching the host dict
    spelling where such rails are simply missing). The fused serve tick
    packs these rows into its single host bundle; `.any(axis=0)` is the
    in-graph `pinned_chip_mask`."""
    n = plane.n_chips
    rows = []
    for name in RAIL_LANES:
        pinned = _pinned_lane(plane, request, name, envelope, rail_map,
                              atol)
        rows.append(jnp.zeros((n,), bool) if pinned is None
                    else jnp.broadcast_to(jnp.atleast_1d(pinned), (n,)))
    return jnp.stack(rows)


def pinned_chip_mask(plane: PowerPlaneState, request: RailRequest | None,
                     rail_map: RailMap = TPU_V5E_RAIL_MAP,
                     envelope: Any = None, atol: float = 1e-4) -> np.ndarray:
    """[n_chips] bool: chips pinned on ANY requested rail — the drain mask
    headroom routing excludes from new placements (serve/router.py)."""
    out = np.zeros(plane.n_chips, bool)
    for mask in pinned_rails(plane, request, rail_map, envelope,
                             atol).values():
        out |= mask
    return out


def worst_chip_pinned(plane: PowerPlaneState, request: RailRequest | None,
                      rail_map: RailMap = TPU_V5E_RAIL_MAP,
                      envelope: Any = None, atol: float = 1e-4) -> bool:
    """Host-side: is any chip pinned at any requested rail's envelope floor
    — i.e. did the latest decision *want* a voltage at/below the floor
    arbitration holds it to? A pinned worst chip means the fleet has no
    safe headroom left on that rail; serve-side admission control sheds
    load on this signal rather than letting the envelope absorb unbounded
    demand. Checks EVERY rail the request touched (a VDD_HBM floor during
    decode gates exactly like the historical VDD_IO-only check); use
    `pinned_rails` for the per-rail breakdown."""
    return any(bool(mask.any())
               for mask in pinned_rails(plane, request, rail_map, envelope,
                                        atol).values())


def _has_decide(policy: Any) -> bool:
    """True when the policy implements the decision-as-data API (its own
    decide(), not the abstract base)."""
    fn = getattr(type(policy), "decide", None)
    return fn is not None and fn is not Policy.decide


def require_decide_for_sor(policy: Any) -> None:
    """A controller configured with sor= runs decide_env + envelope-clamped
    arbitration — the legacy update_* path ignores envelopes entirely, so a
    legacy policy under SOR would LEARN regions that are never consumed.
    Reject loudly instead of silently no-op'ing the learned control."""
    if policy is not None and not _has_decide(policy):
        raise ValueError(
            "sor= needs a decide(state, frame) policy; "
            f"{getattr(policy, 'name', type(policy).__name__)} only "
            "implements the legacy update_* API, which ignores learned "
            "envelopes — the SOR state would be fitted but never consumed")


def validate_in_graph_sor(cfg: Any) -> None:
    """In-graph SOR has no bus: the only observations it can learn from are
    the frames the decision consumes, so `ingest="polled"` (the host
    controller's READ_VOUT path) would be silently meaningless — reject it
    up front instead of oracle-training a 'polled-only' config."""
    if cfg is not None and cfg.ingest != "frames":
        raise ValueError(
            "in-graph SOR learns from the frames the decision consumes; "
            "use SorConfig(ingest='frames') (ingest='polled' is the "
            "HostRailController READ_VOUT path)")


def with_sor(controller: Any, sor_cfg: Any) -> Any:
    """One implementation of "give this in-graph controller a SorConfig"
    for every consumer (fleet train step, serve engine): validates the
    config and the policy, and NEVER mutates a caller-owned controller —
    a controller without SOR is rebuilt with the config; one already
    carrying the SAME config passes through; a different config is a loud
    conflict."""
    validate_in_graph_sor(sor_cfg)
    if not hasattr(controller, "control_step_sor"):
        raise ValueError(
            "sor= needs an InGraphRailController (or a bare policy); got "
            f"{type(controller).__name__}")
    require_decide_for_sor(controller.policy)
    if controller.sor is not None:
        if controller.sor != sor_cfg:
            raise ValueError(
                "conflicting SorConfig: the controller already carries its "
                "own sor=; configure it in one place")
        return controller
    return InGraphRailController(controller.policy, name=controller.name,
                                 rail_map=controller.rail_map, sor=sor_cfg)


def _concrete_or_none(tree):
    """`tree` if every leaf is a concrete array, else None. Controllers use
    this to record their latest decision (`last_request`/`last_envelope`)
    only on eager paths — inside a jitted step the values are tracers, and
    storing those would leak them (and go stale on cache hits anyway)."""
    if tree is None:
        return None
    if any(isinstance(leaf, jax.core.Tracer)
           for leaf in jax.tree_util.tree_leaves(tree)):
        return None
    return tree


def _all_concrete(tree) -> bool:
    """True when no leaf is a tracer — i.e. the caller is eager, so a cached
    jitted round may be dispatched instead of retracing through op-by-op."""
    return not any(isinstance(leaf, jax.core.Tracer)
                   for leaf in jax.tree_util.tree_leaves(tree))


def _run_policy(policy: Any, plane: PowerPlaneState, frame: TelemetryFrame,
                telemetry: Any, rail_map: RailMap, *, host: bool,
                envelope: Any = None
                ) -> tuple[PowerPlaneState, RailRequest | None]:
    """decide() + arbitrate() for API-native policies; the pre-redesign
    state-mutating `update_*` methods for legacy policies that never defined
    decide() (kept working, unclamped, exactly as before). Returns
    (arbitrated plane, the pre-arbitration request) — the request is None on
    the legacy path, which never speaks decision-as-data.

    `envelope` is the learned `sor.SafeEnvelope` state — a single VDD_IO
    envelope (historical spelling) or a {rail name: SafeEnvelope} dict
    covering every fitted rail: it warm-starts the decision
    (policy.decide_env) and tightens/extends the arbitration clamp for those
    rails, in one place for both controllers."""
    if _has_decide(policy):
        if envelope is not None:
            from repro.core.sor import as_envelopes
            request = policy.decide_env(plane, frame, envelope)
            arbitrated = arbitrate(plane, request, rail_map,
                                   envelopes=as_envelopes(envelope))
        else:
            request = policy.decide(plane, frame)
            arbitrated = arbitrate(plane, request, rail_map)
        return arbitrated, request
    telem = telemetry if isinstance(telemetry, dict) else frame.to_dict()
    if jnp.ndim(plane.v_core) >= 1:
        return policy.update_fleet(plane, telem), None
    if host:
        return policy.update_host(plane, telem), None
    return policy.update_jax(plane, telem), None


@dataclasses.dataclass
class ControlPlaneStats:
    """What a control path cost, in the units the paper reports (§V-F):
    number of actuations and simulated control-path seconds."""
    decisions: int = 0
    actuations: int = 0              # rail writes that completed on a bus
    failed_actuations: int = 0       # rejected writes (e.g. outside envelope)
    actuation_seconds: float = 0.0   # fleet-time spent actuating (max-over-segments)
    serialized_seconds: float = 0.0  # single-shared-bus equivalent (sum)
    polls: int = 0                   # periodic READ_VOUT rounds completed
    polls_deferred: int = 0          # poll rounds that slipped (back-pressure)
    poll_decisions: int = 0          # decisions made from POLLED frames
    skipped_actuations: int = 0      # PMBus writes skipped by the deadband
    #                                  scheduler (target pinned at a learned
    #                                  floor within the confidence-scaled
    #                                  deadband) — saved bus transactions
    relaxed_polls: int = 0           # poll rounds fired at a deadband-
    #                                  relaxed interval (poll back-pressure
    #                                  on steady-state pinned boards)


@runtime_checkable
class RailController(Protocol):
    """The one actuation interface. `control_step` takes the current rail
    state and the latest observation (TelemetryFrame, or a legacy metrics
    dict), runs the policy, arbitrates, actuates, and returns the achieved
    state; `stats` reports what the control path cost."""

    name: str

    def control_step(self, plane: PowerPlaneState,
                     telemetry: Telemetry) -> PowerPlaneState: ...

    def stats(self) -> ControlPlaneStats: ...


def as_controller(policy_or_controller: Any, *,
                  host: bool = False) -> "RailController | None":
    """Normalize a config knob: an existing controller passes through; None
    stays None; a bare Policy is wrapped for the requesting path —
    `host=False` (in-graph slots) -> InGraphRailController,
    `host=True` (between-steps slots) -> HostDecisionController, so the
    decision runs where the SW-path analogue is expected."""
    if policy_or_controller is None:
        return None
    if hasattr(policy_or_controller, "control_step"):
        return policy_or_controller
    if host:
        return HostDecisionController(policy_or_controller)
    return InGraphRailController(policy_or_controller)


# ---------------------------------------------------------------------------
# HW-path analogue: in-graph, deterministic, fleet-vectorized
# ---------------------------------------------------------------------------

class InGraphRailController:
    """Pure-jnp controller compiled into the jitted step (paper §III-B).

    Actuation is the identity: in the HW path the arbitrated operating point
    is applied deterministically before the next step, with no bus
    transaction on the modelled timeline (its cost is pinned separately by
    the Table VII/IX overhead benchmarks).

    With `sor=SorConfig(...)` the controller learns per-chip safe operating
    regions *inside the graph*: the caller threads a functional `SorState`
    (init_sor) through its scan and calls `control_step_sor`, which pushes
    the frame into the history, refreshes the frontier estimate on the
    configured cadence, and runs the envelope-warm-started decision +
    envelope-clamped arbitration — all pure jnp."""

    def __init__(self, policy: Any, name: str | None = None,
                 rail_map: RailMap = TPU_V5E_RAIL_MAP,
                 sor: "Any | None" = None, donate: bool = False):
        if policy is None:
            raise ValueError("InGraphRailController needs a policy")
        validate_in_graph_sor(sor)
        if sor is not None:
            require_decide_for_sor(policy)
        self.policy = policy
        self.rail_map = rail_map
        self.sor = sor
        # donate=True makes the cached eager-dispatch jit donate the
        # SorState input buffers, so the O(capacity x rails x chips)
        # history ring is updated in place instead of copied every round.
        # The plane is NOT donated: telemetry frames routinely alias the
        # plane's rail arrays (`as_frame(..., state=plane)` passes them
        # through), and XLA rejects a buffer that is both donated and a
        # live second argument (`f(donate(a), a)`). Caveat: donated
        # inputs are invalidated — an eager caller must rebind to the
        # returned (plane', sor_state') and never touch the SorState it
        # passed in again (the loop idiom `plane, ss =
        # ctrl.control_step_sor(plane, frame, ss)` is already safe).
        self.donate = donate
        self.name = name or f"in-graph[{getattr(policy, 'name', 'policy')}]"
        self.last_request: RailRequest | None = None
        self.last_envelope: Any = None
        self._round_jit = None   # cached jit of control_round (eager callers)

    def control_step(self, plane: PowerPlaneState,
                     telemetry: Telemetry) -> PowerPlaneState:
        frame = as_frame(telemetry, state=plane)
        plane, request = _run_policy(
            self.policy, plane, frame, telemetry, self.rail_map, host=False)
        self.last_request = _concrete_or_none(request)
        return plane

    # -- learned safe-operating-region path -----------------------------------
    def init_sor(self, n_chips: int | None = None):
        """Fresh functional SOR state for a `control_step_sor` loop."""
        from repro.core import sor as _sor
        if self.sor is None:
            raise ValueError("construct the controller with sor=SorConfig() "
                             "before init_sor()")
        return _sor.init_state(self.sor, n_chips)

    def control_round(self, plane: PowerPlaneState, frame: TelemetryFrame,
                      sor_state, fused: bool = True):
        """ONE fused SOR control round, pure jnp: ingest the frame, refresh
        the frontier estimate on the batched `refresh_every` cadence
        (`lax.cond` — the refit graph executes only on-cadence instead of
        every round), derive the per-rail envelopes, and run the
        envelope-warm-started decide + envelope-clamped arbitration.
        Returns (plane', sor_state', request, envelopes). `fused=False`
        runs the historical per-observation-refit graph — the
        bit-equivalence oracle the fused path is pinned against."""
        from repro.core import sor as _sor
        if self.sor is None:
            raise ValueError("control_step_sor needs sor=SorConfig()")
        sor_state = _sor.observe(sor_state, frame, self.sor, fused=fused)
        env = _sor.rail_envelopes(sor_state.estimate, self.sor)
        plane, request = _run_policy(
            self.policy, plane, frame, frame, self.rail_map, host=False,
            envelope=env)
        return plane, sor_state, request, env

    def control_step_sor(self, plane: PowerPlaneState, telemetry: Telemetry,
                         sor_state):
        """One SOR-aware control round: observe -> refresh-on-cadence ->
        envelope-driven decide + arbitrate, all one fused `control_round`.
        Returns (plane', sor_state'). Pure jnp — thread `sor_state` through
        the caller's scan carry (the round inlines into the caller's trace);
        eager callers (serve engine, host-side loops) dispatch a cached
        jitted compilation of the round instead of retracing op-by-op."""
        if self.sor is None:
            raise ValueError("control_step_sor needs sor=SorConfig()")
        frame = as_frame(telemetry, state=plane)
        if _all_concrete((plane, frame, sor_state)):
            if self._round_jit is None:
                self._round_jit = jax.jit(
                    lambda p, f, s: self.control_round(p, f, s),
                    donate_argnums=(2,) if self.donate else ())
            plane, sor_state, request, env = self._round_jit(
                plane, frame, sor_state)
        else:
            plane, sor_state, request, env = self.control_round(
                plane, frame, sor_state)
        self.last_request = _concrete_or_none(request)
        self.last_envelope = _concrete_or_none(env)
        return plane, sor_state

    def stats(self) -> ControlPlaneStats:
        # decisions happen inside the compiled step; host-side cost is zero
        return ControlPlaneStats()


def sharded_control_round(controller: InGraphRailController, mesh,
                          axis_name: str = "chips"):
    """Shard-parallel spelling of `InGraphRailController.control_round` over
    a 1-D `axis_name` mesh: each shard ingests its slice of the frame into
    its resident slice of the `[capacity, n_rails, n_chips]` history ring,
    refits on the replicated `tick` cadence (`lax.cond` — every shard takes
    the same branch), derives envelopes and runs decide + arbitrate — all
    elementwise per chip, so per-shard results are bit-equal to slices of
    the single-device round. The only cross-shard traffic is the confidence
    summary (one psum + one pmin scalar); the plane/SorState never gather.

    Returns `round(plane, frame, sor_state) -> (plane', sor_state',
    conf_sum, conf_min)` where `conf_sum` is the fleet-wide sum of estimate
    confidence (divide by `confidence.size` for the mean) and `conf_min`
    its fleet-wide min. Inputs must carry a trailing `[n_chips]` axis
    divisible by the mesh size; RNG-derived frame fields must be drawn on
    global shapes *outside* the round (the `make_fleet_train_step` pattern)
    so sharded and unsharded trajectories stay bit-equal.

    Cross-chip policies (`policy.cross_chip`, e.g. `WorstChipGate`) are
    rejected up front: inside shard_map their fleet reduction would
    silently cover only the local shard."""
    from jax.sharding import PartitionSpec as P

    from repro.kernels import ops as _ops

    if controller.sor is None:
        raise ValueError("sharded_control_round needs a controller built "
                         "with sor=SorConfig(...) — the per-shard resident "
                         "state is the SorState")
    if getattr(controller.policy, "cross_chip", False):
        raise ValueError(
            f"policy {getattr(controller.policy, 'name', '?')!r} reduces "
            "across chips (cross_chip=True); inside the sharded control "
            "round it would only see its local shard. Run it on the "
            "unsharded path (FleetStepConfig.shard_control=False).")

    def _local(plane, frame, sor_state):
        plane, sor_state, _request, _env = controller.control_round(
            plane, frame, sor_state)
        conf = sor_state.estimate.confidence
        conf_sum = jax.lax.psum(jnp.sum(conf), axis_name)
        conf_min = jax.lax.pmin(jnp.min(conf), axis_name)
        return plane, sor_state, conf_sum, conf_min

    def round(plane, frame, sor_state):
        n_chips = sor_state.history.chip_shape[-1]
        in_specs = (_ops.chip_specs(plane, n_chips, axis_name),
                    _ops.chip_specs(frame, n_chips, axis_name),
                    _ops.chip_specs(sor_state, n_chips, axis_name))
        out_specs = (in_specs[0], in_specs[2], P(), P())
        return _ops._shard_map(_local, mesh, in_specs, out_specs)(
            plane, frame, sor_state)

    return round


# ---------------------------------------------------------------------------
# SW-path analogue: host-side decisions, PMBus-actuated over the fleet bus
# ---------------------------------------------------------------------------

class HostDecisionController:
    """Decide-only host controller: runs the policy between steps with no
    bus actuation — for studying SW-path decision logic without paying (or
    modelling) PMBus latency. Pair with HostRailController when actuation
    cost matters."""

    def __init__(self, policy: Any, rail_map: RailMap = TPU_V5E_RAIL_MAP):
        if policy is None:
            raise ValueError("HostDecisionController needs a policy")
        self.policy = policy
        self.rail_map = rail_map
        self.name = f"host-decide[{getattr(policy, 'name', 'policy')}]"
        self.decisions = 0
        self.last_request: RailRequest | None = None

    def control_step(self, plane: PowerPlaneState,
                     telemetry: Telemetry) -> PowerPlaneState:
        self.decisions += 1
        frame = as_frame(telemetry, state=plane)
        plane, request = _run_policy(
            self.policy, plane, frame, telemetry, self.rail_map, host=True)
        self.last_request = _concrete_or_none(request)
        return plane

    def stats(self) -> ControlPlaneStats:
        return ControlPlaneStats(decisions=self.decisions)


class HostRailController:
    """Host controller driving 1..N boards through the event-scheduled
    multi-segment PMBus model (paper §III-C analogue at fleet scale).

    With `policy=None` it is pure actuation (push whatever the state asks
    for); with a policy it is decide-then-actuate. Scalar states drive board
    0; `[n_chips]` states drive one board per chip concurrently in simulated
    time.

    `decide_from` selects the observation source:
      * "telemetry" (default): decide from the frame/dict the caller passes
        (rail observations fall back to the oracle plane state — the
        pre-redesign behavior);
      * "poll": decide from this controller's own READ_VOUT polling loop —
        sampled rail voltages with their per-chip staleness (`age_s`),
        merged over the caller's non-electrical measurements (grad error,
        roofline terms). Requires `enable_polling()`; chips never sampled
        yet fall back to the plane value at age 0."""

    def __init__(
        self,
        policy: Any = None,
        *,
        n_chips: int = 1,
        path: ControlPath | str = ControlPath.SOFTWARE,
        clock_hz: int = 400_000,
        spec: ChipSpec = V5E,
        settle_band_frac: float = 0.01,
        fleet: FleetPowerManager | None = None,
        seed: int = 0,
        decide_from: str = "telemetry",
        rail_map: RailMap = TPU_V5E_RAIL_MAP,
        sor: "Any | None" = None,
        deadband_v: float = 0.0,
        poll_relax: float = 0.0,
    ):
        if decide_from not in ("telemetry", "poll"):
            raise ValueError(f"decide_from must be 'telemetry' or 'poll', "
                             f"got {decide_from!r}")
        if (decide_from == "poll" and policy is not None
                and not _has_decide(policy)):
            # a legacy update_* policy reads rail voltages from the oracle
            # state, so the polled frame would be silently ignored while
            # stats reported poll-driven decisions
            raise ValueError(
                "decide_from='poll' needs a decide(state, frame) policy; "
                f"{getattr(policy, 'name', type(policy).__name__)} only "
                "implements the legacy update_* API")
        if sor is not None:
            if policy is None:
                # pure-actuation controllers never run decide(), so the
                # learner would silently never see an observation
                raise ValueError("sor= needs a policy: an actuate-only "
                                 "HostRailController never decides, so "
                                 "nothing would ever feed the learner")
            require_decide_for_sor(policy)
        self.policy = policy
        self.spec = spec
        self.settle_band_frac = settle_band_frac
        self.decide_from = decide_from
        self.rail_map = rail_map
        self.fleet = fleet if fleet is not None else FleetPowerManager(
            n_chips, rail_map, path=path, clock_hz=clock_hz, seed=seed)
        self.name = (f"host[{getattr(policy, 'name', 'actuate-only')}]"
                     f"x{self.fleet.n_boards}")
        self.decisions = 0
        self.poll_decisions = 0
        self.last_report = None   # FleetActuationReport of the latest round
        self.last_frame: TelemetryFrame | None = None  # latest decision input
        self.last_request: RailRequest | None = None   # latest decision output
        self.last_envelope: Any = None                 # latest SOR envelope
        # learned safe-operating-region state (core/sor.py): lazily sized on
        # the first decide (scalar vs [n_chips] follows the plane)
        self.sor = sor
        self.sor_state = None
        # deadband actuation scheduling (docs/sor.md "fused control round"):
        # a lane whose arbitrated target sits within a confidence-scaled
        # deadband of its learned floor — and whose regulator already holds
        # that target — is a steady-state lane pinned by the envelope; its
        # PMBus write is skipped (counted in stats().skipped_actuations).
        # 0.0 (default) disables the scheduler: every lane writes, as before.
        self.deadband_v = deadband_v
        self.skipped_actuations = 0
        # deadband-paired poll back-pressure (> 1.0 enables, with
        # deadband_v): a board whose every *governed* lane (learned
        # envelope, nonzero confidence) is deadband-pinned this round gets
        # its READ_VOUT poll interval relaxed by this factor
        # (fleet.set_poll_relax) — steady-state boards stop paying the full
        # Table VI telemetry rate, and the relax is lifted the moment any
        # lane leaves its band. Requires deadband_v > 0 to ever trigger.
        if poll_relax and poll_relax < 1.0:
            raise ValueError(f"poll_relax must be >= 1.0 (or 0 to disable), "
                             f"got {poll_relax}")
        self.poll_relax = poll_relax

    # -- observe --------------------------------------------------------------
    def observed_frame(self, plane: PowerPlaneState,
                       telemetry: Telemetry | None = None,
                       sampled: TelemetryFrame | None = None
                       ) -> TelemetryFrame:
        """POLLED TelemetryFrame: the rail voltages this controller's polling
        loop last *sampled* (LINEAR16-quantized READ_VOUT values, with their
        fleet-clock staleness in `age_s`), merged over the caller-supplied
        non-electrical measurements. Lanes never polled fall back to the
        plane value at age 0. `sampled` optionally reuses a `poll_frame`
        the caller already took this round."""
        base = as_frame(telemetry if telemetry is not None else {})
        if sampled is None:
            sampled = self.fleet.poll_frame()
        batched = jnp.ndim(plane.v_core) >= 1

        def pick(field):
            s = getattr(sampled, field)
            want = np.asarray(s, np.float64)
            have = ~np.isnan(want)
            fallback = np.atleast_1d(np.asarray(
                jax.device_get(getattr(plane, field)), np.float64))
            fallback = np.broadcast_to(fallback, want.shape)
            v = np.where(have, want, fallback).astype(np.float32)
            return jnp.asarray(v if batched else v[0])

        age = np.asarray(sampled.age_s, np.float64)
        age = np.where(np.isnan(age), 0.0, age).astype(np.float32)
        return dataclasses.replace(
            base,
            v_core=pick("v_core"), v_hbm=pick("v_hbm"), v_io=pick("v_io"),
            age_s=jnp.asarray(age if batched else age[0]),
            provenance=Provenance.POLLED)

    # -- learn ----------------------------------------------------------------
    def _sor_observe(self, plane: PowerPlaneState, frame: TelemetryFrame,
                     sampled: TelemetryFrame | None = None) -> Any:
        """Feed the SOR learner one observation and return the current
        per-rail envelopes ({rail: sor.SafeEnvelope}). With
        `ingest="polled"` (default) the history ingests the *raw*
        `FleetPowerManager.poll_frame` samples — NaN where a lane was never
        sampled, so chips with no real READ_VOUT telemetry record nothing
        and the envelopes stay bit-exactly static (cold-start pin) — with
        the per-rail failure observables the fit needs overlaid from the
        decision frame (`sor.merge_observables`: a rail whose observable
        the caller never reported records NaN and that rail's lane simply
        stays invalid); `ingest="frames"` learns from whatever frame the
        decision consumed (EXACT oracle values included). `sampled` reuses
        a poll sweep the caller already took this round instead of sweeping
        the bus twice."""
        from repro.core import sor as _sor
        batched = jnp.ndim(plane.v_core) >= 1
        if self.sor_state is None:
            self.sor_state = _sor.init_state(
                self.sor, plane.v_core.shape[0] if batched else None)
        if self.sor.ingest == "polled":
            raw = sampled if sampled is not None else self.fleet.poll_frame()
            sample = _sor.merge_observables(raw, frame, self.sor)
            if not batched:
                sample = dataclasses.replace(
                    sample, v_core=sample.v_core[0], v_hbm=sample.v_hbm[0],
                    v_io=sample.v_io[0], age_s=sample.age_s[0])
        else:
            sample = frame
        self.sor_state = _sor.observe(self.sor_state, sample, self.sor)
        return _sor.rail_envelopes(self.sor_state.estimate, self.sor)

    def sor_summary(self) -> dict | None:
        """Host-side view of the learned safe operating regions (None until
        the first decision under sor=SorConfig)."""
        from repro.core import sor as _sor
        if self.sor is None or self.sor_state is None:
            return None
        return _sor.summary(self.sor_state.estimate, self.sor)

    # -- decide ---------------------------------------------------------------
    def decide(self, plane: PowerPlaneState,
               telemetry: Telemetry) -> PowerPlaneState:
        """Run the policy (no actuation): observation → request →
        arbitration, returning the target state the bus would be asked for."""
        if self.policy is None:
            return plane
        sampled = None
        if self.decide_from == "poll":
            sampled = self.fleet.poll_frame()   # ONE bus sweep per round
            frame = self.observed_frame(plane, telemetry, sampled=sampled)
            self.poll_decisions += 1
        else:
            frame = as_frame(telemetry, state=plane)
        self.last_frame = frame
        env = (self._sor_observe(plane, frame, sampled=sampled)
               if self.sor is not None else None)
        plane, request = _run_policy(
            self.policy, plane, frame, telemetry, self.rail_map, host=True,
            envelope=env)
        self.last_request = _concrete_or_none(request)
        self.last_envelope = _concrete_or_none(env)
        return plane

    # -- actuate --------------------------------------------------------------
    def _deadband_skips(self, want: dict[str, np.ndarray], n: int
                        ) -> tuple[dict[str, np.ndarray],
                                   dict[str, np.ndarray]]:
        """(skips, governed): per-rail [n] bool masks. `skips` marks lanes
        the deadband scheduler holds back from the bus this round: the
        target sits within `confidence * deadband_v` of the rail's learned
        floor AND the regulator already holds it (within the same band) — a
        steady-state envelope-pinned lane whose write would be a no-op
        transaction. `governed` marks lanes with a learned envelope at
        nonzero confidence — the lanes whose pinning can justify poll
        back-pressure. Rails without a learned envelope (or at zero
        confidence) never skip, so cold start actuates every lane, exactly
        as before."""
        skips = {name: np.zeros(n, bool) for name in RAIL_LANES}
        governed = {name: np.zeros(n, bool) for name in RAIL_LANES}
        if self.deadband_v <= 0.0 or self.last_envelope is None:
            return skips, governed
        from repro.core.sor import envelope_for
        for name, lane in RAIL_LANES.items():
            env = envelope_for(self.last_envelope, name)
            if env is None:
                continue
            r = self.rail_map.by_name(name)
            conf = np.broadcast_to(np.asarray(
                jax.device_get(env.confidence), np.float64), (n,))
            floor = np.broadcast_to(np.asarray(
                jax.device_get(env.floor(r.v_min)), np.float64), (n,))
            held = np.array([self.fleet.segments[i].rail_voltage(lane)
                             for i in range(n)], np.float64)
            band = conf * self.deadband_v
            governed[name] = conf > 0.0
            skips[name] = (governed[name]
                           & (np.abs(want[name] - floor) <= band)
                           & (np.abs(held - want[name]) <= band))
        return skips, governed

    def actuate(self, plane: PowerPlaneState) -> PowerPlaneState:
        """Push the state's rail voltages through PMBus on every board;
        returns the state with voltages replaced by what the regulators
        actually achieved (clamp + LINEAR16 quantization + settling).
        Lanes held back by the deadband scheduler (`deadband_v` > 0 with a
        learned envelope) are omitted from the bus round entirely and read
        back as the voltage the regulator already holds."""
        batched = jnp.ndim(plane.v_core) >= 1
        want = {name: np.atleast_1d(np.asarray(jax.device_get(
                    getattr(plane, field)), dtype=np.float64))
                for name, field in _LANE_FIELDS.items()}
        n = want["VDD_CORE"].shape[0]
        if n != self.fleet.n_boards:
            raise ValueError(
                f"state has {n} chip(s) but the fleet bus has "
                f"{self.fleet.n_boards} board(s)")
        skips, governed = self._deadband_skips(want, n)
        self.skipped_actuations += int(sum(s.sum() for s in skips.values()))
        if self.poll_relax > 1.0:
            # deadband-paired poll back-pressure: a board whose every
            # governed lane is pinned this round polls at poll_relax x the
            # requested interval; any lane leaving its band restores the
            # full rate on the board's next firing
            skp = np.stack([skips[name] for name in RAIL_LANES])
            gov = np.stack([governed[name] for name in RAIL_LANES])
            pinned_board = gov.any(axis=0) & (skp | ~gov).all(axis=0)
            lanes_pinned = skp.sum(axis=0)
            for i in range(n):
                self.fleet.set_poll_relax(
                    i, self.poll_relax if pinned_board[i] else 1.0,
                    lanes_pinned=int(lanes_pinned[i]))
        setpoints = [{RAIL_LANES[name]: float(want[name][i])
                      for name in RAIL_LANES if not skips[name][i]}
                     for i in range(n)]
        achieved, self.last_report = self.fleet.apply_setpoints(
            setpoints, settle_band_frac=self.settle_band_frac)
        # skipped lanes read back whatever the regulator holds
        got = {name: np.array(
                   [achieved[i].get(lane,
                                    self.fleet.segments[i].rail_voltage(lane))
                    for i in range(n)], dtype=np.float32)
               for name, lane in RAIL_LANES.items()}
        if not batched:
            return dataclasses.replace(
                plane,
                v_core=jnp.float32(got["VDD_CORE"][0]),
                v_hbm=jnp.float32(got["VDD_HBM"][0]),
                v_io=jnp.float32(got["VDD_IO"][0]))
        return dataclasses.replace(
            plane,
            v_core=jnp.asarray(got["VDD_CORE"]),
            v_hbm=jnp.asarray(got["VDD_HBM"]),
            v_io=jnp.asarray(got["VDD_IO"]))

    # old single-board HostPowerController spelling
    apply = actuate

    def control_step(self, plane: PowerPlaneState,
                     telemetry: Telemetry) -> PowerPlaneState:
        self.decisions += 1
        return self.actuate(self.decide(plane, telemetry))

    # -- observability --------------------------------------------------------
    @property
    def pm(self):
        """Board 0's PowerManager (single-board back-compat)."""
        return self.fleet.segments[0].pm

    @property
    def actuations(self) -> int:
        return self.fleet.lane_writes

    @property
    def actuation_seconds(self) -> float:
        return self.fleet.actuation_seconds

    def readback(self, board: int = 0) -> dict[str, float]:
        """PMBus-sampled (READ_VOUT) rail voltages of one board."""
        pm = self.fleet.segments[board].pm
        return {name: pm.get_voltage(lane)
                for name, lane in RAIL_LANES.items()}

    def enable_polling(self, interval_s: float | None = None,
                       lanes=None) -> None:
        """Start periodic READ_VOUT telemetry polling on every board's bus
        segment (paper Table VI intervals by default), interleaved with this
        controller's actuations on the fleet timeline. Polls fire as fleet
        time advances — call `self.fleet.idle(dt)` between control rounds to
        model the training time a real deployment would poll through."""
        self.fleet.start_polling(interval_s, lanes)

    def stats(self) -> ControlPlaneStats:
        return ControlPlaneStats(
            decisions=self.decisions,
            actuations=self.fleet.lane_writes,
            failed_actuations=self.fleet.failed_writes,
            actuation_seconds=self.fleet.actuation_seconds,
            serialized_seconds=self.fleet.serialized_seconds,
            polls=sum(st.polls for st in self.fleet.poll_stats.values()),
            polls_deferred=sum(st.deferred
                               for st in self.fleet.poll_stats.values()),
            poll_decisions=self.poll_decisions,
            skipped_actuations=self.skipped_actuations,
            relaxed_polls=sum(st.relaxed_polls
                              for st in self.fleet.poll_stats.values()))


class HostPowerController(HostRailController):
    """Back-compat shim: the pre-control-plane single-board actuator
    (`apply(state)`), now a thin alias over HostRailController."""

    def __init__(self, path: ControlPath | str = ControlPath.SOFTWARE,
                 clock_hz: int = 400_000, spec: ChipSpec = V5E):
        super().__init__(None, n_chips=1, path=path, clock_hz=clock_hz,
                         spec=spec)
