"""The unified rail control plane (paper §III): one `RailController`
interface serving both of VolTune's control paths.

The paper's architectural claim is that a single controller design covers a
deterministic hardware path and a flexible software path. This module is that
claim in code: every consumer (trainer, serve engine, benchmarks) actuates
rails exclusively through `RailController.control_step(plane, telemetry)`,
and the two implementations differ only in *where* the decision runs and
*what* the actuation costs:

  * `InGraphRailController` (HW-path analogue): the policy is pure jnp and is
    compiled into the jitted step — deterministic, zero host round-trip, and
    the decided operating point takes effect immediately (the RTL FSM
    analogue). Scalar states control one chip; `[n_chips]`-batched states
    control a fleet via `Policy.update_fleet` (vmap + optional fleet-level
    reductions such as worst-chip BER gating).

  * `HostRailController` (SW-path analogue): the policy runs host-side
    between steps and every actuation is pushed through the simulated
    PMBus/regulator stack — per-board `PowerManager`s over the
    event-scheduled multi-segment `FleetPowerManager` bus — paying the
    paper-characterized millisecond-scale command-sequence + settling cost,
    with achieved voltages (clamp + LINEAR16 quantization + settling band)
    written back into the state.

Both controllers run the *same policy logic*, so on the same telemetry
stream they produce the same rail trajectory up to actuation quantization —
the two-paths-one-behavior property pinned by tests/test_control_plane.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fleet import FleetPowerManager
from repro.core.hwspec import V5E, ChipSpec
from repro.core.power_manager import ControlPath
from repro.core.power_plane import PowerPlaneState
from repro.core.rails import TPU_V5E_RAIL_MAP

Telemetry = dict[str, Any]

# TPU logical rails in PowerPlaneState field order.
RAIL_LANES = {"VDD_CORE": 0, "VDD_HBM": 1, "VDD_IO": 2}
_LANE_FIELDS = {"VDD_CORE": "v_core", "VDD_HBM": "v_hbm", "VDD_IO": "v_io"}


@dataclasses.dataclass
class ControlPlaneStats:
    """What a control path cost, in the units the paper reports (§V-F):
    number of actuations and simulated control-path seconds."""
    decisions: int = 0
    actuations: int = 0              # rail writes that completed on a bus
    failed_actuations: int = 0       # rejected writes (e.g. outside envelope)
    actuation_seconds: float = 0.0   # fleet-time spent actuating (max-over-segments)
    serialized_seconds: float = 0.0  # single-shared-bus equivalent (sum)
    polls: int = 0                   # periodic READ_VOUT rounds completed
    polls_deferred: int = 0          # poll rounds that slipped (back-pressure)


@runtime_checkable
class RailController(Protocol):
    """The one actuation interface. `control_step` takes the current rail
    state and the latest telemetry, runs the policy, actuates, and returns
    the achieved state; `stats` reports what the control path cost."""

    name: str

    def control_step(self, plane: PowerPlaneState,
                     telemetry: Telemetry) -> PowerPlaneState: ...

    def stats(self) -> ControlPlaneStats: ...


def as_controller(policy_or_controller: Any, *,
                  host: bool = False) -> "RailController | None":
    """Normalize a config knob: an existing controller passes through; None
    stays None; a bare Policy is wrapped for the requesting path —
    `host=False` (in-graph slots) -> InGraphRailController,
    `host=True` (between-steps slots) -> HostDecisionController, so
    `Policy.update_host` runs where the SW-path analogue is expected."""
    if policy_or_controller is None:
        return None
    if hasattr(policy_or_controller, "control_step"):
        return policy_or_controller
    if host:
        return HostDecisionController(policy_or_controller)
    return InGraphRailController(policy_or_controller)


# ---------------------------------------------------------------------------
# HW-path analogue: in-graph, deterministic, fleet-vectorized
# ---------------------------------------------------------------------------

class InGraphRailController:
    """Pure-jnp controller compiled into the jitted step (paper §III-B).

    Actuation is the identity: in the HW path the decided operating point is
    applied deterministically before the next step, with no bus transaction
    on the modelled timeline (its cost is pinned separately by the Table
    VII/IX overhead benchmarks)."""

    def __init__(self, policy: Any, name: str | None = None):
        if policy is None:
            raise ValueError("InGraphRailController needs a policy")
        self.policy = policy
        self.name = name or f"in-graph[{getattr(policy, 'name', 'policy')}]"

    def control_step(self, plane: PowerPlaneState,
                     telemetry: Telemetry) -> PowerPlaneState:
        if jnp.ndim(plane.v_core) >= 1:
            return self.policy.update_fleet(plane, telemetry)
        return self.policy.update_jax(plane, telemetry)

    def stats(self) -> ControlPlaneStats:
        # decisions happen inside the compiled step; host-side cost is zero
        return ControlPlaneStats()


# ---------------------------------------------------------------------------
# SW-path analogue: host-side decisions, PMBus-actuated over the fleet bus
# ---------------------------------------------------------------------------

class HostDecisionController:
    """Decide-only host controller: runs `Policy.update_host` between steps
    with no bus actuation — for studying SW-path decision logic without
    paying (or modelling) PMBus latency. Pair with HostRailController when
    actuation cost matters."""

    def __init__(self, policy: Any):
        if policy is None:
            raise ValueError("HostDecisionController needs a policy")
        self.policy = policy
        self.name = f"host-decide[{getattr(policy, 'name', 'policy')}]"
        self.decisions = 0

    def control_step(self, plane: PowerPlaneState,
                     telemetry: Telemetry) -> PowerPlaneState:
        self.decisions += 1
        if jnp.ndim(plane.v_core) >= 1:
            return self.policy.update_fleet(plane, telemetry)
        return self.policy.update_host(plane, telemetry)

    def stats(self) -> ControlPlaneStats:
        return ControlPlaneStats(decisions=self.decisions)

class HostRailController:
    """Host controller driving 1..N boards through the event-scheduled
    multi-segment PMBus model (paper §III-C analogue at fleet scale).

    With `policy=None` it is pure actuation (push whatever the state asks
    for); with a policy it is decide-then-actuate. Scalar states drive board
    0; `[n_chips]` states drive one board per chip concurrently in simulated
    time."""

    def __init__(
        self,
        policy: Any = None,
        *,
        n_chips: int = 1,
        path: ControlPath | str = ControlPath.SOFTWARE,
        clock_hz: int = 400_000,
        spec: ChipSpec = V5E,
        settle_band_frac: float = 0.01,
        fleet: FleetPowerManager | None = None,
        seed: int = 0,
    ):
        self.policy = policy
        self.spec = spec
        self.settle_band_frac = settle_band_frac
        self.fleet = fleet if fleet is not None else FleetPowerManager(
            n_chips, TPU_V5E_RAIL_MAP, path=path, clock_hz=clock_hz, seed=seed)
        self.name = (f"host[{getattr(policy, 'name', 'actuate-only')}]"
                     f"x{self.fleet.n_boards}")
        self.decisions = 0
        self.last_report = None   # FleetActuationReport of the latest round

    # -- decide ---------------------------------------------------------------
    def decide(self, plane: PowerPlaneState,
               telemetry: Telemetry) -> PowerPlaneState:
        if self.policy is None:
            return plane
        if jnp.ndim(plane.v_core) >= 1:
            return self.policy.update_fleet(plane, telemetry)
        return self.policy.update_host(plane, telemetry)

    # -- actuate --------------------------------------------------------------
    def actuate(self, plane: PowerPlaneState) -> PowerPlaneState:
        """Push the state's rail voltages through PMBus on every board;
        returns the state with voltages replaced by what the regulators
        actually achieved (clamp + LINEAR16 quantization + settling)."""
        batched = jnp.ndim(plane.v_core) >= 1
        want = {name: np.atleast_1d(np.asarray(jax.device_get(
                    getattr(plane, field)), dtype=np.float64))
                for name, field in _LANE_FIELDS.items()}
        n = want["VDD_CORE"].shape[0]
        if n != self.fleet.n_boards:
            raise ValueError(
                f"state has {n} chip(s) but the fleet bus has "
                f"{self.fleet.n_boards} board(s)")
        setpoints = [{RAIL_LANES[name]: float(want[name][i])
                      for name in RAIL_LANES} for i in range(n)]
        achieved, self.last_report = self.fleet.apply_setpoints(
            setpoints, settle_band_frac=self.settle_band_frac)
        got = {name: np.array([achieved[i][lane] for i in range(n)],
                              dtype=np.float32)
               for name, lane in RAIL_LANES.items()}
        if not batched:
            return dataclasses.replace(
                plane,
                v_core=jnp.float32(got["VDD_CORE"][0]),
                v_hbm=jnp.float32(got["VDD_HBM"][0]),
                v_io=jnp.float32(got["VDD_IO"][0]))
        return dataclasses.replace(
            plane,
            v_core=jnp.asarray(got["VDD_CORE"]),
            v_hbm=jnp.asarray(got["VDD_HBM"]),
            v_io=jnp.asarray(got["VDD_IO"]))

    # old single-board HostPowerController spelling
    apply = actuate

    def control_step(self, plane: PowerPlaneState,
                     telemetry: Telemetry) -> PowerPlaneState:
        self.decisions += 1
        return self.actuate(self.decide(plane, telemetry))

    # -- observability --------------------------------------------------------
    @property
    def pm(self):
        """Board 0's PowerManager (single-board back-compat)."""
        return self.fleet.segments[0].pm

    @property
    def actuations(self) -> int:
        return self.fleet.lane_writes

    @property
    def actuation_seconds(self) -> float:
        return self.fleet.actuation_seconds

    def readback(self, board: int = 0) -> dict[str, float]:
        """PMBus-sampled (READ_VOUT) rail voltages of one board."""
        pm = self.fleet.segments[board].pm
        return {name: pm.get_voltage(lane)
                for name, lane in RAIL_LANES.items()}

    def enable_polling(self, interval_s: float | None = None,
                       lanes=None) -> None:
        """Start periodic READ_VOUT telemetry polling on every board's bus
        segment (paper Table VI intervals by default), interleaved with this
        controller's actuations on the fleet timeline. Polls fire as fleet
        time advances — call `self.fleet.idle(dt)` between control rounds to
        model the training time a real deployment would poll through."""
        self.fleet.start_polling(interval_s, lanes)

    def stats(self) -> ControlPlaneStats:
        return ControlPlaneStats(
            decisions=self.decisions,
            actuations=self.fleet.lane_writes,
            failed_actuations=self.fleet.failed_writes,
            actuation_seconds=self.fleet.actuation_seconds,
            serialized_seconds=self.fleet.serialized_seconds,
            polls=sum(st.polls for st in self.fleet.poll_stats.values()),
            polls_deferred=sum(st.deferred
                               for st in self.fleet.poll_stats.values()))


class HostPowerController(HostRailController):
    """Back-compat shim: the pre-control-plane single-board actuator
    (`apply(state)`), now a thin alias over HostRailController."""

    def __init__(self, path: ControlPath | str = ControlPath.SOFTWARE,
                 clock_hz: int = 400_000, spec: ChipSpec = V5E):
        super().__init__(None, n_chips=1, path=path, clock_hz=clock_hz,
                         spec=spec)
