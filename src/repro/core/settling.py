"""Settling-time detection (paper §V-D, Fig 9).

Given a sampled voltage trace v[0..T] during a transition:
  (a) stable-voltage estimate v_avg = mean of the last N samples,
  (b) stability band v_avg +/- x%,
  (c) first index t_s such that N consecutive samples starting at t_s are
      inside the band,
  (d) settling time = t[t_s] - t[0].

Robust to transient overshoot and measurement noise, and reproducible across
PMBus clock rates / control paths (the paper's stated design goals). Written
in jnp so it can run in-graph on telemetry streams (the in-graph controller
uses it) as well as on host numpy traces.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SettlingResult:
    settled: bool
    settling_time_s: float
    t_s_index: int
    v_avg: float
    band_v: float


def _stable_window_start(stable: jnp.ndarray, n: int) -> jnp.ndarray:
    """First index i such that stable[i:i+n] are all True, else -1."""
    c = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(stable.astype(jnp.int32))])
    win = c[n:] - c[:-n]          # win[i] = number of stable samples in [i, i+n)
    hit = win == n
    idx = jnp.argmax(hit)         # first True (0 if none — disambiguate below)
    return jnp.where(jnp.any(hit), idx, -1)


def settling_time(times, volts, *, n: int = 8, band_pct: float = 1.0) -> SettlingResult:
    """Detect the settling time of a sampled transition (paper Fig 9).

    `n` is the window length N (both for the stable-voltage average and the
    consecutive-stability requirement); `band_pct` is x in the +/- x% band.
    """
    t = np.asarray(times, np.float64)  # host-side: keep full time resolution
    v = jnp.asarray(volts)
    if v.shape[0] < n + 1:
        raise ValueError(f"need more than n={n} samples, got {v.shape[0]}")
    v_avg = jnp.mean(v[-n:])
    band = jnp.abs(v_avg) * (band_pct / 100.0)
    stable = jnp.abs(v - v_avg) <= band
    ts_idx = _stable_window_start(stable, n)
    settled = bool(ts_idx >= 0)
    st = float(t[ts_idx] - t[0]) if settled else float("nan")
    return SettlingResult(settled, st, int(ts_idx), float(v_avg), float(band))


def settling_time_jax(times: jnp.ndarray, volts: jnp.ndarray,
                      *, n: int = 8, band_pct: float = 1.0) -> jnp.ndarray:
    """Pure-jnp scalar variant for in-graph use: returns settling time in
    seconds, or NaN when the trace never stabilizes. jit/vmap-safe."""
    v_avg = jnp.mean(volts[-n:])
    band = jnp.abs(v_avg) * (band_pct / 100.0)
    stable = jnp.abs(volts - v_avg) <= band
    idx = _stable_window_start(stable, n)
    return jnp.where(idx >= 0, times[idx] - times[0], jnp.nan)
