"""PMBus transaction engine + UCD9248 device model (paper §IV).

Wire-level timing model (paper §IV-A, Fig 4): PMBus is an I2C-compatible
two-wire bus. Every byte costs 9 SCL periods (8 data bits + ACK on the 9th
clock pulse); START, repeated-START and STOP each cost one period. The
engine supports the exact transaction primitives of Fig 4:

    Write Byte : S  addr+W  cmd  data                 P   -> 29 clocks
    Write Word : S  addr+W  cmd  lo  hi               P   -> 38 clocks
    Read Byte  : S  addr+W  cmd  Sr  addr+R  data     P   -> 39 clocks
    Read Word  : S  addr+W  cmd  Sr  addr+R  lo  hi   P   -> 48 clocks

and the two PMBus clock rates used by VolTune, 100 kHz and 400 kHz
(paper §IV-B). Transactions execute atomically and serially (paper §IV-F):
the engine refuses to start a transaction before the previous one completed.

The UCD9248 model implements exactly the Table I command subset with PAGE
multiplexing across output channels, LINEAR16 voltage registers, and
READ_VOUT/READ_IOUT telemetry backed by `RegulatorChannel` dynamics.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
from typing import Callable

from repro.core import codecs
from repro.core.rails import Rail, RailMap
from repro.core.regulator import RegulatorChannel


# ---------------------------------------------------------------------------
# PMBus command bytes (paper Table I)
# ---------------------------------------------------------------------------

class Cmd(enum.IntEnum):
    PAGE = 0x00
    CLEAR_FAULTS = 0x03
    VOUT_COMMAND = 0x21
    VOUT_UV_WARN_LIMIT = 0x43
    VOUT_UV_FAULT_LIMIT = 0x44
    POWER_GOOD_ON = 0x5E
    POWER_GOOD_OFF = 0x5F
    READ_VOUT = 0x8B
    READ_IOUT = 0x8C


class Primitive(enum.Enum):
    WRITE_BYTE = "write_byte"
    WRITE_WORD = "write_word"
    READ_BYTE = "read_byte"
    READ_WORD = "read_word"
    SEND_BYTE = "send_byte"  # command only, no payload (CLEAR_FAULTS)


# SCL periods per primitive: 9 per byte + START/STOP/repeated-START framing.
_CLOCKS = {
    Primitive.SEND_BYTE: 2 + 2 * 9,    # S addr cmd P
    Primitive.WRITE_BYTE: 2 + 3 * 9,   # S addr cmd data P            = 29
    Primitive.WRITE_WORD: 2 + 4 * 9,   # S addr cmd lo hi P           = 38
    Primitive.READ_BYTE: 3 + 4 * 9,    # S addr cmd Sr addr data P    = 39
    Primitive.READ_WORD: 3 + 5 * 9,    # S addr cmd Sr addr lo hi P   = 48
}

SUPPORTED_CLOCK_HZ = (100_000, 400_000)


def primitive_clocks(p: Primitive) -> int:
    return _CLOCKS[p]


def transaction_seconds(p: Primitive, clock_hz: int) -> float:
    if clock_hz not in SUPPORTED_CLOCK_HZ:
        raise ValueError(f"unsupported PMBus clock {clock_hz}; VolTune uses {SUPPORTED_CLOCK_HZ}")
    return _CLOCKS[p] / float(clock_hz)


@dataclasses.dataclass
class Transaction:
    primitive: Primitive
    address: int
    command: int
    payload: tuple[int, ...] = ()


@dataclasses.dataclass
class Completion:
    """Structured status returned to the PowerManager (paper §IV-B: 'protocol
    failures ... reported through structured status signals')."""
    ok: bool
    data: tuple[int, ...] = ()
    nack: bool = False
    error: str | None = None
    t_start: float = 0.0
    t_end: float = 0.0


class SimClock:
    """Monotonic simulated time in seconds shared by bus + regulators."""

    def __init__(self) -> None:
        self._t = 0.0

    @property
    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("time cannot go backwards")
        self._t += dt
        return self._t

    def advance_to(self, t: float) -> float:
        """Advance to absolute time `t` (no-op if already past it)."""
        if t > self._t:
            self._t = t
        return self._t

    def age(self, t: float) -> float:
        """Seconds elapsed since timestamp `t` (clamped at 0 — a sample from
        a segment clock that ran ahead of fleet time is 'fresh', not from
        the future). Used to stamp staleness onto POLLED telemetry frames."""
        return max(0.0, self._t - t)


@dataclasses.dataclass(order=True)
class Event:
    """One scheduled callback on a simulated timeline. Ordering is
    (time, seq) so simultaneous events fire in scheduling order."""
    t: float
    seq: int
    fn: Callable[[float], None] = dataclasses.field(compare=False)


class EventQueue:
    """Discrete-event scheduler over simulated time.

    The fleet bus model (fleet.py) uses this to let N per-board bus segments
    make progress concurrently in simulated time: work on each segment is
    scheduled as events on the shared fleet timeline and drained in global
    time order, instead of serializing the whole world through one PmBus."""

    def __init__(self) -> None:
        self._heap: list[Event] = []
        self._seq = 0
        self.processed = 0

    def __len__(self) -> int:
        return len(self._heap)

    def schedule(self, t: float, fn: Callable[[float], None]) -> Event:
        ev = Event(t, self._seq, fn)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def next_time(self) -> float | None:
        return self._heap[0].t if self._heap else None

    def schedule_periodic(self, t0: float,
                          fn: Callable[[float], float | None]) -> Event:
        """Self-rescheduling event: `fn(t_fire)` returns the *absolute* time
        of its next firing, or None to stop. The callback choosing its own
        next time (rather than a fixed period) is what lets periodic
        telemetry polling degrade gracefully under back-pressure instead of
        accumulating an unbounded backlog of overdue polls (fleet.py)."""
        def wrapper(t_fire: float) -> None:
            nxt = fn(t_fire)
            if nxt is None:
                return
            if nxt <= t_fire:
                raise ValueError(
                    f"periodic event must advance: next={nxt} <= t={t_fire}")
            self.schedule(nxt, wrapper)
        return self.schedule(t0, wrapper)

    def run_until(self, t: float) -> int:
        """Pop and run every event with fire time <= t, in (time, seq) order.
        Returns the number of events processed. Events may schedule further
        events; those are honored in the same drain if they land <= t."""
        n = 0
        while self._heap and self._heap[0].t <= t:
            ev = heapq.heappop(self._heap)
            ev.fn(ev.t)
            n += 1
        self.processed += n
        return n

    def run_all(self) -> int:
        n = 0
        while self._heap:
            ev = heapq.heappop(self._heap)
            ev.fn(ev.t)
            n += 1
        self.processed += n
        return n


# ---------------------------------------------------------------------------
# UCD9248 device model
# ---------------------------------------------------------------------------

class Ucd9248:
    """A multi-rail digital PWM controller at one PMBus address.

    PAGE selects the output channel for subsequent commands (paper §IV-A:
    'Rail selection is performed using the PAGE mechanism').
    `loads` optionally maps page -> current(volts, t) for READ_IOUT telemetry.
    """

    def __init__(
        self,
        address: int,
        channels: dict[int, RegulatorChannel],
        loads: dict[int, Callable[[float, float], float]] | None = None,
    ):
        self.address = address
        self.channels = channels
        self.loads = loads or {}
        self.page = 0

    def _chan(self) -> RegulatorChannel | None:
        return self.channels.get(self.page)

    def handle(self, txn: Transaction, t_end: float) -> Completion:
        cmd, p = txn.command, txn.primitive
        ch = self._chan()

        if cmd == Cmd.PAGE:
            if p == Primitive.WRITE_BYTE:
                if txn.payload[0] not in self.channels:
                    return Completion(False, nack=True, error=f"bad PAGE {txn.payload[0]}")
                self.page = txn.payload[0]
                return Completion(True)
            if p == Primitive.READ_BYTE:
                return Completion(True, data=(self.page,))

        if ch is None:
            return Completion(False, nack=True, error=f"no channel at page {self.page}")

        if cmd == Cmd.CLEAR_FAULTS and p == Primitive.SEND_BYTE:
            ch.fault_latched = False
            return Completion(True)

        if cmd == Cmd.VOUT_COMMAND:
            if p == Primitive.WRITE_WORD:
                volts = codecs.linear16_decode(codecs.bytes_le_to_word(*txn.payload))
                ch.command_voltage(volts, t_end)
                return Completion(True)
            if p == Primitive.READ_WORD:
                word = codecs.linear16_encode(ch.target_v)
                return Completion(True, data=codecs.word_to_bytes_le(word))

        _limit_attrs = {
            Cmd.VOUT_UV_WARN_LIMIT: "uv_warn_limit_v",
            Cmd.VOUT_UV_FAULT_LIMIT: "uv_fault_limit_v",
            Cmd.POWER_GOOD_ON: "power_good_on_v",
            Cmd.POWER_GOOD_OFF: "power_good_off_v",
        }
        if cmd in _limit_attrs:
            attr = _limit_attrs[Cmd(cmd)]
            if p == Primitive.WRITE_WORD:
                volts = codecs.linear16_decode(codecs.bytes_le_to_word(*txn.payload))
                setattr(ch, attr, volts)
                return Completion(True)
            if p == Primitive.READ_WORD:
                word = codecs.linear16_encode(getattr(ch, attr))
                return Completion(True, data=codecs.word_to_bytes_le(word))

        if cmd == Cmd.READ_VOUT and p == Primitive.READ_WORD:
            v = ch.telemetry_voltage(t_end)
            ch.update_faults(t_end)
            return Completion(True, data=codecs.word_to_bytes_le(codecs.linear16_encode(v)))

        if cmd == Cmd.READ_IOUT and p == Primitive.READ_WORD:
            load = self.loads.get(self.page)
            v = ch.voltage_at(t_end)
            amps = load(v, t_end) if load is not None else 0.0
            return Completion(True, data=codecs.word_to_bytes_le(codecs.linear11_encode(amps)))

        return Completion(False, nack=True,
                          error=f"unsupported cmd 0x{cmd:02X} primitive {p.value}")


# ---------------------------------------------------------------------------
# The bus
# ---------------------------------------------------------------------------

class PmBus:
    """Serialized PMBus master. One transaction in flight at a time
    (paper §IV-F: 'A new PMBus request is not issued until the previous
    request completes')."""

    def __init__(self, clock: SimClock, clock_hz: int = 400_000):
        if clock_hz not in SUPPORTED_CLOCK_HZ:
            raise ValueError(f"unsupported PMBus clock {clock_hz}")
        self.clock = clock
        self.clock_hz = clock_hz
        self.devices: dict[int, Ucd9248] = {}
        self._busy = False
        self.transaction_count = 0
        self.busy_seconds = 0.0

    def attach(self, dev: Ucd9248) -> None:
        if dev.address in self.devices:
            raise ValueError(f"duplicate PMBus address {dev.address}")
        self.devices[dev.address] = dev

    def execute(self, txn: Transaction) -> Completion:
        if self._busy:
            raise RuntimeError("PMBus transaction overlap — serialization violated")
        self._busy = True
        try:
            t_start = self.clock.now
            dt = transaction_seconds(txn.primitive, self.clock_hz)
            t_end = self.clock.advance(dt)
            self.transaction_count += 1
            self.busy_seconds += dt
            dev = self.devices.get(txn.address)
            if dev is None:
                # Address NACK: full addressing cost was still paid on the wire.
                return Completion(False, nack=True, error=f"address NACK 0x{txn.address:02X}",
                                  t_start=t_start, t_end=t_end)
            comp = dev.handle(txn, t_end)
            comp.t_start, comp.t_end = t_start, t_end
            return comp
        finally:
            self._busy = False


# ---------------------------------------------------------------------------
# Board assembly
# ---------------------------------------------------------------------------

def build_board(
    rail_map: RailMap,
    clock: SimClock | None = None,
    clock_hz: int = 400_000,
    loads: dict[str, Callable[[float, float], float]] | None = None,
    seed: int = 0,
) -> tuple[SimClock, PmBus, dict[int, RegulatorChannel]]:
    """Instantiate regulators + bus for a rail map (KC705 or TPU logical).

    Returns (clock, bus, channels_by_lane). `loads` maps rail *name* ->
    current(volts, t) for READ_IOUT telemetry.
    """
    clock = clock or SimClock()
    bus = PmBus(clock, clock_hz)
    channels_by_lane: dict[int, RegulatorChannel] = {}
    loads = loads or {}
    for address in rail_map.devices():
        pages = rail_map.pages_for_device(address)
        chans: dict[int, RegulatorChannel] = {}
        page_loads: dict[int, Callable[[float, float], float]] = {}
        for page, rail in pages.items():
            ch = RegulatorChannel(rail.nominal_v, rail.v_min, rail.v_max,
                                  seed=seed * 131 + rail.lane)
            chans[page] = ch
            channels_by_lane[rail.lane] = ch
            if rail.name in loads:
                page_loads[page] = loads[rail.name]
        bus.attach(Ucd9248(address, chans, page_loads))
    return clock, bus, channels_by_lane
