"""The TPU power plane: VolTune's rail abstraction mapped onto a TPU chip
(DESIGN.md §2.2).

Three logical rails per chip — VDD_CORE (MXU/VPU), VDD_HBM, VDD_IO (ICI
SerDes, the MGTAVCC analogue) — are runtime-controlled state threaded through
the training/serving step. Mirroring the paper's two control paths:

  * in-graph controller (HW-path analogue): a pure `jax.lax` state update
    compiled into the jitted step — deterministic, zero host round-trip;
  * host controller (SW-path analogue): a Python policy loop between steps
    that actuates through a real (simulated) PMBus `PowerManager` on the
    TPU rail map, so every actuation pays the paper-characterized
    millisecond-scale PMBus latency and is logged transaction-by-transaction.

Step time/energy are derived from the compiled step's roofline terms
(`StepProfile`), scaled by rail voltages (DVFS: f ∝ v) and the collective
compression level ("link voltage" knob — see ecollectives.py).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import ecollectives
from repro.core.hwspec import V5E, ChipSpec, FleetSpec


@partial(jax.tree_util.register_dataclass,
         data_fields=["v_core", "v_hbm", "v_io", "comp_level", "energy_j", "step"],
         meta_fields=[])
@dataclasses.dataclass
class PowerPlaneState:
    """Rail state. Scalar fields model one chip (replicated across the mesh;
    SPMD-identical); `[n_chips]`-shaped fields model a fleet with per-chip
    operating points — every accounting/policy function below is elementwise
    jnp, so the same code path serves both via `jax.vmap` (see
    `account_step_fleet` and control_plane.InGraphRailController)."""
    v_core: jnp.ndarray    # f32 [] or [n_chips]
    v_hbm: jnp.ndarray     # f32 [] or [n_chips]
    v_io: jnp.ndarray      # f32 [] or [n_chips]
    comp_level: jnp.ndarray  # i32 [] or [n_chips] — ecollectives compression level
    energy_j: jnp.ndarray  # f32 [] or [n_chips] — accumulated chip energy
    step: jnp.ndarray      # i32 [] or [n_chips]

    @staticmethod
    def nominal(spec: ChipSpec = V5E) -> "PowerPlaneState":
        return PowerPlaneState(
            v_core=jnp.float32(spec.nominal_v_core),
            v_hbm=jnp.float32(spec.nominal_v_hbm),
            v_io=jnp.float32(spec.nominal_v_io),
            comp_level=jnp.int32(ecollectives.LEVEL_LOSSLESS),
            energy_j=jnp.float32(0.0),
            step=jnp.int32(0),
        )

    @staticmethod
    def fleet(n_chips: int,
              spec: "ChipSpec | FleetSpec" = V5E) -> "PowerPlaneState":
        """Batched state for an `n_chips` fleet. With a plain `ChipSpec`
        every chip starts at the shared nominal point; with a `FleetSpec`
        each chip starts at its *own* process-varied nominal voltages."""
        if isinstance(spec, FleetSpec):
            if spec.n_chips != n_chips:
                raise ValueError(f"FleetSpec has {spec.n_chips} chips, "
                                 f"asked for {n_chips}")
            return PowerPlaneState.from_fleet(spec)
        ones = jnp.ones((n_chips,), jnp.float32)
        return PowerPlaneState(
            v_core=ones * spec.nominal_v_core,
            v_hbm=ones * spec.nominal_v_hbm,
            v_io=ones * spec.nominal_v_io,
            comp_level=jnp.full((n_chips,), ecollectives.LEVEL_LOSSLESS,
                                jnp.int32),
            energy_j=jnp.zeros((n_chips,), jnp.float32),
            step=jnp.zeros((n_chips,), jnp.int32),
        )

    @staticmethod
    def from_fleet(fleet: FleetSpec) -> "PowerPlaneState":
        """Fleet state with every chip at its own per-chip nominal point."""
        n = fleet.n_chips
        return PowerPlaneState(
            v_core=jnp.asarray(fleet.v_core_nominal, jnp.float32),
            v_hbm=jnp.asarray(fleet.v_hbm_nominal, jnp.float32),
            v_io=jnp.asarray(fleet.v_io_nominal, jnp.float32),
            comp_level=jnp.full((n,), ecollectives.LEVEL_LOSSLESS, jnp.int32),
            energy_j=jnp.zeros((n,), jnp.float32),
            step=jnp.zeros((n,), jnp.int32),
        )

    @property
    def is_fleet(self) -> bool:
        return jnp.ndim(self.v_core) >= 1

    @property
    def n_chips(self) -> int:
        return int(self.v_core.shape[0]) if self.is_fleet else 1

    def chip(self, i: int) -> "PowerPlaneState":
        """Scalar view of chip `i` of a fleet state."""
        if not self.is_fleet:
            if i != 0:
                raise IndexError("scalar state has exactly one chip")
            return self
        return jax.tree_util.tree_map(lambda x: x[i], self)


@dataclasses.dataclass(frozen=True)
class StepProfile:
    """Static per-(arch, shape, mesh) roofline terms of one compiled step,
    extracted by repro.roofline from the dry-run artifacts."""
    flops_per_chip: float
    hbm_bytes_per_chip: float
    ici_bytes_per_chip: float      # at lossless compression
    grad_bytes_per_chip: float = 0.0  # gradient-sync share of ici bytes

    def as_jnp(self) -> dict[str, jnp.ndarray]:
        return {k: jnp.float32(v) for k, v in dataclasses.asdict(self).items()}


# ---------------------------------------------------------------------------
# Step time + power as differentiable-free jnp (usable in-graph)
# ---------------------------------------------------------------------------

def _freq_scale(v: jnp.ndarray, v_nom) -> jnp.ndarray:
    return jnp.maximum(0.4, v / v_nom)


def _nominals(spec: ChipSpec, variation: dict | None):
    """(v_core_nom, v_hbm_nom, v_io_nom, leak_scale) — spec scalars, or the
    per-chip values of a FleetSpec.variation() row. A chip whose nominal sits
    above the spec's is a *weak* chip: at the same absolute voltage it runs
    slower and its leakage multiplier burns more static power."""
    if variation is None:
        return (jnp.float32(spec.nominal_v_core),
                jnp.float32(spec.nominal_v_hbm),
                jnp.float32(spec.nominal_v_io), jnp.float32(1.0))
    return (variation["v_core_nom"], variation["v_hbm_nom"],
            variation["v_io_nom"], variation["leak_scale"])


def step_terms(profile: StepProfile, state: PowerPlaneState,
               spec: ChipSpec = V5E, k_fraction: float = 0.25,
               variation: dict | None = None):
    """Three roofline terms (seconds) under the current rail state."""
    v_core_nom, v_hbm_nom, v_io_nom, _ = _nominals(spec, variation)
    f_core = _freq_scale(state.v_core, v_core_nom)
    f_hbm = _freq_scale(state.v_hbm, v_hbm_nom)
    f_io = _freq_scale(state.v_io, v_io_nom)

    # compression rescales only the gradient-sync share of ICI traffic
    lossless = ecollectives.wire_cost(ecollectives.LEVEL_LOSSLESS).bytes_per_element
    ratios = jnp.array([
        1.0,
        ecollectives.wire_cost(ecollectives.LEVEL_INT8).bytes_per_element / lossless,
        ecollectives.wire_cost(ecollectives.LEVEL_INT8_TOPK, k_fraction).bytes_per_element / lossless,
    ], jnp.float32)
    ratio = ratios[jnp.clip(state.comp_level, 0, 2)]
    grad_b = jnp.float32(profile.grad_bytes_per_chip)
    other_b = jnp.float32(profile.ici_bytes_per_chip) - grad_b
    ici_bytes = other_b + grad_b * ratio

    t_comp = jnp.float32(profile.flops_per_chip) / (spec.peak_bf16_flops * f_core)
    t_mem = jnp.float32(profile.hbm_bytes_per_chip) / (spec.hbm_bandwidth * f_hbm)
    t_coll = ici_bytes / (spec.ici_link_bandwidth * spec.ici_links_per_chip * f_io)
    return t_comp, t_mem, t_coll


def step_time_s(profile: StepProfile, state: PowerPlaneState,
                spec: ChipSpec = V5E, overlap: float = 1.0,
                variation: dict | None = None) -> jnp.ndarray:
    """Step wall time: max of the three terms under perfect overlap
    (overlap=1.0), or their weighted blend toward the sum when overlap<1."""
    t_comp, t_mem, t_coll = step_terms(profile, state, spec,
                                       variation=variation)
    t_max = jnp.maximum(t_comp, jnp.maximum(t_mem, t_coll))
    t_sum = t_comp + t_mem + t_coll
    return overlap * t_max + (1.0 - overlap) * t_sum


@dataclasses.dataclass(frozen=True)
class BatchShares:
    """How much of each roofline term a continuous-batching decode batch
    SHARES across its resident lanes (1.0 = fully amortized, one copy of
    the work serves every lane; 0.0 = per-lane, the term scales linearly
    with batch size). Decode FLOPs are per-token (nothing shared); the HBM
    term is dominated by the weights read, which one batched matmul
    amortizes over every lane; collectives carry mostly weight-sharded
    traffic with a per-lane activation tail."""
    flops: float = 0.0
    hbm: float = 0.9
    ici: float = 0.7


def batched_lane_time_s(t_comp, t_mem, t_coll, lanes,
                        shares: BatchShares = BatchShares(),
                        overlap: float = 1.0) -> jnp.ndarray:
    """Per-lane step time of a `lanes`-deep continuous decode batch, from
    the single-lane roofline terms: each term grows by its UNSHARED
    fraction per extra lane,

        t_term' = t_term * (1 + (1 - share_term) * (b - 1)),  b = max(lanes, 1)

    and the terms recombine exactly like `step_time_s` (max under perfect
    overlap, blended toward the sum below it). Every lane advances one
    token per batched step, so chip throughput is `b / t_lane` — sublinear
    in b through the unshared fractions, the roofline's diminishing
    return. At b == 1 every scale factor is exactly 1.0f, so the result is
    BITWISE equal to `step_time_s` on the same terms — the batch-cap=1
    oracle guarantee the serve engine's fused tick is pinned on."""
    b = jnp.maximum(jnp.asarray(lanes, jnp.float32), 1.0)
    extra = b - 1.0
    tc = t_comp * (1.0 + jnp.float32(1.0 - shares.flops) * extra)
    tm = t_mem * (1.0 + jnp.float32(1.0 - shares.hbm) * extra)
    tl = t_coll * (1.0 + jnp.float32(1.0 - shares.ici) * extra)
    t_max = jnp.maximum(tc, jnp.maximum(tm, tl))
    t_sum = tc + tm + tl
    return overlap * t_max + (1.0 - overlap) * t_sum


def chip_power_w_jnp(state: PowerPlaneState, util_mxu, util_hbm, util_ici,
                     spec: ChipSpec = V5E,
                     variation: dict | None = None) -> jnp.ndarray:
    v_core_nom, v_hbm_nom, v_io_nom, leak = _nominals(spec, variation)
    sv_core = state.v_core / v_core_nom
    sv_hbm = state.v_hbm / v_hbm_nom
    sv_io = state.v_io / v_io_nom
    p_core = (spec.p_core_dynamic_w * util_mxu * sv_core**3
              + spec.p_core_static_w * leak * sv_core**2)
    p_hbm = spec.p_hbm_w * (0.3 + 0.7 * util_hbm) * sv_hbm**2
    p_ici = spec.p_ici_w * (0.15 + 0.85 * util_ici) * sv_io**2
    return p_core + p_hbm + p_ici + spec.p_other_w


def account_step(profile: StepProfile, state: PowerPlaneState,
                 spec: ChipSpec = V5E, overlap: float = 1.0,
                 variation: dict | None = None
                 ) -> tuple[PowerPlaneState, dict[str, jnp.ndarray]]:
    """Advance the energy accumulator by one step; returns (state', metrics).
    Pure jnp — runs inside the jitted step (in-graph controller path).
    `variation` carries one chip's process-variation row (per-chip nominal
    voltages + leakage multiplier) when accounting a FleetSpec fleet."""
    t_comp, t_mem, t_coll = step_terms(profile, state, spec,
                                       variation=variation)
    t_step = step_time_s(profile, state, spec, overlap, variation=variation)
    util_mxu = t_comp / t_step
    util_hbm = t_mem / t_step
    util_ici = t_coll / t_step
    p = chip_power_w_jnp(state, util_mxu, util_hbm, util_ici, spec,
                         variation=variation)
    e = p * t_step
    new = dataclasses.replace(state, energy_j=state.energy_j + e,
                              step=state.step + 1)
    metrics = {
        "t_step_s": t_step, "t_comp_s": t_comp, "t_mem_s": t_mem,
        "t_coll_s": t_coll, "power_w": p, "energy_step_j": e,
        "util_mxu": util_mxu, "util_hbm": util_hbm, "util_ici": util_ici,
    }
    return new, metrics


# ---------------------------------------------------------------------------
# Fleet accounting: the same elementwise math vectorized over [n_chips]
# ---------------------------------------------------------------------------

def account_step_fleet(profile: StepProfile, state: PowerPlaneState,
                       spec: "ChipSpec | FleetSpec" = V5E,
                       overlap: float = 1.0
                       ) -> tuple[PowerPlaneState, dict[str, jnp.ndarray]]:
    """`account_step` vmapped over a `[n_chips]`-batched state: every chip is
    accounted at its own operating point; metrics come back `[n_chips]`.
    With a `FleetSpec` each chip is additionally accounted at its *own*
    process-varied nominals (per-chip DVFS curve + leakage)."""
    if isinstance(spec, FleetSpec):
        if spec.n_chips != state.n_chips:
            raise ValueError(f"FleetSpec has {spec.n_chips} chips but the "
                             f"state has {state.n_chips}")
        var = {k: jnp.asarray(v) for k, v in spec.variation().items()}
        return jax.vmap(
            lambda s, v: account_step(profile, s, spec.base, overlap,
                                      variation=v))(state, var)
    return jax.vmap(lambda s: account_step(profile, s, spec, overlap))(state)


# ---------------------------------------------------------------------------
# Typed observation builders (decision-as-data API, stage 1)
# ---------------------------------------------------------------------------

def account_and_observe(profile: StepProfile, state: PowerPlaneState,
                        spec: ChipSpec = V5E, overlap: float = 1.0,
                        variation: dict | None = None):
    """`account_step` that additionally builds the typed EXACT observation:
    returns (state', frame, metrics). The frame carries the oracle rail
    voltages (age 0) plus the step's roofline/power measurements — what the
    in-graph (HW-path) controller decides from."""
    from repro.core.telemetry import TelemetryFrame
    new, metrics = account_step(profile, state, spec, overlap,
                                variation=variation)
    nominals = None
    if variation is not None:
        nominals = {"v_nom_core": variation["v_core_nom"],
                    "v_nom_hbm": variation["v_hbm_nom"],
                    "v_nom_io": variation["v_io_nom"]}
    frame = TelemetryFrame.from_account(new, metrics, nominals=nominals)
    return new, frame, metrics


def account_fleet_and_observe(profile: StepProfile, state: PowerPlaneState,
                              spec: "ChipSpec | FleetSpec" = V5E,
                              overlap: float = 1.0):
    """`account_step_fleet` returning (state', frame, metrics): the EXACT
    `[n_chips]` observation, anchored to each chip's process-varied nominal
    voltages when `spec` is a `FleetSpec`."""
    from repro.core.telemetry import TelemetryFrame
    new, metrics = account_step_fleet(profile, state, spec, overlap)
    nominals = None
    if isinstance(spec, FleetSpec):
        nominals = {"v_nom_core": spec.v_core_nominal,
                    "v_nom_hbm": spec.v_hbm_nominal,
                    "v_nom_io": spec.v_io_nominal}
    frame = TelemetryFrame.from_account(new, metrics, nominals=nominals)
    return new, frame, metrics


def fleet_summary(state: PowerPlaneState) -> dict[str, jnp.ndarray]:
    """Fleet-level reductions of a batched state (worst/best chip + totals).
    The hot-path [n_chips, n_fields] telemetry reduction lives in
    repro.kernels.ops.fleet_reduce; this is the convenience view of the
    state itself."""
    if not state.is_fleet:
        raise ValueError("fleet_summary needs a batched ([n_chips]) state")
    return {
        "v_core_min": jnp.min(state.v_core), "v_core_max": jnp.max(state.v_core),
        "v_io_min": jnp.min(state.v_io), "v_io_max": jnp.max(state.v_io),
        "energy_total_j": jnp.sum(state.energy_j),
        "comp_level_min": jnp.min(state.comp_level),
    }


# The host controller (SW-path analogue) moved into the unified control plane;
# keep the historical import path working lazily to avoid a circular import.
def __getattr__(name: str):
    if name == "HostPowerController":
        from repro.core.control_plane import HostPowerController
        return HostPowerController
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
