"""The TPU power plane: VolTune's rail abstraction mapped onto a TPU chip
(DESIGN.md §2.2).

Three logical rails per chip — VDD_CORE (MXU/VPU), VDD_HBM, VDD_IO (ICI
SerDes, the MGTAVCC analogue) — are runtime-controlled state threaded through
the training/serving step. Mirroring the paper's two control paths:

  * in-graph controller (HW-path analogue): a pure `jax.lax` state update
    compiled into the jitted step — deterministic, zero host round-trip;
  * host controller (SW-path analogue): a Python policy loop between steps
    that actuates through a real (simulated) PMBus `PowerManager` on the
    TPU rail map, so every actuation pays the paper-characterized
    millisecond-scale PMBus latency and is logged transaction-by-transaction.

Step time/energy are derived from the compiled step's roofline terms
(`StepProfile`), scaled by rail voltages (DVFS: f ∝ v) and the collective
compression level ("link voltage" knob — see ecollectives.py).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import ecollectives
from repro.core.hwspec import V5E, ChipSpec
from repro.core.power_manager import ControlPath, PowerManager
from repro.core.rails import TPU_V5E_RAIL_MAP


@partial(jax.tree_util.register_dataclass,
         data_fields=["v_core", "v_hbm", "v_io", "comp_level", "energy_j", "step"],
         meta_fields=[])
@dataclasses.dataclass
class PowerPlaneState:
    """Per-step rail state (replicated across the mesh; SPMD-identical)."""
    v_core: jnp.ndarray    # f32 []
    v_hbm: jnp.ndarray     # f32 []
    v_io: jnp.ndarray      # f32 []
    comp_level: jnp.ndarray  # i32 [] — ecollectives compression level
    energy_j: jnp.ndarray  # f32 [] — accumulated chip energy
    step: jnp.ndarray      # i32 []

    @staticmethod
    def nominal(spec: ChipSpec = V5E) -> "PowerPlaneState":
        return PowerPlaneState(
            v_core=jnp.float32(spec.nominal_v_core),
            v_hbm=jnp.float32(spec.nominal_v_hbm),
            v_io=jnp.float32(spec.nominal_v_io),
            comp_level=jnp.int32(ecollectives.LEVEL_LOSSLESS),
            energy_j=jnp.float32(0.0),
            step=jnp.int32(0),
        )


@dataclasses.dataclass(frozen=True)
class StepProfile:
    """Static per-(arch, shape, mesh) roofline terms of one compiled step,
    extracted by repro.roofline from the dry-run artifacts."""
    flops_per_chip: float
    hbm_bytes_per_chip: float
    ici_bytes_per_chip: float      # at lossless compression
    grad_bytes_per_chip: float = 0.0  # gradient-sync share of ici bytes

    def as_jnp(self) -> dict[str, jnp.ndarray]:
        return {k: jnp.float32(v) for k, v in dataclasses.asdict(self).items()}


# ---------------------------------------------------------------------------
# Step time + power as differentiable-free jnp (usable in-graph)
# ---------------------------------------------------------------------------

def _freq_scale(v: jnp.ndarray, v_nom: float) -> jnp.ndarray:
    return jnp.maximum(0.4, v / v_nom)


def step_terms(profile: StepProfile, state: PowerPlaneState,
               spec: ChipSpec = V5E, k_fraction: float = 0.25):
    """Three roofline terms (seconds) under the current rail state."""
    f_core = _freq_scale(state.v_core, spec.nominal_v_core)
    f_hbm = _freq_scale(state.v_hbm, spec.nominal_v_hbm)
    f_io = _freq_scale(state.v_io, spec.nominal_v_io)

    # compression rescales only the gradient-sync share of ICI traffic
    lossless = ecollectives.wire_cost(ecollectives.LEVEL_LOSSLESS).bytes_per_element
    ratios = jnp.array([
        1.0,
        ecollectives.wire_cost(ecollectives.LEVEL_INT8).bytes_per_element / lossless,
        ecollectives.wire_cost(ecollectives.LEVEL_INT8_TOPK, k_fraction).bytes_per_element / lossless,
    ], jnp.float32)
    ratio = ratios[jnp.clip(state.comp_level, 0, 2)]
    grad_b = jnp.float32(profile.grad_bytes_per_chip)
    other_b = jnp.float32(profile.ici_bytes_per_chip) - grad_b
    ici_bytes = other_b + grad_b * ratio

    t_comp = jnp.float32(profile.flops_per_chip) / (spec.peak_bf16_flops * f_core)
    t_mem = jnp.float32(profile.hbm_bytes_per_chip) / (spec.hbm_bandwidth * f_hbm)
    t_coll = ici_bytes / (spec.ici_link_bandwidth * spec.ici_links_per_chip * f_io)
    return t_comp, t_mem, t_coll


def step_time_s(profile: StepProfile, state: PowerPlaneState,
                spec: ChipSpec = V5E, overlap: float = 1.0) -> jnp.ndarray:
    """Step wall time: max of the three terms under perfect overlap
    (overlap=1.0), or their weighted blend toward the sum when overlap<1."""
    t_comp, t_mem, t_coll = step_terms(profile, state, spec)
    t_max = jnp.maximum(t_comp, jnp.maximum(t_mem, t_coll))
    t_sum = t_comp + t_mem + t_coll
    return overlap * t_max + (1.0 - overlap) * t_sum


def chip_power_w_jnp(state: PowerPlaneState, util_mxu, util_hbm, util_ici,
                     spec: ChipSpec = V5E) -> jnp.ndarray:
    sv_core = state.v_core / spec.nominal_v_core
    sv_hbm = state.v_hbm / spec.nominal_v_hbm
    sv_io = state.v_io / spec.nominal_v_io
    p_core = (spec.p_core_dynamic_w * util_mxu * sv_core**3
              + spec.p_core_static_w * sv_core**2)
    p_hbm = spec.p_hbm_w * (0.3 + 0.7 * util_hbm) * sv_hbm**2
    p_ici = spec.p_ici_w * (0.15 + 0.85 * util_ici) * sv_io**2
    return p_core + p_hbm + p_ici + spec.p_other_w


def account_step(profile: StepProfile, state: PowerPlaneState,
                 spec: ChipSpec = V5E, overlap: float = 1.0
                 ) -> tuple[PowerPlaneState, dict[str, jnp.ndarray]]:
    """Advance the energy accumulator by one step; returns (state', metrics).
    Pure jnp — runs inside the jitted step (in-graph controller path)."""
    t_comp, t_mem, t_coll = step_terms(profile, state, spec)
    t_step = step_time_s(profile, state, spec, overlap)
    util_mxu = t_comp / t_step
    util_hbm = t_mem / t_step
    util_ici = t_coll / t_step
    p = chip_power_w_jnp(state, util_mxu, util_hbm, util_ici, spec)
    e = p * t_step
    new = dataclasses.replace(state, energy_j=state.energy_j + e,
                              step=state.step + 1)
    metrics = {
        "t_step_s": t_step, "t_comp_s": t_comp, "t_mem_s": t_mem,
        "t_coll_s": t_coll, "power_w": p, "energy_step_j": e,
        "util_mxu": util_mxu, "util_hbm": util_hbm, "util_ici": util_ici,
    }
    return new, metrics


# ---------------------------------------------------------------------------
# Host controller (SW-path analogue): actuates via simulated PMBus
# ---------------------------------------------------------------------------

class HostPowerController:
    """Python-side controller that drives the TPU logical rails through the
    same PowerManager/PMBus stack as the KC705 (paper §III-C analogue).

    Every actuation pays the characterized PMBus cost: the returned
    `actuation_latency_s` is the simulated control-path latency (command
    sequence + regulator settling), and transactions are logged."""

    LANES = {"VDD_CORE": 0, "VDD_HBM": 1, "VDD_IO": 2}

    def __init__(self, path: ControlPath | str = ControlPath.SOFTWARE,
                 clock_hz: int = 400_000, spec: ChipSpec = V5E):
        self.spec = spec
        self.pm = PowerManager(TPU_V5E_RAIL_MAP, path=path, clock_hz=clock_hz)
        self.actuations = 0
        self.actuation_seconds = 0.0

    def apply(self, state: PowerPlaneState) -> PowerPlaneState:
        """Push the requested rail voltages through PMBus; returns the state
        with voltages replaced by what the regulators actually achieved
        (clamp + LINEAR16 quantization + settling)."""
        wanted = {"VDD_CORE": float(state.v_core), "VDD_HBM": float(state.v_hbm),
                  "VDD_IO": float(state.v_io)}
        t0 = self.pm.clock.now
        achieved = {}
        for name, volts in wanted.items():
            lane = self.LANES[name]
            cur = self.pm.rail_voltage_now(lane)
            if abs(cur - volts) > 1e-4:
                res = self.pm.set_voltage(lane, volts)
                if res.ok:
                    # wait out regulator settling (1% band)
                    ch = self.pm.channels[lane]
                    self.pm.clock.advance(ch.settle_time_to_band(volts * 0.01))
                self.actuations += 1
            achieved[name] = self.pm.rail_voltage_now(lane)
        self.actuation_seconds += self.pm.clock.now - t0
        return dataclasses.replace(
            state,
            v_core=jnp.float32(achieved["VDD_CORE"]),
            v_hbm=jnp.float32(achieved["VDD_HBM"]),
            v_io=jnp.float32(achieved["VDD_IO"]),
        )

    def readback(self) -> dict[str, float]:
        return {name: self.pm.get_voltage(lane) for name, lane in self.LANES.items()}
