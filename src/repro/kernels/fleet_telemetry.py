"""Fleet telemetry reduction as a Pallas TPU kernel — the hot path of
fleet-scale rail control.

A fleet controller's decisions hinge on cross-chip reductions of the per-chip
telemetry matrix `[n_chips, n_fields]` (worst-chip gradient error for BER
gating, min/max rail headroom, total power/energy). At 1000+ chips x O(10)
fields polled every control round this is a bandwidth-bound streaming
reduction, so one kernel computes all three reductions (max, min, sum) in a
single pass over the data: the grid walks chip tiles sequentially and
accumulates per-field running reductions in the output block, which stays
resident in VMEM across grid steps.

Row padding is masked inside the kernel (per-reduction neutral elements);
column padding only pollutes lanes that are sliced off afterwards.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHIPS_PER_STEP = 128   # chip-tile rows per grid step
LANES = 128            # TPU lane width; fields are padded up to this


def _kernel(x_ref, max_ref, min_ref, sum_ref, *, n_valid: int, tile: int):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                     # [tile, F]
    rows = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0) + i * tile
    valid = rows < n_valid
    t_max = jnp.max(jnp.where(valid, x, -jnp.inf), axis=0, keepdims=True)
    t_min = jnp.min(jnp.where(valid, x, jnp.inf), axis=0, keepdims=True)
    t_sum = jnp.sum(jnp.where(valid, x, 0.0), axis=0, keepdims=True)

    @pl.when(i == 0)
    def _init():
        max_ref[...] = t_max
        min_ref[...] = t_min
        sum_ref[...] = t_sum

    @pl.when(i > 0)
    def _accumulate():
        max_ref[...] = jnp.maximum(max_ref[...], t_max)
        min_ref[...] = jnp.minimum(min_ref[...], t_min)
        sum_ref[...] = sum_ref[...] + t_sum


def _sor_kernel(x_ref, y_ref, w_ref,
                sw_ref, sx_ref, sy_ref, sxx_ref, sxy_ref):
    x = x_ref[...].astype(jnp.float32)                     # [window, L]
    y = y_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    wx = w * x
    sw_ref[...] = jnp.sum(w, axis=0, keepdims=True)
    sx_ref[...] = jnp.sum(wx, axis=0, keepdims=True)
    sy_ref[...] = jnp.sum(w * y, axis=0, keepdims=True)
    sxx_ref[...] = jnp.sum(wx * x, axis=0, keepdims=True)
    sxy_ref[...] = jnp.sum(wx * y, axis=0, keepdims=True)


SOR_ROWS_ALIGN = 8   # sublane alignment for the window axis


def sor_accumulate(x, y, w, *, interpret: bool = False):
    """Fused EWLS accumulation for the safe-operating-region fit: one pass
    over the `[window, n]` telemetry window computes all five weighted sums
    (sum w, w·x, w·y, w·x², w·x·y), each `[n]` f32 — `n` is the flattened
    n_rails x n_chips lane axis, so at O(1000) chips x 3 rails x 32-deep
    windows this is the same bandwidth-bound streaming reduction as
    `fleet_reduce`, with the five accumulators materialized in VMEM in a
    single read of the data. Row padding carries zero weight (every term is
    w-multiplied), so no in-kernel masking is needed; column padding only
    pollutes lanes that are sliced off afterwards."""
    window, n = x.shape
    rpad = (-window) % SOR_ROWS_ALIGN
    cpad = (-n) % LANES

    def pad(a):
        return jnp.pad(a.astype(jnp.float32), ((0, rpad), (0, cpad)))

    xm, ym, wm = pad(x), pad(y), pad(w)
    rows, cols = xm.shape
    n_steps = cols // LANES

    in_spec = pl.BlockSpec((rows, LANES), lambda i: (0, i))
    out_spec = pl.BlockSpec((1, LANES), lambda i: (0, i))
    out_shape = jax.ShapeDtypeStruct((1, cols), jnp.float32)
    outs = pl.pallas_call(
        _sor_kernel,
        grid=(n_steps,),
        in_specs=[in_spec, in_spec, in_spec],
        out_specs=(out_spec,) * 5,
        out_shape=(out_shape,) * 5,
        interpret=interpret,
    )(xm, ym, wm)
    return tuple(o[0, :n] for o in outs)


def fleet_reduce(x, *, interpret: bool = False):
    """x [n_chips, n_fields] f32 -> (max, min, sum), each [n_fields] f32."""
    n_chips, n_fields = x.shape
    fpad = (-n_fields) % LANES
    rpad = (-n_chips) % CHIPS_PER_STEP
    mat = jnp.pad(x.astype(jnp.float32), ((0, rpad), (0, fpad)))
    cols = mat.shape[1]
    n_steps = mat.shape[0] // CHIPS_PER_STEP

    out_spec = pl.BlockSpec((1, cols), lambda i: (0, 0))
    out_shape = jax.ShapeDtypeStruct((1, cols), jnp.float32)
    mx, mn, sm = pl.pallas_call(
        functools.partial(_kernel, n_valid=n_chips, tile=CHIPS_PER_STEP),
        grid=(n_steps,),
        in_specs=[pl.BlockSpec((CHIPS_PER_STEP, cols), lambda i: (i, 0))],
        out_specs=(out_spec, out_spec, out_spec),
        out_shape=(out_shape, out_shape, out_shape),
        interpret=interpret,
    )(mat)
    return mx[0, :n_fields], mn[0, :n_fields], sm[0, :n_fields]
