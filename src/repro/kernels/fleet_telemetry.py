"""Fleet telemetry reduction as a Pallas TPU kernel — the hot path of
fleet-scale rail control.

A fleet controller's decisions hinge on cross-chip reductions of the per-chip
telemetry matrix `[n_chips, n_fields]` (worst-chip gradient error for BER
gating, min/max rail headroom, total power/energy). At 1000+ chips x O(10)
fields polled every control round this is a bandwidth-bound streaming
reduction, so one kernel computes all three reductions (max, min, sum) in a
single pass over the data: the grid walks chip tiles sequentially and
accumulates per-field running reductions in the output block, which stays
resident in VMEM across grid steps.

Row padding is masked inside the kernel (per-reduction neutral elements);
column padding only pollutes lanes that are sliced off afterwards.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHIPS_PER_STEP = 128   # chip-tile rows per grid step
LANES = 128            # TPU lane width; fields are padded up to this


def _kernel(x_ref, max_ref, min_ref, sum_ref, *, n_valid: int, tile: int):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                     # [tile, F]
    rows = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0) + i * tile
    valid = rows < n_valid
    t_max = jnp.max(jnp.where(valid, x, -jnp.inf), axis=0, keepdims=True)
    t_min = jnp.min(jnp.where(valid, x, jnp.inf), axis=0, keepdims=True)
    t_sum = jnp.sum(jnp.where(valid, x, 0.0), axis=0, keepdims=True)

    @pl.when(i == 0)
    def _init():
        max_ref[...] = t_max
        min_ref[...] = t_min
        sum_ref[...] = t_sum

    @pl.when(i > 0)
    def _accumulate():
        max_ref[...] = jnp.maximum(max_ref[...], t_max)
        min_ref[...] = jnp.minimum(min_ref[...], t_min)
        sum_ref[...] = sum_ref[...] + t_sum


def _sor_kernel(x_ref, y_ref, w_ref,
                sw_ref, sx_ref, sy_ref, sxx_ref, sxy_ref):
    x = x_ref[...].astype(jnp.float32)                     # [window, L]
    y = y_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    wx = w * x
    sw_ref[...] = jnp.sum(w, axis=0, keepdims=True)
    sx_ref[...] = jnp.sum(wx, axis=0, keepdims=True)
    sy_ref[...] = jnp.sum(w * y, axis=0, keepdims=True)
    sxx_ref[...] = jnp.sum(wx * x, axis=0, keepdims=True)
    sxy_ref[...] = jnp.sum(wx * y, axis=0, keepdims=True)


SOR_ROWS_ALIGN = 8   # sublane alignment for the window axis


def _sor_fit_kernel(x_ref, y_ref, w_ref, bound_ref, guard_ref,
                    int_ref, slope_ref, front_ref, conf_ref, neff_ref,
                    floor_ref, *, min_slope: float, min_spread_v: float,
                    conf_samples: float):
    """One lane tile of the fused SOR fit: the five EWLS sums accumulate in
    VMEM exactly as `_sor_kernel`, then the per-lane solve + envelope floor
    run on the accumulators before anything leaves the chip — the estimate
    (6 x [1, L]) is the only thing written back, not the O(window) sums."""
    x = x_ref[...].astype(jnp.float32)                     # [window, L]
    y = y_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    wx = w * x
    sw = jnp.sum(w, axis=0, keepdims=True)                 # [1, L]
    sx = jnp.sum(wx, axis=0, keepdims=True)
    sy = jnp.sum(w * y, axis=0, keepdims=True)
    sxx = jnp.sum(wx * x, axis=0, keepdims=True)
    sxy = jnp.sum(wx * y, axis=0, keepdims=True)

    # the solve — the identical elementwise f32 op sequence as
    # ref.sor_solve_reference (bit-equivalence is pinned by tests)
    eps = jnp.float32(1e-9)
    denom = sw * sxx - sx * sx
    slope = (sw * sxy - sx * sy) / jnp.maximum(denom, eps)
    intercept = (sy - slope * sx) / jnp.maximum(sw, eps)
    var_x = jnp.maximum(sxx / jnp.maximum(sw, eps)
                        - (sx / jnp.maximum(sw, eps)) ** 2, 0.0)

    steep = slope < -jnp.float32(min_slope)
    spread = var_x > jnp.float32(min_spread_v) ** 2
    usable = steep & spread & (denom > eps)

    bound = bound_ref[...].astype(jnp.float32)             # [1, L]
    v_frontier = jnp.where(
        usable, (bound - intercept) / jnp.where(usable, slope, -1.0), 0.0)
    v_frontier = jnp.clip(v_frontier, 0.0, 2.0)
    confidence = jnp.where(
        usable, 1.0 - jnp.exp(-sw / jnp.float32(conf_samples)), 0.0)

    int_ref[...] = jnp.where(usable, intercept, 0.0)
    slope_ref[...] = jnp.where(usable, slope, 0.0)
    front_ref[...] = v_frontier
    conf_ref[...] = confidence
    neff_ref[...] = sw
    floor_ref[...] = v_frontier + guard_ref[...].astype(jnp.float32)


def sor_fit(x, y, w, log10_bound, guard, *, min_slope: float,
            min_spread_v: float, conf_samples: float,
            interpret: bool = False):
    """Fused safe-operating-region fit: EWLS accumulation + per-lane solve +
    envelope floor in ONE streaming pass over the `[window, n]` telemetry
    window (`n` = flattened n_rails x n_chips). Where `sor_accumulate`
    returns the five sums for a host-side solve, this carries the solve out
    of the same pass — the window is read once and only the 6 x [n] estimate
    (intercept, slope, v_frontier, confidence, n_eff, floor) is written
    back. `log10_bound`/`guard` are per-lane arrays (per-rail overrides
    broadcast over chips); the usability thresholds are compile-time
    scalars. Row padding carries zero weight, so no in-kernel masking;
    column padding only pollutes lanes that are sliced off afterwards."""
    window, n = x.shape
    rpad = (-window) % SOR_ROWS_ALIGN
    cpad = (-n) % LANES

    def pad(a):
        return jnp.pad(a.astype(jnp.float32), ((0, rpad), (0, cpad)))

    def pad_lane(a):
        return jnp.pad(a.astype(jnp.float32), (0, cpad)).reshape(1, -1)

    xm, ym, wm = pad(x), pad(y), pad(w)
    bm, gm = pad_lane(log10_bound), pad_lane(guard)
    rows, cols = xm.shape
    n_steps = cols // LANES

    win_spec = pl.BlockSpec((rows, LANES), lambda i: (0, i))
    lane_spec = pl.BlockSpec((1, LANES), lambda i: (0, i))
    out_shape = jax.ShapeDtypeStruct((1, cols), jnp.float32)
    outs = pl.pallas_call(
        functools.partial(_sor_fit_kernel, min_slope=min_slope,
                          min_spread_v=min_spread_v,
                          conf_samples=conf_samples),
        grid=(n_steps,),
        in_specs=[win_spec, win_spec, win_spec, lane_spec, lane_spec],
        out_specs=(lane_spec,) * 6,
        out_shape=(out_shape,) * 6,
        interpret=interpret,
    )(xm, ym, wm, bm, gm)
    return tuple(o[0, :n] for o in outs)


def sor_accumulate(x, y, w, *, interpret: bool = False):
    """Fused EWLS accumulation for the safe-operating-region fit: one pass
    over the `[window, n]` telemetry window computes all five weighted sums
    (sum w, w·x, w·y, w·x², w·x·y), each `[n]` f32 — `n` is the flattened
    n_rails x n_chips lane axis, so at O(1000) chips x 3 rails x 32-deep
    windows this is the same bandwidth-bound streaming reduction as
    `fleet_reduce`, with the five accumulators materialized in VMEM in a
    single read of the data. Row padding carries zero weight (every term is
    w-multiplied), so no in-kernel masking is needed; column padding only
    pollutes lanes that are sliced off afterwards."""
    window, n = x.shape
    rpad = (-window) % SOR_ROWS_ALIGN
    cpad = (-n) % LANES

    def pad(a):
        return jnp.pad(a.astype(jnp.float32), ((0, rpad), (0, cpad)))

    xm, ym, wm = pad(x), pad(y), pad(w)
    rows, cols = xm.shape
    n_steps = cols // LANES

    in_spec = pl.BlockSpec((rows, LANES), lambda i: (0, i))
    out_spec = pl.BlockSpec((1, LANES), lambda i: (0, i))
    out_shape = jax.ShapeDtypeStruct((1, cols), jnp.float32)
    outs = pl.pallas_call(
        _sor_kernel,
        grid=(n_steps,),
        in_specs=[in_spec, in_spec, in_spec],
        out_specs=(out_spec,) * 5,
        out_shape=(out_shape,) * 5,
        interpret=interpret,
    )(xm, ym, wm)
    return tuple(o[0, :n] for o in outs)


def fleet_reduce(x, *, interpret: bool = False):
    """x [n_chips, n_fields] f32 -> (max, min, sum), each [n_fields] f32."""
    n_chips, n_fields = x.shape
    fpad = (-n_fields) % LANES
    rpad = (-n_chips) % CHIPS_PER_STEP
    mat = jnp.pad(x.astype(jnp.float32), ((0, rpad), (0, fpad)))
    cols = mat.shape[1]
    n_steps = mat.shape[0] // CHIPS_PER_STEP

    out_spec = pl.BlockSpec((1, cols), lambda i: (0, 0))
    out_shape = jax.ShapeDtypeStruct((1, cols), jnp.float32)
    mx, mn, sm = pl.pallas_call(
        functools.partial(_kernel, n_valid=n_chips, tile=CHIPS_PER_STEP),
        grid=(n_steps,),
        in_specs=[pl.BlockSpec((CHIPS_PER_STEP, cols), lambda i: (i, 0))],
        out_specs=(out_spec, out_spec, out_spec),
        out_shape=(out_shape, out_shape, out_shape),
        interpret=interpret,
    )(mat)
    return mx[0, :n_fields], mn[0, :n_fields], sm[0, :n_fields]
