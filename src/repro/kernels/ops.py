"""Public jit'd wrappers for the Pallas kernels with backend dispatch.

On TPU the Pallas implementations run natively; elsewhere (this container is
CPU-only) the mathematically-identical XLA reference path executes, and the
Pallas bodies are validated in interpret mode by the kernel test suite.
Set REPRO_PALLAS=interpret to force interpret-mode Pallas everywhere
(slow; used by tests)."""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _pallas_mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env == "interpret":
        return "interpret"
    if env == "off":
        return "off"
    return "native" if jax.default_backend() == "tpu" else "off"


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("causal", "group",
                                             "sliding_window", "use_flash"))
def flash_attention(q, k, v, *, causal: bool = True, group: int = 1,
                    sliding_window: int = 0, use_flash: bool = True):
    """q [B,T,Hq,Dh], k/v [B,S,Hkv,Dh] -> [B,T,Hq,Dh]."""
    mode = _pallas_mode() if use_flash else "off"
    if mode != "off":
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(q, k, v, causal=causal, group=group,
                                  sliding_window=sliding_window,
                                  interpret=(mode == "interpret"))
    return ref.mha_reference(q, k, v, causal=causal, group=group,
                             sliding_window=sliding_window)


@functools.partial(jax.jit, static_argnames=("group",))
def decode_attention(q, k, v, lengths, *, group: int = 1):
    """q [B,1,Hq,Dh] against cache k/v [B,S,Hkv,Dh]; lengths [B] valid slots."""
    mode = _pallas_mode()
    if mode != "off":
        from repro.kernels import decode_attention as da
        return da.decode_attention(q, k, v, lengths, group=group,
                                   interpret=(mode == "interpret"))
    return ref.mha_reference(q, k, v, causal=False, group=group,
                             lengths=lengths)


# ---------------------------------------------------------------------------
# Mamba2 SSD chunked scan
#
# The Pallas scans run the forward; the backward recomputes through the
# differentiable jnp reference (identical math) via custom_vjp, so training
# through the kernels is exact on TPU. Off-TPU the reference runs directly.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _mamba2_kernel_vjp(x, dt, A, B, C, D, chunk, interpret, init_state):
    from repro.kernels import mamba2_ssd as m2
    return m2.mamba2_ssd(x, dt, A, B, C, D, chunk=chunk,
                         init_state=init_state, interpret=interpret)


def _mamba2_fwd(x, dt, A, B, C, D, chunk, interpret, init_state):
    out = _mamba2_kernel_vjp(x, dt, A, B, C, D, chunk, interpret, init_state)
    return out, (x, dt, A, B, C, D, init_state)


def _mamba2_bwd(chunk, interpret, res, g):
    x, dt, A, B, C, D, init_state = res
    _, vjp = jax.vjp(
        lambda *a: ref.mamba2_scan_reference(*a[:6], init_state=a[6]),
        x, dt, A, B, C, D,
        init_state if init_state is not None
        else jnp.zeros((x.shape[0], x.shape[2], B.shape[3], x.shape[3]),
                       jnp.float32))
    grads = vjp(g)
    return grads[:6] + (grads[6] if init_state is not None else None,)


_mamba2_kernel_vjp.defvjp(_mamba2_fwd, _mamba2_bwd)


@functools.partial(jax.jit, static_argnames=("chunk",))
def mamba2_scan(x, dt, A, B, C, D, *, chunk: int = 128, init_state=None):
    mode = _pallas_mode()
    if mode != "off":
        return _mamba2_kernel_vjp(x, dt, A, B, C, D, chunk,
                                  mode == "interpret", init_state)
    return ref.mamba2_scan_reference(x, dt, A, B, C, D, init_state=init_state)


# ---------------------------------------------------------------------------
# RWKV6 recurrence (same custom_vjp pattern)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _rwkv6_kernel_vjp(r, k, v, w, u, chunk, interpret, init_state):
    from repro.kernels import rwkv6_scan as r6
    return r6.rwkv6_scan(r, k, v, w, u, chunk=chunk,
                         init_state=init_state, interpret=interpret)


def _rwkv6_fwd(r, k, v, w, u, chunk, interpret, init_state):
    out = _rwkv6_kernel_vjp(r, k, v, w, u, chunk, interpret, init_state)
    return out, (r, k, v, w, u, init_state)


def _rwkv6_bwd(chunk, interpret, res, g):
    r, k, v, w, u, init_state = res
    _, vjp = jax.vjp(
        lambda *a: ref.rwkv6_scan_reference(*a[:5], init_state=a[5]),
        r, k, v, w, u,
        init_state if init_state is not None
        else jnp.zeros((r.shape[0], r.shape[2], r.shape[3], r.shape[3]),
                       jnp.float32))
    grads = vjp(g)
    return grads[:5] + (grads[5] if init_state is not None else None,)


_rwkv6_kernel_vjp.defvjp(_rwkv6_fwd, _rwkv6_bwd)


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_scan(r, k, v, w, u, *, chunk: int = 64, init_state=None):
    mode = _pallas_mode()
    if mode != "off":
        return _rwkv6_kernel_vjp(r, k, v, w, u, chunk,
                                 mode == "interpret", init_state)
    return ref.rwkv6_scan_reference(r, k, v, w, u, init_state=init_state)


# ---------------------------------------------------------------------------
# int8 block quantization codec
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block",))
def quantize_int8(x, *, block: int = 256):
    mode = _pallas_mode()
    if mode != "off":
        from repro.kernels import quant_codec as qc
        return qc.quantize_int8(x, block=block, interpret=(mode == "interpret"))
    return ref.quantize_int8_reference(x, block=block)


# ---------------------------------------------------------------------------
# Fleet telemetry reduction (fleet control plane hot path)
# ---------------------------------------------------------------------------

@jax.jit
def fleet_reduce(x):
    """x [n_chips, n_fields] -> (max, min, sum) over chips, each [n_fields].
    One streaming pass on TPU (fleet_telemetry.py); XLA reference elsewhere."""
    mode = _pallas_mode()
    if mode != "off":
        from repro.kernels import fleet_telemetry as ft
        return ft.fleet_reduce(x, interpret=(mode == "interpret"))
    return ref.fleet_reduce_reference(x)


@jax.jit
def sor_accumulate(x, y, w):
    """x/y/w [window, n] -> the five EWLS sums (Σw, Σwx, Σwy, Σwx², Σwxy),
    each [n] f32 — the safe-operating-region fit's accumulation
    (core/sor.py), fused into one streaming pass on TPU
    (fleet_telemetry.sor_accumulate); XLA reference elsewhere."""
    mode = _pallas_mode()
    if mode != "off":
        from repro.kernels import fleet_telemetry as ft
        return ft.sor_accumulate(x, y, w, interpret=(mode == "interpret"))
    return ref.sor_accumulate_reference(x, y, w)


@functools.partial(jax.jit, static_argnames=("min_slope", "min_spread_v",
                                             "conf_samples"))
def sor_fit(x, y, w, log10_bound, guard, *, min_slope: float,
            min_spread_v: float, conf_samples: float):
    """Fused safe-operating-region fit: the five EWLS sums, the per-lane
    solve, and the envelope floor carried out of ONE streaming pass over the
    `[window, n]` telemetry window (fleet_telemetry.sor_fit on TPU; the
    composed jnp reference elsewhere — XLA fuses accumulate+solve into one
    pass under jit). Returns (intercept, slope, v_frontier, confidence,
    n_eff, floor), each [n] f32 — bit-identical to `sor_accumulate` followed
    by the host-side solve (`ref.sor_solve_reference`), pinned by tests."""
    mode = _pallas_mode()
    if mode != "off":
        from repro.kernels import fleet_telemetry as ft
        return ft.sor_fit(x, y, w, log10_bound, guard, min_slope=min_slope,
                          min_spread_v=min_spread_v,
                          conf_samples=conf_samples,
                          interpret=(mode == "interpret"))
    return ref.sor_fit_reference(x, y, w, log10_bound, guard,
                                 min_slope=min_slope,
                                 min_spread_v=min_spread_v,
                                 conf_samples=conf_samples)


@jax.jit
def fleet_percentile(x, q):
    """`[n_chips]` stat vector -> the q-th percentile, [] f32. Routed
    through the kernels layer so the sharded fleet step's only cross-shard
    traffic (the worst/mean/p95 stat vectors) flows through one seam;
    percentile is sort-bound, so there is no streaming-kernel win — the XLA
    reference runs on every backend (including TPU)."""
    return ref.fleet_percentile_reference(x, q)


def chip_specs(tree, n_chips: int, axis_name: str = "chips"):
    """Per-leaf `PartitionSpec` pytree for a fleet-state pytree: any leaf
    whose *trailing* axis is the `[n_chips]` fleet axis shards that axis
    over `axis_name`; every other leaf (scalars like `SorState.tick`, the
    window/rail leading axes of `FrameHistory`) replicates. The chip axis
    is trailing everywhere in this codebase — `PowerPlaneState` `[n]`,
    `TelemetryFrame` `[n]`, `FrameHistory` `[capacity, n_rails, n]`,
    `SorEstimate` `[n_rails, n]` — so trailing-axis matching is exact."""
    from jax.sharding import PartitionSpec as P

    def spec(leaf):
        nd = jnp.ndim(leaf)
        if nd >= 1 and jnp.shape(leaf)[-1] == n_chips:
            return P(*((None,) * (nd - 1)), axis_name)
        return P()

    return jax.tree_util.tree_map(spec, tree)


def shard_chip_tree(tree, mesh, n_chips: int, axis_name: str = "chips"):
    """`device_put` a fleet-state pytree onto `mesh` with its trailing chip
    axis sharded over `axis_name` (`chip_specs` placement) — how a caller
    makes the plane/`SorState` carry physically shard-resident before
    feeding a mesh'd train step or the sharded control round. Scalars and
    chip-less leaves replicate."""
    from jax.sharding import NamedSharding
    specs = chip_specs(tree, n_chips, axis_name)
    return jax.tree_util.tree_map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), tree, specs)


def _shard_map(fn, mesh, in_specs, out_specs):
    """Version-portable shard_map (jax >= 0.5 top-level vs experimental)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def sharded_fleet_reduce(x, *, mesh=None, axis_name: str = "chips",
                         use_shard_map: bool | None = None):
    """`fleet_reduce` for a fleet axis sharded across real devices.

    When `mesh` spans more than one device (the fleet axis is physically
    distributed), each device reduces its local `[n_chips/n_dev, n_fields]`
    shard through the Pallas/XLA `fleet_reduce` hot path, then the partials
    combine in-graph via `pmax`/`pmin`/`psum` inside `shard_map` — the
    worst-chip reduction never gathers per-chip telemetry onto one device.
    On a single-device (CPU) mesh, or with `mesh=None`, it falls back to the
    plain vmap-path `fleet_reduce`. `use_shard_map` overrides the guard
    (tests exercise the collective path on a 1-device mesh)."""
    if use_shard_map is None:
        use_shard_map = mesh is not None and mesh.devices.size > 1
    if not use_shard_map:
        return fleet_reduce(x)
    if mesh is None:
        raise ValueError("sharded_fleet_reduce needs a mesh for shard_map")
    if axis_name not in mesh.axis_names:
        raise ValueError(f"mesh has axes {mesh.axis_names}, not {axis_name!r}")
    from jax.sharding import PartitionSpec as P

    def local(xs):
        mx, mn, sm = fleet_reduce(xs)
        return (jax.lax.pmax(mx, axis_name), jax.lax.pmin(mn, axis_name),
                jax.lax.psum(sm, axis_name))

    return _shard_map(local, mesh, in_specs=(P(axis_name),),
                      out_specs=(P(), P(), P()))(x)
