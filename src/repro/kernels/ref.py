"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references: kernel tests sweep shapes/dtypes and
assert_allclose against these, and non-TPU backends execute them directly
(the kernels target TPU; see kernels/__init__.py)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Attention (flash_attention / decode_attention oracle)
# ---------------------------------------------------------------------------

def mha_reference(q, k, v, *, causal: bool = True, group: int = 1,
                  sliding_window: int = 0, lengths=None):
    """q [B,T,Hq,Dh], k/v [B,S,Hkv,Dh] with Hq = group * Hkv.

    causal assumes aligned positions (self-attention). `lengths` [B] masks
    key slots >= length (decode against a partially-filled cache).
    Accumulates in f32, returns q.dtype."""
    B, T, Hq, Dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    assert Hq == group * Hkv, (Hq, group, Hkv)
    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.float32(Dh))
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to match q heads
    kf = jnp.repeat(kf, group, axis=2)
    vf = jnp.repeat(vf, group, axis=2)
    scores = jnp.einsum("bthk,bshk->bhts", qf, kf)
    neg = jnp.float32(-1e30)
    if causal:
        i = jnp.arange(T)[:, None]
        j = jnp.arange(S)[None, :]
        mask = j <= i
        if sliding_window:
            mask = mask & (j > i - sliding_window)
        scores = jnp.where(mask[None, None], scores, neg)
    if lengths is not None:
        valid = jnp.arange(S)[None, :] < lengths[:, None]
        scores = jnp.where(valid[:, None, None, :], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshk->bthk", probs, vf)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba2 SSD oracle (sequential scan over time)
# ---------------------------------------------------------------------------

def mamba2_scan_reference(x, dt, A, B, C, D, *, init_state=None):
    """Sequential state-space scan (the SSD recurrence, Mamba2 eq. form).

    x  [Bt, T, H, P]   input per head (P = head channel dim)
    dt [Bt, T, H]      softplus-activated step sizes (>0)
    A  [H]             negative scalar decay per head (A < 0)
    B  [Bt, T, G, N]   input->state projection (G groups, N = state dim)
    C  [Bt, T, G, N]   state->output projection
    D  [H]             skip connection
    Heads are split evenly over groups: head h uses group h // (H // G).

    state s_{t} = exp(dt_t * A) * s_{t-1} + dt_t * B_t x_t^T   (per head: [N,P])
    y_t = C_t . s_t + D * x_t
    Returns (y [Bt,T,H,P], final_state [Bt,H,N,P]).
    """
    Bt, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    hpg = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Df = D.astype(jnp.float32)

    Bh = jnp.repeat(Bf, hpg, axis=2)  # [Bt,T,H,N]
    Ch = jnp.repeat(Cf, hpg, axis=2)

    s0 = (jnp.zeros((Bt, H, N, P), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(s, inp):
        xt, dtt, bt, ct = inp  # [Bt,H,P],[Bt,H],[Bt,H,N],[Bt,H,N]
        decay = jnp.exp(dtt * Af)[..., None, None]          # [Bt,H,1,1]
        upd = (dtt[..., None, None]
               * bt[..., :, None] * xt[..., None, :])       # [Bt,H,N,P]
        s = s * decay + upd
        y = jnp.einsum("bhn,bhnp->bhp", ct, s)
        return s, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1) + Df[None, None, :, None] * xf
    return y.astype(x.dtype), s_fin


# ---------------------------------------------------------------------------
# RWKV6 oracle (data-dependent decay linear attention)
# ---------------------------------------------------------------------------

def rwkv6_scan_reference(r, k, v, w, u, *, init_state=None):
    """RWKV6 ("Finch") recurrence, sequential oracle.

    r,k,v [B,T,H,Dh]; w [B,T,H,Dh] per-step decay logits (w<0 after -exp
    transform applied by caller: here w is the *log-decay*, decay=exp(w));
    u [H,Dh] bonus for the current token.

    state S [B,H,Dh,Dh] (key-major):
      y_t = (u * k_t) v_t^T . r_t  +  S_{t-1} . r_t
      S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T
    Returns (y [B,T,H,Dh], final_state).
    """
    B, T, H, Dh = r.shape
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)
    s0 = (jnp.zeros((B, H, Dh, Dh), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(s, inp):
        rt, kt, vt, wt = inp  # each [B,H,Dh]
        att = s + (uf * kt)[..., :, None] * vt[..., None, :]   # [B,H,Dk,Dv]
        y = jnp.einsum("bhk,bhkv->bhv", rt, att)
        s = s * jnp.exp(wt)[..., :, None] + kt[..., :, None] * vt[..., None, :]
        return s, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), s_fin


# ---------------------------------------------------------------------------
# Blockwise int8 quantization oracle (ecollectives codec)
# ---------------------------------------------------------------------------

def quantize_int8_reference(x, block: int = 256):
    flat = jnp.ravel(x)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Fleet telemetry reduction oracle (fleet control plane)
# ---------------------------------------------------------------------------

def fleet_reduce_reference(x):
    """x [n_chips, n_fields] -> (max, min, sum), each [n_fields] f32."""
    xf = x.astype(jnp.float32)
    return jnp.max(xf, axis=0), jnp.min(xf, axis=0), jnp.sum(xf, axis=0)


def fleet_percentile_reference(x, q):
    """x [n_chips] -> the q-th percentile, [] f32: the bit-reference for the
    fleet p95 tail metrics (step time, gradient error). Sort-bound, so it is
    the real implementation on every backend, not just the oracle."""
    return jnp.percentile(x.astype(jnp.float32), q)


# ---------------------------------------------------------------------------
# SOR EWLS accumulation oracle (safe-operating-region fit hot path)
# ---------------------------------------------------------------------------

def sor_accumulate_reference(x, y, w):
    """x/y/w [window, n] -> the five EWLS sums (Σw, Σwx, Σwy, Σwx², Σwxy),
    each [n] f32 — exactly the weighted sums `core.sor.fit_history` solves
    its per-(rail, chip) least squares from (invalid lanes carry w == 0)."""
    xf, yf, wf = (a.astype(jnp.float32) for a in (x, y, w))
    return (jnp.sum(wf, axis=0), jnp.sum(wf * xf, axis=0),
            jnp.sum(wf * yf, axis=0), jnp.sum(wf * xf * xf, axis=0),
            jnp.sum(wf * xf * yf, axis=0))


def sor_solve_reference(sums, log10_bound, guard, *, min_slope: float,
                        min_spread_v: float, conf_samples: float):
    """The EWLS solve on the five accumulated sums — the exact op sequence
    `core.sor.fit_history` historically ran host-side after
    `sor_accumulate`, factored out so the fused kernel path
    (`sor_fit_reference` / fleet_telemetry.sor_fit) is bit-identical to the
    unfused accumulate-then-solve split by construction. All elementwise
    f32; `log10_bound`/`guard` are per-lane arrays (per-rail overrides
    broadcast over chips). Returns (intercept, slope, v_frontier,
    confidence, n_eff, floor), each [n] f32 — `floor` is the envelope floor
    `v_frontier + guard` that `core.sor.rail_envelopes` publishes."""
    sw, sx, sy, sxx, sxy = sums
    eps = jnp.float32(1e-9)
    denom = sw * sxx - sx * sx
    slope = (sw * sxy - sx * sy) / jnp.maximum(denom, eps)
    intercept = (sy - slope * sx) / jnp.maximum(sw, eps)
    var_x = jnp.maximum(sxx / jnp.maximum(sw, eps)
                        - (sx / jnp.maximum(sw, eps)) ** 2, 0.0)

    steep = slope < -jnp.float32(min_slope)
    spread = var_x > jnp.float32(min_spread_v) ** 2
    usable = steep & spread & (denom > eps)

    bound = jnp.asarray(log10_bound, jnp.float32)
    v_frontier = jnp.where(
        usable, (bound - intercept) / jnp.where(usable, slope, -1.0), 0.0)
    v_frontier = jnp.clip(v_frontier, 0.0, 2.0)
    confidence = jnp.where(
        usable, 1.0 - jnp.exp(-sw / jnp.float32(conf_samples)), 0.0)
    floor = v_frontier + jnp.asarray(guard, jnp.float32)
    return (jnp.where(usable, intercept, 0.0).astype(jnp.float32),
            jnp.where(usable, slope, 0.0).astype(jnp.float32),
            v_frontier.astype(jnp.float32), confidence.astype(jnp.float32),
            sw.astype(jnp.float32), floor.astype(jnp.float32))


def sor_fit_reference(x, y, w, log10_bound, guard, *, min_slope: float,
                      min_spread_v: float, conf_samples: float):
    """Fused EWLS fit: accumulate + solve + envelope floor in one call —
    the jnp oracle for `fleet_telemetry.sor_fit`. Composes the two reference
    stages verbatim, so fused == unfused bit-exactly on this path."""
    return sor_solve_reference(
        sor_accumulate_reference(x, y, w), log10_bound, guard,
        min_slope=min_slope, min_spread_v=min_spread_v,
        conf_samples=conf_samples)
