"""Flash-decode Pallas kernel: one query token against a long KV cache.

Grid: (B*Hq, S//BK); the kv-block axis is sequential on TPU so the online-
softmax state lives in VMEM scratch. Valid-length masking (rolling caches
pass the number of valid slots per batch row) arrives via SMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 512
NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale, bk, n_kb):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[pl.program_id(0)]
    run = (ki * bk) < length

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale              # [1, Dh]
        k = k_ref[0].astype(jnp.float32)                      # [bk, Dh]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [1,bk]
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == n_kb - 1)
    def _emit():
        l = l_scr[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l_safe[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, lengths, *, group=1, bk=DEFAULT_BK,
                     interpret=False):
    """q [B,1,Hq,Dh]; k/v [B,S,Hkv,Dh]; lengths [B] -> [B,1,Hq,Dh]."""
    B, _, Hq, Dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    bk = min(bk, S)
    if S % bk:
        raise ValueError(f"S={S} must tile by bk={bk}")
    n_kb = S // bk
    scale = 1.0 / (Dh ** 0.5)

    qf = jnp.swapaxes(q, 1, 2).reshape(B * Hq, 1, Dh)
    kf = jnp.swapaxes(k, 1, 2).reshape(B * Hkv, S, Dh)
    vf = jnp.swapaxes(v, 1, 2).reshape(B * Hkv, S, Dh)
    len_rep = jnp.repeat(lengths.astype(jnp.int32), Hq)

    kv_map = lambda bh, ki, g=group, h=Hq, hkv=Hkv: \
        ((bh // h) * hkv + (bh % h) // g, ki, 0)

    o = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bk=bk, n_kb=n_kb),
        grid=(B * Hq, n_kb),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, Dh), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, bk, Dh), kv_map),
            pl.BlockSpec((1, bk, Dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, Dh), lambda bh, ki: (bh, 0, 0)),
        scratch_shapes=[pltpu.VMEM((1,), jnp.float32),
                        pltpu.VMEM((1,), jnp.float32),
                        pltpu.VMEM((1, Dh), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((B * Hq, 1, Dh), q.dtype),
        interpret=interpret,
    )(len_rep, qf, kf, vf)
    return jnp.swapaxes(o.reshape(B, Hq, 1, Dh), 1, 2)
