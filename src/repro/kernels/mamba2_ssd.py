"""Mamba2 SSD (state-space dual) chunked scan as a Pallas TPU kernel.

The SSD form turns the sequential SSM recurrence into chunked matmuls (MXU
work) with a small cross-chunk state carry:

  within chunk (length Lc):  y_i  = sum_{j<=i} (C_i . B_j) e^{a_i - a_j} dt_j x_j
  cross chunk:               y_i += (C_i e^{a_i}) . S_prev
  carry:                     S    = e^{a_L} S_prev + sum_j e^{a_L - a_j} dt_j B_j x_j^T

with a = cumsum(dt * A) inside the chunk (A < 0 so every exponent is <= 0 —
numerically safe). Grid = (B*H, T//chunk); the chunk axis is sequential on
TPU so the [N, P] state lives in VMEM scratch.

Backward: the op is exposed through jax.custom_vjp in ops.py with the
differentiable chunked jnp reference (ref.mamba2_chunked_reference) as the
bwd path — fwd runs the kernel, bwd recomputes via XLA. Exact (same math),
documented perf trade-off in DESIGN.md §6.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, s0_ref,
            y_ref, sfin_ref, s_scr, *, n_chunks, hpg, n_heads):
    ci = pl.program_id(1)
    h = pl.program_id(0) % n_heads          # program rows are (batch*head)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0]

    x = x_ref[0].astype(jnp.float32)        # [Lc, P]
    dt = dt_ref[0].astype(jnp.float32)      # [Lc, 1] (padded lane dim)
    Bm = B_ref[0].astype(jnp.float32)       # [Lc, N]
    Cm = C_ref[0].astype(jnp.float32)       # [Lc, N]
    A = A_ref[h]                            # scalar (SMEM)
    D = D_ref[h]

    dts = dt[:, 0]                          # [Lc]
    a = jnp.cumsum(dts * A)                 # [Lc], decreasing (A<0)
    a_last = a[-1]

    # cross-chunk contribution
    s_prev = s_scr[...]                                        # [N, P]
    y_inter = jax.lax.dot_general(Cm * jnp.exp(a)[:, None], s_prev,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # within-chunk (causal decay-weighted attention-like matmul)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Lc,Lc]
    rows = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    decay = jnp.exp(a[:, None] - a[None, :])
    m = jnp.where(rows >= cols, decay * dts[None, :], 0.0)
    y_intra = jax.lax.dot_general(scores * m, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0] = (y_inter + y_intra + D * x).astype(y_ref.dtype)

    # state carry
    w = jnp.exp(a_last - a) * dts                              # [Lc]
    s_new = (jnp.exp(a_last) * s_prev
             + jax.lax.dot_general(Bm * w[:, None], x, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    s_scr[...] = s_new

    @pl.when(ci == n_chunks - 1)
    def _emit():
        sfin_ref[0] = s_new


def mamba2_ssd(x, dt, A, B, C, D, *, chunk=DEFAULT_CHUNK, init_state=None,
               interpret=False):
    """x [Bt,T,H,P]; dt [Bt,T,H]; A,D [H]; B,C [Bt,T,G,N].
    Returns (y [Bt,T,H,P], final_state [Bt,H,N,P])."""
    Bt, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    hpg = H // G
    chunk = min(chunk, T)
    if T % chunk:
        raise ValueError(f"T={T} must tile by chunk={chunk}")
    n_chunks = T // chunk

    if init_state is None:
        init_state = jnp.zeros((Bt, H, N, P), jnp.float32)

    # layout: per (batch*head) rows
    xf = jnp.swapaxes(x, 1, 2).reshape(Bt * H, T, P)
    dtf = jnp.swapaxes(dt, 1, 2).reshape(Bt * H, T, 1)
    Bf = jnp.swapaxes(B, 1, 2).reshape(Bt * G, T, N)
    Cf = jnp.swapaxes(C, 1, 2).reshape(Bt * G, T, N)
    s0 = init_state.reshape(Bt * H, N, P)

    bc_map = lambda bh, ci, hpg=hpg, h=H, g=G: \
        ((bh // h) * g + (bh % h) // hpg, ci, 0)

    y, sfin = pl.pallas_call(
        functools.partial(_kernel, n_chunks=n_chunks, hpg=hpg, n_heads=H),
        grid=(Bt * H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),   # A [H] -> indexed by head
            pl.BlockSpec((1, chunk, N), bc_map),
            pl.BlockSpec((1, chunk, N), bc_map),
            pl.BlockSpec(memory_space=pltpu.SMEM),   # D [H]
            pl.BlockSpec((1, N, P), lambda bh, ci: (bh, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, N, P), lambda bh, ci: (bh, 0, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        out_shape=(jax.ShapeDtypeStruct((Bt * H, T, P), x.dtype),
                   jax.ShapeDtypeStruct((Bt * H, N, P), jnp.float32)),
        interpret=interpret,
    )(xf, dtf, _head_mod(A, H), Bf, Cf, _head_mod(D, H), s0)
    return (jnp.swapaxes(y.reshape(Bt, H, T, P), 1, 2),
            sfin.reshape(Bt, H, N, P))


def _head_mod(arr, H):
    """SMEM scalars indexed by program_id(0) = b*H + h -> replicate per head
    row is not needed: kernel indexes arr[bh]; tile A per (batch*head)."""
    return jnp.asarray(arr, jnp.float32)
