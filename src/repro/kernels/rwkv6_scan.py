"""RWKV6 recurrence as a Pallas TPU kernel (chunked).

Unlike Mamba2's scalar-per-head decay, RWKV6's decay is a per-channel vector
(data-dependent), so the clean matmul dual does not apply directly. The
kernel processes chunks sequentially (grid axis) keeping the [Dh, Dh] state
in VMEM scratch, and walks the chunk with an unrolled fori loop of rank-1
outer-product updates — VPU work with the state resident in VMEM, which is
the part XLA does badly (it spills the state to HBM every step).

  y_t = r_t . (S + (u * k_t) v_t^T)
  S   = diag(exp(w_t)) S + k_t v_t^T          (w_t <= 0: log-decay)

Backward: ops.py wires jax.custom_vjp with the differentiable jnp reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sfin_ref,
            s_scr, *, chunk, n_chunks, n_heads):
    ci = pl.program_id(1)
    h = pl.program_id(0) % n_heads

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0]

    r = r_ref[0].astype(jnp.float32)    # [Lc, Dh]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)    # log-decay, <= 0
    u = u_ref[h].astype(jnp.float32)    # [Dh]

    def step(t, carry):
        s, y = carry
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)        # [1, Dh]
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        kv = kt.T * vt                                       # [Dh, Dh] rank-1
        att = s + (u[:, None] * kv)
        yt = jax.lax.dot_general(rt, att, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [1,Dh]
        s = s * jnp.exp(wt).T + kv
        y = jax.lax.dynamic_update_slice_in_dim(y, yt, t, 0)
        return s, y

    y0 = jnp.zeros((chunk, r.shape[1]), jnp.float32)
    s_fin, y = jax.lax.fori_loop(0, chunk, step, (s_scr[...], y0))
    s_scr[...] = s_fin
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit():
        sfin_ref[0] = s_fin


def rwkv6_scan(r, k, v, w, u, *, chunk=DEFAULT_CHUNK, init_state=None,
               interpret=False):
    """r,k,v,w [B,T,H,Dh] (w = log-decay <= 0); u [H,Dh].
    Returns (y [B,T,H,Dh], final_state [B,H,Dh,Dh])."""
    B, T, H, Dh = r.shape
    chunk = min(chunk, T)
    if T % chunk:
        raise ValueError(f"T={T} must tile by chunk={chunk}")
    n_chunks = T // chunk
    if init_state is None:
        init_state = jnp.zeros((B, H, Dh, Dh), jnp.float32)

    def flat(a):
        return jnp.swapaxes(a, 1, 2).reshape(B * H, T, Dh)

    s0 = init_state.reshape(B * H, Dh, Dh)
    row = lambda bh, ci: (bh, ci, 0)
    y, sfin = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks, n_heads=H),
        grid=(B * H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, Dh), row),
            pl.BlockSpec((1, chunk, Dh), row),
            pl.BlockSpec((1, chunk, Dh), row),
            pl.BlockSpec((1, chunk, Dh), row),
            pl.BlockSpec(memory_space=pl.ANY),  # u [H, Dh]
            pl.BlockSpec((1, Dh, Dh), lambda bh, ci: (bh, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, Dh), row),
            pl.BlockSpec((1, Dh, Dh), lambda bh, ci: (bh, 0, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((Dh, Dh), jnp.float32)],
        out_shape=(jax.ShapeDtypeStruct((B * H, T, Dh), r.dtype),
                   jax.ShapeDtypeStruct((B * H, Dh, Dh), jnp.float32)),
        interpret=interpret,
    )(flat(r), flat(k), flat(v), flat(w), jnp.asarray(u, jnp.float32), s0)
    return jnp.swapaxes(y.reshape(B, H, T, Dh), 1, 2), sfin.reshape(B, H, Dh, Dh)
