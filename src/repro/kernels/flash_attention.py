"""Flash attention (fwd + bwd) as Pallas TPU kernels.

Tiling: queries in (BQ=128) x keys in (BK=128) VMEM blocks — MXU-aligned on
the (128, head_dim) contraction. The kv-block grid axis is innermost and
sequential on TPU, so the streaming-softmax state (m, l, acc) lives in VMEM
scratch across kv steps and the normalized output is written on the last
step. Causal + sliding-window masking, GQA via kv-head index mapping
(q head h reads kv head h // group). Backward uses the standard two-kernel
split: dq accumulates over kv blocks; dk/dv accumulate over q blocks and the
GQA group. All accumulation in f32; lse saved by the forward for the vjp.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _mask(scores, qi, ki, bq, bk, *, causal, window):
    """Apply causal/sliding-window mask to a [bq, bk] score block located at
    query offset qi*bq, key offset ki*bk."""
    if not causal and not window:
        return scores
    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    keep = jnp.ones(scores.shape, jnp.bool_)
    if causal:
        keep = keep & (cols <= rows)
    if window:
        keep = keep & (cols > rows - window)
    return jnp.where(keep, scores, NEG_INF)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *, scale, causal, window, bq, bk,
                n_kb):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # with causal masking, kv blocks strictly above the diagonal contribute
    # nothing — skip their compute entirely
    run = jnp.bool_(True)
    if causal:
        run = (ki * bk) <= (qi * bq + bq - 1)
    if window:
        run = jnp.logical_and(run, (ki + 1) * bk - 1 > qi * bq - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale           # [bq, Dh]
        k = k_ref[0].astype(jnp.float32)                   # [bk, Dh]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = _mask(s, qi, ki, bq, bk, causal=causal, window=window)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == n_kb - 1)
    def _emit():
        l = l_scr[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0] = (m_scr[...] + jnp.log(l_safe)).astype(lse_ref.dtype)


def _fwd(q, k, v, *, causal, group, window, bq, bk, interpret):
    """q [B,Hq,T,Dh]; k/v [B,Hkv,S,Dh] -> (o [B,Hq,T,Dh], lse [B,Hq,T])."""
    B, Hq, T, Dh = q.shape
    S = k.shape[2]
    scale = 1.0 / (Dh ** 0.5)
    n_qb, n_kb = T // bq, S // bk
    grid = (B * Hq, n_qb, n_kb)

    kernel = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, n_kb=n_kb)
    out_shape = (jax.ShapeDtypeStruct((B * Hq, T, Dh), q.dtype),
                 jax.ShapeDtypeStruct((B * Hq, T), jnp.float32))
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, Dh),
                         lambda bh, qi, ki, g=group, h=Hq:
                         ((bh // h) * (h // g) + (bh % h) // g, ki, 0)),
            pl.BlockSpec((1, bk, Dh),
                         lambda bh, qi, ki, g=group, h=Hq:
                         ((bh // h) * (h // g) + (bh % h) // g, ki, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, bq, Dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bq), lambda bh, qi, ki: (bh, qi)),
        ),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(q.reshape(B * Hq, T, Dh), k.reshape(B * k.shape[1], S, Dh),
      v.reshape(B * v.shape[1], S, Dh))
    return o.reshape(B, Hq, T, Dh), lse.reshape(B, Hq, T)


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------

def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   acc_scr, *, scale, causal, window, bq, bk, n_kb):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = jnp.bool_(True)
    if causal:
        run = (ki * bk) <= (qi * bq + bq - 1)
    if window:
        run = jnp.logical_and(run, (ki + 1) * bk - 1 > qi * bq - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = _mask(s, qi, ki, bq, bk, causal=causal, window=window)
        p = jnp.exp(s - lse_ref[0][:, None])
        do = do_ref[0].astype(jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, None])
        acc_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32)

    @pl.when(ki == n_kb - 1)
    def _emit():
        dq_ref[0] = (acc_scr[...] * scale).astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_scr, dv_scr,
                    *, scale, causal, window, bq, bk, n_qb, group):
    # grid: (B*Hkv, kv block, group member, q block)
    ki = pl.program_id(1)
    gi = pl.program_id(2)
    qi = pl.program_id(3)

    @pl.when(jnp.logical_and(gi == 0, qi == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    run = jnp.bool_(True)
    if causal:
        run = (ki * bk) <= (qi * bq + bq - 1)
    if window:
        run = jnp.logical_and(run, (ki + 1) * bk - 1 > qi * bq - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = _mask(s, qi, ki, bq, bk, causal=causal, window=window)
        p = jnp.exp(s - lse_ref[0][:, None])                 # [bq, bk]
        do = do_ref[0].astype(jnp.float32)                   # [bq, Dh]
        dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, None])
        dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(gi == pl.num_programs(2) - 1,
                             qi == n_qb - 1))
    def _emit():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _bwd(res, g, *, causal, group, window, bq, bk, interpret):
    q, k, v, o, lse = res
    do = g
    B, Hq, T, Dh = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    scale = 1.0 / (Dh ** 0.5)
    n_qb, n_kb = T // bq, S // bk
    delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32), axis=-1)

    qf = q.reshape(B * Hq, T, Dh)
    kf = k.reshape(B * Hkv, S, Dh)
    vf = v.reshape(B * Hkv, S, Dh)
    dof = do.reshape(B * Hq, T, Dh)
    lsef = lse.reshape(B * Hq, T)
    deltaf = delta.reshape(B * Hq, T)

    kv_map = lambda bh, qi, ki, g=group, h=Hq: \
        ((bh // h) * (h // g) + (bh % h) // g, ki, 0)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, n_kb=n_kb),
        grid=(B * Hq, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, Dh), kv_map),
            pl.BlockSpec((1, bk, Dh), kv_map),
            pl.BlockSpec((1, bq, Dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bq), lambda bh, qi, ki: (bh, qi)),
            pl.BlockSpec((1, bq), lambda bh, qi, ki: (bh, qi)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda bh, qi, ki: (bh, qi, 0)),
        scratch_shapes=[pltpu.VMEM((bq, Dh), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((B * Hq, T, Dh), q.dtype),
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, deltaf)

    # dk/dv: grid walks (kv block, group member, q block) for each B*Hkv
    def q_map(bhkv, ki, gi, qi, g=group, hkv=Hkv):
        return ((bhkv // hkv) * (hkv * g) + (bhkv % hkv) * g + gi, qi, 0)

    def q_map_flat(bhkv, ki, gi, qi, g=group, hkv=Hkv):
        b = bhkv // hkv
        hq = (bhkv % hkv) * g + gi
        return (b * (hkv * g) + hq, qi, 0)

    kv_self = lambda bhkv, ki, gi, qi: (bhkv, ki, 0)
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, n_qb=n_qb, group=group),
        grid=(B * Hkv, n_kb, group, n_qb),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), q_map_flat),
            pl.BlockSpec((1, bk, Dh), kv_self),
            pl.BlockSpec((1, bk, Dh), kv_self),
            pl.BlockSpec((1, bq, Dh), q_map_flat),
            pl.BlockSpec((1, bq), lambda bhkv, ki, gi, qi:
                         (q_map_flat(bhkv, ki, gi, qi)[0], qi)),
            pl.BlockSpec((1, bq), lambda bhkv, ki, gi, qi:
                         (q_map_flat(bhkv, ki, gi, qi)[0], qi)),
        ],
        out_specs=(
            pl.BlockSpec((1, bk, Dh), lambda bhkv, ki, gi, qi: (bhkv, ki, 0)),
            pl.BlockSpec((1, bk, Dh), lambda bhkv, ki, gi, qi: (bhkv, ki, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((bk, Dh), jnp.float32),
                        pltpu.VMEM((bk, Dh), jnp.float32)],
        out_shape=(jax.ShapeDtypeStruct((B * Hkv, S, Dh), k.dtype),
                   jax.ShapeDtypeStruct((B * Hkv, S, Dh), v.dtype)),
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, deltaf)

    return (dq.reshape(B, Hq, T, Dh),
            dk.reshape(B, Hkv, S, Dh),
            dv.reshape(B, Hkv, S, Dh))


# ---------------------------------------------------------------------------
# Public entry (BTHD layout) with custom vjp
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _fa(q, k, v, causal, group, window, bq, bk, interpret):
    o, _ = _fwd(q, k, v, causal=causal, group=group, window=window,
                bq=bq, bk=bk, interpret=interpret)
    return o


def _fa_fwd(q, k, v, causal, group, window, bq, bk, interpret):
    o, lse = _fwd(q, k, v, causal=causal, group=group, window=window,
                  bq=bq, bk=bk, interpret=interpret)
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, group, window, bq, bk, interpret, res, g):
    return _bwd(res, g, causal=causal, group=group, window=window,
                bq=bq, bk=bk, interpret=interpret)


_fa.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(q, k, v, *, causal=True, group=1, sliding_window=0,
                    bq=DEFAULT_BQ, bk=DEFAULT_BK, interpret=False):
    """Public API, [B,T,H,Dh] layout (matches models/attention.py)."""
    B, T, Hq, Dh = q.shape
    S = k.shape[1]
    bq = min(bq, T)
    bk = min(bk, S)
    if T % bq or S % bk:
        raise ValueError(f"T={T}, S={S} must tile by ({bq},{bk})")
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = _fa(qt, kt, vt, causal, group, sliding_window, bq, bk, interpret)
    return jnp.swapaxes(o, 1, 2)
