"""Blockwise int8 quantization codec as a Pallas TPU kernel — the hot loop of
the error-bounded collectives (the paper-technique data path: every gradient
byte that crosses ICI goes through this).

One grid row handles ROWS_PER_STEP quantization blocks; absmax reduction and
scale/round/clip run entirely in VMEM. The dequantize side is a trivial
broadcast-multiply left to XLA (it fuses into the consumer).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_PER_STEP = 32


def _kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # [R, block]
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def quantize_int8(x, *, block: int = 256, interpret: bool = False):
    """x any shape -> (q [nblocks, block] int8, scale [nblocks, 1] f32).
    Zero-pads the tail block (matches ref.quantize_int8_reference)."""
    flat = jnp.ravel(x)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    rows = flat.size // block
    # pad rows so the grid tiles evenly
    rpad = (-rows) % ROWS_PER_STEP
    if rpad:
        flat = jnp.concatenate([flat, jnp.zeros((rpad * block,), flat.dtype)])
    mat = flat.reshape(-1, block)
    n_steps = mat.shape[0] // ROWS_PER_STEP

    q, s = pl.pallas_call(
        _kernel,
        grid=(n_steps,),
        in_specs=[pl.BlockSpec((ROWS_PER_STEP, block), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((ROWS_PER_STEP, block), lambda i: (i, 0)),
                   pl.BlockSpec((ROWS_PER_STEP, 1), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct(mat.shape, jnp.int8),
                   jax.ShapeDtypeStruct((mat.shape[0], 1), jnp.float32)),
        interpret=interpret,
    )(mat)
    return q[:rows], s[:rows]
