"""Serving tier: batched engine + headroom-aware fleet routing
(docs/serve.md)."""

from repro.serve.engine import ServeEngine, ServeStats
from repro.serve.router import (HeadroomRouter, RequestLedger,
                                RoundRobinRouter, rail_headroom)
from repro.serve.traffic import Request, TrafficTrace, bursty_trace

__all__ = [
    "HeadroomRouter", "Request", "RequestLedger", "RoundRobinRouter",
    "ServeEngine", "ServeStats", "TrafficTrace", "bursty_trace",
    "rail_headroom",
]
