"""Seeded bursty serving-traffic traces (docs/serve.md).

The router/SLO subsystem is exercised against *replayable* open-loop
arrival processes: a two-state modulated Poisson source (quiet <-> burst)
with per-request prefill/decode token draws. Everything is derived from one
`numpy` generator seeded by the caller, so the same (seed, knobs) always
yields the same trace — placement comparisons (headroom router vs
round-robin) and the CI bench gate replay the identical workload.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request of the open-loop trace. `t_arrival_s` is when the
    request enters the system (trace time, seconds); token counts model the
    prompt (prefill, compute-bound) and generation (decode, HBM-bound)
    phases the router weighs against per-rail headroom."""
    rid: int
    t_arrival_s: float
    prefill_tokens: int
    decode_tokens: int

    @property
    def total_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    @property
    def decode_fraction(self) -> float:
        """Share of the request's work that is decode — the router's
        phase-mix weight (1.0 = pure decode, memory-bound)."""
        return self.decode_tokens / max(self.total_tokens, 1)


@dataclasses.dataclass(frozen=True)
class TrafficTrace:
    """An arrival-ordered tuple of `Request`s plus the knobs that produced
    it (for records/provenance). Deterministic by construction: rebuilding
    with the same metadata yields the identical trace."""
    requests: tuple
    seed: int
    metadata: dict

    def __len__(self) -> int:
        return len(self.requests)

    def __iter__(self):
        return iter(self.requests)

    @property
    def duration_s(self) -> float:
        return self.requests[-1].t_arrival_s if self.requests else 0.0

    @property
    def total_decode_tokens(self) -> int:
        return sum(r.decode_tokens for r in self.requests)


def steady_trace(
    n_requests: int,
    *,
    rate_hz: float = 10.0,
    t_start_s: float = 0.0,
    prefill_tokens: int = 8,
    decode_tokens: int = 48,
) -> TrafficTrace:
    """Deterministic evenly-spaced arrivals with FIXED token counts — no
    randomness at all. The forced-pin migration scenario and the
    fast-forward tests want full control of exactly when work lands and
    how big it is; a seeded bursty trace can only approximate that."""
    if n_requests < 1:
        raise ValueError(f"n_requests must be >= 1, got {n_requests}")
    if rate_hz <= 0:
        raise ValueError("rate_hz must be positive")
    requests = tuple(
        Request(rid=rid, t_arrival_s=float(t_start_s + rid / rate_hz),
                prefill_tokens=int(prefill_tokens),
                decode_tokens=int(decode_tokens))
        for rid in range(n_requests))
    metadata = {
        "kind": "steady", "n_requests": n_requests, "rate_hz": rate_hz,
        "t_start_s": t_start_s, "prefill_tokens": prefill_tokens,
        "decode_tokens": decode_tokens,
    }
    return TrafficTrace(requests=requests, seed=0, metadata=metadata)


def bursty_trace(
    n_requests: int,
    seed: int = 0,
    *,
    quiet_rate_hz: float = 4.0,
    burst_rate_hz: float = 40.0,
    mean_quiet_s: float = 2.0,
    mean_burst_s: float = 1.0,
    prefill_mean: float = 48.0,
    decode_mean: float = 40.0,
    token_sigma: float = 0.5,
) -> TrafficTrace:
    """Two-state modulated Poisson arrivals: exponential dwell times in a
    `quiet` state (rate `quiet_rate_hz`) and a `burst` state (rate
    `burst_rate_hz`), exponential inter-arrivals at the current state's
    rate. Token counts are lognormal around the given means (sigma in log
    space `token_sigma`), floored at 1. All randomness flows from ONE
    seeded `np.random.default_rng`, so the trace is a pure function of its
    arguments."""
    if n_requests < 1:
        raise ValueError(f"n_requests must be >= 1, got {n_requests}")
    if quiet_rate_hz <= 0 or burst_rate_hz <= 0:
        raise ValueError("arrival rates must be positive")
    rng = np.random.default_rng(seed)

    requests = []
    t = 0.0
    bursting = False
    state_end = rng.exponential(mean_quiet_s)
    for rid in range(n_requests):
        rate = burst_rate_hz if bursting else quiet_rate_hz
        t += rng.exponential(1.0 / rate)
        while t > state_end:
            bursting = not bursting
            state_end += rng.exponential(
                mean_burst_s if bursting else mean_quiet_s)
        # lognormal with the requested arithmetic mean: mu = ln(m) - s^2/2
        def draw(mean: float) -> int:
            mu = np.log(mean) - 0.5 * token_sigma**2
            return max(1, int(round(rng.lognormal(mu, token_sigma))))
        requests.append(Request(rid=rid, t_arrival_s=float(t),
                                prefill_tokens=draw(prefill_mean),
                                decode_tokens=draw(decode_mean)))
    metadata = {
        "kind": "bursty", "n_requests": n_requests, "seed": seed,
        "quiet_rate_hz": quiet_rate_hz, "burst_rate_hz": burst_rate_hz,
        "mean_quiet_s": mean_quiet_s, "mean_burst_s": mean_burst_s,
        "prefill_mean": prefill_mean, "decode_mean": decode_mean,
        "token_sigma": token_sigma,
    }
    return TrafficTrace(requests=tuple(requests), seed=seed,
                        metadata=metadata)
