"""Headroom-aware fleet placement + per-request SLO accounting
(docs/serve.md).

The fourth consumer tier of the control API: observe -> decide ->
arbitrate -> **place**. The control plane learns per-chip per-rail safe
operating regions (`core/sor.py`); this module spends them — each chip's
per-rail *headroom* (held voltage minus its confidence-blended learned
floor) is the margin the chip has left to absorb runtime drift
(load-coupled onset shifts, the consolidated-margins result), so work is
placed where that margin is deepest:

* memory-bound decode-heavy requests go to the deepest-VDD_HBM-headroom
  chips, prefill-heavy ones weigh VDD_CORE;
* chips pinned at an envelope floor (arbitration holds them at the learned
  limit the policy keeps pushing against — `control_plane.pinned_rails`)
  receive no new work and drain what they hold;
* a `RoundRobinRouter` provides the headroom-blind baseline the
  `benchmarks/serve_router.py` comparison (and its CI gate) is measured
  against.

Routers are host-side and numpy-only: placement runs between accounted
ticks on concrete telemetry (the eager `last_request`/`last_envelope` the
controllers record), never inside the jitted round.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.power_plane import PowerPlaneState
from repro.core.rails import TPU_V5E_RAIL_MAP, RailMap

_RAIL_FIELDS = {"VDD_CORE": "v_core", "VDD_HBM": "v_hbm", "VDD_IO": "v_io"}


def rail_headroom(plane: PowerPlaneState, envelopes: Any,
                  rail_map: RailMap = TPU_V5E_RAIL_MAP
                  ) -> dict[str, np.ndarray]:
    """{rail: [n_chips] float} — held voltage minus the rail's
    confidence-blended floor (`SafeEnvelope.floor(static v_min)`; the
    platform static floor where no envelope is fitted). This is the margin
    the chip has below its current operating point before arbitration pins
    it: 0 means the chip is operating AT its learned limit. All three
    rails come back in ONE stacked device transfer (the historical
    spelling paid one blocking `device_get` per rail); the fused serve
    tick avoids even that by packing the same rows into its per-tick host
    bundle (`headroom_from_packed`)."""
    from repro.core.control_plane import rail_floors
    n = plane.n_chips
    held = jnp.stack([
        jnp.broadcast_to(jnp.atleast_1d(
            jnp.asarray(getattr(plane, field), jnp.float32)), (n,))
        for field in _RAIL_FIELDS.values()])
    h = np.asarray(jax.device_get(
        held - rail_floors(plane, envelopes, rail_map)), np.float64)
    return {name: h[i].copy() for i, name in enumerate(_RAIL_FIELDS)}


def headroom_from_packed(rows) -> dict[str, np.ndarray]:
    """{rail: [n_chips] float} from already-transferred per-rail headroom
    rows (`[n_rails, n_chips]`, `control_plane.RAIL_LANES` order) — the
    fused serve tick's packed host bundle. Zero device syncs: the rows
    rode the tick's single bundle transfer."""
    a = np.asarray(rows, np.float64)
    return {name: a[i].copy() for i, name in enumerate(_RAIL_FIELDS)}


@dataclasses.dataclass
class HeadroomRouter:
    """Scores each chip from the live learned envelopes and places a request
    on the best-scoring eligible chip.

    score_i = w_prefill * headroom[prefill_rail][i]
            + w_decode  * headroom[decode_rail][i]
            - occupancy_weight_v * occupancy[i] / capacity

    where (w_prefill, w_decode) is the request's token mix — decode-heavy
    requests chase VDD_HBM headroom (decode is HBM-bound), prefill-heavy
    ones VDD_CORE — and the occupancy term trades volts of headroom against
    queueing (one full batch slot costs `occupancy_weight_v / capacity`
    volts of score). Pinned chips are excluded while `drain_pinned` (they
    finish what they hold and shed first); ties break on the lowest chip
    index (np.argmax), so placement is deterministic given the inputs."""
    capacity: int
    decode_rail: str = "VDD_HBM"
    prefill_rail: str = "VDD_CORE"
    occupancy_weight_v: float = 0.01
    drain_pinned: bool = True
    name: str = "headroom"

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")

    def reset(self) -> None:
        """Per-trace reset (`serve_trace` calls it at trace start). The
        headroom router is stateless — this exists so both routers share
        the trace-lifecycle interface."""

    def place(self, request, occupancy, headroom: dict[str, np.ndarray],
              pinned=None) -> "int | None":
        occ = np.asarray(occupancy, np.float64)
        n = occ.shape[0]
        eligible = occ < self.capacity
        if self.drain_pinned and pinned is not None:
            eligible &= ~np.asarray(pinned, bool)
        if not eligible.any():
            return None
        w_decode = request.decode_fraction
        zeros = np.zeros(n, np.float64)
        h_d = np.asarray(headroom.get(self.decode_rail, zeros), np.float64)
        h_p = np.asarray(headroom.get(self.prefill_rail, zeros), np.float64)
        score = ((1.0 - w_decode) * h_p + w_decode * h_d
                 - self.occupancy_weight_v * occ / self.capacity)
        score = np.where(eligible, score, -np.inf)
        return int(np.argmax(score))

    def place_batch(self, requests, occupancy,
                    headroom: dict[str, np.ndarray],
                    pinned=None) -> list[int]:
        """Place a whole FIFO queue in one pass: the headroom terms of
        every request's score are computed as one `[n_requests, n_chips]`
        matrix, and only the occupancy term (the one thing placement
        itself changes) updates between requests. Returns the chip per
        placed request, head-of-line prefix order — placement stops at the
        first request with no eligible chip, exactly like repeated
        sequential `place()` calls (same arithmetic, same lowest-index
        tie-break), which tests pin bit-equal."""
        if not requests:
            return []
        occ = np.asarray(occupancy, np.float64).copy()
        n = occ.shape[0]
        elig = np.ones(n, bool)
        if self.drain_pinned and pinned is not None:
            elig &= ~np.asarray(pinned, bool)
        w = np.asarray([r.decode_fraction for r in requests], np.float64)
        zeros = np.zeros(n, np.float64)
        h_d = np.asarray(headroom.get(self.decode_rail, zeros), np.float64)
        h_p = np.asarray(headroom.get(self.prefill_rail, zeros), np.float64)
        base = (1.0 - w)[:, None] * h_p[None, :] + w[:, None] * h_d[None, :]
        out: list[int] = []
        for k in range(len(requests)):
            eligible = elig & (occ < self.capacity)
            if not eligible.any():
                break
            score = base[k] - self.occupancy_weight_v * occ / self.capacity
            score = np.where(eligible, score, -np.inf)
            chip = int(np.argmax(score))
            out.append(chip)
            occ[chip] += 1.0
        return out

    def plan_migration(self, requests, occupancy,
                       headroom: dict[str, np.ndarray],
                       pinned=None, exclude=None) -> "list[int | None]":
        """Destinations for in-flight lanes being evacuated off hot chips:
        one entry per request, the deepest-headroom eligible chip by the
        SAME score `place` uses (phase-mix headroom blend minus the
        occupancy term, lowest-index tie-break), or None when no chip is
        eligible. Unlike `place_batch` an unplaceable request does NOT
        block the ones behind it — migration is best-effort, not FIFO.
        Eligibility: below capacity, not `exclude`d (the source chips
        being evacuated), and never pinned — pinned chips are excluded
        regardless of `drain_pinned`, since parking evacuated work on a
        chip already at its envelope floor recreates the problem being
        solved. Occupancy advances per granted destination, so one
        planning pass spreads a whole evacuation."""
        if not requests:
            return []
        occ = np.asarray(occupancy, np.float64).copy()
        n = occ.shape[0]
        elig = np.ones(n, bool)
        if pinned is not None:
            elig &= ~np.asarray(pinned, bool)
        if exclude is not None:
            elig &= ~np.asarray(exclude, bool)
        w = np.asarray([r.decode_fraction for r in requests], np.float64)
        zeros = np.zeros(n, np.float64)
        h_d = np.asarray(headroom.get(self.decode_rail, zeros), np.float64)
        h_p = np.asarray(headroom.get(self.prefill_rail, zeros), np.float64)
        base = (1.0 - w)[:, None] * h_p[None, :] + w[:, None] * h_d[None, :]
        out: "list[int | None]" = []
        for k in range(len(requests)):
            eligible = elig & (occ < self.capacity)
            if not eligible.any():
                out.append(None)
                continue
            score = base[k] - self.occupancy_weight_v * occ / self.capacity
            score = np.where(eligible, score, -np.inf)
            chip = int(np.argmax(score))
            out.append(chip)
            occ[chip] += 1.0
        return out


@dataclasses.dataclass
class RoundRobinRouter:
    """Headroom-blind baseline: next chip with a free batch slot, cursor
    order, ignoring envelopes and pinning entirely — what serving looked
    like before the fleet had per-chip margins to read."""
    capacity: int
    name: str = "roundrobin"
    _cursor: int = dataclasses.field(default=0, repr=False)

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")

    def reset(self) -> None:
        """Per-trace reset: rewind the cursor so back-to-back traces on
        one engine place identically (`serve_trace` calls it at trace
        start; historically the second trace started mid-cursor)."""
        self._cursor = 0

    def place(self, request, occupancy, headroom=None,
              pinned=None) -> "int | None":
        n = len(occupancy)
        for k in range(n):
            i = (self._cursor + k) % n
            if occupancy[i] < self.capacity:
                self._cursor = (i + 1) % n
                return i
        return None

    def place_batch(self, requests, occupancy, headroom=None,
                    pinned=None) -> list[int]:
        """Whole-queue round-robin in one numpy pass. Sequential cursor
        semantics place one request per free chip per cyclic sweep (between
        two visits to the same chip every other chip is visited once), so
        the placement order is exactly: sweep s = 0, 1, ... over the
        cursor-rotated chip order, keeping chips with more than s free
        slots — which vectorizes as a boolean [capacity, n_chips] mask.
        Tests pin the result bit-equal to repeated `place()` calls,
        including the final cursor position."""
        if not requests:
            return []
        occ = np.asarray(occupancy, np.int64)
        n = occ.shape[0]
        rot = (self._cursor + np.arange(n)) % n
        free = self.capacity - occ[rot]
        keep = free[None, :] > np.arange(self.capacity)[:, None]
        order = np.broadcast_to(rot, keep.shape)[keep]   # sweep-major
        out = order[: len(requests)].tolist()
        if out:
            self._cursor = int((out[-1] + 1) % n)
        return [int(i) for i in out]


# ---------------------------------------------------------------------------
# Per-request SLO accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _RequestRecord:
    rid: int
    t_arrival_s: float
    prefill_tokens: int
    decode_tokens: int
    t_placed_s: "float | None" = None
    chip: "int | None" = None
    t_done_s: "float | None" = None
    tokens_out: int = 0
    energy_j: float = 0.0        # modeled busy-energy share while resident
    defers: int = 0
    defer_time_s: float = 0.0
    migrations: int = 0          # in-flight moves off pinned/over chips
    stall_time_s: float = 0.0    # KV-transfer stall paid across migrations


class RequestLedger:
    """Per-request SLO accounting for a routed serve run: admission,
    placement, deferral (by reason code), completion, and modeled energy —
    plus the latency percentiles the SLO story is told in. Timestamps are
    trace-time seconds supplied by the caller (the engine's simulated
    clock), so ledgers from the same seeded trace are reproducible."""

    def __init__(self):
        self._recs: dict[int, _RequestRecord] = {}
        self._order: list[int] = []
        self.fleet_energy_j = 0.0           # all chips, busy + idle
        self.defers_by_reason: dict[str, int] = {}
        # "migrated" lifecycle events, trace order: one dict per in-flight
        # move (rid, t_s, src, dst, stall_s, src_streak — the pinned/over
        # streak length that triggered the evacuation)
        self.migration_events: list[dict] = []

    def __len__(self) -> int:
        return len(self._recs)

    def __getitem__(self, rid: int) -> _RequestRecord:
        return self._recs[rid]

    def records(self) -> list[_RequestRecord]:
        return [self._recs[r] for r in self._order]

    # -- lifecycle ------------------------------------------------------------
    def admit(self, request, t_s: "float | None" = None) -> None:
        if request.rid in self._recs:
            raise ValueError(f"request {request.rid} already admitted")
        self._recs[request.rid] = _RequestRecord(
            rid=request.rid,
            t_arrival_s=float(request.t_arrival_s if t_s is None else t_s),
            prefill_tokens=request.prefill_tokens,
            decode_tokens=request.decode_tokens)
        self._order.append(request.rid)

    def place(self, rid: int, t_s: float, chip: int) -> None:
        rec = self._recs[rid]
        if rec.t_placed_s is not None:
            raise ValueError(f"request {rid} already placed")
        rec.t_placed_s = float(t_s)
        rec.chip = int(chip)

    def defer(self, rid: int, reason: str, dt_s: float = 0.0) -> None:
        rec = self._recs[rid]
        rec.defers += 1
        rec.defer_time_s += float(dt_s)
        self.defers_by_reason[reason] = (
            self.defers_by_reason.get(reason, 0) + 1)

    def migrate(self, rid: int, t_s: float, src: int, dst: int,
                stall_s: float = 0.0, src_streak: int = 0) -> None:
        """Record an in-flight move of a resident request from chip `src`
        to chip `dst` (the "migrated" lifecycle event): the record's chip
        becomes the destination, and the KV-transfer stall it pays is
        accumulated. Guards mirror the rest of the lifecycle — migrating
        an unplaced or finished request raises, as does a source that
        disagrees with where the ledger believes the request lives."""
        rec = self._recs[rid]
        if rec.t_placed_s is None:
            raise ValueError(f"request {rid} migrated before placement")
        if rec.t_done_s is not None:
            raise ValueError(f"request {rid} migrated after completion")
        if rec.chip != int(src):
            raise ValueError(f"request {rid} lives on chip {rec.chip}, "
                             f"not the claimed source {src}")
        if int(src) == int(dst):
            raise ValueError(f"request {rid}: migration source == "
                             f"destination ({src})")
        rec.chip = int(dst)
        rec.migrations += 1
        rec.stall_time_s += float(stall_s)
        self.migration_events.append({
            "rid": rid, "t_s": float(t_s), "src": int(src),
            "dst": int(dst), "stall_s": float(stall_s),
            "src_streak": int(src_streak)})

    def charge(self, rid: int, joules: float) -> None:
        self._recs[rid].energy_j += float(joules)

    def tick_energy(self, joules: float) -> None:
        self.fleet_energy_j += float(joules)

    def finish(self, rid: int, t_s: float, tokens_out: int) -> None:
        rec = self._recs[rid]
        if rec.t_placed_s is None:
            raise ValueError(f"request {rid} finished before placement")
        rec.t_done_s = float(t_s)
        rec.tokens_out = int(tokens_out)

    # -- statistics -----------------------------------------------------------
    @staticmethod
    def percentile(values, q: float) -> float:
        """Linear-interpolated percentile at rank q/100 * (n-1) — the exact
        arithmetic pinned by tests (numpy's default 'linear' method,
        spelled out so the SLO numbers are specified, not inherited)."""
        vals = sorted(float(v) for v in values)
        if not vals:
            return float("nan")
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"q must be in [0, 100], got {q}")
        rank = (len(vals) - 1) * q / 100.0
        lo = int(np.floor(rank))
        hi = int(np.ceil(rank))
        frac = rank - lo
        return vals[lo] * (1.0 - frac) + vals[hi] * frac

    def summary(self) -> dict[str, Any]:
        recs = self.records()
        done = [r for r in recs if r.t_done_s is not None]
        latency = [r.t_done_s - r.t_arrival_s for r in done]
        queue = [r.t_placed_s - r.t_arrival_s for r in done]
        tokens = sum(r.tokens_out for r in done)
        out = {
            "n_requests": len(recs),
            "completed": len(done),
            "placed": sum(1 for r in recs if r.t_placed_s is not None),
            "defers": sum(r.defers for r in recs),
            "defers_by_reason": dict(self.defers_by_reason),
            "tokens_out": tokens,
            "fleet_energy_j": self.fleet_energy_j,
            "tokens_per_joule": tokens / max(self.fleet_energy_j, 1e-12),
            "request_energy_j": sum(r.energy_j for r in recs),
            "migrations": sum(r.migrations for r in recs),
            "migration_stall_s": sum(r.stall_time_s for r in recs),
        }
        for label, vals in (("latency_s", latency), ("queue_s", queue)):
            out[f"p50_{label}"] = self.percentile(vals, 50.0)
            out[f"p95_{label}"] = self.percentile(vals, 95.0)
            out[f"p99_{label}"] = self.percentile(vals, 99.0)
            out[f"mean_{label}"] = (float(np.mean(vals)) if vals
                                    else float("nan"))
        return out
