"""Batched serving engine: continuous prefill + greedy decode with KV caches,
power-plane energy accounting per token, and the serve-side host controller.

Serving is where the paper's "communication-light phases" argument (§I) bites
hardest: decode is HBM-bound, so the PhaseAware policy undervolts VDD_CORE
and VDD_IO during decode and restores them for prefill bursts — the serving
analogue of the transceiver case study.

Fleet serving (`fleet=` constructor arg): the engine drives a `[n_chips]`
power plane seeded from a `hwspec.FleetSpec` — every decode/prefill step is
accounted at each chip's own process-varied operating point, and a bare
policy is wrapped in `WorstChipGate` so no chip undervolts past what the
worst chip's telemetry allows (serving replicas step together; the fleet is
only as fast and as safe as its weakest chip). Default is the original
scalar single-chip behavior.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import sor as sor_mod
from repro.core.control_plane import (InGraphRailController, as_controller,
                                      with_sor, worst_chip_pinned)
from repro.core.hwspec import FleetSpec
from repro.core.policy import WorstChipGate
from repro.core.power_plane import (PowerPlaneState, StepProfile,
                                    account_and_observe,
                                    account_fleet_and_observe, step_time_s)
from repro.core.telemetry import scalar_view
from repro.models import registry


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    energy_j: float = 0.0          # per-chip (fleet mean) energy
    model_time_s: float = 0.0
    fleet_energy_j: float = 0.0    # whole-fleet energy (mean x n_chips)
    decode_sheds: int = 0          # decode batches deferred by admission gate
    defer_time_s: float = 0.0      # simulated time spent waiting out sheds


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int,
                 batch_size: int,
                 prefill_profile: StepProfile | None = None,
                 decode_profile: StepProfile | None = None,
                 controller=None, policy=None,
                 fleet: FleetSpec | None = None,
                 sor: "sor_mod.SorConfig | None" = None,
                 admission_gate: bool = False):
        self.cfg = cfg
        self.params = params
        self.api = registry.build(cfg)
        self.max_len = max_len
        self.batch_size = batch_size
        self.fleet_spec = fleet
        self.plane = (PowerPlaneState.from_fleet(fleet) if fleet is not None
                      else PowerPlaneState.nominal())
        # single actuation path: a RailController (a bare policy is wrapped
        # into the in-graph controller for back-compat; on a fleet plane a
        # bare policy is additionally gated on the worst chip's telemetry)
        if controller is not None and policy is not None:
            raise ValueError("pass either controller= or policy=, not both")
        if (fleet is not None and policy is not None
                and not isinstance(policy, WorstChipGate)
                and not hasattr(policy, "control_step")):
            policy = WorstChipGate(policy)
        self.controller = as_controller(controller if controller is not None
                                        else policy)
        # learned safe-operating-region state (core/sor.py): the engine's
        # serving loop is eager, so it threads the functional SorState itself
        if sor is not None:
            if not isinstance(self.controller, InGraphRailController):
                raise ValueError("sor= needs an in-graph policy/controller "
                                 "(the serve loop threads SorState through "
                                 "InGraphRailController.control_step_sor); "
                                 "for a HostRailController pass sor= to the "
                                 "controller itself")
            # shared semantics with make_fleet_train_step (control_plane.
            # with_sor): validate, reject legacy policies, never mutate a
            # caller-owned controller, conflict loudly
            self.controller = with_sor(self.controller, sor)
        self._sor_state = None
        # admission gate: shed/defer decode batches while the arbitrated
        # request shows the worst chip pinned at its VDD_IO envelope floor
        self.admission_gate = admission_gate
        self.last_shed_reason: str | None = None
        self.prefill_profile = prefill_profile or StepProfile(1e9, 1e9, 0.0)
        self.decode_profile = decode_profile or StepProfile(1e8, 1e9, 0.0)
        self.stats = ServeStats()

        self._decode = jax.jit(
            lambda params, cache, batch: self.api.decode_fn(params, cache, batch))
        self._prefill = (jax.jit(
            lambda params, toks: self.api.prefill_fn(params, toks, max_len))
            if self.api.prefill_fn else None)

    @property
    def n_chips(self) -> int:
        return self.plane.n_chips

    def _account(self, profile: StepProfile, n: int = 1):
        for _ in range(n):
            if self.fleet_spec is not None:
                self.plane, frame, m = account_fleet_and_observe(
                    profile, self.plane, self.fleet_spec)
            else:
                self.plane, frame, m = account_and_observe(profile, self.plane)
            # array-aware reductions (TelemetryLog's scalar-view convention):
            # scalars pass through, [n_chips] metrics report the fleet mean
            e = scalar_view(m["energy_step_j"])
            self.stats.energy_j += e
            self.stats.fleet_energy_j += e * self.n_chips
            self.stats.model_time_s += scalar_view(m["t_step_s"])
            if self.controller is not None:
                c = self.controller
                if getattr(c, "sor", None) is not None and hasattr(
                        c, "control_step_sor"):
                    if self._sor_state is None:
                        self._sor_state = c.init_sor(
                            self.n_chips if self.plane.is_fleet else None)
                    # one fused control round per decision: observe + refit
                    # (amortized by refresh_every) + decide + arbitrate run
                    # as a single cached jitted program, so per-decision
                    # controller cost stays flat as the fleet grows
                    self.plane, self._sor_state = c.control_step_sor(
                        self.plane, frame, self._sor_state)
                else:
                    self.plane = c.control_step(self.plane, frame)

    def _worst_chip_pinned(self) -> bool:
        """Did the latest arbitration pin the worst chip at its VDD_IO
        envelope floor (request wanted at/below what the envelope holds)?
        The shed signal carries the arbitrated `RailRequest.reason`."""
        c = self.controller
        req = getattr(c, "last_request", None) if c is not None else None
        env = getattr(c, "last_envelope", None) if c is not None else None
        if req is None:
            return False
        if worst_chip_pinned(self.plane, req, envelope=env):
            self.last_shed_reason = req.reason or "pinned-at-envelope-floor"
            return True
        return False

    def _defer_tick(self) -> None:
        """Admission shed: the batch waits out one *accounted* decode tick
        before being admitted — simulated time passes and the control loop
        runs (so the controller genuinely gets a round to back off the
        floor, e.g. escalate compression or raise the rail); a real
        deployment would route the deferred batch to another replica."""
        self.stats.decode_sheds += 1
        self.stats.defer_time_s += scalar_view(
            step_time_s(self.decode_profile, self.plane))
        self._account(self.decode_profile)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 eos_id: int | None = None) -> np.ndarray:
        """prompts [B, Tp] int32 -> generated [B, max_new_tokens]."""
        B, Tp = prompts.shape
        assert B == self.batch_size, (B, self.batch_size)
        toks = jnp.asarray(prompts, jnp.int32)

        if self._prefill is not None:
            logits, cache, cur = self._prefill(self.params, toks)
            self._account(self.prefill_profile)
            self.stats.prefill_tokens += B * Tp
            next_tok = jnp.argmax(logits[:, -1, : self.cfg.vocab_size],
                                  axis=-1).astype(jnp.int32)[:, None]
            cur_index = jnp.int32(Tp)
        else:
            raise NotImplementedError("encdec serving uses serve_encdec()")

        out = [next_tok]
        for i in range(max_new_tokens - 1):
            if self.admission_gate and self._worst_chip_pinned():
                self._defer_tick()
            logits, cache = self._decode(
                self.params, cache,
                {"tokens": out[-1], "cur_index": cur_index})
            self._account(self.decode_profile)
            self.stats.decode_tokens += B
            nxt = jnp.argmax(logits[:, -1, : self.cfg.vocab_size],
                             axis=-1).astype(jnp.int32)[:, None]
            out.append(nxt)
            cur_index = cur_index + 1
            if eos_id is not None and bool(jnp.all(nxt == eos_id)):
                break
        return np.asarray(jnp.concatenate(out, axis=1))

    def summary(self) -> dict[str, Any]:
        toks = max(self.stats.decode_tokens, 1)
        out = {
            "prefill_tokens": self.stats.prefill_tokens,
            "decode_tokens": self.stats.decode_tokens,
            "energy_j": self.stats.energy_j,
            "model_time_s": self.stats.model_time_s,
            "j_per_decoded_token": self.stats.energy_j / toks,
            # array-aware: fleet planes report the mean operating point
            "v_core": scalar_view(self.plane.v_core),
            "v_io": scalar_view(self.plane.v_io),
            "n_chips": self.n_chips,
        }
        if self.plane.is_fleet:
            out["fleet_energy_j"] = self.stats.fleet_energy_j
            out["v_core_min"] = float(jnp.min(self.plane.v_core))
            out["v_io_min"] = float(jnp.min(self.plane.v_io))
            out["comp_level_min"] = int(jnp.min(self.plane.comp_level))
        if self.admission_gate:
            out["decode_sheds"] = self.stats.decode_sheds
            out["defer_time_s"] = self.stats.defer_time_s
            if self.last_shed_reason is not None:
                out["shed_reason"] = self.last_shed_reason
        if self._sor_state is not None:
            out["sor"] = sor_mod.summary(self._sor_state.estimate,
                                         self.controller.sor)
        else:
            # a HostRailController(sor=...) learns on its own control_step;
            # surface its view the same way
            summarize = getattr(self.controller, "sor_summary", None)
            s = summarize() if callable(summarize) else None
            if s:
                out["sor"] = s
        return out
