"""Batched serving engine: continuous prefill + greedy decode with KV caches,
power-plane energy accounting per token, and the serve-side host controller.

Serving is where the paper's "communication-light phases" argument (§I) bites
hardest: decode is HBM-bound, so the PhaseAware policy undervolts VDD_CORE
and VDD_IO during decode and restores them for prefill bursts — the serving
analogue of the transceiver case study.

Fleet serving (`fleet=` constructor arg): the engine drives a `[n_chips]`
power plane seeded from a `hwspec.FleetSpec` — every decode/prefill step is
accounted at each chip's own process-varied operating point, and a bare
policy is wrapped in `WorstChipGate` so no chip undervolts past what the
worst chip's telemetry allows (serving replicas step together; the fleet is
only as fast and as safe as its weakest chip). Default is the original
scalar single-chip behavior.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import sor as sor_mod
from repro.core.control_plane import (RAIL_LANES, InGraphRailController,
                                      _concrete_or_none, _run_policy,
                                      as_controller, pinned_lane_masks,
                                      pinned_rails, rail_floors,
                                      sharded_control_round, with_sor)
from repro.core.hwspec import FleetSpec
from repro.core.policy import WorstChipGate
from repro.core.power_plane import (BatchShares, PowerPlaneState,
                                    StepProfile, account_and_observe,
                                    account_fleet_and_observe,
                                    batched_lane_time_s, chip_power_w_jnp,
                                    step_time_s)
from repro.core.rails import TPU_V5E_RAIL_MAP
from repro.core.telemetry import scalar_view
from repro.models import registry

# per-rail failure observables the serve loop reads back each tick (the
# over-bound goodput-degrade signal) — extras keys overlaid by the caller's
# observe() hook plus the typed grad_error field
_OBS_KEYS = ("grad_error", "straggle_rate", "hbm_error_rate")


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    energy_j: float = 0.0          # per-chip (fleet mean) energy
    model_time_s: float = 0.0
    fleet_energy_j: float = 0.0    # whole-fleet energy (mean x n_chips)
    decode_sheds: int = 0          # decode batches deferred by admission gate
    defer_time_s: float = 0.0      # simulated time spent waiting out sheds
    # shed/defer breakdown: which rail's envelope floor pinned the fleet,
    # and the reason code the deferral carried (the aggregate counters stay
    # for back-compat; these are their per-rail / per-reason split)
    sheds_by_rail: dict = dataclasses.field(default_factory=dict)
    sheds_by_reason: dict = dataclasses.field(default_factory=dict)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int,
                 batch_size: int,
                 prefill_profile: StepProfile | None = None,
                 decode_profile: StepProfile | None = None,
                 controller=None, policy=None,
                 fleet: FleetSpec | None = None,
                 sor: "sor_mod.SorConfig | None" = None,
                 admission_gate: bool = False,
                 router=None, mesh=None,
                 shard_control: "bool | None" = None,
                 batch_cap: "int | None" = None,
                 batch_shares: "BatchShares | None" = None):
        self.cfg = cfg
        self.params = params
        self.api = registry.build(cfg)
        self.max_len = max_len
        self.batch_size = batch_size
        self.fleet_spec = fleet
        self.plane = (PowerPlaneState.from_fleet(fleet) if fleet is not None
                      else PowerPlaneState.nominal())
        # single actuation path: a RailController (a bare policy is wrapped
        # into the in-graph controller for back-compat; on a fleet plane a
        # bare policy is additionally gated on the worst chip's telemetry)
        if controller is not None and policy is not None:
            raise ValueError("pass either controller= or policy=, not both")
        if (fleet is not None and policy is not None
                and not isinstance(policy, WorstChipGate)
                and not hasattr(policy, "control_step")):
            policy = WorstChipGate(policy)
        self.controller = as_controller(controller if controller is not None
                                        else policy)
        # learned safe-operating-region state (core/sor.py): the engine's
        # serving loop is eager, so it threads the functional SorState itself
        if sor is not None:
            if not isinstance(self.controller, InGraphRailController):
                raise ValueError("sor= needs an in-graph policy/controller "
                                 "(the serve loop threads SorState through "
                                 "InGraphRailController.control_step_sor); "
                                 "for a HostRailController pass sor= to the "
                                 "controller itself")
            # shared semantics with make_fleet_train_step (control_plane.
            # with_sor): validate, reject legacy policies, never mutate a
            # caller-owned controller, conflict loudly
            self.controller = with_sor(self.controller, sor)
        self._sor_state = None
        # admission gate: shed/defer decode batches while the arbitrated
        # request shows any chip pinned at any requested rail's envelope
        # floor (all-rails admission — a VDD_HBM floor during decode gates
        # exactly like the historical VDD_IO check)
        self.admission_gate = admission_gate
        self.last_shed_reason: str | None = None
        self._last_pinned_rails: list[str] = []
        # headroom-aware placement (serve/router.py): serve_trace() routes a
        # traffic trace over the fleet by per-rail voltage headroom
        self.router = router
        if router is not None and fleet is None:
            raise ValueError("router= places work across a fleet; pass "
                             "fleet=FleetSpec (n_chips=1 degenerates to the "
                             "plain engine)")
        self.last_trace: dict | None = None
        # continuous batching: `batch_cap=B` makes each chip a token-level
        # decode batch over its B resident lanes — the fused tick's rate
        # model shares the roofline terms across lanes (batched_lane_time_s)
        # instead of granting every slot the chip's full single-lane rate.
        # Lanes ARE the router's slots, so the cap must equal the router's
        # capacity; None keeps the historical full-rate-per-slot model, and
        # batch_cap=1 degenerates to it EXACTLY (the rate model is bitwise
        # the base model at b=1), so both reuse the unbatched tick graph —
        # the PR-9 ledger bit-equality oracle.
        if batch_cap is not None:
            if router is None:
                raise ValueError("batch_cap batches a chip's resident "
                                 "lanes; pass router= (the lanes are the "
                                 "router's slots)")
            if batch_cap < 1:
                raise ValueError(f"batch_cap must be >= 1, got {batch_cap}")
            if batch_cap != router.capacity:
                raise ValueError(
                    f"batch_cap={batch_cap} must equal the router's "
                    f"capacity ({router.capacity}) — lanes are the "
                    f"router's slots, one number describes both")
        self.batch_cap = batch_cap
        self.batch_shares = batch_shares or BatchShares()
        self._batched = batch_cap is not None and batch_cap > 1
        if batch_shares is not None and batch_cap is None:
            raise ValueError("batch_shares= tunes the batched rate model; "
                             "pass batch_cap= as well")
        self.prefill_profile = prefill_profile or StepProfile(1e9, 1e9, 0.0)
        self.decode_profile = decode_profile or StepProfile(1e8, 1e9, 0.0)
        self.stats = ServeStats()
        # fleet-scale serving: `mesh=` threads a 1-D "chips" device mesh
        # into the fused serve tick so the in-tick learned control round
        # runs shard-parallel (control_plane.sharded_control_round under
        # the router). `shard_control` mirrors FleetStepConfig: None
        # auto-enables when the mesh spans more than one device; True
        # forces the shard_map path even on a 1-device mesh (the
        # bit-equality pin); False leaves a supplied mesh unused.
        self.mesh = mesh
        if shard_control is None:
            shard_control = mesh is not None and mesh.devices.size > 1
        if shard_control:
            if mesh is None:
                raise ValueError("shard_control=True needs a mesh")
            if fleet is None:
                raise ValueError("mesh= shards the [n_chips] serve plane; "
                                 "pass fleet=FleetSpec")
            if not (isinstance(self.controller, InGraphRailController)
                    and self.controller.sor is not None):
                raise ValueError(
                    "mesh= shards the in-tick learned control round; build "
                    "the engine with an in-graph controller carrying "
                    "sor=SorConfig(...) (cross-chip policies are rejected "
                    "— their fleet reduction would only see one shard)")
            if self.n_chips % mesh.devices.size:
                raise ValueError(
                    f"n_chips={self.n_chips} is not divisible by the mesh "
                    f"size {mesh.devices.size}")
            self._sharded_round = sharded_control_round(self.controller,
                                                        mesh)
        else:
            self._sharded_round = None
        self.shard_control = bool(shard_control)
        self._tick_cache: dict = {}   # (observe id, tick_s, bound) -> jit

        self._decode = jax.jit(
            lambda params, cache, batch: self.api.decode_fn(params, cache, batch))
        self._prefill = (jax.jit(
            lambda params, toks: self.api.prefill_fn(params, toks, max_len))
            if self.api.prefill_fn else None)

    @property
    def n_chips(self) -> int:
        return self.plane.n_chips

    def _control_tick(self, frame) -> None:
        """One controller round on `frame` — shared by the per-step
        accounting loop and the routed trace loop."""
        if self.controller is None:
            return
        c = self.controller
        if getattr(c, "sor", None) is not None and hasattr(
                c, "control_step_sor"):
            if self._sor_state is None:
                self._sor_state = c.init_sor(
                    self.n_chips if self.plane.is_fleet else None)
            # one fused control round per decision: observe + refit
            # (amortized by refresh_every) + decide + arbitrate run
            # as a single cached jitted program, so per-decision
            # controller cost stays flat as the fleet grows
            self.plane, self._sor_state = c.control_step_sor(
                self.plane, frame, self._sor_state)
        else:
            self.plane = c.control_step(self.plane, frame)

    def _account(self, profile: StepProfile, n: int = 1):
        for _ in range(n):
            if self.fleet_spec is not None:
                self.plane, frame, m = account_fleet_and_observe(
                    profile, self.plane, self.fleet_spec)
            else:
                self.plane, frame, m = account_and_observe(profile, self.plane)
            # array-aware reductions (TelemetryLog's scalar-view convention):
            # scalars pass through, [n_chips] metrics report the fleet mean
            e = scalar_view(m["energy_step_j"])
            self.stats.energy_j += e
            self.stats.fleet_energy_j += e * self.n_chips
            self.stats.model_time_s += scalar_view(m["t_step_s"])
            self._control_tick(frame)

    def _worst_chip_pinned(self) -> bool:
        """Did the latest arbitration pin any chip at any requested rail's
        envelope floor (request wanted at/below what the envelope holds)?
        Records the per-rail breakdown for the shed counters; the shed
        signal carries the arbitrated `RailRequest.reason`."""
        c = self.controller
        req = getattr(c, "last_request", None) if c is not None else None
        env = getattr(c, "last_envelope", None) if c is not None else None
        if req is None:
            return False
        masks = pinned_rails(self.plane, req, envelope=env)
        rails = [r for r, m in masks.items() if m.any()]
        if not rails:
            return False
        self._last_pinned_rails = rails
        self.last_shed_reason = req.reason or "pinned-at-envelope-floor"
        return True

    def _defer_tick(self) -> None:
        """Admission shed: the batch waits out one *accounted* decode tick
        before being admitted — simulated time passes and the control loop
        runs (so the controller genuinely gets a round to back off the
        floor, e.g. escalate compression or raise the rail); a real
        deployment would route the deferred batch to another replica."""
        self.stats.decode_sheds += 1
        reason = self.last_shed_reason or "pinned-at-envelope-floor"
        self.stats.sheds_by_reason[reason] = (
            self.stats.sheds_by_reason.get(reason, 0) + 1)
        for rail in self._last_pinned_rails:
            self.stats.sheds_by_rail[rail] = (
                self.stats.sheds_by_rail.get(rail, 0) + 1)
        self.stats.defer_time_s += scalar_view(
            step_time_s(self.decode_profile, self.plane))
        self._account(self.decode_profile)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 eos_id: int | None = None) -> np.ndarray:
        """prompts [B, Tp] int32 -> generated [B, max_new_tokens]."""
        B, Tp = prompts.shape
        assert B == self.batch_size, (B, self.batch_size)
        toks = jnp.asarray(prompts, jnp.int32)

        if self._prefill is not None:
            logits, cache, cur = self._prefill(self.params, toks)
            self._account(self.prefill_profile)
            self.stats.prefill_tokens += B * Tp
            next_tok = jnp.argmax(logits[:, -1, : self.cfg.vocab_size],
                                  axis=-1).astype(jnp.int32)[:, None]
            cur_index = jnp.int32(Tp)
        else:
            raise NotImplementedError("encdec serving uses serve_encdec()")

        out = [next_tok]
        for i in range(max_new_tokens - 1):
            if self.admission_gate and self._worst_chip_pinned():
                self._defer_tick()
            logits, cache = self._decode(
                self.params, cache,
                {"tokens": out[-1], "cur_index": cur_index})
            self._account(self.decode_profile)
            self.stats.decode_tokens += B
            nxt = jnp.argmax(logits[:, -1, : self.cfg.vocab_size],
                             axis=-1).astype(jnp.int32)[:, None]
            out.append(nxt)
            cur_index = cur_index + 1
            if eos_id is not None and bool(jnp.all(nxt == eos_id)):
                break
        return np.asarray(jnp.concatenate(out, axis=1))

    def serve_trace(self, trace, *, max_ticks: int = 20_000,
                    observe=None, tick_s: "float | None" = None,
                    error_bound: float = 5e-3, degrade: float = 0.5,
                    prefill_speedup: float = 8.0,
                    fused: "bool | None" = None,
                    fast_forward: bool = False,
                    migrate_after_ticks: "int | None" = None,
                    migrate_stall_s_per_token: float = 1e-3):
        """Route a seeded traffic trace (`serve/traffic.py`) over the fleet
        and return the per-request SLO ledger (`serve/router.py`).

        A modeled continuous-batching loop in simulated time — no model
        forward runs; what is modeled is exactly what the control plane
        governs: per-chip step time (f ∝ v, process variation), per-chip
        busy/idle power, and per-chip reliability. Each tick:

        1. arrivals with `t_arrival_s <= now` join the FIFO queue;
        2. the fleet is accounted (`account_fleet_and_observe`) and the
           caller's `observe(plane, frame, tick, busy_frac)` overlays the
           per-rail failure observables (measured error world — the bench
           couples onsets to load, the consolidated-margins drift);
        3. the controller runs one round (SOR learning included), exactly
           the `_account` control path;
        4. per-rail headroom and the pinned-chip drain mask feed the
           router, which places queued requests head-of-line FIFO (a
           request it cannot place defers — reason `capacity` when every
           slot is full, `pinned-drain` when only pinned chips had room);
        5. resident requests progress at their chip's modeled rate
           (`tick_s / t_step_chip` decode tokens per tick, batched decode:
           every slot advances together; prefill runs `prefill_speedup` x
           faster). A chip whose measured observables sit over
           `error_bound` this tick delivers only `degrade` of its rate —
           the goodput cost of operating past the frontier (the BER
           retransmission analogue), which is what makes zero-headroom
           placement genuinely expensive;
        6. energy is accounted busy/idle-blended per chip (idle slots do
           not burn dynamic power) into the ledger and the engine stats;
           each resident request is charged its share of its chip's busy
           energy.

        `fused` selects the tick's device path (docs/serve.md "serving at
        fleet scale"). `None` (default) auto-resolves: in-graph
        controllers (and controller-less engines) run ONE jitted
        `serve_tick` per tick — accounting, observe overlay, control
        round, busy/idle energy rescale and the per-chip rate/over-bound
        flags compile into a single dispatch whose packed host bundle is
        the tick's only device transfer, and slot bookkeeping runs as
        numpy `[n_chips, capacity]` arrays. Host-actuated controllers
        (PMBus path) fall back to the historical per-tick loop, which
        `fused=False` also forces — the oracle the fused path's ledger is
        pinned against in tests. With a `mesh=` engine the fused tick's
        learned round runs shard-parallel (`sharded_control_round`).

        `fast_forward=True` (fused path only) jumps simulated time to the
        next arrival whenever the queue is empty and no slot is resident —
        the skipped ticks run no accounting and no control round, so the
        trajectory is NOT tick-for-tick identical to a fast_forward=False
        run across idle gaps (default off; `last_trace` reports the ticks
        skipped).

        `migrate_after_ticks=K` (fused path, headroom-planner routers
        only) arms in-flight migration: a chip whose pinned/over-bound
        flag has held for K consecutive ticks gets its resident
        decode-phase lanes re-placed by `router.plan_migration` onto the
        deepest-headroom unpinned chips, most-decode-left first. A
        migrated lane pays a KV-transfer stall of
        `migrate_stall_s_per_token x tokens processed so far` before it
        progresses again (it occupies its destination lane throughout),
        and the ledger records a "migrated" event with source/destination.
        Sustained `pinned-drain` pressure thereby MOVES work instead of
        only deferring admits; a triggered chip that keeps lanes (no
        eligible destination) re-arms after another K ticks.

        `tick_s` defaults to the fleet-mean decode step time at the current
        operating point. Deterministic given (trace, observe, controller):
        placement ties break by chip index and all randomness lives in the
        caller's seeded trace/observe."""
        if self.router is None:
            raise ValueError("serve_trace needs the engine built with "
                             "router= (HeadroomRouter or RoundRobinRouter)")
        if self.fleet_spec is None:
            raise ValueError("serve_trace routes over a fleet plane; pass "
                             "fleet=FleetSpec")
        from repro.serve.router import RequestLedger
        # routers carry placement state (the round-robin cursor) — reset it
        # per trace so back-to-back traces on one engine place identically
        reset = getattr(self.router, "reset", None)
        if callable(reset):
            reset()
        if fused is None:
            fused = (self.controller is None
                     or isinstance(self.controller, InGraphRailController))
        if fused and self.controller is not None and not isinstance(
                self.controller, InGraphRailController):
            raise ValueError(
                "fused=True compiles the control round into the serve "
                "tick; a host-actuated controller (PMBus path) needs "
                "fused=False")
        if fast_forward and not fused:
            raise ValueError("fast_forward rides the fused tick path; "
                             "drop fused=False (or the host controller)")
        if self._batched and not fused:
            raise ValueError(
                "continuous batching (batch_cap >= 2) rides the fused "
                "tick path — the loop path is kept verbatim as the "
                "batch-cap=1 semantics oracle; drop fused=False")
        if migrate_after_ticks is not None:
            if migrate_after_ticks < 1:
                raise ValueError(f"migrate_after_ticks must be >= 1, got "
                                 f"{migrate_after_ticks}")
            if not fused:
                raise ValueError("migration rides the fused tick path; "
                                 "drop fused=False")
            if not callable(getattr(self.router, "plan_migration", None)):
                raise ValueError(
                    "migrate_after_ticks needs a router with a migration "
                    "planner (HeadroomRouter.plan_migration) — the "
                    "round-robin baseline is headroom-blind and cannot "
                    "pick destinations")
        if tick_s is None:
            tick_s = float(scalar_view(
                step_time_s(self.decode_profile, self.plane)))
        ledger = RequestLedger()
        arrivals = sorted(trace, key=lambda r: (r.t_arrival_s, r.rid))
        kw = dict(max_ticks=max_ticks, observe=observe, tick_s=tick_s,
                  error_bound=error_bound, degrade=degrade,
                  prefill_speedup=prefill_speedup)
        if fused:
            return self._serve_trace_fused(
                arrivals, ledger, fast_forward=fast_forward,
                migrate_after_ticks=migrate_after_ticks,
                migrate_stall_s_per_token=migrate_stall_s_per_token, **kw)
        return self._serve_trace_loop(arrivals, ledger, **kw)

    # -- fused path: one jitted device round + vectorized host bookkeeping ----

    def _serve_tick_jit(self, observe, tick_s: float, error_bound: float):
        """The cached jitted serve tick for this (observe, tick_s,
        error_bound) world — cached like `control_step_sor`'s round jit so
        repeated traces dispatch without retracing."""
        key = (id(observe), float(tick_s), float(error_bound))
        fn = self._tick_cache.get(key)
        if fn is None:
            fn = self._build_serve_tick(observe, tick_s, error_bound)
            self._tick_cache[key] = fn
        return fn

    def _build_serve_tick(self, observe, tick_s: float, error_bound: float):
        """Build ONE fused serve tick: accounting -> observe overlay ->
        control round -> busy/idle energy rescale -> per-chip rate/
        over-bound flags, pure jnp, jitted as a single program. Returns
        `(plane', sor_state', bundle, request, env)` where `bundle` is the
        packed `[13, n_chips]` float32 host bundle — rows 0-3 `e_tick`,
        `e_busy`, `t_step`, `over`; rows 4-6 per-rail floors; rows 7-9
        per-rail headroom; rows 10-12 per-rail pinned masks (RAIL_LANES
        order) — the tick's ONLY device->host transfer. A continuous-
        batching engine (`batch_cap >= 2`) grows it to `[15, n_chips]`:
        row 13 the effective batch depth the rate was computed at
        (`max(round(busy_frac * batch_cap), 1)` — occupancy recovered
        exactly from the busy fraction, so the tick signature does not
        change) and row 14 the batched PER-LANE step time
        (`batched_lane_time_s` over this tick's roofline terms)."""
        spec = self.fleet_spec
        variation = {k: jnp.asarray(v) for k, v in spec.variation().items()}
        profile = self.decode_profile
        c = self.controller
        n = self.n_chips
        rail_map = (getattr(c, "rail_map", TPU_V5E_RAIL_MAP)
                    if c is not None else TPU_V5E_RAIL_MAP)
        use_sor = (c is not None and getattr(c, "sor", None) is not None
                   and hasattr(c, "control_step_sor"))
        sharded = self._sharded_round
        ts = jnp.float32(tick_s)
        batched = self._batched
        cap = jnp.float32(self.batch_cap) if batched else None
        shares = self.batch_shares

        def _b(x):
            return jnp.broadcast_to(
                jnp.atleast_1d(jnp.asarray(x, jnp.float32)), (n,))

        def tick(plane, sor_state, busy_frac, tick_idx):
            plane, frame, m = account_fleet_and_observe(profile, plane,
                                                        spec)
            if observe is not None:
                frame = observe(plane, frame, tick_idx, busy_frac)
            request = env = None
            if c is None:
                pass
            elif use_sor:
                if sharded is not None:
                    pre = plane
                    plane, sor_state, _conf_sum, _conf_min = sharded(
                        plane, frame, sor_state)
                    # the request/envelopes the bundle rows need are
                    # re-derived OUTSIDE the shard_map on the global
                    # (sharded) shapes: envelopes are elementwise in the
                    # post-ingest estimate and the decision is elementwise
                    # per chip — the same math the per-shard round
                    # arbitrated with
                    env = sor_mod.rail_envelopes(sor_state.estimate, c.sor)
                    request = c.policy.decide_env(pre, frame, env)
                else:
                    plane, sor_state, request, env = c.control_round(
                        plane, frame, sor_state)
            else:
                plane, request = _run_policy(
                    c.policy, plane, frame, frame, rail_map, host=False)
            # busy/idle-blended energy: accounting assumed every chip
            # fully busy — rescale to this tick's occupancy (idle slots
            # burn static + uncore power only) and rewrite the plane's
            # accumulator to match
            p_busy = m["power_w"]
            p_idle = chip_power_w_jnp(plane, 0.0, 0.0, 0.0, spec.base,
                                      variation=variation)
            p_eff = p_idle + (p_busy - p_idle) * busy_frac
            e_tick = p_eff * ts
            plane = dataclasses.replace(
                plane, energy_j=plane.energy_j - m["energy_step_j"]
                + e_tick)
            over = jnp.zeros((n,), bool)
            for key in _OBS_KEYS:
                v = frame.get(key)
                if v is None:
                    continue
                a = _b(v)
                over = over | ((~jnp.isnan(a))
                               & (a > jnp.float32(error_bound)))
            floors = rail_floors(plane, env, rail_map)
            held = jnp.stack([_b(getattr(plane, f))
                              for f in ("v_core", "v_hbm", "v_io")])
            pinned = pinned_lane_masks(plane, request, rail_map,
                                       envelope=env)
            rows = [
                jnp.stack([_b(e_tick), _b((p_eff - p_idle) * ts),
                           _b(m["t_step_s"]), over.astype(jnp.float32)]),
                floors,
                held - floors,
                pinned.astype(jnp.float32),
            ]
            if batched:
                # effective batch depth from the busy fraction (occ/cap is
                # exact in f32 for occ <= cap; round kills the dust) and
                # the shared-roofline per-lane step time it implies
                b_eff = jnp.maximum(jnp.round(_b(busy_frac) * cap), 1.0)
                t_lane = batched_lane_time_s(
                    _b(m["t_comp_s"]), _b(m["t_mem_s"]), _b(m["t_coll_s"]),
                    b_eff, shares)
                rows.append(jnp.stack([b_eff, t_lane]))
            bundle = jnp.concatenate(rows)
            return plane, sor_state, bundle, request, env

        donate = (1,) if (use_sor and getattr(c, "donate", False)) else ()
        return jax.jit(tick, donate_argnums=donate)

    def _serve_trace_fused(self, arrivals, ledger, *, max_ticks, observe,
                           tick_s, error_bound, degrade, prefill_speedup,
                           fast_forward, migrate_after_ticks=None,
                           migrate_stall_s_per_token=1e-3):
        """The fused serve loop: per tick, ONE jitted device dispatch and
        ONE packed bundle transfer; slot progress/finish bookkeeping runs
        as numpy `[n_chips, capacity]` lane arrays (no per-slot dicts).
        Ledger and stats are pinned equal to `_serve_trace_loop` on the
        same world (tests/test_serve_scale.py); a batched engine reads its
        per-lane rate from the bundle's grown rows, and migration (when
        armed) re-places decode-phase lanes off chips whose pinned/over
        flag held for K ticks, before placement sees the tick's queue."""
        from repro.serve.router import headroom_from_packed
        n = self.n_chips
        cap = self.router.capacity
        c = self.controller
        use_sor = (c is not None and getattr(c, "sor", None) is not None
                   and hasattr(c, "control_step_sor"))
        if use_sor and self._sor_state is None:
            self._sor_state = c.init_sor(n if self.plane.is_fleet else None)
        if self._sharded_round is not None:
            from repro.kernels import ops as _ops
            self.plane = _ops.shard_chip_tree(self.plane, self.mesh, n)
            if self._sor_state is not None:
                self._sor_state = _ops.shard_chip_tree(
                    self._sor_state, self.mesh, n)
        tick_fn = self._serve_tick_jit(observe, tick_s, error_bound)

        n_req = len(arrivals)
        arr_t = np.asarray([r.t_arrival_s for r in arrivals], np.float64)
        req_prefill = np.asarray([r.prefill_tokens for r in arrivals],
                                 np.int64)
        req_decode = np.asarray([r.decode_tokens for r in arrivals],
                                np.int64)
        # per-request busy-energy accumulator, charged to the ledger once
        # at trace end: one float64 add per resident tick in tick order —
        # float-equal to the loop path's per-tick ledger.charge
        energy_acc = np.zeros(n_req, np.float64)
        charged = np.zeros(n_req, bool)

        slot_req = np.full((n, cap), -1, np.int64)   # arrival index; -1 free
        slot_prefill = np.zeros((n, cap), np.float64)
        slot_decode = np.zeros((n, cap), np.float64)
        # KV-transfer stall left per lane (seconds): a freshly migrated
        # lane occupies its destination but makes no progress until its
        # stall drains
        slot_stall = np.zeros((n, cap), np.float64)
        migrating = migrate_after_ticks is not None
        streak = np.zeros(n, np.int64)   # consecutive pinned/over ticks
        n_migrations = 0

        pending: collections.deque = collections.deque()  # arrival indices
        ai = 0
        t = 0.0
        max_occ = 0
        degraded_ticks = 0
        resident_degraded_ticks = 0
        ticks_run = 0
        ff_ticks = 0

        for tick in range(max_ticks):
            active = slot_req >= 0
            resident = bool(active.any())
            if ai >= n_req and not pending and not resident:
                break
            if (fast_forward and not pending and not resident
                    and ai < n_req and arr_t[ai] > t):
                # idle fleet, empty queue: jump simulated time to the
                # first on-grid tick that reaches the next arrival. The
                # skipped ticks run no accounting and no control round.
                k = int(np.ceil((arr_t[ai] - t) / tick_s))
                t += k * tick_s
                ff_ticks += k
            ticks_run += 1
            while ai < n_req and arrivals[ai].t_arrival_s <= t:
                ledger.admit(arrivals[ai])
                pending.append(ai)
                ai += 1
            occ = active.sum(axis=1)
            busy_frac = jnp.asarray(
                np.minimum(occ.astype(np.float64), cap) / cap, jnp.float32)

            self.plane, self._sor_state, bundle, request, env = tick_fn(
                self.plane, self._sor_state, busy_frac, jnp.int32(tick))
            if c is not None:
                c.last_request = _concrete_or_none(request)
                c.last_envelope = _concrete_or_none(env)
            b = np.asarray(jax.device_get(bundle), np.float64)  # 1 transfer
            e_np, e_busy, t_step = b[0], b[1], b[2]
            over = b[3] > 0.5
            headroom = headroom_from_packed(b[7:10])
            pinned_rows = b[10:13] > 0.5
            pinned = pinned_rows.any(axis=0)
            # batched engines progress lanes at the shared-roofline
            # per-lane step time the tick computed (row 14); unbatched
            # (and batch_cap=1) engines keep the base step time — the
            # SAME host arithmetic either way, so batch_cap=1 stays
            # bit-equal to the historical path
            t_rate = b[14] if self._batched else t_step

            self.stats.energy_j += float(e_np.mean())
            self.stats.fleet_energy_j += float(e_np.sum())
            self.stats.model_time_s += tick_s
            ledger.tick_energy(float(e_np.sum()))
            if resident:
                chips, slots = np.nonzero(active)
                idx = slot_req[chips, slots]
                np.add.at(energy_acc, idx, e_busy[chips] / occ[chips])
                charged[idx] = True
                resident_degraded_ticks += int((over & (occ > 0)).sum())

            # in-flight migration: a chip whose pinned/over flag held K
            # consecutive ticks hands its decode-phase lanes to the
            # planner, most decode-left first; each migrated lane pays a
            # token-proportional KV-transfer stall at its destination.
            # Runs BEFORE placement, so this tick's admits see the
            # post-migration occupancy.
            if migrating:
                streak = np.where(pinned | over, streak + 1, 0)
                trig = streak >= migrate_after_ticks
                cand = (active & trig[:, None] & (slot_prefill <= 0)
                        if trig.any() else None)
                if cand is not None and cand.any():
                    c_chips, c_slots = np.nonzero(cand)
                    left = slot_decode[c_chips, c_slots]
                    order = np.lexsort(
                        (slot_req[c_chips, c_slots], -left))
                    reqs = [arrivals[int(slot_req[c_chips[k], c_slots[k]])]
                            for k in order]
                    dests = self.router.plan_migration(
                        reqs, occ, headroom, pinned=pinned, exclude=trig)
                    for k, dst in zip(order, dests):
                        if dst is None:
                            continue
                        src_c, src_s = int(c_chips[k]), int(c_slots[k])
                        i = int(slot_req[src_c, src_s])
                        d_slot = int(np.argmin(slot_req[dst]))  # first free
                        done_tokens = (req_prefill[i] + req_decode[i]
                                       - slot_decode[src_c, src_s])
                        stall_s = float(migrate_stall_s_per_token
                                        * done_tokens)
                        slot_req[dst, d_slot] = i
                        slot_prefill[dst, d_slot] = 0.0
                        slot_decode[dst, d_slot] = slot_decode[src_c, src_s]
                        slot_stall[dst, d_slot] = stall_s
                        slot_req[src_c, src_s] = -1
                        slot_stall[src_c, src_s] = 0.0
                        active[dst, d_slot] = True
                        active[src_c, src_s] = False
                        occ[dst] += 1
                        occ[src_c] -= 1
                        ledger.migrate(arrivals[i].rid, t, src_c, int(dst),
                                       stall_s=stall_s,
                                       src_streak=int(streak[src_c]))
                        n_migrations += 1
                if trig.any():
                    # triggered chips had their shot (or nothing to move);
                    # re-arm after another K hot ticks
                    streak[trig] = 0

            # placement: the whole pending queue in one vectorized router
            # pass, FIFO head-of-line semantics pinned to sequential
            # place(); an unplaceable head defers once and blocks the
            # queue behind it
            if pending:
                placed = self.router.place_batch(
                    [arrivals[i] for i in pending], occ, headroom, pinned)
                for chip in placed:
                    i = pending.popleft()
                    ledger.place(arrivals[i].rid, t, chip)
                    slot = int(np.argmin(slot_req[chip]))   # first free
                    slot_req[chip, slot] = i
                    slot_prefill[chip, slot] = float(
                        arrivals[i].prefill_tokens)
                    slot_decode[chip, slot] = float(
                        arrivals[i].decode_tokens)
                    slot_stall[chip, slot] = 0.0
                    active[chip, slot] = True
                    occ[chip] += 1
                if pending:
                    reason = ("capacity" if bool((occ >= cap).all())
                              else "pinned-drain")
                    ledger.defer(arrivals[pending[0]].rid, reason, tick_s)
                    self.stats.decode_sheds += 1
                    self.stats.sheds_by_reason[reason] = (
                        self.stats.sheds_by_reason.get(reason, 0) + 1)
                    if reason == "pinned-drain":
                        for lane, rail in enumerate(RAIL_LANES):
                            if pinned_rows[lane].any():
                                self.stats.sheds_by_rail[rail] = (
                                    self.stats.sheds_by_rail.get(rail, 0)
                                    + 1)
                    self.stats.defer_time_s += tick_s
            max_occ = max(max_occ, int(occ.max()) if n else 0)

            # progress: batched decode over the [n_chips, capacity] lane
            # arrays; over-bound chips deliver degraded goodput this tick
            rate = tick_s / np.maximum(t_rate, 1e-12)
            if over.any():
                degraded_ticks += int(over.sum())
            rate = np.where(over, rate * degrade, rate)
            t_end = t + tick_s
            rate2d = np.broadcast_to(rate[:, None], (n, cap))
            if migrating:
                # freshly migrated lanes sit out their KV-transfer stall:
                # they occupy (and count toward the batch) but advance
                # nothing until the stall drains
                stalled = active & (slot_stall > 0)
                if stalled.any():
                    slot_stall[stalled] -= tick_s
                    active = active & ~stalled
            in_prefill = active & (slot_prefill > 0)
            if in_prefill.any():
                slot_prefill[in_prefill] -= (rate2d[in_prefill]
                                             * prefill_speedup)
                pf_done = in_prefill & (slot_prefill <= 0)
                if pf_done.any():
                    self.stats.prefill_tokens += int(
                        req_prefill[slot_req[pf_done]].sum())
            # a slot whose prefill crossed zero THIS tick decodes only
            # from the next tick (the loop path's `continue`)
            in_decode = active & ~in_prefill
            if in_decode.any():
                slot_decode[in_decode] -= rate2d[in_decode]
                fin = in_decode & (slot_decode <= 0)
                if fin.any():
                    for chip, slot in zip(*np.nonzero(fin)):
                        i = slot_req[chip, slot]
                        self.stats.decode_tokens += int(req_decode[i])
                        ledger.finish(arrivals[i].rid, t_end,
                                      tokens_out=int(req_decode[i]))
                    slot_req[fin] = -1
            t = t_end

        for i in np.nonzero(charged)[0]:
            ledger.charge(arrivals[int(i)].rid, float(energy_acc[i]))

        self.last_trace = {
            "router": getattr(self.router, "name",
                              type(self.router).__name__),
            "ticks": ticks_run, "tick_s": tick_s,
            "max_occupancy": max_occ, "capacity": cap,
            "degraded_chip_ticks": degraded_ticks,
            "resident_degraded_ticks": resident_degraded_ticks,
            "unplaced": len(pending),
            "unfinished": int((slot_req >= 0).sum()),
            "fused": True,
            "fast_forward_ticks": ff_ticks,
            "batch_cap": self.batch_cap,
            "migrations": n_migrations,
        }
        return ledger

    # -- loop path: the historical per-tick host loop (the fused oracle) ------

    def _serve_trace_loop(self, arrivals, ledger, *, max_ticks, observe,
                          tick_s, error_bound, degrade, prefill_speedup):
        """The PR-8 per-tick host loop: eager accounting, one control
        dispatch and scattered device reads per tick, per-slot dict
        bookkeeping. Kept verbatim as the semantics oracle the fused path
        is pinned against, and as the only path host-actuated (PMBus)
        controllers can run."""
        from repro.serve.router import rail_headroom
        n = self.n_chips
        cap = self.router.capacity
        spec = self.fleet_spec
        variation = {k: jnp.asarray(v) for k, v in spec.variation().items()}
        account = lambda p: account_fleet_and_observe(
            self.decode_profile, p, spec)
        p_idle_fn = lambda p: chip_power_w_jnp(
            p, 0.0, 0.0, 0.0, spec.base, variation=variation)

        ai = 0
        pending: collections.deque = collections.deque()
        running: list[list[dict]] = [[] for _ in range(n)]
        t = 0.0
        max_occ = 0
        degraded_ticks = 0
        ticks_run = 0

        for tick in range(max_ticks):
            if ai >= len(arrivals) and not pending \
                    and not any(running):
                break
            ticks_run += 1
            while ai < len(arrivals) and arrivals[ai].t_arrival_s <= t:
                ledger.admit(arrivals[ai])
                pending.append(arrivals[ai])
                ai += 1
            occ = np.array([len(r) for r in running], np.float64)
            busy_frac = jnp.asarray(np.minimum(occ, cap) / cap, jnp.float32)

            self.plane, frame, m = account(self.plane)
            if observe is not None:
                frame = observe(self.plane, frame, tick, busy_frac)
            self._control_tick(frame)

            # busy/idle-blended energy: the accounting above assumed every
            # chip fully busy — rescale its step energy to this tick's
            # occupancy (idle slots burn static + uncore power only) and
            # rewrite the plane's accumulator to match
            p_busy = m["power_w"]
            p_idle = p_idle_fn(self.plane)
            p_eff = p_idle + (p_busy - p_idle) * busy_frac
            e_tick = p_eff * jnp.float32(tick_s)
            self.plane = dataclasses.replace(
                self.plane,
                energy_j=self.plane.energy_j - m["energy_step_j"] + e_tick)
            e_np = np.asarray(jax.device_get(e_tick), np.float64)
            e_busy = np.asarray(jax.device_get(
                (p_eff - p_idle) * jnp.float32(tick_s)), np.float64)
            self.stats.energy_j += float(e_np.mean())
            self.stats.fleet_energy_j += float(e_np.sum())
            self.stats.model_time_s += tick_s
            ledger.tick_energy(float(e_np.sum()))
            for i in range(n):
                if running[i]:
                    share = e_busy[i] / len(running[i])
                    for slot in running[i]:
                        ledger.charge(slot["req"].rid, share)

            # placement: headroom + drain mask from the eager round just
            # run; FIFO with head-of-line blocking (placement order is the
            # SLO order — a starved head is a deferral, not a skip). The
            # pinned masks are computed ONCE per tick (one stacked
            # transfer) and reused by the defer path — their inputs don't
            # change within a tick
            envs = getattr(self.controller, "last_envelope", None) \
                if self.controller is not None else None
            req = getattr(self.controller, "last_request", None) \
                if self.controller is not None else None
            headroom = rail_headroom(self.plane, envs)
            pin_masks = (pinned_rails(self.plane, req, envelope=envs)
                         if req is not None else {})
            pinned = np.zeros(n, bool)
            for mask in pin_masks.values():
                pinned |= mask
            while pending:
                occ_now = [len(r) for r in running]
                chip = self.router.place(pending[0], occ_now, headroom,
                                         pinned)
                if chip is None:
                    reason = ("capacity"
                              if all(o >= cap for o in occ_now)
                              else "pinned-drain")
                    ledger.defer(pending[0].rid, reason, tick_s)
                    self.stats.decode_sheds += 1
                    self.stats.sheds_by_reason[reason] = (
                        self.stats.sheds_by_reason.get(reason, 0) + 1)
                    if reason == "pinned-drain":
                        for rail, mask in pin_masks.items():
                            if mask.any():
                                self.stats.sheds_by_rail[rail] = (
                                    self.stats.sheds_by_rail.get(rail, 0)
                                    + 1)
                    self.stats.defer_time_s += tick_s
                    break
                r = pending.popleft()
                ledger.place(r.rid, t, chip)
                running[chip].append({
                    "req": r,
                    "prefill_left": float(r.prefill_tokens),
                    "decode_left": float(r.decode_tokens)})
            max_occ = max(max_occ, max(len(r) for r in running))

            # progress: batched decode — every resident slot advances at
            # the chip's modeled token rate; over-bound chips deliver
            # degraded goodput this tick
            t_step = np.asarray(jax.device_get(m["t_step_s"]), np.float64)
            rate = tick_s / np.maximum(
                np.broadcast_to(np.atleast_1d(t_step), (n,)), 1e-12)
            over = np.zeros(n, bool)
            for key in _OBS_KEYS:
                v = frame.get(key)
                if v is None:
                    continue
                a = np.asarray(jax.device_get(v), np.float64)
                a = np.broadcast_to(np.atleast_1d(a), (n,))
                over |= (~np.isnan(a)) & (a > error_bound)
            if over.any():
                degraded_ticks += int(over.sum())
            rate = np.where(over, rate * degrade, rate)
            t_end = t + tick_s
            for i in range(n):
                if not running[i]:
                    continue
                finished = []
                for slot in running[i]:
                    if slot["prefill_left"] > 0:
                        slot["prefill_left"] -= rate[i] * prefill_speedup
                        if slot["prefill_left"] <= 0:
                            self.stats.prefill_tokens += (
                                slot["req"].prefill_tokens)
                        continue
                    slot["decode_left"] -= rate[i]
                    if slot["decode_left"] <= 0:
                        finished.append(slot)
                for slot in finished:
                    running[i].remove(slot)
                    self.stats.decode_tokens += slot["req"].decode_tokens
                    ledger.finish(slot["req"].rid, t_end,
                                  tokens_out=slot["req"].decode_tokens)
            t = t_end

        self.last_trace = {
            "router": getattr(self.router, "name", type(self.router).__name__),
            "ticks": ticks_run, "tick_s": tick_s,
            "max_occupancy": max_occ, "capacity": cap,
            "degraded_chip_ticks": degraded_ticks,
            "unplaced": len(pending),
            "unfinished": sum(len(r) for r in running),
            "fused": False,
            "fast_forward_ticks": 0,
        }
        return ledger

    def summary(self) -> dict[str, Any]:
        toks = max(self.stats.decode_tokens, 1)
        out = {
            "prefill_tokens": self.stats.prefill_tokens,
            "decode_tokens": self.stats.decode_tokens,
            "energy_j": self.stats.energy_j,
            "model_time_s": self.stats.model_time_s,
            # array-aware: fleet planes report the mean operating point
            "v_core": scalar_view(self.plane.v_core),
            "v_io": scalar_view(self.plane.v_io),
            "n_chips": self.n_chips,
        }
        if self.plane.is_fleet:
            # fleet planes report joules/token from whole-fleet energy —
            # energy_j is the per-chip MEAN while decode_tokens counts the
            # whole fleet, so dividing the mean by fleet-total tokens (the
            # historical j_per_decoded_token spelling) understated the
            # fleet's cost by 1/n_chips; the scalar field stays
            # scalar-plane-only
            out["fleet_energy_j"] = self.stats.fleet_energy_j
            out["fleet_j_per_decoded_token"] = (
                self.stats.fleet_energy_j / toks)
            out["v_core_min"] = float(jnp.min(self.plane.v_core))
            out["v_io_min"] = float(jnp.min(self.plane.v_io))
            out["comp_level_min"] = int(jnp.min(self.plane.comp_level))
        else:
            out["j_per_decoded_token"] = self.stats.energy_j / toks
        if self.admission_gate or self.router is not None:
            out["decode_sheds"] = self.stats.decode_sheds
            out["defer_time_s"] = self.stats.defer_time_s
            # per-rail / per-reason split of the aggregate counters: which
            # rail's envelope floor drove the shed (all-rails admission)
            # and what reason each deferral carried
            out["decode_sheds_by_rail"] = dict(self.stats.sheds_by_rail)
            out["decode_sheds_by_reason"] = dict(self.stats.sheds_by_reason)
            if self.last_shed_reason is not None:
                out["shed_reason"] = self.last_shed_reason
        if self._sor_state is not None:
            out["sor"] = sor_mod.summary(self._sor_state.estimate,
                                         self.controller.sor)
        else:
            # a HostRailController(sor=...) learns on its own control_step;
            # surface its view the same way
            summarize = getattr(self.controller, "sor_summary", None)
            s = summarize() if callable(summarize) else None
            if s:
                out["sor"] = s
        return out
