"""InternVL2-2B: InternViT (stub frontend) + InternLM2 backbone [arXiv:2404.16821; hf]

Exact assigned configuration (see system prompt / DESIGN.md §4); TINY is the
reduced same-family smoke-test variant (CPU, tp=1).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=8192, vocab_size=92553,
    n_img_tokens=256)

TINY = ModelConfig(
    name="internvl2-tiny", family="vlm", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512, tp=1,
    n_img_tokens=16)
