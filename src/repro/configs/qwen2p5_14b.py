"""Qwen2.5-14B: GQA with QKV bias [hf:Qwen/Qwen2.5; hf]

Exact assigned configuration (see system prompt / DESIGN.md §4); TINY is the
reduced same-family smoke-test variant (CPU, tp=1).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=13824, vocab_size=152064, head_dim=128,
    qkv_bias=True, rope_theta=1e6)

TINY = ModelConfig(
    name="qwen2.5-tiny", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=320, vocab_size=512, tp=1,
    qkv_bias=True)
