"""Granite-20B (code): llama-arch with MQA (kv=1) [arXiv:2405.04324; hf]

Exact assigned configuration (see system prompt / DESIGN.md §4); TINY is the
reduced same-family smoke-test variant (CPU, tp=1).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense", n_layers=52, d_model=6144,
    n_heads=48, n_kv_heads=1, d_ff=24576, vocab_size=49152)

TINY = ModelConfig(
    name="granite-tiny", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=1, d_ff=512, vocab_size=512, tp=1)
