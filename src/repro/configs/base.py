"""Config schema: ModelConfig (architecture), ShapeConfig (assigned input
shapes), and the arch registry. One module per assigned architecture lives
next to this file; each exports CONFIG (exact paper/HF hyperparameters) and
TINY (reduced same-family config for CPU smoke tests)."""

from __future__ import annotations

import dataclasses
import importlib
import math

from repro.models.common import HeadPlan, plan_head_padding

VOCAB_ALIGN = 2048  # pad vocab to a multiple (TP-16 x 128-lane friendly)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    attn_every: int = 0         # hybrid: shared attn block after every k SSM blocks
    sliding_window: int = 0     # used by hybrid attn for long-context cells
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq_len: int = 1500     # whisper: 30 s of audio after conv frontend
    # VLM stub frontend
    n_img_tokens: int = 0
    # numerics / distribution
    dtype: str = "bfloat16"
    tp: int = 16                # model-axis size the head plan targets
    remat_group: int = 0        # 0 -> auto (largest divisor of n_layers <= 8)

    # ---- derived ----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def vocab_padded(self) -> int:
        return math.ceil(self.vocab_size / VOCAB_ALIGN) * VOCAB_ALIGN

    def head_plan(self) -> HeadPlan:
        return plan_head_padding(self.n_heads, self.n_kv_heads, self.tp)

    @property
    def remat_group_(self) -> int:
        if self.remat_group:
            return self.remat_group
        for g in (8, 7, 6, 5, 4, 3, 2, 1):
            if self.n_layers % g == 0:
                return g
        return 1

    @property
    def has_attention(self) -> bool:
        return self.family not in ("ssm",)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND math."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        plan = None
        n = V * D * 2  # embed + lm_head (untied)
        for _ in range(self.n_layers):
            if self.family in ("dense", "moe", "vlm", "encdec"):
                if plan is None:
                    plan = self.head_plan()
                Dh = self.head_dim_
                n += D * (plan.n_q_pad + 2 * plan.n_kv_pad) * Dh + plan.n_q_pad * Dh * D
                if self.family == "moe" and self.n_experts:
                    n += self.n_experts * 3 * D * F + D * self.n_experts
                else:
                    n += 3 * D * F
            elif self.family == "hybrid":
                d_in = 2 * D
                n += D * (2 * d_in + 2 * self.ssm_state + d_in // 64) + d_in * D
            elif self.family == "ssm":
                n += 5 * D * D + 2 * D * F
        if self.family == "encdec":
            for _ in range(self.n_enc_layers):
                Dh = self.head_dim_
                n += 4 * D * self.n_heads * Dh + 2 * D * F
                n += 4 * D * self.n_kv_heads * Dh  # cross-attn kv
        return n

    def active_param_count(self) -> int:
        """MoE: parameters touched per token (for 6*N_active*D FLOPs math)."""
        if self.family != "moe" or not self.n_experts:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        total = self.param_count()
        moe_all = self.n_layers * self.n_experts * 3 * D * F
        moe_active = self.n_layers * self.experts_per_token * 3 * D * F
        return total - moe_all + moe_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The assigned shape grid (system prompt): every LM arch x these four.
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "zamba2_1p2b", "minicpm_2b", "granite_20b", "mistral_large_123b",
    "qwen2p5_14b", "rwkv6_7b", "internvl2_2b", "whisper_base",
    "grok1_314b", "qwen3_moe_30b_a3b",
)

# long_500k runs only for sub-quadratic archs (DESIGN.md §4); whisper has a
# decoder (enc-dec) so decode shapes run, with 500k skipped (full attention).
LONG_CONTEXT_ARCHS = ("zamba2_1p2b", "rwkv6_7b")


def get_config(arch: str, tiny: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.TINY if tiny else mod.CONFIG


def cells(include_skips: bool = False):
    """The (arch x shape) dry-run grid. Yields (arch, shape_name, runnable)."""
    for arch in ARCH_IDS:
        for sname in SHAPES:
            runnable = True
            if sname == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                runnable = False
            if include_skips or runnable:
                yield arch, sname, runnable
