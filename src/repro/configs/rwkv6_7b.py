"""RWKV6-7B 'Finch': attention-free, data-dependent decay [arXiv:2404.05892; hf]

Exact assigned configuration (see system prompt / DESIGN.md §4); TINY is the
reduced same-family smoke-test variant (CPU, tp=1).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm", n_layers=32, d_model=4096,
    n_heads=64, n_kv_heads=64, d_ff=14336, vocab_size=65536)

TINY = ModelConfig(
    name="rwkv6-tiny", family="ssm", n_layers=2, d_model=128,
    n_heads=2, n_kv_heads=2, d_ff=256, vocab_size=512, tp=1)
