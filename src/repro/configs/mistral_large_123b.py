"""Mistral-Large-123B [hf:mistralai/Mistral-Large-Instruct-2407; unverified]

Exact assigned configuration (see system prompt / DESIGN.md §4); TINY is the
reduced same-family smoke-test variant (CPU, tp=1).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense", n_layers=88, d_model=12288,
    n_heads=96, n_kv_heads=8, d_ff=28672, vocab_size=32768, head_dim=128,
    rope_theta=1e6, remat_group=8)

TINY = ModelConfig(
    name="mistral-tiny", family="dense", n_layers=2, d_model=128,
    n_heads=8, n_kv_heads=2, d_ff=384, vocab_size=512, tp=1, head_dim=16)
