"""Zamba2-1.2B: Mamba2 backbone + shared attention block [arXiv:2411.15242; hf]

Exact assigned configuration (see system prompt / DESIGN.md §4); TINY is the
reduced same-family smoke-test variant (CPU, tp=1).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=32000, ssm_state=64,
    attn_every=6, sliding_window=4096, remat_group=2)

TINY = ModelConfig(
    name="zamba2-tiny", family="hybrid", n_layers=4, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512, ssm_state=16,
    attn_every=2, sliding_window=64, tp=1, head_dim=32)
