"""Grok-1-314B: MoE, 8 experts top-2 [hf:xai-org/grok-1; unverified]

Exact assigned configuration (see system prompt / DESIGN.md §4); TINY is the
reduced same-family smoke-test variant (CPU, tp=1).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=32768, vocab_size=131072, head_dim=128,
    n_experts=8, experts_per_token=2, remat_group=8)

TINY = ModelConfig(
    name="grok1-tiny", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512, tp=1,
    n_experts=4, experts_per_token=2)
