"""Whisper-base: enc-dec audio, conv frontend stubbed [arXiv:2212.04356; unverified]

Exact assigned configuration (see system prompt / DESIGN.md §4); TINY is the
reduced same-family smoke-test variant (CPU, tp=1).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec", n_layers=6, n_enc_layers=6,
    d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=51865,
    qkv_bias=True, enc_seq_len=1500)

TINY = ModelConfig(
    name="whisper-tiny", family="encdec", n_layers=2, n_enc_layers=2,
    d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512, tp=1,
    qkv_bias=True, enc_seq_len=64)
