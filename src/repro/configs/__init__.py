from repro.configs.base import (
    ARCH_IDS, LONG_CONTEXT_ARCHS, SHAPES, ModelConfig, ShapeConfig, cells,
    get_config,
)

__all__ = ["ARCH_IDS", "LONG_CONTEXT_ARCHS", "SHAPES", "ModelConfig",
           "ShapeConfig", "cells", "get_config"]
