"""MiniCPM-2B: dense llama-like, trained with the WSD schedule [arXiv:2404.06395; hf]

Exact assigned configuration (see system prompt / DESIGN.md §4); TINY is the
reduced same-family smoke-test variant (CPU, tp=1).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense", n_layers=40, d_model=2304,
    n_heads=36, n_kv_heads=36, d_ff=5760, vocab_size=122753)

TINY = ModelConfig(
    name="minicpm-tiny", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=320, vocab_size=512, tp=1)
