"""Qwen3-30B-A3B: MoE, 128 experts top-8, per-expert ff 768 [hf:Qwen/Qwen3-30B-A3B; hf]

Exact assigned configuration (see system prompt / DESIGN.md §4); TINY is the
reduced same-family smoke-test variant (CPU, tp=1).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=4, d_ff=768, vocab_size=151936, head_dim=128,
    n_experts=128, experts_per_token=8)

TINY = ModelConfig(
    name="qwen3-moe-tiny", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=512, tp=1,
    n_experts=8, experts_per_token=2)
