"""Sharding infrastructure: logical-axis rules, activation constraints, and
parameter PartitionSpec trees (MaxText-style, but path-name driven).

Mesh axes (launch/mesh.py): ('pod', 'data', 'model') multi-pod or
('data', 'model') single-pod. Logical axes used by the models:

    batch   -> ('pod', 'data')   (replicated when the batch doesn't divide)
    seq     -> None              (sequence-parallel variants map it to 'model')
    heads/kv_heads/ff/experts_ff -> 'model'   (TP)
    vocab   -> 'model'
    fsdp    -> 'data'            (parameter/optimizer-state sharding)

Activation constraints are applied through `constrain(x, *logical_axes)`,
which resolves against the ambient mesh set by `mesh_context`. With no mesh
active (unit tests, single device) it is a no-op.
"""

from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: contextvars.ContextVar[tuple[Mesh, dict] | None] = \
    contextvars.ContextVar("repro_mesh", default=None)

DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "batch_nodp": None,        # long_500k: batch of 1 cannot shard
    "seq": None,
    "kv_seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "fsdp": "data",
    "experts": None,
    "ssm_heads": "model",
    "state": None,
}


def rules_for_mesh(mesh: Mesh, overrides: dict | None = None) -> dict:
    rules = dict(DEFAULT_RULES)
    if "pod" not in mesh.axis_names:
        rules["batch"] = ("data",)
    if overrides:
        rules.update(overrides)
    return rules


@contextlib.contextmanager
def mesh_context(mesh: Mesh, overrides: dict | None = None):
    """Activates the (mesh, rules) pair that `constrain` resolves against.
    NamedShardings are fully explicit, so no ambient jax mesh is needed."""
    token = _ACTIVE.set((mesh, rules_for_mesh(mesh, overrides)))
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def active_mesh() -> Mesh | None:
    st = _ACTIVE.get()
    return st[0] if st else None


def resolve(*logical: str | None) -> P:
    st = _ACTIVE.get()
    if st is None:
        return P()
    _, rules = st
    out = []
    for name in logical:
        ax = rules.get(name) if name else None
        out.append(ax)
    return P(*out)


def constrain(x, *logical: str | None):
    """with_sharding_constraint against the ambient mesh (no-op without)."""
    st = _ACTIVE.get()
    if st is None:
        return x
    mesh, _ = st
    spec = resolve(*logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs by path-name convention
# ---------------------------------------------------------------------------

# Ordered (regex on dot-joined param path, spec builder) table. The builder
# receives the leaf shape and returns a PartitionSpec of equal rank. All
# models name their parameters so exactly one rule matches.
def _p(*axes):
    return lambda shape: P(*axes[: len(shape)]) if len(axes) >= len(shape) \
        else P(*(list(axes) + [None] * (len(shape) - len(axes))))


PARAM_RULES: list[tuple[str, Any]] = [
    # embeddings / unembedding
    (r"embed$", _p("model", "fsdp")),                    # [Vp, D]
    (r"lm_head$", _p("fsdp", "model")),                  # [D, Vp]
    # attention
    (r"\bwq$", _p("fsdp", "model", None)),               # [D, Hq, Dh]
    (r"\bwk$", _p("fsdp", "model", None)),
    (r"\bwv$", _p("fsdp", "model", None)),
    (r"\bwo$", _p("model", None, "fsdp")),               # [Hq, Dh, D]
    (r"\bb[qkv]$", _p("model", None)),                   # [H, Dh]
    # dense mlp
    (r"w_gate$", _p("fsdp", "model")),                   # [D, F]
    (r"w_in$", _p("fsdp", "model")),
    (r"w_out$", _p("model", "fsdp")),                    # [F, D]
    (r"b_in$", _p("model")),
    (r"b_out$", _p(None)),
    # moe (leading E dim; experts replicated, ff TP + fsdp)
    (r"moe.*router$", _p("fsdp", None)),                 # [D, E]
    (r"moe.*w_gate$", _p(None, "fsdp", "model")),        # [E, D, F]
    (r"moe.*w_in$", _p(None, "fsdp", "model")),
    (r"moe.*w_out$", _p(None, "model", "fsdp")),         # [E, F, D]
    # mamba2
    (r"mamba.*w_z$", _p("fsdp", "model")),               # [D, Din]
    (r"mamba.*w_x$", _p("fsdp", "model")),
    (r"mamba.*w_B$", _p("fsdp", None)),                  # [D, G*N] tiny
    (r"mamba.*w_C$", _p("fsdp", None)),
    (r"mamba.*w_dt$", _p("fsdp", "model")),              # [D, H]
    (r"mamba.*conv_x_w$", _p(None, "model")),
    (r"mamba.*conv_[BC]_w$", _p(None, None)),
    (r"mamba.*conv_x_b$", _p("model")),
    (r"mamba.*conv_[BC]_b$", _p(None)),
    (r"mamba.*(A_log|dt_bias)$", _p("model")),           # [H]
    (r"mamba.*\bD$", _p("model")),
    (r"mamba.*norm_w$", _p("model")),                    # [Din]
    (r"mamba.*w_out$", _p("model", "fsdp")),             # [Din, D]
    # rwkv6
    (r"rwkv.*w_[rkvg]$", _p("fsdp", "model")),           # [D, D]
    (r"rwkv.*w_o$", _p("model", "fsdp")),
    (r"rwkv.*mix_base$", _p(None, None)),
    (r"rwkv.*mix_w1$", _p("fsdp", None)),
    (r"rwkv.*mix_w2$", _p(None, None, None)),
    (r"rwkv.*decay_base$", _p(None)),
    (r"rwkv.*decay_w1$", _p("fsdp", None)),
    (r"rwkv.*decay_w2$", _p(None, "model")),
    (r"rwkv.*bonus_u$", _p("model", None)),              # [H, Dh]
    (r"rwkv.*ln_x_[wb]$", _p(None)),
    (r"rwkv.*cmix_[kr]$", _p(None)),
    (r"rwkv.*cm_wk$", _p("fsdp", "model")),
    (r"rwkv.*cm_wv$", _p("model", "fsdp")),
    (r"rwkv.*cm_wr$", _p("fsdp", "model")),
    # int8 optimizer moments: flat [n_blocks, block]/[n_blocks, 1] arrays,
    # FSDP-sharded over the block dim when divisible
    (r"\.q$", _p("fsdp", None)),
    (r"\.scale$", _p("fsdp", None)),
    # norms / misc scalars+vectors
    (r"(ln|norm).*(_w|_b|weight|bias)?$", _p(None)),
]

# True expert parallelism (E % model == 0): experts sharded over 'model',
# per-expert F kept full-width (MXU-friendly for skinny experts like
# qwen3's F=768); dispatch becomes all-to-all over the model axis.
# Consulted BEFORE the base table when the moe_ep profile is active.
PARAM_RULES_MOE_EP: list[tuple[str, Any]] = [
    (r"moe.*router$", _p("fsdp", None)),
    (r"moe.*w_gate$", _p("model", "fsdp", None)),
    (r"moe.*w_in$", _p("model", "fsdp", None)),
    (r"moe.*w_out$", _p("model", None, "fsdp")),
]


def spec_for_path(path: str, shape: tuple[int, ...], *,
                  moe_ep: bool = False) -> P:
    if moe_ep:
        for pat, builder in PARAM_RULES_MOE_EP:
            if re.search(pat, path):
                return builder(shape)
    for pat, builder in PARAM_RULES:
        if re.search(pat, path):
            return builder(shape)
    return P(*([None] * len(shape)))


def _axis_size(mesh: Mesh, name) -> int:
    """Axis size; 0 for axes absent from this mesh (caller drops them)."""
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        s = 1
        for n in name:
            sz = _axis_size(mesh, n)
            if sz == 0:
                return 0
            s *= sz
        return s
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 0)


def _validate_divisible(spec: P, shape: tuple[int, ...], mesh: Mesh, path: str) -> P:
    """Drop sharding on dims the mesh axis doesn't divide, or axes the mesh
    doesn't have (tests/examples on smaller meshes)."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        size = _axis_size(mesh, ax)
        if ax is not None and (size == 0 or dim % size != 0):
            out.append(None)
        else:
            out.append(ax)
    return P(*out)


def _rewrite_fsdp(spec: P, fsdp_axes) -> P:
    return P(*((fsdp_axes if ax == "fsdp" else ax) for ax in spec))


def param_pspecs(abstract_params, mesh: Mesh, *, fsdp="data", moe_ep=False,
                 stacked_prefixes: tuple[str, ...] = ("blocks", "enc_blocks",
                                                      "dec_blocks")):
    """PartitionSpec tree for a parameter pytree.

    Stacked (scan-over-layers) params carry a leading L dim which is never
    sharded: rules are applied to the trailing dims and shifted right."""
    def one(path_tuple, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path_tuple]
        path = ".".join(str(k) for k in keys if k is not None)
        shape = leaf.shape
        # int8-optimizer moment leaves (…/q, …/scale) are flat block arrays,
        # never layer-stacked even when their path mentions 'blocks'
        flat_moment = re.search(r"\.(q|scale)$", path) is not None
        stacked = (not flat_moment and len(shape) >= 1
                   and any(seg in stacked_prefixes for seg in path.split(".")))
        eff_shape = shape[1:] if stacked else shape
        spec = spec_for_path(path, tuple(eff_shape), moe_ep=moe_ep)
        spec = _rewrite_fsdp(spec, fsdp)
        if fsdp is not None and not isinstance(fsdp, str):
            # wide-FSDP profiles shard params over (data, model): drop the
            # 'model' TP assignment so dims aren't double-sharded
            spec = P(*((None if ax == "model" else ax) for ax in spec))
        spec = _validate_divisible(spec, tuple(eff_shape), mesh, path)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(one, abstract_params)


def named_shardings(abstract_params, mesh: Mesh, **kw):
    specs = param_pspecs(abstract_params, mesh, **kw)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


# ---------------------------------------------------------------------------
# Decode-cache PartitionSpecs (leading stacked layer/occurrence axis)
# ---------------------------------------------------------------------------

def cache_pspecs(abstract_cache, mesh: Mesh, *, batch_axes) -> Any:
    """Shard decode caches: batch over the DP axes, heads over 'model'.

    Leaf layouts (leading L = stacked layers/occurrences):
      k/v        [L,B,S,Hkv,Dh] -> (None, batch, None, 'model', None)
      wkv        [L,B,H,Dk,Dv]  -> (None, batch, 'model', None, None)
      ssm state  [L,B,H,N,P]    -> (None, batch, 'model', None, None)
      conv state [L,B,W-1,C]    -> (None, batch, None, 'model')
      *_last     [L,B,1,D]      -> (None, batch, None, None)
    Dims that don't divide fall back to replication (validated)."""
    def one(path_tuple, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", None)))
                for k in path_tuple]
        path = ".".join(keys)
        shape = leaf.shape
        rank = len(shape)
        if re.search(r"(^|\.)([kv]|wkv)$", path) and rank == 5:
            spec = P(None, batch_axes, None, "model", None)
        elif rank == 5:
            spec = P(None, batch_axes, "model", None, None)
        elif rank == 4 and shape[-1] % _axis_size(mesh, "model") == 0 \
                and "last" not in path:
            spec = P(None, batch_axes, None, "model")
        elif rank >= 2:
            spec = P(*((None, batch_axes) + (None,) * (rank - 2)))
        else:
            spec = P(*([None] * rank))
        return _validate_divisible(spec, shape, mesh, path)

    return jax.tree_util.tree_map_with_path(one, abstract_cache)
