"""Step-atomic checkpointing with elastic restore.

Layout: <dir>/step_<N>/
    manifest.msgpack   — leaf paths, shapes, dtypes, step, mesh metadata
    arrays.npz         — one entry per leaf (path-keyed)
    .complete          — commit marker written LAST (atomicity: a partially
                         written checkpoint is never visible to restore)

Elastic restore: arrays are saved as full (unsharded) host arrays with their
*logical* role recorded via path names; restore re-shards onto whatever mesh
is active via parallel.sharding.param_pspecs — a 2x16x16 checkpoint restores
onto 16x16 (or 1 device) unchanged. Background (async) save is supported for
step-overlap; `wait()` joins the writer.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Any

import jax
import msgpack
import numpy as np

_DTYPE_FIX = {"bfloat16": "bfloat16"}


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}

    def go(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", None)))
                for k in path]
        flat["/".join(keys)] = np.asarray(jax.device_get(leaf))

    jax.tree_util.tree_map_with_path(go, tree)
    return flat


def _unflatten_into(tree_like, flat: dict[str, np.ndarray]):
    def go(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", None)))
                for k in path]
        arr = flat["/".join(keys)]
        return arr

    return jax.tree_util.tree_map_with_path(go, tree_like)


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_save: bool = False
    _thread: threading.Thread | None = None

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: dict[str, Any]) -> str:
        """state: dict of pytrees, e.g. {'params': ..., 'opt': ..., 'plane': ...}"""
        self.wait()
        path = os.path.join(self.directory, f"step_{step:08d}")

        host = {name: _flatten(tree) for name, tree in state.items()}
        bf16_mask = {name: {k: str(v.dtype) for k, v in flat.items()}
                     for name, flat in host.items()}

        def write():
            os.makedirs(path, exist_ok=True)
            arrays = {}
            manifest = {"step": step, "groups": {}, "time": time.time()}
            for name, flat in host.items():
                manifest["groups"][name] = {
                    k: {"shape": list(v.shape), "dtype": bf16_mask[name][k]}
                    for k, v in flat.items()}
                for k, v in flat.items():
                    # npz has no bf16: store as uint16 view, dtype in manifest
                    if v.dtype == jax.numpy.bfloat16:
                        v = v.view(np.uint16)
                    arrays[f"{name}::{k}"] = v
            np.savez(os.path.join(path, "arrays.npz"), **arrays)
            with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
                f.write(msgpack.packb(manifest))
            with open(os.path.join(path, ".complete"), "w") as f:
                f.write("ok")
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
        return path

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            p = os.path.join(self.directory, f"step_{s:08d}")
            for fn in os.listdir(p):
                os.unlink(os.path.join(p, fn))
            os.rmdir(p)

    # -- restore --------------------------------------------------------------
    def list_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, d, ".complete")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, state_like: dict[str, Any], step: int | None = None,
                shardings: dict[str, Any] | None = None) -> tuple[int, dict]:
        """Restore into the structure of `state_like`. If `shardings` maps
        group name -> NamedSharding pytree, leaves are device_put sharded
        (elastic restore onto a different mesh)."""
        import jax.numpy as jnp
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        with np.load(os.path.join(path, "arrays.npz")) as z:
            out = {}
            for name, tree in state_like.items():
                flat = {}
                for k, meta in manifest["groups"][name].items():
                    v = z[f"{name}::{k}"]
                    if meta["dtype"] == "bfloat16":
                        v = v.view(jnp.bfloat16)
                    flat[k] = v
                restored = _unflatten_into(tree, flat)
                if shardings and name in shardings:
                    restored = jax.tree_util.tree_map(
                        lambda a, s: jax.device_put(jnp.asarray(a), s),
                        restored, shardings[name])
                else:
                    restored = jax.tree_util.tree_map(jnp.asarray, restored)
                out[name] = restored
        return manifest["step"], out
