"""Step-atomic checkpointing with elastic restore.

Layout: <dir>/step_<N>/
    manifest.msgpack   — leaf paths, shapes, dtypes, step, mesh metadata,
                         and (fleet runs) the FleetSpec provenance
    arrays.npz         — one entry per leaf (path-keyed); fleet runs add a
                         `fleet_spec::` group with the per-chip nominals
    .complete          — commit marker written LAST (atomicity: a partially
                         written checkpoint is never visible to restore)

Elastic restore: arrays are saved as full (unsharded) host arrays with their
*logical* role recorded via path names; restore re-shards onto whatever mesh
is active via parallel.sharding.param_pspecs — a 2x16x16 checkpoint restores
onto 16x16 (or 1 device) unchanged. Background (async) save is supported for
step-overlap; `wait()` joins the writer.

Fleet elasticity: `save(..., fleet=FleetSpec)` records the fleet's seed and
per-chip process-variation arrays next to the plane state; restoring onto a
*different* fleet size goes through `remap_plane` — surviving chips keep
their per-chip operating point/energy, new chips start at their own nominal
— so the remapping is explicit, never a silent broadcast/truncation.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Any

import jax
import msgpack
import numpy as np

from repro.core.hwspec import FleetSpec

_DTYPE_FIX = {"bfloat16": "bfloat16"}

# FleetSpec per-chip arrays persisted under the `fleet_spec::` npz group
_FLEET_FIELDS = ("v_core_nominal", "v_hbm_nominal", "v_io_nominal",
                 "leakage_scale", "error_sensitivity")


def remap_plane(plane, target: FleetSpec):
    """Explicitly remap a restored plane onto a `target` fleet of a possibly
    different size: chips 0..min(n_old, n_new)-1 keep their restored per-chip
    state (operating point, accumulated energy, step counter); chips beyond
    the restored fleet start at their *own* process-varied nominal point with
    zero energy. A scalar plane is treated as a 1-chip fleet. Returns the
    plane unchanged when the sizes already match."""
    import dataclasses as _dc

    import jax.numpy as jnp

    from repro.core.power_plane import PowerPlaneState

    n_old = plane.n_chips
    n_new = target.n_chips
    if plane.is_fleet and n_old == n_new:
        return plane
    fresh = PowerPlaneState.from_fleet(target)
    k = min(n_old, n_new)

    def take(old, new):
        old = jnp.atleast_1d(jnp.asarray(old))
        return new.at[:k].set(old[:k].astype(new.dtype))

    # joining chips adopt the fleet's step counter (a synchronous fleet
    # steps together; per-step RNG derives from plane.step)
    step = jnp.full((n_new,),
                    jnp.max(jnp.atleast_1d(plane.step)), jnp.int32)
    return _dc.replace(
        fresh,
        v_core=take(plane.v_core, fresh.v_core),
        v_hbm=take(plane.v_hbm, fresh.v_hbm),
        v_io=take(plane.v_io, fresh.v_io),
        comp_level=take(plane.comp_level, fresh.comp_level),
        energy_j=take(plane.energy_j, fresh.energy_j),
        step=take(plane.step, step),
    )


def remap_sor(sor_state, target):
    """Explicitly remap a restored `sor.SorState` onto a `target` fleet
    (a FleetSpec or an int chip count) of a possibly different size — the
    learned-region counterpart of `remap_plane`: chips 0..min(n_old,
    n_new)-1 keep their learned telemetry window and fitted frontier;
    joining chips start empty, which is ZERO confidence — the cold-start
    pin — so a joiner runs at static envelopes until its own telemetry
    accrues. Returns the state unchanged when the sizes already match."""
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp

    n_new = target.n_chips if hasattr(target, "n_chips") else int(target)
    hist = sor_state.history
    chip = hist.chip_shape
    if not chip:
        raise ValueError("remap_sor needs a fleet-shaped ([n_chips]) "
                         "SorState; a scalar learner has nothing to remap")
    n_old = chip[0]
    if n_old == n_new:
        return sor_state
    k = min(n_old, n_new)

    def take(a):
        a = jnp.asarray(a)
        z = jnp.zeros(a.shape[:-1] + (n_new,), a.dtype)
        return z.at[..., :k].set(a[..., :k])

    return _dc.replace(
        sor_state,
        history=_dc.replace(
            hist, v=take(hist.v), obs=take(hist.obs),
            age_s=take(hist.age_s), polled=take(hist.polled),
            valid=take(hist.valid)),
        estimate=jax.tree_util.tree_map(take, sor_state.estimate))


def _path_key(k) -> str:
    """One path entry -> stable string: DictKey.key, GetAttrKey.name
    (registered dataclasses like PowerPlaneState), SequenceKey.idx. Falling
    through to None would collapse distinct leaves onto one npz entry."""
    for attr in ("key", "name", "idx"):
        v = getattr(k, attr, None)
        if v is not None:
            return str(v)
    return str(k)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}

    def go(path, leaf):
        flat["/".join(_path_key(k) for k in path)] = np.asarray(
            jax.device_get(leaf))

    jax.tree_util.tree_map_with_path(go, tree)
    return flat


def _unflatten_into(tree_like, flat: dict[str, np.ndarray]):
    def go(path, leaf):
        return flat["/".join(_path_key(k) for k in path)]

    return jax.tree_util.tree_map_with_path(go, tree_like)


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_save: bool = False
    _thread: threading.Thread | None = None

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: dict[str, Any],
             fleet: FleetSpec | None = None) -> str:
        """state: dict of pytrees, e.g. {'params': ..., 'opt': ..., 'plane': ...}.
        `fleet` additionally records the FleetSpec (seed + per-chip nominal
        arrays) the plane was seeded from, so an elastic restart onto a
        different fleet size can remap per-chip state explicitly."""
        self.wait()
        path = os.path.join(self.directory, f"step_{step:08d}")

        host = {name: _flatten(tree) for name, tree in state.items()}
        bf16_mask = {name: {k: str(v.dtype) for k, v in flat.items()}
                     for name, flat in host.items()}
        # learned-region groups (sor.SorState) record their full rail
        # layout — names AND observable keys/bounds — so a restore under a
        # different SorConfig.rails cannot silently misassign one rail's
        # learned frontier to another, or relabel a frontier cut at one
        # bound as an envelope for a different one
        sor_rails = {name: {"rails": [dataclasses.asdict(s)
                                      for s in tree.history.rails],
                            "capacity": int(tree.history.capacity)}
                     for name, tree in state.items()
                     if hasattr(getattr(tree, "history", None), "rails")}
        fleet_arrays = ({f: np.asarray(getattr(fleet, f))
                         for f in _FLEET_FIELDS} if fleet is not None else None)
        fleet_meta = ({"n_chips": fleet.n_chips, "seed": fleet.seed,
                       "base": dataclasses.asdict(fleet.base)}
                      if fleet is not None else None)

        def write():
            os.makedirs(path, exist_ok=True)
            arrays = {}
            manifest = {"step": step, "groups": {}, "time": time.time()}
            if sor_rails:
                manifest["sor_rails"] = sor_rails
            if fleet_meta is not None:
                manifest["fleet"] = fleet_meta
                for f, v in fleet_arrays.items():
                    arrays[f"fleet_spec::{f}"] = v
            for name, flat in host.items():
                manifest["groups"][name] = {
                    k: {"shape": list(v.shape), "dtype": bf16_mask[name][k]}
                    for k, v in flat.items()}
                for k, v in flat.items():
                    # npz has no bf16: store as uint16 view, dtype in manifest
                    if v.dtype == jax.numpy.bfloat16:
                        v = v.view(np.uint16)
                    arrays[f"{name}::{k}"] = v
            np.savez(os.path.join(path, "arrays.npz"), **arrays)
            with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
                f.write(msgpack.packb(manifest))
            with open(os.path.join(path, ".complete"), "w") as f:
                f.write("ok")
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
        return path

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            p = os.path.join(self.directory, f"step_{s:08d}")
            for fn in os.listdir(p):
                os.unlink(os.path.join(p, fn))
            os.rmdir(p)

    # -- restore --------------------------------------------------------------
    def list_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, d, ".complete")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore_fleet(self, step: int | None = None) -> FleetSpec | None:
        """The FleetSpec a checkpoint was written under (None for scalar /
        pre-fleet checkpoints): seed + the exact per-chip nominal arrays, so
        a restart can compare it to its own fleet and `remap_plane`
        explicitly when the sizes differ."""
        if step is None:
            step = self.latest_step()
        if step is None:
            return None
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        meta = manifest.get("fleet")
        if meta is None:
            return None
        from repro.core.hwspec import V5E, ChipSpec
        base = (ChipSpec(**meta["base"]) if meta.get("base") else V5E)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrs = {f: z[f"fleet_spec::{f}"] for f in _FLEET_FIELDS}
        return FleetSpec(base=base, seed=int(meta["seed"]), **arrs)

    def restore(self, state_like: dict[str, Any], step: int | None = None,
                shardings: dict[str, Any] | None = None,
                optional: tuple = ()) -> tuple[int, dict]:
        """Restore into the structure of `state_like`. If `shardings` maps
        group name -> NamedSharding pytree, leaves are device_put sharded
        (elastic restore onto a different mesh). A group the checkpoint
        never recorded raises KeyError — unless named in `optional`, in
        which case it is skipped (absent from the returned dict): that is
        how a SOR-enabled trainer restores a pre-SOR checkpoint and keeps
        its in-memory cold start, without a missing REQUIRED group (renamed
        key, truncated manifest) silently restarting from fresh state."""
        import jax.numpy as jnp
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        with np.load(os.path.join(path, "arrays.npz")) as z:
            out = {}
            for name, tree in state_like.items():
                if name not in manifest["groups"]:
                    if name in optional:
                        continue
                    raise KeyError(
                        f"checkpoint step_{step:08d} has no state group "
                        f"{name!r} (has {sorted(manifest['groups'])}); "
                        f"pass optional=({name!r},) if the caller can "
                        f"genuinely proceed without it")
                saved = manifest.get("sor_rails", {}).get(name)
                if saved is not None:
                    hist = getattr(tree, "history", None)
                    want = {"rails": [dataclasses.asdict(s) for s in
                                      getattr(hist, "rails", ())],
                            "capacity": int(getattr(hist, "capacity", 0))}
                    if saved != want:
                        # substituting the arrays would index one rail's
                        # learned frontier as another's, relabel a frontier
                        # cut at a different bound, or hand a window of the
                        # wrong depth to the ring arithmetic — refuse loudly
                        raise ValueError(
                            f"checkpoint group {name!r} was learned under "
                            f"rails/capacity {saved} but this run's "
                            f"SorConfig declares {want}; restore with the "
                            f"config the state was learned under (or drop "
                            f"the group)")
                flat = {}
                for k, meta in manifest["groups"][name].items():
                    v = z[f"{name}::{k}"]
                    if meta["dtype"] == "bfloat16":
                        v = v.view(jnp.bfloat16)
                    flat[k] = v
                restored = _unflatten_into(tree, flat)
                if shardings and name in shardings:
                    restored = jax.tree_util.tree_map(
                        lambda a, s: jax.device_put(jnp.asarray(a), s),
                        restored, shardings[name])
                else:
                    restored = jax.tree_util.tree_map(jnp.asarray, restored)
                out[name] = restored
        return manifest["step"], out
