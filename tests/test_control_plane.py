"""Control-plane tests: the paper's two-paths-one-behavior claim (host and
in-graph controllers produce the same rail trajectory on the same telemetry
stream), fleet vectorization (batched account_step == loop of scalar calls),
the event-scheduled multi-segment bus (fleet actuation time = max over
segments, not sum), the fleet telemetry reduction kernel, and the
PowerManager request-validation regressions."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.control_plane import (ControlPlaneStats, HostPowerController,
                                      HostRailController,
                                      InGraphRailController, RailController,
                                      as_controller)
from repro.core.fleet import FleetPowerManager
from repro.core.pmbus import EventQueue
from repro.core.policy import (BERBounded, ClosedLoop, PhaseAware,
                               StaticNominal, WorstChipGate)
from repro.core.power_manager import Opcode, PowerManager
from repro.core.power_plane import (PowerPlaneState, StepProfile, account_step,
                                    account_step_fleet, fleet_summary)

PROFILE = StepProfile(flops_per_chip=2e12, hbm_bytes_per_chip=8e9,
                      ici_bytes_per_chip=4e9, grad_bytes_per_chip=3e9)


# -- two paths, one behavior ---------------------------------------------------

def _telemetry_stream(steps=12):
    """A deterministic grad-error stream crossing the ClosedLoop bound in
    both directions."""
    bound = 5e-3
    return [{"grad_error": jnp.float32(bound * (0.2 if s % 5 else 3.0))}
            for s in range(steps)]


def test_host_and_in_graph_controllers_agree():
    """Same policy, same telemetry stream -> same rail trajectory, up to the
    host path's actuation quantization (LINEAR16 + settling band)."""
    ig = InGraphRailController(ClosedLoop())
    host = HostRailController(ClosedLoop(), settle_band_frac=0.001)

    p_ig = PowerPlaneState.nominal()
    p_host = PowerPlaneState.nominal()
    traj_ig, traj_host = [], []
    for telem in _telemetry_stream():
        p_ig = ig.control_step(p_ig, telem)
        p_host = host.control_step(p_host, telem)
        traj_ig.append(float(p_ig.v_io))
        traj_host.append(float(p_host.v_io))
    np.testing.assert_allclose(traj_host, traj_ig, atol=5e-3)
    assert traj_ig[0] != traj_ig[-1]          # the stream actually moved rails
    # and only the host path paid PMBus time
    assert ig.stats().actuation_seconds == 0.0
    assert host.stats().actuation_seconds > 0.0


def test_as_controller_normalizes():
    assert as_controller(None) is None
    c = as_controller(PhaseAware())
    assert isinstance(c, InGraphRailController)
    assert as_controller(c) is c
    assert isinstance(c, RailController)       # runtime-checkable protocol
    hc = HostRailController()
    assert isinstance(hc, RailController)


def test_trainer_config_bare_policy_decides_between_steps():
    """A bare Policy in the trainer's host-path slot runs its decision
    between steps (the SW-path hook) through the decide/arbitrate API."""
    from repro.core.control_plane import HostDecisionController
    from repro.train.trainer import TrainerConfig

    class Marking(StaticNominal):
        decide_calls = 0

        def decide(self, state, frame):
            Marking.decide_calls += 1
            return super().decide(state, frame)

    cfg = TrainerConfig(total_steps=1, controller=Marking())
    assert isinstance(cfg.controller, HostDecisionController)
    cfg.controller.control_step(PowerPlaneState.nominal(), {})
    assert Marking.decide_calls == 1
    assert cfg.controller.stats().decisions == 1


def test_legacy_update_policy_still_runs_through_controllers():
    """A pre-redesign policy (state-mutating update_* methods, no decide())
    keeps working behind every controller: the host path routes through
    update_host, the in-graph path through update_jax."""
    from repro.core.control_plane import HostDecisionController
    from repro.core.policy import Policy

    class Legacy(Policy):
        name = "legacy"
        jax_calls = 0
        host_calls = 0

        def update_jax(self, state, telemetry):
            Legacy.jax_calls += 1
            return dataclasses.replace(state, v_io=jnp.float32(0.85))

        def update_host(self, state, telemetry):
            Legacy.host_calls += 1
            return dataclasses.replace(state, v_io=jnp.float32(0.84))

    plane = PowerPlaneState.nominal()
    out = HostDecisionController(Legacy()).control_step(plane, {})
    assert Legacy.host_calls == 1 and float(out.v_io) == pytest.approx(0.84)
    out = InGraphRailController(Legacy()).control_step(plane, {})
    assert Legacy.jax_calls == 1 and float(out.v_io) == pytest.approx(0.85)


def test_legacy_update_jax_only_policy_keeps_old_base_defaults():
    """A pre-redesign policy overriding ONLY update_jax relied on the old
    base-class defaults (update_host -> update_jax, update_fleet ->
    vmap(update_jax)); the deprecated shims must preserve that, on the host
    path and on fleet planes alike."""
    from repro.core.control_plane import HostDecisionController
    from repro.core.policy import Policy

    class JaxOnly(Policy):
        name = "jax-only"

        def update_jax(self, state, telemetry):
            return dataclasses.replace(state, v_io=state.v_io - 0.01)

    plane = PowerPlaneState.nominal()
    # the base-class shims fire the deprecation warning on the way through
    with pytest.warns(DeprecationWarning):
        out = HostDecisionController(JaxOnly()).control_step(plane, {})
    assert float(out.v_io) == pytest.approx(float(plane.v_io) - 0.01)
    fleet = PowerPlaneState.fleet(3)
    with pytest.warns(DeprecationWarning):
        out = InGraphRailController(JaxOnly()).control_step(fleet, {})
    np.testing.assert_allclose(np.asarray(out.v_io),
                               np.asarray(fleet.v_io) - 0.01, rtol=1e-6)


# -- fleet vectorization -------------------------------------------------------

def _varied_fleet(n=16):
    f = PowerPlaneState.fleet(n)
    return dataclasses.replace(
        f,
        v_core=jnp.linspace(0.70, 0.90, n, dtype=jnp.float32),
        v_hbm=jnp.linspace(0.95, 1.15, n, dtype=jnp.float32),
        v_io=jnp.linspace(0.70, 0.95, n, dtype=jnp.float32),
        comp_level=jnp.arange(n, dtype=jnp.int32) % 3,
    )


def test_batched_account_step_matches_scalar_loop():
    fleet = _varied_fleet(16)
    fleet2, metrics = account_step_fleet(PROFILE, fleet)
    for i in range(fleet.n_chips):
        chip2, m = account_step(PROFILE, fleet.chip(i))
        np.testing.assert_allclose(np.asarray(fleet2.energy_j)[i],
                                   float(chip2.energy_j), rtol=1e-6)
        for k in ("t_step_s", "power_w", "util_mxu"):
            np.testing.assert_allclose(np.asarray(metrics[k])[i], float(m[k]),
                                       rtol=1e-6, err_msg=k)
    assert np.all(np.asarray(fleet2.step) == 1)


def test_fleet_policy_matches_scalar_loop():
    """One elementwise decide() on a [n_chips] frame == the per-chip scalar
    decisions (the fleet path is the scalar path, vectorized)."""
    ctrl = InGraphRailController(PhaseAware())
    fleet = _varied_fleet(8)
    _, metrics = account_step_fleet(PROFILE, fleet)
    telem = {**metrics, "grad_error": jnp.linspace(0, 1e-2, 8)}
    out = ctrl.control_step(fleet, telem)
    for i in range(8):
        chip_t = {k: v[i] for k, v in telem.items()}
        chip_out = ctrl.control_step(fleet.chip(i), chip_t)
        np.testing.assert_allclose(np.asarray(out.v_core)[i],
                                   float(chip_out.v_core), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(out.v_io)[i],
                                   float(chip_out.v_io), rtol=1e-6)


def test_worst_chip_gate_reduces_over_fleet():
    """One bad chip must retreat the whole fleet (worst-chip BER gating)."""
    n = 8
    fleet = dataclasses.replace(
        PowerPlaneState.fleet(n),
        comp_level=jnp.full((n,), 2, jnp.int32))   # everyone compressed
    err = jnp.zeros((n,)).at[3].set(1.0)           # chip 3 is over the bound
    gated = InGraphRailController(WorstChipGate(BERBounded())).control_step(
        fleet, {"grad_error": err})
    assert np.all(np.asarray(gated.comp_level) == 1)   # ALL chips retreat
    # per-chip policy (no gate) would only retreat chip 3
    solo = InGraphRailController(BERBounded()).control_step(
        fleet, {"grad_error": err})
    assert np.asarray(solo.comp_level)[3] == 1
    assert np.all(np.delete(np.asarray(solo.comp_level), 3) == 2)


def test_fleet_summary_reductions():
    s = fleet_summary(_varied_fleet(4))
    assert float(s["v_core_min"]) == pytest.approx(0.70, abs=1e-6)
    assert float(s["v_core_max"]) == pytest.approx(0.90, abs=1e-6)
    with pytest.raises(ValueError):
        fleet_summary(PowerPlaneState.nominal())


# -- event-scheduled multi-segment bus ----------------------------------------

def test_fleet_actuation_is_max_not_sum():
    """N boards actuating concurrently cost max-over-segments fleet time —
    the property that makes 1000-chip sweeps tractable."""
    single = HostRailController(settle_band_frac=0.01)
    sp = dataclasses.replace(PowerPlaneState.nominal(), v_io=jnp.float32(0.85))
    single.actuate(sp)
    t_single = single.stats().actuation_seconds

    n = 16
    fpm = FleetPowerManager(n)
    setpoints = [{2: 0.85} for _ in range(n)]
    _, report = fpm.apply_setpoints(setpoints)
    assert report.boards_touched == n
    assert report.elapsed_s == pytest.approx(t_single, rel=1e-6)
    assert report.serialized_s == pytest.approx(n * t_single, rel=1e-6)
    assert report.overlap_speedup == pytest.approx(n, rel=1e-6)


def test_fleet_actuation_deadband_skips_untouched_boards():
    n = 4
    fpm = FleetPowerManager(n)
    # only board 2 actually changes
    setpoints = [{2: 0.95}, {2: 0.95}, {2: 0.80}, {2: 0.95}]
    achieved, report = fpm.apply_setpoints(setpoints)
    assert report.boards_touched == 1 and report.lane_writes == 1
    assert achieved[2][2] == pytest.approx(0.80, abs=5e-3)
    assert achieved[0][2] == pytest.approx(0.95, abs=5e-3)


def test_fleet_rejected_write_is_surfaced_not_counted():
    """An out-of-envelope setpoint must come back as a failed write with the
    rejection reason, not be silently counted as completed."""
    fpm = FleetPowerManager(2)
    achieved, report = fpm.apply_setpoints([{2: 0.50}, {2: 0.85}])  # 0.50 < v_min
    assert not report.ok
    assert report.failed_writes == 1 and report.lane_writes == 1
    assert "outside" in report.errors[0] and "board 0" in report.errors[0]
    assert achieved[0][2] == pytest.approx(0.95, abs=5e-3)  # rail unchanged
    assert achieved[1][2] == pytest.approx(0.85, abs=5e-3)
    assert fpm.stats()["failed_writes"] == 1


def test_fleet_readback_and_idle():
    fpm = FleetPowerManager(3)
    fpm.apply_setpoints([{0: 0.80}, {0: 0.85}, {0: 0.90}])
    fpm.idle(10e-3)   # rails keep settling while the fleet computes
    v = fpm.readback(lanes=[0])
    np.testing.assert_allclose(v[:, 0], [0.80, 0.85, 0.90], atol=2e-3)
    st = fpm.stats()
    assert st["actuation_rounds"] == 1 and st["events_processed"] >= 3


def test_event_queue_orders_by_time_then_seq():
    q = EventQueue()
    fired = []
    q.schedule(2.0, lambda t: fired.append(("b", t)))
    q.schedule(1.0, lambda t: fired.append(("a", t)))
    q.schedule(1.0, lambda t: fired.append(("a2", t)))
    assert q.next_time() == 1.0
    assert q.run_until(1.5) == 2
    assert [f[0] for f in fired] == ["a", "a2"]
    q.run_all()
    assert [f[0] for f in fired] == ["a", "a2", "b"]
    assert q.processed == 3


def test_fleet_host_controller_batched_actuation():
    n = 8
    hc = HostRailController(n_chips=n, settle_band_frac=0.001)
    fleet = dataclasses.replace(
        PowerPlaneState.fleet(n),
        v_io=jnp.linspace(0.70, 0.95, n, dtype=jnp.float32))
    out = hc.actuate(fleet)
    np.testing.assert_allclose(np.asarray(out.v_io),
                               np.linspace(0.70, 0.95, n), atol=2e-3)
    # board count mismatch is a structured error
    with pytest.raises(ValueError, match="board"):
        hc.actuate(PowerPlaneState.fleet(n + 1))


# -- fleet telemetry reduction kernel -----------------------------------------

@pytest.mark.parametrize("n,f", [(64, 9), (130, 5), (1000, 12)])
def test_fleet_reduce_kernel_matches_reference(n, f):
    from repro.kernels import ref
    from repro.kernels.fleet_telemetry import fleet_reduce
    x = jax.random.normal(jax.random.PRNGKey(n + f), (n, f)) * 7.0
    mx, mn, sm = fleet_reduce(x, interpret=True)
    rmx, rmn, rsm = ref.fleet_reduce_reference(x)
    np.testing.assert_allclose(mx, rmx, rtol=1e-6)
    np.testing.assert_allclose(mn, rmn, rtol=1e-6)
    np.testing.assert_allclose(sm, rsm, rtol=1e-5, atol=1e-4)


# -- PowerManager request-validation regressions -------------------------------

@pytest.mark.parametrize("opcode", [Opcode.SET_UNDER_VOLTAGE,
                                    Opcode.SET_POWER_GOOD_ON,
                                    Opcode.SET_POWER_GOOD_OFF,
                                    Opcode.SET_VOLTAGE])
def test_execute_value_none_returns_structured_error(opcode):
    pm = PowerManager(path="hw", clock_hz=400_000)
    before = pm.bus.transaction_count
    res = pm.execute(opcode, lane=6, value=None)
    assert not res.ok and "requires a value" in res.error
    assert pm.bus.transaction_count == before      # nothing hit the wire
    assert pm.status_fault
    assert pm.request_log[-1] is res


def test_measure_transition_clamps_overlong_command_sequence():
    """SW path at 100 kHz: the command sequence alone can exceed a short
    measurement window; the trace must come back empty with NaN latency, not
    raise on a negative sample duration."""
    pm = PowerManager(path="sw", clock_hz=100_000)
    tr = pm.measure_transition(6, 0.8, duration_s=1e-3)
    assert tr.times.size == 0
    assert math.isnan(tr.end_to_end_latency_s())


def test_envelope_boundary_actuates_despite_f32_rounding():
    """A policy clamping to the rail floor emits f32(0.65) < 0.65; the
    mechanism must clamp it into the envelope, not silently reject — else
    the two control paths diverge exactly at the interesting operating
    points."""
    hc = HostRailController(settle_band_frac=0.001)
    want = dataclasses.replace(PowerPlaneState.nominal(),
                               v_io=jnp.float32(0.65))   # VDD_IO floor
    got = hc.actuate(want)
    assert float(got.v_io) == pytest.approx(0.65, abs=2e-3)
    # far-out-of-envelope requests are still rejected at the mechanism layer
    res = hc.pm.set_voltage(2, 0.2)
    assert not res.ok and "outside" in res.error


def test_host_power_controller_backcompat_shim():
    hc = HostPowerController()
    want = dataclasses.replace(PowerPlaneState.nominal(),
                               v_io=jnp.float32(0.80))
    got = hc.apply(want)
    assert float(got.v_io) == pytest.approx(0.80, abs=2e-3)
    assert hc.actuations == 1 and hc.actuation_seconds > 0
    assert hc.pm.bus.transaction_count >= 6
    # the lazy power_plane import path still resolves
    from repro.core.power_plane import HostPowerController as legacy
    assert legacy is HostPowerController
