"""PowerManager controller characterization tests — pins the paper's
measured numbers (§V): Table VI intervals, Fig 7 transition latency 2.3 ms,
monotone dV->time, opcode->PMBus expansion (Table III), settling detection
(§V-D), and the overhead tables (§V-F)."""

import numpy as np
import pytest

from repro.core import overhead
from repro.core.power_manager import ControlPath, Opcode, PowerManager
from repro.core.settling import settling_time


@pytest.mark.parametrize("path,hz,expect_ms", [
    ("hw", 400_000, 0.2), ("hw", 100_000, 0.6),
    ("sw", 400_000, 0.8), ("sw", 100_000, 1.0),
])
def test_measurement_interval_table_vi(path, hz, expect_ms):
    pm = PowerManager(path=path, clock_hz=hz)
    assert pm.measurement_interval_s() * 1e3 == pytest.approx(expect_ms, rel=0.02)


def test_end_to_end_transition_2p3ms():
    """Paper Fig 7a: HW/400kHz, 1.0 V -> 0.5 V completes in 2.3 ms."""
    pm = PowerManager(path="hw", clock_hz=400_000)
    tr = pm.measure_transition(6, 0.5, duration_s=6e-3)  # MGTAVCC
    lat = tr.end_to_end_latency_s(n=8, band_pct=1.0)
    assert lat * 1e3 == pytest.approx(2.3, abs=0.25)


def test_transition_monotone_in_dv():
    """Paper Fig 7b: larger dV takes longer (HW/400kHz)."""
    lats = []
    for tgt in (0.9, 0.8, 0.7, 0.6, 0.5):
        pm = PowerManager(path="hw", clock_hz=400_000)
        tr = pm.measure_transition(6, tgt, duration_s=6e-3)
        lats.append(tr.end_to_end_latency_s())
    assert all(b >= a for a, b in zip(lats, lats[1:])), lats


def test_sw_path_slower_than_hw():
    lat = {}
    for path in ("hw", "sw"):
        pm = PowerManager(path=path, clock_hz=400_000)
        tr = pm.measure_transition(6, 0.8, duration_s=10e-3)
        lat[path] = tr.end_to_end_latency_s()
    assert lat["sw"] > lat["hw"]


def test_set_voltage_expands_to_six_transactions():
    """Fig 5 prototype workflow: PAGE + UV warn + UV fault + PG on + PG off
    + VOUT_COMMAND = 6 PMBus transactions on first touch of a lane."""
    pm = PowerManager(path="hw", clock_hz=400_000)
    res = pm.set_voltage(9, 0.9)   # the paper's own VCCBRAM example
    assert res.ok
    assert len(res.completions) == 6
    # second set on the same lane: PAGE cached -> 5 transactions (§IV-C)
    res2 = pm.set_voltage(9, 0.95)
    assert len(res2.completions) == 5


def test_opcode_get_voltage_reads_back():
    pm = PowerManager(path="hw", clock_hz=400_000)
    pm.set_voltage(6, 0.85)
    pm.clock.advance(5e-3)
    v = pm.get_voltage(6)
    assert v == pytest.approx(0.85, abs=5e-3)


def test_envelope_rejected_at_mechanism_layer():
    pm = PowerManager(path="hw", clock_hz=400_000)
    res = pm.set_voltage(6, 0.2)   # below MGTAVCC v_min
    assert not res.ok and "outside" in res.error


def test_clear_status_no_pmbus_traffic():
    """Table III: opcode 0x0 is controller-internal (no transaction)."""
    pm = PowerManager(path="hw", clock_hz=400_000)
    before = pm.bus.transaction_count
    res = pm.execute(Opcode.CLEAR_STATUS)
    assert res.ok and pm.bus.transaction_count == before


# -- §V-D settling detection ---------------------------------------------------

def test_settling_detector_basic():
    t = np.linspace(0, 5e-3, 50)
    v = 0.5 + 0.5 * np.exp(-t / 3e-4)
    res = settling_time(t, v, n=8, band_pct=1.0)
    assert res.settled
    assert 0 < res.settling_time_s < 4e-3


def test_settling_detector_robust_to_overshoot():
    t = np.linspace(0, 5e-3, 100)
    v = 0.5 + 0.3 * np.exp(-t / 2e-4) * np.cos(t / 1e-4)  # ringing
    res = settling_time(t, v, n=8, band_pct=1.0)
    assert res.settled
    # overshoot excursions beyond the band must not count as settled
    first_stable = res.t_s_index
    band = res.band_v
    assert np.all(np.abs(v[first_stable:first_stable + 8] - res.v_avg) <= band)


def test_settling_detector_never_settles():
    t = np.linspace(0, 1e-3, 64)
    v = np.where(np.arange(64) % 2 == 0, 1.0, 0.5)  # oscillates forever
    res = settling_time(t, v, n=8, band_pct=1.0)
    assert not res.settled


# -- §V-F overhead tables ---------------------------------------------------------

def test_static_power_ratio_5p6x():
    assert overhead.static_power_ratio() == pytest.approx(5.60, abs=0.01)
    assert overhead.HW_STATIC_TOTAL_W == pytest.approx(0.015)
    assert overhead.SW_STATIC_TOTAL_W == pytest.approx(0.084)


def test_bram_ratio_31p96x():
    assert overhead.bram_ratio() == pytest.approx(31.96, abs=0.01)


def test_controller_budget_check():
    rep = overhead.ControllerOverheadReport(
        path="in_graph", controller_flops_per_step=1e6,
        model_flops_per_step=1e12, controller_bytes_per_step=1e3,
        model_bytes_per_step=1e9, host_seconds_per_step=1e-5,
        step_seconds=0.1)
    assert rep.within_budget(0.02)
