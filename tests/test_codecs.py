"""LINEAR16/LINEAR11 codec tests (paper §IV-B) — exact formats + hypothesis
round-trip properties."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import codecs


def test_linear16_known_values():
    # 2^-12 exponent: 0.9 V -> 3686 LSBs
    assert codecs.linear16_encode(0.9) == round(0.9 * 4096)
    assert codecs.linear16_decode(4096) == 1.0
    assert codecs.linear16_resolution() == pytest.approx(1 / 4096)


def test_linear16_clamps():
    assert codecs.linear16_encode(-1.0) == 0
    assert codecs.linear16_encode(1e9) == 0xFFFF


@given(st.floats(min_value=0.0, max_value=15.0, allow_nan=False))
@settings(max_examples=200)
def test_linear16_roundtrip_within_lsb(v):
    dec = codecs.linear16_decode(codecs.linear16_encode(v))
    assert abs(dec - v) <= codecs.linear16_resolution() / 2 + 1e-12


@given(st.integers(min_value=0, max_value=0xFFFF))
def test_linear16_decode_encode_exact(word):
    assert codecs.linear16_encode(codecs.linear16_decode(word)) == word


@given(st.floats(min_value=-500.0, max_value=500.0, allow_nan=False))
@settings(max_examples=200)
def test_linear11_roundtrip_relative(v):
    word = codecs.linear11_encode(v)
    dec = codecs.linear11_decode(word)
    # 11-bit mantissa: relative error bounded by ~2^-10
    assert abs(dec - v) <= max(abs(v) * 2 ** -9, 2 ** -16 + 1e-12)


@given(st.integers(min_value=0, max_value=0xFFFF))
def test_linear11_word_roundtrip(word):
    v = codecs.linear11_decode(word)
    # re-encoding with the same exponent must reproduce the word
    exp = codecs._twos_complement(word >> 11, 5)
    assert codecs.linear11_encode(v, exponent=exp) == word


def test_word_bytes_le():
    lo, hi = codecs.word_to_bytes_le(0xABCD)
    assert (lo, hi) == (0xCD, 0xAB)
    assert codecs.bytes_le_to_word(lo, hi) == 0xABCD
