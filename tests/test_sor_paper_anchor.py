"""Paper-model cross-check (ROADMAP open item): fit the SOR learner against
`transceiver.GtxLinkModel` sweeps — the *measured* BER including the
deterministic Poisson-ish jitter and the detection floor (zero errors below
~0.5 expected counts) — and assert the learned VDD_IO onset lands within
tolerance of the static Fig 12/14 anchors the model was built from.

The anchor per line rate: the RX BER onset voltage (Fig 12/14) minus the
5 mV transition band, i.e. the voltage where the modeled log10(BER) ramp
reaches the paper's BER <= 1e-6 boundary (Fig 12c: 0.864 V at 10 Gbps —
the operating point behind the headline 29.3% rail-power saving)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sor
from repro.core.telemetry import (FrameHistory, Provenance, RailObservable,
                                  TelemetryFrame)
from repro.core.transceiver import RX_BER_ONSET_V, GtxLinkModel

BER_BOUND = 1e-6          # the paper's bounded-region cut
TOL_V = 0.008             # learned onset within 8 mV of the static anchor

# one fitted rail: VDD_IO (MGTAVCC analogue), frontier cut at BER <= 1e-6
_SPEC = (RailObservable("VDD_IO", "v_io", "grad_error",
                        error_bound=BER_BOUND),)


def _fit_sweep(model: GtxLinkModel, speed: float, v_hi: float, v_lo: float,
               step: float = 0.001) -> sor.SorEstimate:
    """Sweep RX-side voltage (TX at nominal, the §VI-B procedure), push the
    *measured* BER of each point through the learner, fit."""
    vs = np.arange(v_hi, v_lo - 1e-9, -step)
    cfg = sor.SorConfig(capacity=max(32, len(vs)), refresh_every=1,
                        decay=1.0, error_bound=BER_BOUND, guard_v=0.0,
                        min_slope=5.0, rails=_SPEC)
    h = FrameHistory.create(cfg.capacity, rails=_SPEC)
    for v in vs:
        r = model.run_link_test(1.0, float(v), speed)
        h = h.push(TelemetryFrame(grad_error=jnp.float32(r.ber),
                                  v_io=jnp.float32(v),
                                  provenance=Provenance.POLLED))
    return sor.fit_history(h, cfg)


def _anchor(speed: float) -> float:
    """Where the model's Fig-12c ramp meets BER == 1e-6: the static onset
    minus the 5 mV transition band."""
    return RX_BER_ONSET_V[speed] - 0.005


@pytest.mark.parametrize("speed", [10.0, 5.0])
def test_learned_onset_matches_fig12_14_anchor(speed):
    model = GtxLinkModel(seed=0)
    onset = RX_BER_ONSET_V[speed]
    est = _fit_sweep(model, speed, v_hi=onset - 0.001, v_lo=onset - 0.017)
    conf = float(est.confidence[0])
    front = float(est.v_frontier[0])
    assert conf > 0.5, "the sweep must yield a trusted fit"
    assert float(est.slope[0]) < -50.0   # decades/V: a real BER wall
    # the learned frontier lands at the static Fig 12/14 anchor
    assert abs(front - _anchor(speed)) <= TOL_V, (front, _anchor(speed))
    # and below the detection onset: the learner never claims BER <= 1e-6
    # ABOVE the voltage where errors first appear
    assert front < onset


def test_learned_onsets_ordered_like_the_paper():
    """Fig 14: higher line rates need more voltage — the learned onsets
    must come back in the same order as the static anchors."""
    model = GtxLinkModel(seed=0)
    fronts = {}
    for speed in (5.0, 7.5, 10.0):
        onset = RX_BER_ONSET_V[speed]
        est = _fit_sweep(model, speed, v_hi=onset - 0.001,
                         v_lo=onset - 0.017)
        assert float(est.confidence[0]) > 0.5
        fronts[speed] = float(est.v_frontier[0])
    assert fronts[10.0] > fronts[7.5] > fronts[5.0]


def test_detection_floor_points_pull_the_fit_conservatively():
    """Sweeping from ABOVE the onset includes zero-error points (the
    detection floor clamps them at the log floor). They flatten the fitted
    slope, which moves the frontier DOWN (conservative: claims less
    headroom, never more) and must not break the fit."""
    model = GtxLinkModel(seed=0)
    onset = RX_BER_ONSET_V[10.0]
    with_floor = _fit_sweep(model, 10.0, v_hi=onset + 0.006,
                            v_lo=onset - 0.017)
    below_only = _fit_sweep(model, 10.0, v_hi=onset - 0.001,
                            v_lo=onset - 0.017)
    assert float(with_floor.confidence[0]) > 0.5
    assert (float(with_floor.v_frontier[0])
            <= float(below_only.v_frontier[0]) + 1e-6)
    # still anchored: within a widened tolerance of the Fig 12c point
    assert abs(float(with_floor.v_frontier[0]) - _anchor(10.0)) <= 0.012
