"""Error-bounded collective tests: quantization error bounds, error-feedback
contraction (the property that makes the bounded-error region usable),
wire-cost accounting, and hypothesis properties of the codec."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import ecollectives as ec


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (4096,))
    q, s = ec.quantize_int8(x)
    xr = ec.dequantize_int8(q, s, x.shape)
    # per-block absmax scaling: |err| <= scale/2 elementwise
    scales = np.repeat(np.asarray(s)[:, 0], ec.DEFAULT_BLOCK)[: x.size]
    err = np.abs(np.asarray(x) - np.asarray(xr))
    assert np.all(err <= scales / 2 + 1e-7)


@given(st.integers(min_value=1, max_value=1000),
       st.floats(min_value=1e-3, max_value=1e3))
@settings(max_examples=30, deadline=None)
def test_quantize_scale_invariance(n, scale):
    x = jnp.linspace(-1.0, 1.0, n) * scale
    q1, _ = ec.quantize_int8(x)
    q2, _ = ec.quantize_int8(x / scale)
    # int8 codes are scale-invariant up to one ulp of rounding jitter
    assert int(jnp.max(jnp.abs(q1.astype(jnp.int32) - q2.astype(jnp.int32)))) <= 1


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.1, 0.05, 2.0, -0.3] * 32)
    m = ec.topk_mask(x, k_fraction=0.25, block=256)
    kept = np.flatnonzero(np.asarray(m))
    assert len(kept) == 64
    assert np.min(np.abs(np.asarray(x)[kept])) >= 2.0


def test_error_feedback_bounded_over_steps():
    """With EF the residual norm stays bounded (contractive); without EF the
    cumulative dropped mass grows linearly for top-k."""
    key = jax.random.PRNGKey(1)
    g = {"w": jax.random.normal(key, (2048,))}
    resid = ec.zeros_like_residuals(g)
    norms = []
    for i in range(30):
        gi = {"w": g["w"] * (1.0 + 0.01 * i)}
        ghat, resid = ec.ef_compress(gi, resid, ec.LEVEL_INT8_TOPK,
                                     k_fraction=0.25)
        norms.append(float(jnp.linalg.norm(resid["w"])))
    # bounded: last norms shouldn't exceed a small multiple of the first
    assert max(norms[-5:]) < 5.0 * max(norms[:5]) + 1e-6


def test_ef_lossless_passthrough():
    g = {"w": jnp.arange(8.0)}
    r0 = ec.zeros_like_residuals(g)
    ghat, r = ec.ef_compress(g, r0, ec.LEVEL_LOSSLESS)
    assert bool(jnp.all(ghat["w"] == g["w"]))
    assert bool(jnp.all(r["w"] == 0))


def test_wire_cost_ordering():
    lossless = ec.wire_cost(ec.LEVEL_LOSSLESS).bytes_per_element
    int8 = ec.wire_cost(ec.LEVEL_INT8).bytes_per_element
    topk = ec.wire_cost(ec.LEVEL_INT8_TOPK, 0.25).bytes_per_element
    assert lossless > int8 > topk
    assert lossless == 4.0   # 2 passes x bf16
    assert int8 == pytest.approx(1.0, abs=0.05)


def test_compression_error_norm_zero_when_equal():
    g = {"a": jnp.ones((16,))}
    assert float(ec.compression_error_norm(g, g)) == 0.0


def test_psum_int8_single_device():
    """On one device the compressed psum must equal plain quantize-dequant."""
    mesh = jax.make_mesh((1,), ("d",))
    x = jax.random.normal(jax.random.PRNGKey(2), (512,))

    def f(x):
        return ec.psum_int8(x, "d")

    y = jax.shard_map(f, mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
                      out_specs=jax.sharding.PartitionSpec(),
                      check_vma=False)(x)
    q, s = ec.quantize_int8(x)
    expect = ec.dequantize_int8(q, s, x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               rtol=1e-6, atol=1e-7)
