"""Fused in-graph control round coverage (PR 6, docs/sor.md "fused
control round"):

  * bit-equivalence — the fused round (one-pass `ops.sor_fit` kernel +
    `lax.cond`-batched refits) and the unfused PR-5 composition
    (`sor_accumulate` + host-graph solve, refit computed every round and
    off-cadence results discarded by select) produce bit-identical
    SorEstimate / SafeEnvelope / RailRequest trajectories when compiled —
    under a scanned rollout and under jit+vmap;
  * the kernel's sixth output (the envelope floor) is exactly the
    `v_frontier + guard` f32 add `rail_envelopes` re-derives;
  * the Pallas `sor_fit` body in interpret mode matches the jnp reference
    through the real `ops.sor_fit` dispatch (REPRO_PALLAS=interpret);
  * deadband actuation scheduling — steady-state envelope-pinned lanes are
    held back from the PMBus round (and counted), boundary cases actuate;
  * `ops.sharded_fleet_reduce` falls back cleanly on a single-device CPU
    mesh, the forced shard_map path agrees, and `FleetStepConfig.mesh`
    plumbs through the fleet train step without changing results.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sor
from repro.core.control_plane import HostRailController, InGraphRailController
from repro.core.hwspec import FleetSpec
from repro.core.policy import MultiRailClosedLoop
from repro.core.power_plane import (PowerPlaneState, StepProfile,
                                    account_fleet_and_observe)
from repro.core.rails import TPU_V5E_RAIL_MAP
from repro.core.telemetry import (ALL_RAIL_OBSERVABLES, FrameHistory,
                                  Provenance, TelemetryFrame)
from repro.kernels import fleet_telemetry, ops, ref

N = 8
STEPS = 12
BOUND = 5e-3
CFG = sor.SorConfig(capacity=16, refresh_every=4, decay=0.96,
                    error_bound=BOUND, guard_v=0.004, max_extension_v=0.12,
                    ingest="frames", rails=ALL_RAIL_OBSERVABLES)
FLOORS = {"VDD_CORE": 0.70, "VDD_HBM": 1.00, "VDD_IO": 0.70}
ONSETS = {"VDD_CORE": 0.598, "VDD_HBM": 0.878, "VDD_IO": 0.62}
PROFILE = StepProfile(flops_per_chip=2e12, hbm_bytes_per_chip=8e9,
                      ici_bytes_per_chip=4e9, grad_bytes_per_chip=3e9)

SOLVE_KW = dict(min_slope=CFG.min_slope, min_spread_v=CFG.min_spread_v,
                conf_samples=CFG.conf_samples)


def _frontier_err(v, onset, k, n):
    noise = 1.0 + 0.05 * jax.random.normal(k, (n,))
    return BOUND * noise * 10.0 ** jnp.clip(30.0 * (onset - v), -6.0, 3.0)


def _rollout(fused: bool, n: int = N, steps: int = STEPS):
    """One compiled learned-control rollout; `fused` selects the round's
    graph. Returns (final SorState, per-step trajectory dict)."""
    ctrl = InGraphRailController(MultiRailClosedLoop(floors=dict(FLOORS)),
                                 sor=CFG)
    fs = FleetSpec.sample(n, seed=17)

    def round_fn(carry, k):
        plane, ss = carry
        plane, frame, _ = account_fleet_and_observe(PROFILE, plane, fs)
        k1, k2, k3 = jax.random.split(k, 3)
        frame = dataclasses.replace(
            frame,
            grad_error=_frontier_err(plane.v_io, ONSETS["VDD_IO"], k1, n),
            extras={**frame.extras,
                    "straggle_rate": _frontier_err(
                        plane.v_core, ONSETS["VDD_CORE"], k2, n),
                    "hbm_error_rate": _frontier_err(
                        plane.v_hbm, ONSETS["VDD_HBM"], k3, n)})
        plane, ss, req, env = ctrl.control_round(plane, frame, ss,
                                                 fused=fused)
        out = {"v_core": plane.v_core, "v_hbm": plane.v_hbm,
               "v_io": plane.v_io,
               "req_core": req.v_core, "req_hbm": req.v_hbm,
               "req_io": req.v_io,
               "floor_io": env["VDD_IO"].floor(
                   TPU_V5E_RAIL_MAP.by_name("VDD_IO").v_min),
               "conf_io": env["VDD_IO"].confidence}
        return (plane, ss), out

    @jax.jit
    def run():
        keys = jax.random.split(jax.random.PRNGKey(5), steps)
        plane = PowerPlaneState.from_fleet(fs)
        ss = sor.init_state(CFG, n)
        (plane, ss), hist = jax.lax.scan(round_fn, (plane, ss), keys)
        return ss, hist

    ss, hist = run()
    jax.block_until_ready(hist["v_io"])
    return ss, hist


def test_fused_trajectory_bit_equal_to_unfused():
    """The acceptance pin: the fused round is an OPTIMIZATION, not a new
    estimator — plane voltages, pre-arbitration RailRequests, envelope
    floors/confidences, and every SorEstimate field match the unfused
    PR-5 composition bit-for-bit across a scanned rollout (several refit
    cadences deep, fleet-shaped)."""
    ss_f, h_f = _rollout(fused=True)
    ss_u, h_u = _rollout(fused=False)
    for key in h_f:
        np.testing.assert_array_equal(np.asarray(h_f[key]),
                                      np.asarray(h_u[key]), err_msg=key)
    for field in ("intercept", "slope", "v_frontier", "confidence", "n_eff"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ss_f.estimate, field)),
            np.asarray(getattr(ss_u.estimate, field)), err_msg=field)
    assert int(ss_f.tick) == int(ss_u.tick) == STEPS


def _filled_history(n: int, onset_shift: float) -> FrameHistory:
    h = FrameHistory.create(CFG.capacity, n, rails=CFG.rails)
    for i, v in enumerate(np.linspace(0.62, 0.80, 10)):
        vv = jnp.full((n,), float(v), jnp.float32)
        k = jax.random.PRNGKey(i)
        ks = jax.random.split(k, 3)
        h = h.push(TelemetryFrame(
            grad_error=_frontier_err(vv, ONSETS["VDD_IO"] + onset_shift,
                                     ks[0], n),
            v_io=vv, v_core=vv, v_hbm=vv, age_s=jnp.zeros((n,)),
            extras={"straggle_rate": _frontier_err(
                        vv, ONSETS["VDD_CORE"] + onset_shift, ks[1], n),
                    "hbm_error_rate": _frontier_err(
                        vv, ONSETS["VDD_HBM"] + onset_shift, ks[2], n)},
            provenance=Provenance.POLLED))
    return h


def test_fused_fit_bit_equal_under_jit_vmap():
    """fit_history(fused=True) == fit_history(fused=False) bit-for-bit when
    both compile — including through a vmap over a batch of histories."""
    hb = jax.tree_util.tree_map(
        lambda *a: jnp.stack(a),
        _filled_history(N, 0.0), _filled_history(N, 0.01))
    est_f = jax.jit(jax.vmap(
        lambda h: sor.fit_history(h, CFG, fused=True)))(hb)
    est_u = jax.jit(jax.vmap(
        lambda h: sor.fit_history(h, CFG, fused=False)))(hb)
    for field in ("intercept", "slope", "v_frontier", "confidence", "n_eff"):
        got = np.asarray(getattr(est_f, field))
        assert got.shape[:1] == (2,)
        np.testing.assert_array_equal(got, np.asarray(getattr(est_u, field)),
                                      err_msg=field)
    # the fit found a real frontier in at least one lane
    assert (np.asarray(est_f.confidence) > 0).any()


def _solve_inputs(window: int, n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.6, 1.0, (window, n)).astype(np.float32)
    y = (-3.0 + 30.0 * (0.62 - x) + 0.1
         * rng.standard_normal((window, n))).astype(np.float32)
    w = rng.uniform(0.0, 1.0, (window, n)).astype(np.float32)
    bound = np.full((n,), np.log10(BOUND), np.float32)
    guard = np.full((n,), CFG.guard_v, np.float32)
    return x, y, w, bound, guard


def test_kernel_floor_output_matches_rail_envelopes():
    """The fused pass's sixth output (the envelope floor) is exactly the
    `v_frontier + guard` f32 add that `rail_envelopes` re-derives —
    SorEstimate can keep its 5-field checkpoint layout with nothing lost."""
    x, y, w, bound, guard = _solve_inputs(12, 40)
    outs = ref.sor_fit_reference(jnp.asarray(x), jnp.asarray(y),
                                 jnp.asarray(w), jnp.asarray(bound),
                                 jnp.asarray(guard), **SOLVE_KW)
    _, _, v_frontier, _, _, floor = outs
    np.testing.assert_array_equal(
        np.asarray(floor),
        np.asarray(v_frontier + jnp.asarray(guard, jnp.float32)))


@pytest.mark.parametrize("window,n", [(12, 5), (16, 128), (9, 131)])
def test_sor_fit_kernel_interpret_matches_reference(window, n):
    """The Pallas fused-fit body (run in interpret mode on CPU) matches the
    jnp reference across lane/sublane padding boundaries."""
    x, y, w, bound, guard = _solve_inputs(window, n, seed=window + n)
    want = ref.sor_fit_reference(jnp.asarray(x), jnp.asarray(y),
                                 jnp.asarray(w), jnp.asarray(bound),
                                 jnp.asarray(guard), **SOLVE_KW)
    got = fleet_telemetry.sor_fit(jnp.asarray(x), jnp.asarray(y),
                                  jnp.asarray(w), jnp.asarray(bound),
                                  jnp.asarray(guard), **SOLVE_KW,
                                  interpret=True)
    names = ("intercept", "slope", "v_frontier", "confidence", "n_eff",
             "floor")
    for name, a, b in zip(names, got, want):
        assert a.shape == (n,)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5, err_msg=name)


def test_ops_sor_fit_dispatch_interpret_mode(monkeypatch):
    """REPRO_PALLAS=interpret routes `ops.sor_fit` through the Pallas body
    (odd shapes force a fresh trace so the env is actually consulted)."""
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    x, y, w, bound, guard = _solve_inputs(11, 97, seed=3)
    got = ops.sor_fit(jnp.asarray(x), jnp.asarray(y), jnp.asarray(w),
                      jnp.asarray(bound), jnp.asarray(guard), **SOLVE_KW)
    want = ref.sor_fit_reference(jnp.asarray(x), jnp.asarray(y),
                                 jnp.asarray(w), jnp.asarray(bound),
                                 jnp.asarray(guard), **SOLVE_KW)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# deadband actuation scheduling
# ---------------------------------------------------------------------------

def _deadband_controller(n: int, conf: float, deadband_v: float = 0.01):
    hc = HostRailController(n_chips=n, deadband_v=deadband_v)
    s = TPU_V5E_RAIL_MAP.by_name("VDD_IO")
    floor = np.float32(s.v_min + 0.02)
    hc.last_envelope = {"VDD_IO": sor.SafeEnvelope(
        v_min=jnp.float32(floor), confidence=jnp.full((n,), conf),
        max_extension_v=0.12, rail="VDD_IO")}
    return hc, float(floor)


def test_deadband_skips_steady_state_envelope_pinned_lane():
    """Chip 0 sits inside the confidence-scaled deadband of its learned
    floor; chip 1 sits well outside. After one settling round, re-actuating
    the same targets skips chip 0's VDD_IO write (held + counted) and still
    pushes chip 1 through the bus."""
    n = 2
    hc, floor = _deadband_controller(n, conf=1.0)
    plane = PowerPlaneState.from_fleet(FleetSpec.sample(n, seed=0))
    plane = dataclasses.replace(
        plane, v_io=jnp.asarray([floor + 0.004, floor + 0.05], jnp.float32))
    plane = hc.actuate(plane)          # settle: regulators now hold targets
    assert hc.skipped_actuations == 0  # cold regulators: every lane written
    out = hc.actuate(plane)
    assert hc.skipped_actuations == 1  # chip 0 steady inside the band
    assert hc.stats().skipped_actuations == hc.skipped_actuations
    # the skipped lane reads back the regulator-held voltage, unchanged
    np.testing.assert_allclose(float(out.v_io[0]), floor + 0.004, atol=2e-3)
    np.testing.assert_allclose(float(out.v_io[1]), floor + 0.05, atol=2e-3)


def test_deadband_boundary_cases_actuate():
    """Zero confidence, zero deadband, or a missing envelope: nothing is
    ever held back — cold start actuates every lane exactly as before."""
    n = 2
    plane = PowerPlaneState.from_fleet(FleetSpec.sample(n, seed=0))
    s = TPU_V5E_RAIL_MAP.by_name("VDD_IO")
    plane = dataclasses.replace(
        plane, v_io=jnp.full((n,), s.v_min + 0.02, jnp.float32))

    hc, _ = _deadband_controller(n, conf=0.0)      # no confidence yet
    hc.actuate(plane)
    hc.actuate(plane)
    assert hc.skipped_actuations == 0

    hc2, _ = _deadband_controller(n, conf=1.0, deadband_v=0.0)  # disabled
    hc2.actuate(plane)
    hc2.actuate(plane)
    assert hc2.skipped_actuations == 0

    hc3 = HostRailController(n_chips=n, deadband_v=0.01)  # never decided
    assert hc3.last_envelope is None
    hc3.actuate(plane)
    hc3.actuate(plane)
    assert hc3.skipped_actuations == 0


def test_fleet_report_counts_hardware_deadband_separately():
    """The bus-level write deadband (regulator already AT the request) is
    counted in FleetActuationReport.deadband_skipped — distinct from the
    controller's envelope-aware scheduling."""
    n = 2
    hc = HostRailController(n_chips=n)
    plane = PowerPlaneState.from_fleet(FleetSpec.sample(n, seed=0))
    hc.actuate(plane)
    hc.actuate(plane)                  # identical round: all lanes settled
    rep = hc.last_report
    assert rep.deadband_skipped > 0
    assert hc.fleet.deadband_skips >= rep.deadband_skipped
    assert hc.skipped_actuations == 0  # no envelope: scheduler never held


# ---------------------------------------------------------------------------
# sharded fleet reduction
# ---------------------------------------------------------------------------

def test_sharded_fleet_reduce_single_device_fallback():
    from jax.sharding import Mesh
    x = jnp.asarray(np.random.default_rng(1).normal(size=(16, 3)),
                    jnp.float32)
    want = ops.fleet_reduce(x)
    mesh = Mesh(np.array(jax.devices()[:1]), ("chips",))
    got = ops.sharded_fleet_reduce(x, mesh=mesh)       # guard: falls back
    forced = ops.sharded_fleet_reduce(x, mesh=mesh,     # collective path
                                      use_shard_map=True)
    for a, b, c in zip(want, got, forced):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-6, atol=1e-6)
    with pytest.raises(ValueError, match="mesh"):
        ops.sharded_fleet_reduce(x, mesh=None, use_shard_map=True)


@pytest.mark.slow
def test_fleet_step_mesh_smoke():
    """FleetStepConfig.mesh on a single-device CPU mesh: the step builds,
    runs, and matches the mesh=None fallback bit-for-bit (the guard routes
    both through the same fleet_reduce graph)."""
    from jax.sharding import Mesh
    from repro.configs import get_config
    from repro.models import registry
    from repro.optim import adamw
    from repro.optim.schedule import wsd
    from repro.train.step import (FleetStepConfig, StepConfig,
                                  jit_train_step, make_fleet_train_step)
    from repro.train.trainer import initial_plane_and_ef
    from repro.data.pipeline import SyntheticLM, DataConfig

    cfg_m = get_config("minicpm_2b", tiny=True)
    api = registry.build(cfg_m, remat="none")
    params = api.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(grad_clip_norm=1.0)
    sched = lambda s: wsd(s, peak_lr=1e-3, warmup_steps=2, stable_steps=50,
                          decay_steps=50)
    n = 3
    fs = FleetSpec.sample(n, seed=7)
    data = SyntheticLM(DataConfig(vocab_size=cfg_m.vocab_size, seq_len=32,
                                  global_batch=4, seed=0))
    mesh = Mesh(np.array(jax.devices()[:1]), ("chips",))

    def run(mesh_arg):
        fleet_cfg = FleetStepConfig(spec=fs, hbm_error_base=1e-4,
                                    mesh=mesh_arg)
        step = jit_train_step(
            make_fleet_train_step(lambda p, b: api.loss_fn(p, b), opt_cfg,
                                  sched, PROFILE,
                                  StepConfig(policy=MultiRailClosedLoop()),
                                  fleet_cfg),
            donate=False)
        p, opt = params, adamw.init_state(params, opt_cfg)
        plane, ef = initial_plane_and_ef(p, fleet=fs)
        for i in range(2):
            p, opt, plane, ef, metrics = step(p, opt, plane, ef,
                                              data.jax_batch(i))
        return plane, metrics

    plane_m, metrics_m = run(mesh)
    plane_0, metrics_0 = run(None)
    np.testing.assert_array_equal(np.asarray(plane_m.v_io),
                                  np.asarray(plane_0.v_io))
    np.testing.assert_array_equal(float(metrics_m["loss"]),
                                  float(metrics_0["loss"]))
    for k in ("fleet/power_max_w", "fleet/power_sum_w"):
        if k in metrics_m:
            np.testing.assert_array_equal(float(metrics_m[k]),
                                          float(metrics_0[k]))
