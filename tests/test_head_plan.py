"""Head-padding planner: invariants (hypothesis) + numeric exactness of the
padded attention vs an unpadded reference."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.models import common
from repro.models.attention import AttnSpec, attention_full, init_attention
from repro.models.common import plan_head_padding


@given(st.sampled_from([1, 2, 4, 8, 16]),
       st.integers(min_value=1, max_value=16))
@settings(max_examples=100, deadline=None)
def test_plan_invariants(tp, n_kv):
    for mult in (1, 2, 3, 5, 6, 12):
        n_q = n_kv * mult
        plan = plan_head_padding(n_q, n_kv, tp)
        assert plan.n_q_pad % tp == 0
        assert plan.n_kv_pad % tp == 0
        assert plan.n_q_pad % plan.n_kv_pad == 0
        g = plan.group
        # every original q head appears exactly once
        srcs = [s for s in plan.q_src if s >= 0]
        assert sorted(srcs) == list(range(n_q))
        # mapping consistency: q slot i maps to kv slot i//g whose source is
        # the original kv head of q_src[i]
        for i, qs in enumerate(plan.q_src):
            if qs < 0:
                continue
            kv_slot = i // g
            assert plan.kv_src[kv_slot] == qs // (n_q // n_kv)


@pytest.mark.parametrize("n_q,n_kv,tp", [
    (40, 8, 16),   # qwen2.5
    (48, 1, 16),   # granite MQA
    (96, 8, 16),   # mistral
    (36, 36, 16),  # minicpm MHA
    (8, 8, 16),    # whisper
    (32, 4, 16),   # qwen3-moe
])
def test_padded_attention_matches_unpadded(n_q, n_kv, tp):
    """The padded layout must be numerically identical to the original."""
    D, Dh, B, T = 64, 16, 2, 32
    key = jax.random.PRNGKey(0)
    plan_pad = plan_head_padding(n_q, n_kv, tp)
    plan_ref = plan_head_padding(n_q, n_kv, 1)
    assert plan_ref.n_q_pad == n_q and plan_ref.n_kv_pad == n_kv

    spec_ref = AttnSpec(d_model=D, head_dim=Dh, plan=plan_ref)
    p_ref = init_attention(key, spec_ref, jnp.float32)

    # construct the padded params from the reference via the plan
    spec_pad = AttnSpec(d_model=D, head_dim=Dh, plan=plan_pad)
    q_src = np.asarray(plan_pad.q_src)
    kv_src = np.asarray(plan_pad.kv_src)
    take_q = lambda w, axis: (jnp.take(w, jnp.asarray(np.maximum(q_src, 0)),
                                       axis=axis)
                              * jnp.asarray(q_src >= 0, w.dtype)
                              .reshape((-1,) + (1,) * (w.ndim - 1 - axis)))
    p_pad = {
        "wq": jnp.take(p_ref["wq"], jnp.asarray(np.maximum(q_src, 0)), axis=1)
        * jnp.asarray(q_src >= 0, jnp.float32)[None, :, None],
        "wk": jnp.take(p_ref["wk"], jnp.asarray(np.maximum(kv_src, 0)), axis=1)
        * jnp.asarray(kv_src >= 0, jnp.float32)[None, :, None],
        "wv": jnp.take(p_ref["wv"], jnp.asarray(np.maximum(kv_src, 0)), axis=1)
        * jnp.asarray(kv_src >= 0, jnp.float32)[None, :, None],
        "wo": jnp.take(p_ref["wo"], jnp.asarray(np.maximum(q_src, 0)), axis=0)
        * jnp.asarray(q_src >= 0, jnp.float32)[:, None, None],
    }

    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, D), jnp.float32)
    y_ref, _ = attention_full(p_ref, x, spec_ref, use_flash=False)
    y_pad, _ = attention_full(p_pad, x, spec_pad, use_flash=False)
    np.testing.assert_allclose(np.asarray(y_pad), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
