"""Continuous batching + in-flight migration tests (docs/serve.md
"continuous batching & migration"):

  * rate model — `batched_lane_time_s` at b=1 is BITWISE `step_time_s`
    (every scale factor is exactly 1.0f), monotone in lanes, and inert
    when every roofline term is fully shared;
  * batch-cap=1 oracle — a `batch_cap=1`, migration-off engine reproduces
    the PR-9 fused ledger bit-equal on BOTH routers (same tick graph by
    construction: `batch_cap=1` never builds the batched rows);
  * batched throughput — on a memory-bound decode profile a cap=4 fleet
    drains the same backlog in strictly fewer ticks than cap=1 (the
    shared-HBM amortization the bench measures at scale);
  * migration planner — `plan_migration` picks deepest-headroom eligible
    chips, never pinned/excluded/full ones, spreads an evacuation by
    advancing occupancy, and is best-effort (None entries do not block);
  * migration ledger — the "migrated" lifecycle event moves the record's
    chip, accumulates stall, and guards against unplaced/finished/
    wrong-source/self moves;
  * migrate vs drain — on the warmed bench world under saturating load,
    `migrate_after_ticks=K` strictly reduces degraded chip-ticks vs
    drain_pinned-only and every completed migrated request ends on its
    final destination chip;
  * validation — batching/migration knob misuse fails loudly in the
    engine and the serve launcher;
  * fast-forward — an arrival strictly inside a skipped idle gap (off the
    tick grid) is re-entered at the same tick the walked run reaches.
"""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hwspec import FleetSpec
from repro.core.power_plane import (BatchShares, PowerPlaneState,
                                    batched_lane_time_s, step_terms,
                                    step_time_s)
from repro.serve.router import (HeadroomRouter, RequestLedger,
                                RoundRobinRouter)
from repro.serve.traffic import Request, bursty_trace, steady_trace

from benchmarks import serve_batching as sb
from benchmarks import serve_router as sr
from tests.test_serve_scale import (_assert_analog_close,
                                    _bench_world_engine, _discrete, _mesh,
                                    _tiny_engine, multi_device)


# -- the batched lane-rate model ----------------------------------------------

def test_lane_time_b1_bitwise_equals_step_time():
    """At b=1 every per-term scale factor is exactly (1 + share*0) = 1.0f,
    so the recombination is the SAME f32 arithmetic as step_time_s — the
    identity the batch-cap=1 ledger oracle rests on."""
    fs = FleetSpec.sample(6, seed=sr.SEED)
    plane = PowerPlaneState.from_fleet(fs)
    var = fs.variation()
    tc, tm, tl = step_terms(sr.PROFILE, plane, variation=var)
    lane = batched_lane_time_s(tc, tm, tl, jnp.ones(6, jnp.float32))
    np.testing.assert_array_equal(
        np.asarray(lane), np.asarray(step_time_s(sr.PROFILE, plane,
                                                 variation=var)))


def test_lane_time_monotone_and_sublinear():
    tc = jnp.float32(0.001)
    tm = jnp.float32(0.010)
    tl = jnp.float32(0.006)
    prev = None
    for b in (1, 2, 4, 8, 16):
        t = float(batched_lane_time_s(tc, tm, tl, b))
        if prev is not None:
            assert t > prev[1]                      # more lanes, slower lane
            # ...but sublinearly: chip throughput b/t keeps growing while
            # a shared term dominates
            assert b / t > prev[0] / prev[1]
        prev = (b, t)


def test_lane_time_fully_shared_terms_are_free():
    """shares=1.0 everywhere: one copy of the work serves every lane, so
    the lane time must not move with b at all."""
    shares = BatchShares(flops=1.0, hbm=1.0, ici=1.0)
    tc, tm, tl = (jnp.float32(x) for x in (0.002, 0.010, 0.006))
    t1 = float(batched_lane_time_s(tc, tm, tl, 1, shares))
    t16 = float(batched_lane_time_s(tc, tm, tl, 16, shares))
    assert t1 == t16 == pytest.approx(0.010)


# -- batch-cap=1 + migration-off: the PR-9 ledger bit-equality oracle ---------

@pytest.mark.parametrize("make_router", [
    lambda: HeadroomRouter(capacity=1),
    lambda: RoundRobinRouter(capacity=1),
], ids=["headroom", "roundrobin"])
def test_batch_cap_one_bit_equal_to_unbatched_fused(make_router):
    """batch_cap=1 must reproduce the PR-9 fused path's ledger bit-equal:
    the engine never builds the batched tick rows at cap 1, so both runs
    execute the SAME jitted program — discrete ledger AND analog state
    are exactly equal, not merely close."""
    trace = bursty_trace(16, seed=sr.SEED, quiet_rate_hz=8.0,
                         burst_rate_hz=40.0, decode_mean=48.0)
    runs = {}
    for cap in (None, 1):
        eng, observe = _bench_world_engine(make_router(), n_chips=6,
                                           batch_cap=cap)
        led = eng.serve_trace(trace, observe=observe, max_ticks=900,
                              error_bound=sr.ERROR_BOUND)
        runs[cap] = (eng, led)
    eng_n, led_n = runs[None]
    eng_1, led_1 = runs[1]
    assert not eng_1._batched and eng_1.last_trace["batch_cap"] == 1
    assert eng_1.last_trace["migrations"] == 0
    assert _discrete(eng_n, led_n) == _discrete(eng_1, led_1)
    assert led_n.fleet_energy_j == led_1.fleet_energy_j
    for ra, rb in zip(led_n.records(), led_1.records()):
        assert ra.energy_j == rb.energy_j
    for field in ("v_core", "v_hbm", "v_io", "energy_j"):
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(getattr(eng_n.plane, field))),
            np.asarray(jax.device_get(getattr(eng_1.plane, field))),
            err_msg=field)


# -- batched throughput on a memory-bound decode profile ----------------------

def test_batched_backlog_drains_in_fewer_ticks():
    """A pure backlog (every request at t=0) on the bench's decode-shaped
    profile: the cap=4 fleet must finish in strictly fewer ticks than the
    cap=1 fleet — the weights-read amortization continuous batching is
    for. No observe world: this isolates the lane-rate model from the
    pinning dynamics the migration tests cover."""
    trace = steady_trace(16, rate_hz=1e9, prefill_tokens=8,
                         decode_tokens=48)
    ticks = {}
    for cap in (1, 4):
        fs = FleetSpec.sample(4, seed=sr.SEED)
        eng = _tiny_engine(fleet=fs, router=HeadroomRouter(capacity=cap),
                           batch_cap=cap,
                           decode_profile=sb.DECODE_PROFILE)
        led = eng.serve_trace(trace, max_ticks=3000)
        assert led.summary()["completed"] == 16
        ticks[cap] = eng.last_trace["ticks"]
    assert ticks[4] < ticks[1]
    # the gain is real amortization, not a rounding artifact
    assert ticks[1] / ticks[4] > 1.5


def test_steady_trace_is_deterministic_and_even():
    tr = steady_trace(5, rate_hz=10.0, t_start_s=1.0, prefill_tokens=4,
                      decode_tokens=16)
    assert [r.t_arrival_s for r in tr.requests] == [
        pytest.approx(1.0 + i / 10.0) for i in range(5)]
    assert all(r.prefill_tokens == 4 and r.decode_tokens == 16
               for r in tr.requests)
    assert tr.metadata["kind"] == "steady"


# -- the migration planner ----------------------------------------------------

def _hr(core, hbm, io):
    return {"VDD_CORE": np.asarray(core, np.float64),
            "VDD_HBM": np.asarray(hbm, np.float64),
            "VDD_IO": np.asarray(io, np.float64)}


def _dreq(rid, decode=32, prefill=0):
    return Request(rid=rid, t_arrival_s=0.0, prefill_tokens=prefill,
                   decode_tokens=decode)


def test_plan_migration_prefers_deepest_headroom_skips_hot_chips():
    r = HeadroomRouter(capacity=2)
    occ = np.array([2, 0, 0, 0])
    hr = _hr([0.3, 0.01, 0.2, 0.1], [0.3, 0.01, 0.2, 0.1],
             [0.3, 0.01, 0.2, 0.1])
    exclude = np.array([True, False, False, False])
    dests = r.plan_migration([_dreq(0), _dreq(1), _dreq(2)], occ, hr,
                             exclude=exclude)
    # deepest headroom first (chip 2), occupancy advances: 2, 2, then 3
    assert dests == [2, 2, 3]


def test_plan_migration_never_targets_pinned_even_with_drain_off():
    r = HeadroomRouter(capacity=4, drain_pinned=False)
    occ = np.array([0, 0])
    hr = _hr([0.5, 0.1], [0.5, 0.1], [0.5, 0.1])
    pinned = np.array([True, False])
    # chip 0 has far deeper headroom but is pinned: parking evacuated work
    # there would recreate the problem being solved
    assert r.plan_migration([_dreq(0)], occ, hr, pinned=pinned) == [1]


def test_plan_migration_best_effort_does_not_block():
    r = HeadroomRouter(capacity=1)
    occ = np.array([1, 0])
    hr = _hr([0.1, 0.2], [0.1, 0.2], [0.1, 0.2])
    # one free lane for two evacuees: first takes it, second gets None,
    # and a third request (nothing left) also gets None — no head-of-line
    # blocking, unlike place_batch
    dests = r.plan_migration([_dreq(0), _dreq(1), _dreq(2)], occ, hr)
    assert dests == [1, None, None]


def test_plan_migration_empty_and_roundrobin_has_no_planner():
    assert HeadroomRouter(capacity=2).plan_migration([], [0], _hr([0.1],
                                                    [0.1], [0.1])) == []
    assert not hasattr(RoundRobinRouter(capacity=2), "plan_migration")


# -- the "migrated" lifecycle event -------------------------------------------

def test_ledger_migrate_moves_chip_and_accumulates_stall():
    led = RequestLedger()
    led.admit(_dreq(0, decode=32), 0.0)
    led.place(0, 0.5, chip=3)
    led.migrate(0, 1.0, src=3, dst=1, stall_s=0.04, src_streak=6)
    led.migrate(0, 2.0, src=1, dst=2, stall_s=0.02, src_streak=7)
    led.finish(0, 3.0, tokens_out=32)
    rec = led.records()[0]
    assert rec.chip == 2 and rec.migrations == 2
    assert rec.stall_time_s == pytest.approx(0.06)
    assert [e["src"] for e in led.migration_events] == [3, 1]
    assert led.migration_events[0]["src_streak"] == 6
    s = led.summary()
    assert s["migrations"] == 2
    assert s["migration_stall_s"] == pytest.approx(0.06)


def test_ledger_migrate_guards():
    led = RequestLedger()
    led.admit(_dreq(0), 0.0)
    with pytest.raises(ValueError, match="before placement"):
        led.migrate(0, 1.0, src=0, dst=1)
    led.place(0, 0.5, chip=0)
    with pytest.raises(ValueError, match="not the claimed source"):
        led.migrate(0, 1.0, src=2, dst=1)
    with pytest.raises(ValueError, match="source == destination"):
        led.migrate(0, 1.0, src=0, dst=0)
    led.finish(0, 2.0, tokens_out=8)
    with pytest.raises(ValueError, match="after completion"):
        led.migrate(0, 3.0, src=0, dst=1)


# -- migrate vs drain on the warmed bench world -------------------------------

def _warmed_bench_run(n_chips, cap, trace, migrate_after_ticks):
    eng, observe = _bench_world_engine(HeadroomRouter(capacity=cap),
                                       n_chips=n_chips, batch_cap=cap,
                                       decode_profile=sb.DECODE_PROFILE)
    sb._warm(eng, observe, n_chips)
    led = eng.serve_trace(trace, observe=observe, max_ticks=4000,
                          error_bound=sr.ERROR_BOUND,
                          migrate_after_ticks=migrate_after_ticks)
    return eng, led


def test_migration_strictly_reduces_degraded_chip_ticks():
    """The bench's forced-pin scenario at test scale: saturating load on
    the load-coupled-onset world makes busy chips re-cross the error
    bound and sit degraded; migration must actually fire AND strictly
    reduce degraded chip-ticks vs letting pinned chips drain."""
    n, cap = 8, 4
    trace = bursty_trace(96, seed=sr.SEED, quiet_rate_hz=16.0,
                         burst_rate_hz=80.0, decode_mean=96.0)
    runs = {a: _warmed_bench_run(n, cap, trace, k)
            for a, k in (("migrate", 6), ("drain", None))}
    eng_m, led_m = runs["migrate"]
    eng_d, led_d = runs["drain"]
    assert eng_m.last_trace["migrations"] > 0
    assert eng_d.last_trace["migrations"] == 0
    assert led_d.summary()["migrations"] == 0
    assert (eng_m.last_trace["degraded_chip_ticks"]
            < eng_d.last_trace["degraded_chip_ticks"])
    # both arms still finish the whole trace
    assert led_m.summary()["completed"] == led_d.summary()["completed"] \
        == 96
    # lifecycle consistency: every migrated request's record ends on the
    # destination of its LAST migration event, pays its stall, and the
    # event stream never self-moves
    by_rid = {}
    for e in led_m.migration_events:
        assert e["src"] != e["dst"]
        assert e["src_streak"] >= 6
        by_rid[e["rid"]] = e
    assert by_rid
    recs = {r.rid: r for r in led_m.records()}
    for rid, e in by_rid.items():
        assert recs[rid].migrations >= 1
        assert recs[rid].stall_time_s > 0.0
        assert recs[rid].chip == e["dst"]
    s = led_m.summary()
    assert s["migrations"] == len(led_m.migration_events)
    assert s["migration_stall_s"] == pytest.approx(
        sum(e["stall_s"] for e in led_m.migration_events))


# -- validation ---------------------------------------------------------------

def test_engine_batching_validation_errors():
    fs = FleetSpec.sample(2, seed=5)
    with pytest.raises(ValueError, match="router"):
        _tiny_engine(fleet=fs, batch_cap=2)
    with pytest.raises(ValueError, match=">= 1"):
        _tiny_engine(fleet=fs, router=HeadroomRouter(capacity=2),
                     batch_cap=0)
    with pytest.raises(ValueError, match="must equal the router"):
        _tiny_engine(fleet=fs, router=HeadroomRouter(capacity=3),
                     batch_cap=2)
    with pytest.raises(ValueError, match="batch_cap"):
        _tiny_engine(fleet=fs, router=HeadroomRouter(capacity=2),
                     batch_shares=BatchShares())


def test_serve_trace_batching_validation_errors():
    fs = FleetSpec.sample(2, seed=5)
    eng = _tiny_engine(fleet=fs, router=HeadroomRouter(capacity=2),
                       batch_cap=2)
    with pytest.raises(ValueError, match="batch-cap=1 semantics oracle"):
        eng.serve_trace(bursty_trace(3, seed=2), max_ticks=10,
                        fused=False)
    with pytest.raises(ValueError, match=">= 1"):
        eng.serve_trace(bursty_trace(3, seed=2), max_ticks=10,
                        migrate_after_ticks=0)
    with pytest.raises(ValueError, match="migration rides the fused"):
        eng2 = _tiny_engine(fleet=fs, router=HeadroomRouter(capacity=2))
        eng2.serve_trace(bursty_trace(3, seed=2), max_ticks=10,
                         fused=False, migrate_after_ticks=3)
    with pytest.raises(ValueError, match="migration planner"):
        eng3 = _tiny_engine(fleet=fs, router=RoundRobinRouter(capacity=2))
        eng3.serve_trace(bursty_trace(3, seed=2), max_ticks=10,
                         migrate_after_ticks=3)


def _launch(*extra):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "minicpm_2b", "--tiny", *extra],
        capture_output=True, text=True, timeout=120)


def test_launcher_rejects_bad_batching_flags():
    """argparse-level validation fires before any model build, so these
    subprocesses are cheap."""
    r = _launch("--batch-cap", "2")
    assert r.returncode == 2 and "--router" in r.stderr
    r = _launch("--fleet-chips", "4", "--router", "roundrobin",
                "--migrate-after-ticks", "3")
    assert r.returncode == 2 and "headroom" in r.stderr
    r = _launch("--batch-cap", "-1")
    assert r.returncode == 2 and ">= 0" in r.stderr


# -- fast-forward: arrival strictly inside the skipped gap --------------------

def test_fast_forward_arrival_inside_gap_re_enters_on_time():
    """The second arrival lands OFF the tick grid, strictly inside the
    idle gap the fast-forward jump spans: the jump must re-enter at the
    first tick >= the arrival (never skip past it), reproducing the
    walked run's placement and completion exactly."""
    fs = FleetSpec.sample(2, seed=5)
    trace = [Request(rid=0, t_arrival_s=0.0, prefill_tokens=4,
                     decode_tokens=8),
             Request(rid=1, t_arrival_s=3.7001, prefill_tokens=4,
                     decode_tokens=8)]
    runs = {}
    for ff in (False, True):
        eng = _tiny_engine(fleet=fs, router=HeadroomRouter(capacity=2))
        led = eng.serve_trace(list(trace), max_ticks=6000, tick_s=1 / 64,
                              fast_forward=ff)
        runs[ff] = (eng, led)
    eng_w, led_w = runs[False]
    eng_f, led_f = runs[True]
    assert eng_f.last_trace["fast_forward_ticks"] > 0
    assert [(r.rid, r.t_placed_s, r.chip, r.t_done_s, r.tokens_out)
            for r in led_f.records()] == \
           [(r.rid, r.t_placed_s, r.chip, r.t_done_s, r.tokens_out)
            for r in led_w.records()]
    # the re-entry tick is the first grid point at/after the arrival —
    # placement is never EARLIER than the arrival and less than one tick
    # after the walked run's own grid hit
    r1 = led_f.records()[1]
    assert r1.t_placed_s >= 3.7001
    assert r1.t_placed_s - 3.7001 < 1 / 64 + 1e-9


# -- batched fused tick on a device mesh --------------------------------------

@multi_device
def test_mesh_batched_serve_matches_unmeshed():
    """The batched fused tick under shard_map: the [15, n] bundle's extra
    rows (b_eff, t_lane) ride the same sharded control round. Discrete
    token/defer accounting must match the unmeshed batched engine with
    analog state allclose (the PR-7 multi-device drift bound)."""
    ndev = max(d for d in (2, 4, 8) if d <= len(jax.devices()))
    n_chips, cap = 2 * ndev, 4
    trace = bursty_trace(16, seed=sr.SEED, quiet_rate_hz=8.0,
                         burst_rate_hz=40.0, decode_mean=48.0)

    def _eng(mesh=None):
        return _bench_world_engine(HeadroomRouter(capacity=cap),
                                   n_chips=n_chips, batch_cap=cap,
                                   decode_profile=sb.DECODE_PROFILE,
                                   mesh=mesh)

    eng0, obs0 = _eng()
    led0 = eng0.serve_trace(trace, observe=obs0, max_ticks=600,
                            error_bound=sr.ERROR_BOUND)
    eng8, obs8 = _eng(mesh=_mesh(ndev))
    assert eng8.shard_control and eng8._batched
    led8 = eng8.serve_trace(trace, observe=obs8, max_ticks=600,
                            error_bound=sr.ERROR_BOUND)
    a, b = _discrete(eng0, led0), _discrete(eng8, led8)
    assert [(r[0], r[1], r[4], r[5]) for r in a["records"]] == \
           [(r[0], r[1], r[4], r[5]) for r in b["records"]]
    for key in ("defers_by_reason", "unplaced", "unfinished",
                "prefill_tokens", "decode_tokens"):
        assert a[key] == b[key], key
    assert led0.summary()["completed"] == led8.summary()["completed"] == 16
    _assert_analog_close(led0, led8, eng0, eng8, rtol=1e-3)
