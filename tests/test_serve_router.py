"""Headroom-aware fleet routing tests (serve/router.py, serve/traffic.py,
ServeEngine.serve_trace — docs/serve.md):

  * router invariants — no chip is placed past its batch capacity, pinned
    chips drain (receive no new work) before shedding, placement is
    deterministic under a fixed trace seed;
  * degenerate fleet — a single-chip routed trace walks the exact same
    plane trajectory as the plain engine's accounting loop (the router
    adds placement, never control semantics);
  * ledger — the spelled-out linear-interpolation percentile arithmetic,
    lifecycle guards (double admit / finish-before-place raise);
  * all-rails admission — `pinned_rails` flags a VDD_HBM floor during
    decode exactly like the historical VDD_IO check, and the serve summary
    splits shed counters per rail and per reason code.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.control_plane import (pinned_chip_mask, pinned_rails,
                                      worst_chip_pinned)
from repro.core.hwspec import FleetSpec
from repro.core.policy import MultiRailClosedLoop, Policy, RailRequest
from repro.core.power_plane import PowerPlaneState, StepProfile
from repro.core.rails import TPU_V5E_RAIL_MAP
from repro.serve.router import (HeadroomRouter, RequestLedger,
                                RoundRobinRouter, rail_headroom)
from repro.serve.traffic import Request, bursty_trace

PROFILE = StepProfile(flops_per_chip=2e12, hbm_bytes_per_chip=8e9,
                      ici_bytes_per_chip=4e9, grad_bytes_per_chip=3e9)
STATIC_HBM_FLOOR = TPU_V5E_RAIL_MAP.by_name("VDD_HBM").v_min
STATIC_IO_FLOOR = TPU_V5E_RAIL_MAP.by_name("VDD_IO").v_min


def _req(rid=0, prefill=8, decode=32, t=0.0):
    return Request(rid=rid, t_arrival_s=t, prefill_tokens=prefill,
                   decode_tokens=decode)


def _tiny_engine(**kw):
    from repro.configs import get_config
    from repro.models import registry
    from repro.serve.engine import ServeEngine
    cfg = get_config("minicpm_2b", tiny=True)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, max_len=24, batch_size=2,
                       prefill_profile=PROFILE, decode_profile=PROFILE, **kw)


class _PinHbmPolicy(Policy):
    """Requests an impossible VDD_HBM so arbitration pins every chip at the
    HBM floor — the decode-rail shed condition, deterministically."""
    name = "pin-hbm-floor"

    def decide(self, state, frame):
        return RailRequest(v_hbm=jnp.zeros_like(jnp.asarray(state.v_hbm,
                                                            jnp.float32)),
                           reason="pinned-at-floor")


# -- traffic ------------------------------------------------------------------

def test_bursty_trace_deterministic_and_seed_sensitive():
    a = bursty_trace(32, seed=11)
    b = bursty_trace(32, seed=11)
    c = bursty_trace(32, seed=12)
    assert [dataclasses.astuple(r) for r in a] == \
           [dataclasses.astuple(r) for r in b]
    assert [dataclasses.astuple(r) for r in a] != \
           [dataclasses.astuple(r) for r in c]
    assert len(a) == 32
    assert all(r.prefill_tokens >= 1 and r.decode_tokens >= 1 for r in a)
    ts = [r.t_arrival_s for r in a]
    assert ts == sorted(ts)


# -- ledger percentile arithmetic --------------------------------------------

def test_percentile_linear_interpolation():
    vals = [1.0, 2.0, 3.0, 4.0]
    # rank = (n-1) * q/100: p50 -> 1.5 -> 2.5; p25 -> 0.75 -> 1.75
    assert RequestLedger.percentile(vals, 50.0) == pytest.approx(2.5)
    assert RequestLedger.percentile(vals, 25.0) == pytest.approx(1.75)
    assert RequestLedger.percentile(vals, 0.0) == pytest.approx(1.0)
    assert RequestLedger.percentile(vals, 100.0) == pytest.approx(4.0)
    # matches numpy's default (linear) method on an awkward q
    ref = np.percentile(np.asarray(vals), 99.0)
    assert RequestLedger.percentile(vals, 99.0) == pytest.approx(float(ref))
    assert np.isnan(RequestLedger.percentile([], 50.0))
    with pytest.raises(ValueError):
        RequestLedger.percentile(vals, 101.0)


def test_ledger_lifecycle_guards():
    led = RequestLedger()
    r = _req(rid=7)
    led.admit(r)
    with pytest.raises(ValueError, match="already admitted"):
        led.admit(r)
    with pytest.raises(ValueError, match="before placement"):
        led.finish(7, 1.0, tokens_out=4)
    led.place(7, 0.5, chip=2)
    with pytest.raises(ValueError, match="already placed"):
        led.place(7, 0.6, chip=1)
    led.defer(7, "capacity", 0.1)
    led.finish(7, 1.0, tokens_out=32)
    s = led.summary()
    assert s["completed"] == 1 and s["defers"] == 1
    assert s["defers_by_reason"] == {"capacity": 1}
    assert s["p50_latency_s"] == pytest.approx(1.0)   # t_done - t_arrival
    assert s["p50_queue_s"] == pytest.approx(0.5)     # t_placed - t_arrival


# -- router unit invariants ---------------------------------------------------

def test_headroom_router_respects_capacity_and_pinning():
    r = HeadroomRouter(capacity=2)
    # the pinned chip has the DEEPEST headroom — it must still be skipped
    headroom = {"VDD_HBM": np.array([0.02, 0.50]),
                "VDD_CORE": np.array([0.02, 0.50])}
    assert r.place(_req(), [0, 0], headroom,
                   pinned=np.array([False, True])) == 0
    # full chips are ineligible even with headroom to spare
    assert r.place(_req(), [2, 0], headroom,
                   pinned=np.array([False, False])) == 1
    # nowhere to go: everyone full or pinned
    assert r.place(_req(), [2, 0], headroom,
                   pinned=np.array([False, True])) is None
    assert r.place(_req(), [2, 2], headroom, pinned=None) is None


def test_headroom_router_weighs_token_mix():
    r = HeadroomRouter(capacity=4, occupancy_weight_v=0.0)
    headroom = {"VDD_HBM": np.array([0.30, 0.01]),
                "VDD_CORE": np.array([0.01, 0.30])}
    decode_heavy = _req(prefill=1, decode=99)
    prefill_heavy = _req(prefill=99, decode=1)
    assert r.place(decode_heavy, [0, 0], headroom) == 0   # chases VDD_HBM
    assert r.place(prefill_heavy, [0, 0], headroom) == 1  # chases VDD_CORE


def test_round_robin_router_cursor():
    r = RoundRobinRouter(capacity=1)
    assert r.place(_req(), [0, 0, 0]) == 0
    assert r.place(_req(), [1, 0, 0]) == 1
    assert r.place(_req(), [1, 1, 0]) == 2
    assert r.place(_req(), [1, 1, 1]) is None
    assert r.place(_req(), [0, 1, 1]) == 0   # wraps to the freed slot


def test_rail_headroom_static_floor_when_unfitted():
    plane = PowerPlaneState.fleet(3)
    h = rail_headroom(plane, None)
    for name in ("VDD_CORE", "VDD_HBM", "VDD_IO"):
        r = TPU_V5E_RAIL_MAP.by_name(name)
        assert h[name].shape == (3,)
        np.testing.assert_allclose(h[name], r.nominal_v - r.v_min,
                                   atol=1e-6)


# -- all-rails pinning (satellite 1) ------------------------------------------

def test_pinned_rails_flags_hbm_floor():
    """The historical helper gated on VDD_IO only; a VDD_HBM floor during
    decode must now be flagged too, with the per-rail breakdown."""
    plane = PowerPlaneState.fleet(2)
    floor = jnp.full((2,), np.float32(STATIC_HBM_FLOOR))
    pinned_plane = dataclasses.replace(plane, v_hbm=floor)
    req = RailRequest(v_hbm=jnp.asarray([0.0, 1.1], jnp.float32))
    assert worst_chip_pinned(pinned_plane, req)
    masks = pinned_rails(pinned_plane, req)
    assert list(masks) == ["VDD_HBM"]          # only the requested rail
    np.testing.assert_array_equal(masks["VDD_HBM"], [True, False])
    np.testing.assert_array_equal(pinned_chip_mask(pinned_plane, req),
                                  [True, False])
    # holding above the floor is not pinned, even when the request wants it
    assert not worst_chip_pinned(plane, req)
    # multi-rail request: each rail reported independently
    both = RailRequest(v_hbm=jnp.zeros((2,), jnp.float32),
                       v_io=jnp.zeros((2,), jnp.float32))
    io_floor = jnp.full((2,), np.float32(STATIC_IO_FLOOR))
    pp = dataclasses.replace(pinned_plane, v_io=io_floor)
    masks = pinned_rails(pp, both)
    assert set(masks) == {"VDD_HBM", "VDD_IO"}
    assert masks["VDD_HBM"].any() and masks["VDD_IO"].all()


def test_generate_shed_breakdown_per_rail_and_reason():
    fs = FleetSpec.sample(2, seed=5)
    eng = _tiny_engine(policy=_PinHbmPolicy(), fleet=fs,
                       admission_gate=True)
    eng.generate(np.zeros((2, 4), np.int32), max_new_tokens=4)
    s = eng.summary()
    assert s["decode_sheds"] > 0
    assert s["decode_sheds_by_rail"].get("VDD_HBM", 0) > 0
    assert "VDD_IO" not in s["decode_sheds_by_rail"]
    assert sum(s["decode_sheds_by_reason"].values()) == s["decode_sheds"]
    assert "pinned-at-floor" in s["shed_reason"]


# -- routed trace: engine-level invariants ------------------------------------

def _routed_engine(n_chips=3, seed=9, router=None, **kw):
    fs = FleetSpec.sample(n_chips, seed=seed)
    router = router or HeadroomRouter(capacity=2)
    return _tiny_engine(policy=MultiRailClosedLoop(), fleet=fs,
                        router=router, **kw)


def test_router_requires_fleet():
    with pytest.raises(ValueError, match="fleet"):
        _tiny_engine(policy=MultiRailClosedLoop(),
                     router=HeadroomRouter(capacity=2))


def test_serve_trace_capacity_invariant_and_completion():
    eng = _routed_engine()
    led = eng.serve_trace(bursty_trace(10, seed=4), max_ticks=4000)
    s = led.summary()
    assert s["completed"] == s["n_requests"] == 10
    assert eng.last_trace["max_occupancy"] <= eng.router.capacity
    assert eng.last_trace["unplaced"] == 0
    assert eng.last_trace["unfinished"] == 0
    assert s["fleet_energy_j"] > 0 and s["tokens_per_joule"] > 0
    # engine stats and ledger agree on the fleet energy
    assert eng.stats.fleet_energy_j == pytest.approx(s["fleet_energy_j"])


def test_serve_trace_placement_deterministic():
    def run():
        eng = _routed_engine()
        led = eng.serve_trace(bursty_trace(10, seed=4), max_ticks=4000)
        return [(r.rid, r.chip, r.t_placed_s, r.t_done_s, r.defers)
                for r in led.records()]
    assert run() == run()


def test_serve_trace_pinned_chips_drain_first():
    """With every chip pinned at the HBM floor, the headroom router places
    nothing (drain mode): deferrals carry the pinned-drain reason and the
    per-rail shed split names VDD_HBM. Round-robin, headroom-blind, keeps
    placing on pinned chips."""
    fs = FleetSpec.sample(3, seed=9)
    eng = _tiny_engine(policy=_PinHbmPolicy(), fleet=fs,
                       router=HeadroomRouter(capacity=2))
    led = eng.serve_trace(bursty_trace(4, seed=2), max_ticks=40)
    assert led.summary()["placed"] == 0
    assert led.defers_by_reason.get("pinned-drain", 0) > 0
    assert eng.stats.sheds_by_rail.get("VDD_HBM", 0) > 0

    eng_rr = _tiny_engine(policy=_PinHbmPolicy(), fleet=fs,
                          router=RoundRobinRouter(capacity=2))
    led_rr = eng_rr.serve_trace(bursty_trace(4, seed=2), max_ticks=40)
    assert led_rr.summary()["placed"] > 0


def test_single_chip_router_degenerates_to_plain_engine():
    """On a one-chip fleet there is nothing to route: the traced engine's
    plane must walk the exact trajectory the plain accounting loop walks
    (same accounting, same control rounds; the router only adds placement)."""
    fs = FleetSpec.sample(1, seed=13)
    routed = _tiny_engine(policy=MultiRailClosedLoop(), fleet=fs,
                          router=HeadroomRouter(capacity=2))
    routed.serve_trace(bursty_trace(6, seed=8), max_ticks=400)
    ticks = routed.last_trace["ticks"]
    assert ticks > 0

    plain = _tiny_engine(policy=MultiRailClosedLoop(), fleet=fs)
    plain._account(plain.decode_profile, n=ticks)
    for field in ("v_core", "v_hbm", "v_io"):
        np.testing.assert_allclose(
            np.asarray(getattr(routed.plane, field)),
            np.asarray(getattr(plain.plane, field)),
            rtol=1e-6, err_msg=field)
