"""Decision-as-data control API tests (docs/control_api.md):

  * observation — TelemetryFrame construction, provenance/age, dict shim;
  * decision   — decide()/arbitrate() purity under jit and vmap, RailRequest
    broadcast/clamp semantics;
  * back-compat — the `from_dict` shim keeps every shipped policy's
    trajectory BIT-identical to the pre-redesign dict API on the scalar
    path, and the deprecated `update_*` shims warn (an *error* for in-repo
    callers via pytest.ini);
  * actuation  — HostRailController(decide_from="poll") closes the loop on
    *sampled* voltages: its trajectory matches the exact-frame loop up to
    sampling delay + LINEAR16 quantization, with nonzero sample age;
  * satellites — fleet serve engine (array-aware accounting, worst-chip
    gating), fleet checkpoint provenance + explicit plane remap.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager, remap_plane
from repro.core.control_plane import (HostRailController,
                                      InGraphRailController, arbitrate)
from repro.core.fleet import FleetPowerManager
from repro.core.hwspec import V5E, FleetSpec
from repro.core.policy import (POLICIES, BERBounded, ClosedLoop,
                               ControlAPIDeprecationWarning, PhaseAware,
                               RailRequest, StaticNominal, WorstChipGate,
                               apply_request)
from repro.core.power_plane import (PowerPlaneState, StepProfile,
                                    account_and_observe,
                                    account_fleet_and_observe, account_step)
from repro.core.telemetry import Provenance, TelemetryFrame, as_frame

PROFILE = StepProfile(flops_per_chip=2e12, hbm_bytes_per_chip=8e9,
                      ici_bytes_per_chip=4e9, grad_bytes_per_chip=3e9)
BOUND = 5e-3


def _grad_stream(steps=10):
    """Deterministic grad-error stream crossing the policy bounds both ways."""
    return [jnp.float32(BOUND * (0.2 if s % 3 else 3.0)) for s in range(steps)]


# -- observation ---------------------------------------------------------------

def test_frame_from_dict_roundtrip_and_extras():
    plane = PowerPlaneState.nominal()
    telem = {"grad_error": jnp.float32(1e-3), "t_comp_s": jnp.float32(0.5),
             "custom_metric": jnp.float32(7.0)}
    frame = TelemetryFrame.from_dict(telem, state=plane)
    assert frame.provenance is Provenance.EXACT
    assert float(frame.age_s) == 0.0
    assert float(frame.grad_error) == pytest.approx(1e-3)
    # rail observations come from the plane (oracle) on the dict path
    assert float(frame.v_io) == float(plane.v_io)
    assert float(frame.extras["custom_metric"]) == 7.0
    d = frame.to_dict()
    assert float(d["grad_error"]) == pytest.approx(1e-3)
    assert float(d["custom_metric"]) == 7.0
    assert frame.get("custom_metric") is telem["custom_metric"]
    assert frame.get("v_nom_io", "missing") == "missing"


def test_account_and_observe_builds_exact_frame():
    plane, frame, metrics = account_and_observe(PROFILE,
                                                PowerPlaneState.nominal())
    assert frame.provenance is Provenance.EXACT
    np.testing.assert_array_equal(np.asarray(frame.t_step_s),
                                  np.asarray(metrics["t_step_s"]))
    assert float(frame.v_io) == float(plane.v_io)
    # fleet variant anchors per-chip nominals from the FleetSpec
    fs = FleetSpec.sample(4, seed=9)
    fp, ff, _ = account_fleet_and_observe(PROFILE,
                                          PowerPlaneState.from_fleet(fs), fs)
    np.testing.assert_allclose(np.asarray(ff.v_nom_io), fs.v_io_nominal)
    assert np.asarray(ff.v_core).shape == (4,)


def test_frame_reduce_worst_broadcasts_fleet_max():
    err = jnp.asarray([1.0, 5.0, 2.0], jnp.float32)
    frame = TelemetryFrame(grad_error=err,
                           extras={"aux": jnp.asarray([0.0, 1.0, 9.0])})
    red = frame.reduce_worst(("grad_error", "aux"))
    np.testing.assert_array_equal(np.asarray(red.grad_error), [5.0] * 3)
    np.testing.assert_array_equal(np.asarray(red.extras["aux"]), [9.0] * 3)
    # scalar frames reduce to themselves
    s = TelemetryFrame(grad_error=jnp.float32(3.0)).reduce_worst(("grad_error",))
    assert float(s.grad_error) == 3.0


# -- decision: purity + arbitration --------------------------------------------

def test_decide_arbitrate_pure_under_jit():
    plane, frame, _ = account_and_observe(PROFILE, PowerPlaneState.nominal())
    frame = dataclasses.replace(frame, grad_error=jnp.float32(1e-4))
    for policy in POLICIES.values():
        eager = arbitrate(plane, policy.decide(plane, frame))
        jitted = jax.jit(
            lambda p, f, pol=policy: arbitrate(p, pol.decide(p, f)))(plane, frame)
        for f in ("v_core", "v_hbm", "v_io", "comp_level"):
            np.testing.assert_allclose(
                np.asarray(getattr(jitted, f)), np.asarray(getattr(eager, f)),
                rtol=1e-7, err_msg=f"{policy.name}.{f}")


def test_decide_arbitrate_pure_under_vmap():
    """vmap of the scalar decide+arbitrate == one elementwise fleet call."""
    n = 6
    fs = FleetSpec.sample(n, seed=2)
    plane, frame, _ = account_fleet_and_observe(
        PROFILE, PowerPlaneState.from_fleet(fs), fs)
    frame = dataclasses.replace(frame,
                                grad_error=jnp.linspace(0, 1e-2, n),
                                age_s=jnp.zeros((n,), jnp.float32))
    policy = ClosedLoop()
    direct = arbitrate(plane, policy.decide(plane, frame))
    mapped = jax.vmap(lambda p, f: arbitrate(p, policy.decide(p, f)))(
        plane, frame)
    np.testing.assert_allclose(np.asarray(mapped.v_io),
                               np.asarray(direct.v_io), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(mapped.comp_level),
                                  np.asarray(direct.comp_level))


def test_arbitrate_clamps_to_rail_envelopes():
    plane = PowerPlaneState.nominal()
    req = RailRequest(v_io=jnp.float32(0.10),       # far below VDD_IO v_min
                      v_core=jnp.float32(2.00),     # far above VDD_CORE v_max
                      comp_level=jnp.int32(99),
                      reason="hostile")
    out = arbitrate(plane, req)
    assert float(out.v_io) == pytest.approx(0.65)    # clamped to floor
    assert float(out.v_core) == pytest.approx(0.99)  # clamped to ceiling
    assert int(out.comp_level) == 2                  # codec range
    assert float(out.v_hbm) == float(plane.v_hbm)    # None = untouched


def test_rail_request_broadcast_and_per_chip():
    fleet = PowerPlaneState.fleet(4)
    # scalar request broadcasts; per-chip array lands per chip
    out = arbitrate(fleet, RailRequest(v_io=jnp.float32(0.80)))
    np.testing.assert_allclose(np.asarray(out.v_io), [0.80] * 4)
    per = jnp.asarray([0.70, 0.75, 0.80, 0.85], jnp.float32)
    out = arbitrate(fleet, RailRequest(v_io=per))
    np.testing.assert_allclose(np.asarray(out.v_io), np.asarray(per))
    # apply_request (legacy-shim semantics) merges raw, no clamp
    raw = apply_request(fleet, RailRequest(v_io=jnp.float32(0.10)))
    np.testing.assert_allclose(np.asarray(raw.v_io), [0.10] * 4)
    assert RailRequest().is_empty()


# -- back-compat: bit-identical trajectories + deprecation -------------------

@pytest.mark.parametrize("policy", list(POLICIES.values()),
                         ids=list(POLICIES))
def test_from_dict_shim_trajectory_bit_identical(policy):
    """The deprecated dict API (update_jax shim over from_dict + decide) and
    the new controller path produce BIT-identical scalar trajectories — no
    caller of the old API sees any numeric change."""
    ctrl = InGraphRailController(policy)
    p_shim = PowerPlaneState.nominal()
    p_ctrl = PowerPlaneState.nominal()
    for g in _grad_stream():
        p_shim, m_shim = account_step(PROFILE, p_shim)
        p_ctrl, m_ctrl = account_step(PROFILE, p_ctrl)
        with pytest.warns(ControlAPIDeprecationWarning):
            p_shim = policy.update_jax(p_shim, {**m_shim, "grad_error": g})
        p_ctrl = ctrl.control_step(p_ctrl, {**m_ctrl, "grad_error": g})
        for f in ("v_core", "v_hbm", "v_io", "comp_level"):
            np.testing.assert_array_equal(
                np.asarray(getattr(p_shim, f)),
                np.asarray(getattr(p_ctrl, f)), err_msg=f"{policy.name}.{f}")


def test_update_fleet_shim_matches_controller():
    n = 5
    fleet = PowerPlaneState.fleet(n)
    err = jnp.linspace(0, 1e-2, n)
    with pytest.warns(ControlAPIDeprecationWarning):
        shim = BERBounded().update_fleet(fleet, {"grad_error": err})
    ctrl = InGraphRailController(BERBounded()).control_step(
        fleet, {"grad_error": err})
    np.testing.assert_array_equal(np.asarray(shim.v_io),
                                  np.asarray(ctrl.v_io))
    np.testing.assert_array_equal(np.asarray(shim.comp_level),
                                  np.asarray(ctrl.comp_level))


def test_deprecated_update_api_is_error_for_in_repo_callers():
    """pytest.ini promotes ControlAPIDeprecationWarning to an error: new
    in-repo code cannot quietly regress onto the dict interface."""
    plane = PowerPlaneState.nominal()
    with pytest.raises(ControlAPIDeprecationWarning):
        StaticNominal().update_jax(plane, {})
    with pytest.raises(ControlAPIDeprecationWarning):
        StaticNominal().update_host(plane, {})
    with pytest.raises(ControlAPIDeprecationWarning):
        WorstChipGate(BERBounded()).update_fleet(
            PowerPlaneState.fleet(2), {"grad_error": jnp.zeros((2,))})


# -- actuation: poll-driven closed-loop host control ---------------------------

def _drive(hc, rounds=8, dt=5e-3):
    """One closed loop: train-time passes (polls fire), then a control round
    on a constant under-bound error stream (policy keeps undervolting)."""
    plane = PowerPlaneState.nominal()
    traj = []
    for _ in range(rounds):
        hc.fleet.idle(dt)
        plane = hc.control_step(plane, {"grad_error": jnp.float32(1e-4)})
        traj.append(float(plane.v_io))
    return plane, np.asarray(traj)


def test_poll_driven_host_control_closes_loop_on_sampled_voltages():
    """ROADMAP item 3 / acceptance: decide_from="poll" produces a closed-loop
    trajectory on PMBus-*sampled* voltages — same walk as the exact-frame
    loop up to sampling delay + LINEAR16 quantization, with nonzero
    per-decision sample age."""
    exact = HostRailController(ClosedLoop(), settle_band_frac=0.001)
    polled = HostRailController(ClosedLoop(), settle_band_frac=0.001,
                                decide_from="poll")
    polled.enable_polling(interval_s=1e-3)

    _, traj_exact = _drive(exact)
    _, traj_poll = _drive(polled)

    # the loop genuinely moved, on both observation sources
    assert traj_exact[-1] < traj_exact[0]
    assert traj_poll[-1] < traj_poll[0]
    # ...and they differ only by sampling delay/quantization: at most one
    # control step of lag plus the LINEAR16 LSB
    np.testing.assert_allclose(traj_poll, traj_exact, atol=0.007)

    # the polled decisions really ran on sampled telemetry with nonzero age
    assert polled.last_frame is not None
    assert polled.last_frame.provenance is Provenance.POLLED
    assert float(polled.last_frame.age_s) > 0.0
    st = polled.stats()
    assert st.poll_decisions == st.decisions > 0
    assert st.polls > 0
    # the exact-frame controller never decided from a poll
    assert exact.stats().poll_decisions == 0
    assert exact.last_frame.provenance is Provenance.EXACT


def test_poll_mode_rejects_legacy_policies():
    """decide_from="poll" exists to close the loop on sampled voltages; a
    legacy update_* policy reads the oracle state and would silently ignore
    the polled frame — rejected at construction, not mis-reported."""
    from repro.core.policy import Policy

    class LegacyOnly(Policy):
        name = "legacy-only"

        def update_jax(self, state, telemetry):
            return state

    with pytest.raises(ValueError, match="decide"):
        HostRailController(LegacyOnly(), decide_from="poll")
    # actuate-only (policy=None) and API-native policies are fine
    HostRailController(None, decide_from="poll")
    HostRailController(ClosedLoop(), decide_from="poll")


def test_poll_frame_nan_fallback_before_first_sample():
    """Chips never sampled fall back to the oracle plane value at age 0 —
    a poll-driven controller is safe to start before its first poll."""
    hc = HostRailController(ClosedLoop(), settle_band_frac=0.001,
                            decide_from="poll")
    # no polling enabled at all: poll_frame is all-NaN
    raw = hc.fleet.poll_frame()
    assert np.isnan(np.asarray(raw.v_io)).all()
    plane = PowerPlaneState.nominal()
    frame = hc.observed_frame(plane, {"grad_error": jnp.float32(0.0)})
    assert float(frame.v_io) == float(plane.v_io)
    assert float(frame.age_s) == 0.0
    out = hc.control_step(plane, {"grad_error": jnp.float32(1e-4)})
    assert float(out.v_io) < float(plane.v_io)   # loop still walks down


def test_poll_observation_values_and_ages():
    fpm = FleetPowerManager(2)
    fpm.start_polling(interval_s=1e-3)
    fpm.apply_setpoints([{2: 0.85}, {2: 0.90}])
    fpm.idle(5e-3)
    vals, ages = fpm.poll_observation(lanes=[2])
    np.testing.assert_allclose(vals[:, 0], [0.85, 0.90], atol=5e-3)
    assert (ages[:, 0] >= 0).all() and np.isfinite(ages).all()
    frame = fpm.poll_frame()
    np.testing.assert_allclose(np.asarray(frame.v_io), vals[:, 0])
    assert np.asarray(frame.age_s).shape == (2,)


# -- satellites: fleet serve engine --------------------------------------------

def _tiny_engine(**kw):
    from repro.configs import get_config
    from repro.models import registry
    from repro.serve.engine import ServeEngine
    cfg = get_config("minicpm_2b", tiny=True)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, ServeEngine(cfg, params, max_len=24, batch_size=2,
                            prefill_profile=PROFILE, decode_profile=PROFILE,
                            **kw)


def test_serve_engine_fleet_plane_and_worst_chip_gate():
    """Fleet serving: [n_chips] plane threads through the decode loop, a
    bare policy is worst-chip gated, and accounting/summary are array-aware
    (the pre-redesign float() coercions raised on fleet planes)."""
    fs = FleetSpec.sample(4, seed=11)
    cfg, eng = _tiny_engine(policy=PhaseAware(), fleet=fs)
    assert isinstance(eng.controller.policy, WorstChipGate)
    assert eng.n_chips == 4
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 4)).astype(np.int32)
    out = eng.generate(prompts, max_new_tokens=4)
    assert out.shape == (2, 4)
    s = eng.summary()
    assert s["n_chips"] == 4
    assert s["energy_j"] > 0 and np.isfinite(s["energy_j"])
    assert s["fleet_energy_j"] == pytest.approx(4 * s["energy_j"])
    assert s["v_io_min"] <= s["v_io"]
    # per-chip decode accounting really diverged the operating points
    assert np.asarray(eng.plane.v_core).shape == (4,)


def test_serve_engine_scalar_default_unchanged():
    cfg, eng = _tiny_engine(policy=PhaseAware())
    assert eng.n_chips == 1
    prompts = np.zeros((2, 4), np.int32)
    out = eng.generate(prompts, max_new_tokens=3)
    assert out.shape == (2, 3)
    s = eng.summary()
    assert s["n_chips"] == 1 and "fleet_energy_j" not in s


# -- satellites: fleet checkpoint provenance + explicit remap ------------------

def test_checkpoint_fleet_roundtrip_and_remap(tmp_path):
    fs = FleetSpec.sample(4, seed=21)
    plane = dataclasses.replace(
        PowerPlaneState.from_fleet(fs),
        v_io=jnp.linspace(0.80, 0.95, 4, dtype=jnp.float32),
        energy_j=jnp.arange(4, dtype=jnp.float32),
        step=jnp.full((4,), 7, jnp.int32))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, {"plane": plane}, fleet=fs)

    restored_fs = mgr.restore_fleet()
    assert restored_fs is not None
    assert restored_fs.seed == fs.seed and restored_fs.n_chips == 4
    np.testing.assert_array_equal(restored_fs.v_io_nominal, fs.v_io_nominal)
    assert restored_fs.base == fs.base   # ChipSpec base round-trips too

    _, out = mgr.restore({"plane": plane})
    # grow 4 -> 6: survivors keep state, joiners start at their own nominal
    target = FleetSpec.sample(6, seed=33)
    grown = remap_plane(out["plane"], target)
    assert grown.n_chips == 6
    np.testing.assert_allclose(np.asarray(grown.v_io)[:4],
                               np.linspace(0.80, 0.95, 4), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(grown.v_io)[4:],
                               target.v_io_nominal[4:], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(grown.energy_j)[:4], [0, 1, 2, 3])
    np.testing.assert_allclose(np.asarray(grown.energy_j)[4:], [0, 0])
    assert np.asarray(grown.step).tolist() == [7] * 6  # fleet steps together
    # shrink 4 -> 2: explicit truncation, survivors keep state
    shrunk = remap_plane(out["plane"], FleetSpec.sample(2, seed=33))
    assert shrunk.n_chips == 2
    np.testing.assert_allclose(np.asarray(shrunk.v_io),
                               np.linspace(0.80, 0.95, 4)[:2], rtol=1e-6)


def test_checkpoint_without_fleet_has_no_fleet_meta(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"plane": PowerPlaneState.nominal()})
    assert mgr.restore_fleet() is None


def test_checkpoint_fleet_preserves_custom_chip_spec(tmp_path):
    """A fleet sampled over a non-default ChipSpec must restore with that
    base (power constants/nominals), not silently fall back to V5E."""
    custom = dataclasses.replace(V5E, name="tpu-custom", p_hbm_w=45.0,
                                 nominal_v_io=0.93)
    fs = FleetSpec.sample(3, seed=4, spec=custom)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, {"plane": PowerPlaneState.from_fleet(fs)}, fleet=fs)
    restored = mgr.restore_fleet()
    assert restored.base == custom
    assert restored.base.p_hbm_w == 45.0


def test_trainer_remaps_restored_plane_onto_new_fleet(tmp_path):
    """Elastic restart onto a different fleet size: the trainer restores the
    old [n_old] plane and remaps it onto its own FleetSpec explicitly."""
    from repro.train.trainer import Trainer, TrainerConfig

    fs_old = FleetSpec.sample(3, seed=1)
    plane_old = dataclasses.replace(
        PowerPlaneState.from_fleet(fs_old),
        v_io=jnp.asarray([0.81, 0.82, 0.83], jnp.float32))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, {"plane": plane_old, "params": {"w": jnp.zeros((2,))},
                 "opt": {"step": jnp.int32(5)}, "ef": {}}, fleet=fs_old)

    fs_new = FleetSpec.sample(5, seed=2)
    cfg = TrainerConfig(total_steps=10, ckpt_dir=str(tmp_path),
                        fleet=fs_new)
    tr = Trainer(train_step=None, data=None, cfg=cfg,
                 init_state={"plane": PowerPlaneState.from_fleet(fs_new),
                             "params": {"w": jnp.zeros((2,))},
                             "opt": {"step": jnp.int32(0)}, "ef": {}})
    assert tr.maybe_restore()
    plane = tr.state["plane"]
    assert plane.n_chips == 5
    np.testing.assert_allclose(np.asarray(plane.v_io)[:3],
                               [0.81, 0.82, 0.83], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(plane.v_io)[3:],
                               fs_new.v_io_nominal[3:], rtol=1e-6)
