"""Per-kernel correctness: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp oracles in kernels/ref.py (the required assert_allclose gates)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_ssd import mamba2_ssd
from repro.kernels.quant_codec import quantize_int8
from repro.kernels.rwkv6_scan import rwkv6_scan

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("T,Hq,Hkv,Dh", [
    (128, 4, 4, 64),    # MHA
    (256, 4, 2, 64),    # GQA group 2
    (128, 8, 1, 32),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(T, Hq, Hkv, Dh, dtype, causal):
    ks = jax.random.split(KEY, 3)
    B = 2
    q = jax.random.normal(ks[0], (B, T, Hq, Dh), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, Dh), dtype)
    g = Hq // Hkv
    out = flash_attention(q, k, v, causal=causal, group=g, bq=64, bk=64,
                          interpret=True)
    exp = ref.mha_reference(q, k, v, causal=causal, group=g)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_sliding_window():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 32))
    k = jax.random.normal(ks[1], (1, 256, 2, 32))
    v = jax.random.normal(ks[2], (1, 256, 2, 32))
    out = flash_attention(q, k, v, causal=True, group=1, sliding_window=64,
                          bq=64, bk=64, interpret=True)
    exp = ref.mha_reference(q, k, v, causal=True, group=1, sliding_window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_grads_match_reference():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=True, group=2,
                                       bq=64, bk=64, interpret=True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.mha_reference(q, k, v, causal=True, group=2) ** 2)

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("S,bk", [(512, 256), (1024, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(S, bk, dtype):
    ks = jax.random.split(KEY, 3)
    B, Hq, Hkv, Dh = 2, 4, 2, 64
    q = jax.random.normal(ks[0], (B, 1, Hq, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    lengths = jnp.array([S // 3, S], jnp.int32)
    out = decode_attention(q, k, v, lengths, group=2, bk=bk, interpret=True)
    exp = ref.mha_reference(q, k, v, causal=False, group=2, lengths=lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("T,H,P,G,N,chunk", [
    (128, 4, 32, 1, 16, 64),
    (256, 4, 64, 2, 32, 128),
    (64, 2, 16, 2, 16, 64),
])
def test_mamba2_ssd_sweep(T, H, P, G, N, chunk):
    ks = jax.random.split(KEY, 6)
    Bt = 2
    x = jax.random.normal(ks[0], (Bt, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (Bt, T, G, N))
    Cm = jax.random.normal(ks[4], (Bt, T, G, N))
    D = jax.random.normal(ks[5], (H,))
    y1, s1 = mamba2_ssd(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    y2, s2 = ref.mamba2_scan_reference(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-3, atol=2e-4)


def test_mamba2_ssd_initial_state_continuation():
    """Scanning [0:T] must equal scanning [0:T/2] then [T/2:T] with the
    carried state — the decode/prefill contract."""
    ks = jax.random.split(KEY, 6)
    Bt, T, H, P, G, N = 1, 128, 2, 32, 1, 16
    x = jax.random.normal(ks[0], (Bt, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (Bt, T, G, N))
    Cm = jax.random.normal(ks[4], (Bt, T, G, N))
    D = jnp.zeros((H,))
    y_full, s_full = ref.mamba2_scan_reference(x, dt, A, Bm, Cm, D)
    h = T // 2
    y1, s1 = mamba2_ssd(x[:, :h], dt[:, :h], A, Bm[:, :h], Cm[:, :h], D,
                        chunk=64, interpret=True)
    y2, s2 = mamba2_ssd(x[:, h:], dt[:, h:], A, Bm[:, h:], Cm[:, h:], D,
                        chunk=64, init_state=s1, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-3, atol=2e-4)


@pytest.mark.parametrize("T,H,Dh,chunk", [(64, 2, 32, 32), (128, 4, 64, 64)])
def test_rwkv6_scan_sweep(T, H, Dh, chunk):
    ks = jax.random.split(KEY, 5)
    B = 2
    r = jax.random.normal(ks[0], (B, T, H, Dh))
    k = jax.random.normal(ks[1], (B, T, H, Dh))
    v = jax.random.normal(ks[2], (B, T, H, Dh))
    w = -jnp.exp(jax.random.normal(ks[3], (B, T, H, Dh)))
    u = jax.random.normal(ks[4], (H, Dh))
    y1, s1 = rwkv6_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    y2, s2 = ref.rwkv6_scan_reference(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-3, atol=2e-4)


@pytest.mark.parametrize("n,block", [(1000, 256), (4096, 256), (65, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_codec_sweep(n, block, dtype):
    x = jax.random.normal(KEY, (n,), dtype)
    q1, s1 = quantize_int8(x, block=block, interpret=True)
    q2, s2 = ref.quantize_int8_reference(x, block=block)
    assert bool(jnp.all(q1 == q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
