"""Dry-run guards: the HLO cost walker's correctness on a known case, and a
subprocess smoke of launch/dryrun.py on the production mesh (subprocess so
the 512-device XLA flag never leaks into this test process)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_costs import analyze_hlo_text

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_walker_counts_scan_trip_counts():
    """cost_analysis() counts while bodies once (verified upstream bug);
    the walker must multiply by trip count exactly."""
    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=10)
        return y

    x = jnp.ones((128, 128))
    compiled = jax.jit(f).lower(x).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: one dict per program
        cost = cost[0]
    # the XLA bug: ~1x matmul reported (plus a few loop-counter flops)
    assert cost["flops"] == pytest.approx(2 * 128**3, rel=1e-4)
    c = analyze_hlo_text(compiled.as_text())
    assert c.flops == 10 * 2 * 128**3                       # walker corrects
    assert c.n_whiles == 1


def test_walker_handles_fusion_calls():
    def f(x):
        return jnp.sum(jax.nn.relu(x @ x) * 2.0)

    x = jnp.ones((64, 64))
    c = analyze_hlo_text(jax.jit(f).lower(x).compile().as_text())
    assert c.flops == 2 * 64**3


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    """One full production-mesh cell: 256 forced devices, lower+compile,
    JSON record with cost/collective analysis."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "whisper_base",
         "--shape", "decode_32k", "--mesh", "single", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    rec = json.load(open(tmp_path / "dryrun_single.json"))[0]
    assert rec["ok"] and rec["devices"] == 256
    assert rec["flops"] > 0
    assert rec["collective_bytes"]["total"] > 0
