"""Sharded learned control plane (docs/fleet.md "sharded control plane").

Pins the PR's contracts:

  * per-shard SOR trajectories — `sharded_control_round` on a forced
    1-device mesh is BIT-EQUAL to the unsharded `control_round`, and on a
    multi-device mesh it is BIT-EQUAL to running the unsharded round
    independently on each shard's chip slice (shard_map adds nothing).
    The multi-device round vs the GLOBAL-shape unsharded round is only
    allclose: XLA CPU vectorizes transcendentals differently per lane
    count, so a 2-chip slice and a 16-chip batch of the same math differ
    by ~1e-5 — a shape-dependent codegen artifact, not a sharding bug
    (the per-slice bit-equality test is what isolates that).
  * buffer donation (`InGraphRailController(donate=True)`,
    `jit_train_step`) never changes a trajectory — it only invalidates
    the donated input buffers.
  * a sharded `SorState` checkpoints through the gather-on-save path and
    round-trips `ckpt.remap_sor` grow/shrink semantics unchanged.
  * deadband-paired poll back-pressure (`FleetPowerManager.
    set_poll_relax`, `HostRailController(poll_relax=...)`) relaxes only
    fully-pinned boards and restores the full Table VI rate the moment a
    lane leaves its band.

Multi-device cases need forced host devices at process start::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 pytest \
        tests/test_sharded_control_plane.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import sor
from repro.core.control_plane import (HostRailController,
                                      InGraphRailController,
                                      sharded_control_round)
from repro.core.fleet import FleetPowerManager
from repro.core.hwspec import FleetSpec
from repro.core.policy import (MultiRailClosedLoop, PhaseAware,
                               WorstChipGate)
from repro.core.power_plane import PowerPlaneState, StepProfile
from repro.core.rails import TPU_V5E_RAIL_MAP
from repro.core.telemetry import as_frame
from repro.kernels import ops

N = 16
CFG = sor.SorConfig(capacity=16, refresh_every=4, decay=0.96, guard_v=0.004,
                    max_extension_v=0.12, ingest="frames",
                    rails=sor.ALL_RAIL_OBSERVABLES)
NDEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    NDEV < 2, reason="needs >= 2 devices (XLA_FLAGS="
                     "--xla_force_host_platform_device_count=8)")


def _fleet(n=N, seed=3):
    fs = FleetSpec.sample(n, seed=seed)
    plane = PowerPlaneState.from_fleet(fs)
    ctrl = InGraphRailController(MultiRailClosedLoop(), sor=CFG)
    return plane, ctrl, ctrl.init_sor(n)


def _frame_err(i: int, n: int = N):
    k = jax.random.fold_in(jax.random.PRNGKey(0), i)
    return 1e-4 * (1.0 + jax.random.uniform(k, (n,)))


def _frame_at(plane, i: int, n: int = N, sl: slice = slice(None)):
    m = len(range(*sl.indices(n)))
    return as_frame({"grad_error": _frame_err(i, n)[sl],
                     "t_chip_s": jnp.full((m,), 1e-3),
                     "straggle_rate": jnp.full((m,), 1e-3),
                     "hbm_error_rate": jnp.full((m,), 1e-4)}, state=plane)


def _unsharded_rounds(plane, ctrl, ss, rounds: int, n: int = N,
                      sl: slice = slice(None)):
    rj = jax.jit(lambda p, f, s: ctrl.control_round(p, f, s))
    for i in range(rounds):
        plane, ss, _, _ = rj(plane, _frame_at(plane, i, n, sl), ss)
    return plane, ss


def _slice_tree(tree, sl: slice, n: int = N):
    return jax.tree_util.tree_map(
        lambda a: a[..., sl] if jnp.ndim(a) >= 1 and jnp.shape(a)[-1] == n
        else a, tree)


# ---------------------------------------------------------------------------
# partition-spec layout
# ---------------------------------------------------------------------------

def test_chip_specs_shards_trailing_chip_axis_only():
    plane, _, ss = _fleet()
    specs = ops.chip_specs(ss, N)
    assert specs.history.v == P(None, None, "chips")      # [cap, rails, n]
    assert specs.estimate.v_frontier == P(None, "chips")  # [rails, n]
    assert specs.history.cursor == P()                    # scalar: replicate
    assert ops.chip_specs(plane, N).v_core == P("chips")


def test_shard_fleet_state_places_chip_groups_only():
    from repro.train.step import shard_fleet_state
    plane, _, ss = _fleet()
    params = {"w": jnp.ones((4,))}
    mesh = Mesh(np.array(jax.devices()[:1]), ("chips",))
    out = shard_fleet_state({"params": params, "plane": plane, "sor": ss},
                            mesh)
    assert out["params"]["w"] is params["w"]   # model groups pass through
    assert out["plane"].v_core.sharding.spec == P("chips")
    assert out["sor"].history.v.sharding.spec == P(None, None, "chips")


# ---------------------------------------------------------------------------
# per-shard SOR trajectories: bit-equality pins
# ---------------------------------------------------------------------------

def _assert_states_equal(a_plane, a_ss, b_plane, b_ss):
    for fld in ("v_core", "v_hbm", "v_io"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a_plane, fld)),
            np.asarray(getattr(b_plane, fld)), err_msg=fld)
    np.testing.assert_array_equal(np.asarray(a_ss.history.v),
                                  np.asarray(b_ss.history.v))
    np.testing.assert_array_equal(np.asarray(a_ss.estimate.v_frontier),
                                  np.asarray(b_ss.estimate.v_frontier))
    np.testing.assert_array_equal(np.asarray(a_ss.estimate.confidence),
                                  np.asarray(b_ss.estimate.confidence))


def test_forced_single_device_shard_map_bit_equal():
    """The shard_map wrapper itself adds nothing: on a 1-device mesh the
    sharded round reproduces the unsharded round bit for bit (the same pin
    FleetStepConfig.shard_control=True relies on)."""
    plane, ctrl, ss = _fleet()
    p0, s0 = _unsharded_rounds(plane, ctrl, ss, rounds=6)

    mesh = Mesh(np.array(jax.devices()[:1]), ("chips",))
    rnd = jax.jit(sharded_control_round(ctrl, mesh))
    p1 = ops.shard_chip_tree(plane, mesh, N)
    s1 = ops.shard_chip_tree(ss, mesh, N)
    for i in range(6):
        p1, s1, conf_sum, conf_min = rnd(p1, _frame_at(p1, i), s1)
    _assert_states_equal(p0, s0, p1, s1)
    # the only cross-shard traffic: two confidence summary scalars
    np.testing.assert_allclose(
        float(conf_sum), float(jnp.sum(s0.estimate.confidence)), rtol=1e-6)
    np.testing.assert_allclose(
        float(conf_min), float(jnp.min(s0.estimate.confidence)), rtol=1e-6)


@multi_device
def test_multi_device_sharded_matches_per_slice_unsharded():
    """N-device sharded round == the unsharded round run independently on
    each shard's chip slice, BIT-EQUAL — per-shard residency is exact; no
    hidden cross-shard coupling in ingest/refit/decide/arbitrate."""
    ndev = min(8, NDEV)
    plane, ctrl, ss = _fleet()
    mesh = Mesh(np.array(jax.devices()[:ndev]), ("chips",))
    rnd = jax.jit(sharded_control_round(ctrl, mesh))
    p1 = ops.shard_chip_tree(plane, mesh, N)
    s1 = ops.shard_chip_tree(ss, mesh, N)
    for i in range(6):
        p1, s1, _, _ = rnd(p1, _frame_at(p1, i), s1)

    k = N // ndev
    parts = []
    for b in range(0, N, k):
        sl = slice(b, b + k)
        pb, sb = _unsharded_rounds(_slice_tree(plane, sl), ctrl,
                                   _slice_tree(ss, sl), rounds=6, sl=sl)
        parts.append((pb, sb))
    v_io = np.concatenate([np.asarray(p.v_io) for p, _ in parts])
    vf = np.concatenate([np.asarray(s.estimate.v_frontier)
                         for _, s in parts], axis=-1)
    hv = np.concatenate([np.asarray(s.history.v) for _, s in parts],
                        axis=-1)
    np.testing.assert_array_equal(np.asarray(p1.v_io), v_io)
    np.testing.assert_array_equal(np.asarray(s1.estimate.v_frontier), vf)
    np.testing.assert_array_equal(np.asarray(s1.history.v), hv)


@multi_device
def test_multi_device_sharded_close_to_global_unsharded():
    """Sharded vs the GLOBAL-shape unsharded round: tight allclose only.
    XLA CPU compiles the round's transcendentals differently for a 2-chip
    slice than for the 16-chip batch (vectorization width), so the last
    ~1e-5 differs — documented shape-dependent codegen drift, bounded
    here; the per-slice test above pins that sharding itself is exact."""
    ndev = min(8, NDEV)
    plane, ctrl, ss = _fleet()
    p0, s0 = _unsharded_rounds(plane, ctrl, ss, rounds=6)

    mesh = Mesh(np.array(jax.devices()[:ndev]), ("chips",))
    rnd = jax.jit(sharded_control_round(ctrl, mesh))
    p1 = ops.shard_chip_tree(plane, mesh, N)
    s1 = ops.shard_chip_tree(ss, mesh, N)
    for i in range(6):
        p1, s1, _, _ = rnd(p1, _frame_at(p1, i), s1)
    np.testing.assert_allclose(np.asarray(p1.v_io), np.asarray(p0.v_io),
                               rtol=0, atol=5e-4)
    np.testing.assert_allclose(np.asarray(s1.estimate.v_frontier),
                               np.asarray(s0.estimate.v_frontier),
                               rtol=0, atol=5e-4)


def test_sharded_round_rejects_unshardable_controllers():
    mesh = Mesh(np.array(jax.devices()[:1]), ("chips",))
    with pytest.raises(ValueError, match="sor"):
        sharded_control_round(InGraphRailController(PhaseAware()), mesh)
    with pytest.raises(ValueError, match="cross.chip"):
        sharded_control_round(
            InGraphRailController(WorstChipGate(inner=MultiRailClosedLoop()),
                                  sor=CFG), mesh)


# ---------------------------------------------------------------------------
# buffer donation
# ---------------------------------------------------------------------------

def _telemetry_at(i: int, n: int = N):
    return {"grad_error": _frame_err(i, n),
            "t_chip_s": jnp.full((n,), 1e-3),
            "straggle_rate": jnp.full((n,), 1e-3),
            "hbm_error_rate": jnp.full((n,), 1e-4)}


def test_donation_preserves_trajectory_and_frees_ring():
    """donate=True changes WHERE the history ring lives (updated in place),
    never what the round computes: 6 rounds bit-equal to donate=False, and
    the donated SorState input is invalidated while the plane — aliased by
    telemetry frames — is not."""
    plane, _, ss = _fleet()
    ctrl_n = InGraphRailController(MultiRailClosedLoop(), sor=CFG)
    ctrl_d = InGraphRailController(MultiRailClosedLoop(), sor=CFG,
                                   donate=True)

    p_n, s_n = plane, ss
    p_d = plane
    s_d = jax.tree_util.tree_map(jnp.copy, ss)
    s_d_first = s_d
    for i in range(6):
        p_n, s_n = ctrl_n.control_step_sor(p_n, _telemetry_at(i), s_n)
        p_d, s_d = ctrl_d.control_step_sor(p_d, _telemetry_at(i), s_d)
    _assert_states_equal(p_n, s_n, p_d, s_d)
    # the donated ring was consumed in place...
    assert s_d_first.history.v.is_deleted()
    # ...but the plane is never donated (frames alias its rail arrays)
    assert not plane.v_io.is_deleted()
    assert not ss.history.v.is_deleted()   # non-donating controller copies


def test_jit_train_step_donates_carry_not_batch():
    """jit_train_step donates the carry argnums — (0..3) for the 5-arg
    step, (0..4) for the 6-arg SOR step — and never the batch."""
    from repro.train.step import jit_train_step

    def step5(params, opt, plane, ef, batch):
        return params + 1, opt + 1, plane + 1, ef + 1, {"m": batch.sum()}

    def step6(params, opt, plane, ef, sor_state, batch):
        return (params + 1, opt + 1, plane + 1, ef + 1, sor_state + 1,
                {"m": batch.sum()})

    for fn, n_carry in ((step5, 4), (step6, 5)):
        args = [jnp.ones((8,)) * i for i in range(n_carry + 1)]
        jit_train_step(fn)(*args)
        for i, a in enumerate(args[:-1]):
            assert a.is_deleted(), f"carry arg {i} of {fn.__name__}"
        assert not args[-1].is_deleted(), "batch must not be donated"
        # donate=False leaves every input alive
        args = [jnp.ones((8,)) * i for i in range(n_carry + 1)]
        jit_train_step(fn, donate=False)(*args)
        assert not any(a.is_deleted() for a in args)


# ---------------------------------------------------------------------------
# sharded SorState checkpoint round-trip
# ---------------------------------------------------------------------------

@multi_device
def test_sharded_sor_checkpoint_roundtrip_remap(tmp_path):
    """ckpt.save gathers a shard-resident SorState transparently; restore +
    remap_sor grow/shrink behave exactly as on a single-device state, and
    the remapped state re-shards onto the mesh with values intact."""
    from repro.checkpoint.ckpt import CheckpointManager, remap_sor

    ndev = min(8, NDEV)
    plane, ctrl, ss = _fleet()
    mesh = Mesh(np.array(jax.devices()[:ndev]), ("chips",))
    rnd = jax.jit(sharded_control_round(ctrl, mesh))
    p1 = ops.shard_chip_tree(plane, mesh, N)
    s1 = ops.shard_chip_tree(ss, mesh, N)
    for i in range(CFG.refresh_every + 1):   # past one refit cadence
        p1, s1, _, _ = rnd(p1, _frame_at(p1, i), s1)
    gathered_v = np.asarray(jax.device_get(s1.history.v))
    gathered_conf = np.asarray(jax.device_get(s1.estimate.confidence))

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"sor": s1})
    _, restored = mgr.restore({"sor": ctrl.init_sor(N)})
    rs = restored["sor"]
    np.testing.assert_array_equal(np.asarray(rs.history.v), gathered_v)
    np.testing.assert_array_equal(np.asarray(rs.estimate.confidence),
                                  gathered_conf)

    # grow 16 -> 24: survivors keep their window/fit, joiners start at the
    # zero-confidence cold-start pin; the grown state re-shards cleanly
    grown = remap_sor(rs, 24)
    np.testing.assert_array_equal(
        np.asarray(grown.history.v)[..., :N], gathered_v)
    assert np.all(np.asarray(grown.estimate.confidence)[..., N:] == 0.0)
    g1 = ops.shard_chip_tree(grown, mesh, 24)
    assert g1.history.v.sharding.spec == P(None, None, "chips")
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(g1.history.v))[..., :N], gathered_v)

    # shrink 16 -> 8: the kept prefix is untouched
    shrunk = remap_sor(rs, 8)
    np.testing.assert_array_equal(np.asarray(shrunk.history.v),
                                  gathered_v[..., :8])


# ---------------------------------------------------------------------------
# sharded fleet train step
# ---------------------------------------------------------------------------

def _fleet_step_run(fs, data_batches, mesh_arg, shard_control):
    from repro.optim import adamw
    from repro.train.step import (FleetStepConfig, StepConfig,
                                  jit_train_step, make_fleet_train_step)
    from repro.train.trainer import initial_plane_and_ef

    params = {"w": jnp.ones((4,), jnp.float32)}

    def loss_fn(p, b):
        loss = jnp.mean((b @ p["w"]) ** 2)
        return loss, {}

    opt_cfg = adamw.AdamWConfig(grad_clip_norm=1.0)
    fleet_cfg = FleetStepConfig(
        spec=fs, hbm_error_base=1e-4, straggler_prob=0.05,
        mesh=mesh_arg, shard_control=shard_control, sor=CFG)
    step = jit_train_step(
        make_fleet_train_step(loss_fn, opt_cfg, lambda s: 1e-3,
                              StepProfile(2e12, 8e9, 4e9, 3e9),
                              StepConfig(policy=MultiRailClosedLoop()),
                              fleet_cfg),
        donate=False)
    p, opt = params, adamw.init_state(params, opt_cfg)
    plane, ef = initial_plane_and_ef(p, fleet=fs)
    ss = sor.init_state(CFG, fs.n_chips)
    if mesh_arg is not None and shard_control:
        plane = ops.shard_chip_tree(plane, mesh_arg, fs.n_chips)
        ss = ops.shard_chip_tree(ss, mesh_arg, fs.n_chips)
    for b in data_batches:
        p, opt, plane, ef, ss, metrics = step(p, opt, plane, ef, ss, b)
    return plane, ss, metrics


def test_fleet_step_shard_control_forced_single_device_bit_equal():
    """FleetStepConfig.shard_control=True on a 1-device mesh: the whole
    train step (model update + sharded control round + shard_map'd
    reductions) reproduces the unsharded step's trajectory bit for bit;
    the confidence metrics come from the in-round collectives."""
    n = 4
    fs = FleetSpec.sample(n, seed=7)
    batches = [jnp.ones((8, 4), jnp.float32) * (0.1 * (i + 1))
               for i in range(3)]
    mesh = Mesh(np.array(jax.devices()[:1]), ("chips",))
    plane_s, ss_s, m_s = _fleet_step_run(fs, batches, mesh, True)
    plane_u, ss_u, m_u = _fleet_step_run(fs, batches, None, None)
    _assert_states_equal(plane_u, ss_u, plane_s, ss_s)
    np.testing.assert_array_equal(float(m_s["loss"]), float(m_u["loss"]))
    np.testing.assert_allclose(float(m_s["fleet/sor_conf_mean"]),
                               float(m_u["fleet/sor_conf_mean"]), rtol=1e-6)
    np.testing.assert_allclose(float(m_s["fleet/power_w_worst"]),
                               float(m_u["fleet/power_w_worst"]), rtol=1e-6)


@multi_device
def test_fleet_step_shard_control_multi_device_close():
    """Auto-enabled shard_control on a real multi-device mesh: the step
    runs end to end with the SorState shard-resident, and tracks the
    unsharded trajectory to shape-codegen tolerance."""
    ndev = min(8, NDEV)
    n = 2 * ndev
    fs = FleetSpec.sample(n, seed=7)
    batches = [jnp.ones((8, 4), jnp.float32) * (0.1 * (i + 1))
               for i in range(3)]
    mesh = Mesh(np.array(jax.devices()[:ndev]), ("chips",))
    plane_s, ss_s, m_s = _fleet_step_run(fs, batches, mesh, None)  # auto
    plane_u, ss_u, m_u = _fleet_step_run(fs, batches, None, None)
    assert ss_s.history.v.sharding.spec == P(None, None, "chips")
    np.testing.assert_allclose(np.asarray(plane_s.v_io),
                               np.asarray(plane_u.v_io), rtol=0, atol=5e-4)
    np.testing.assert_allclose(float(m_s["loss"]), float(m_u["loss"]),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# deadband-paired poll back-pressure
# ---------------------------------------------------------------------------

def test_set_poll_relax_paces_segment_and_restores():
    fpm = FleetPowerManager(2)
    fpm.start_polling(interval_s=5e-3)
    fpm.idle(0.05)
    base = [fpm.poll_stats[i].polls for i in (0, 1)]

    fpm.set_poll_relax(0, 4.0, lanes_pinned=3)
    fpm.idle(0.2)
    st0, st1 = fpm.poll_stats[0], fpm.poll_stats[1]
    d0, d1 = st0.polls - base[0], st1.polls - base[1]
    assert st0.relax_factor == 4.0 and st0.relaxed_lanes == 3
    assert st0.relaxed_polls > 0
    assert st1.relaxed_polls == 0
    assert d1 > 2.5 * d0              # board 1 still at the full rate
    assert fpm.stats()["polls_relaxed"] == st0.relaxed_polls
    assert fpm.stats()["relaxed_lanes"] == 3

    fpm.set_poll_relax(0, 1.0)        # restore: relax bookkeeping clears
    assert fpm.poll_stats[0].relaxed_lanes == 0
    before = fpm.poll_stats[0].relaxed_polls
    fpm.idle(0.05)
    assert fpm.poll_stats[0].relaxed_polls == before

    with pytest.raises(ValueError, match=">= 1.0"):
        fpm.set_poll_relax(0, 0.5)
    FleetPowerManager(1).set_poll_relax(0, 2.0)   # not polling: no-op


def test_host_controller_poll_relax_pins_only_fully_pinned_boards():
    """A board whose every governed lane is deadband-pinned polls at
    poll_relax x; a board with any lane outside its band keeps the full
    rate, and leaving the band restores it on the next actuation round."""
    n = 2
    hc = HostRailController(n_chips=n, deadband_v=0.01, poll_relax=4.0)
    s = TPU_V5E_RAIL_MAP.by_name("VDD_IO")
    floor = float(np.float32(s.v_min + 0.02))
    hc.last_envelope = {"VDD_IO": sor.SafeEnvelope(
        v_min=jnp.float32(floor), confidence=jnp.full((n,), 1.0),
        max_extension_v=0.12, rail="VDD_IO")}
    hc.enable_polling(interval_s=5e-3)
    plane = PowerPlaneState.from_fleet(FleetSpec.sample(n, seed=0))
    plane = dataclasses.replace(
        plane, v_io=jnp.asarray([floor + 0.004, floor + 0.05], jnp.float32))
    plane = hc.actuate(plane)          # settle: regulators now hold targets
    assert hc.fleet.poll_stats[0].relax_factor == 1.0   # cold: nothing pinned

    hc.actuate(plane)                  # chip 0 steady inside the band
    assert hc.fleet.poll_stats[0].relax_factor == 4.0
    assert hc.fleet.poll_stats[0].relaxed_lanes == 1
    assert hc.fleet.poll_stats[1].relax_factor == 1.0
    hc.fleet.idle(0.1)
    assert hc.stats().relaxed_polls > 0

    # chip 0 leaves its band -> the next round restores the full rate
    plane = dataclasses.replace(
        plane, v_io=jnp.asarray([floor + 0.05, floor + 0.05], jnp.float32))
    hc.actuate(plane)
    assert hc.fleet.poll_stats[0].relax_factor == 1.0
    assert hc.fleet.poll_stats[0].relaxed_lanes == 0


def test_host_controller_poll_relax_validation():
    with pytest.raises(ValueError, match="poll_relax"):
        HostRailController(n_chips=1, poll_relax=0.5)
