"""GTX case-study model tests — pins every quantitative claim of paper §VI:
BER onsets per speed (Fig 12/14), throughput collapse, TX/RX asymmetry
(Fig 13), latency baselines/excursions (Fig 15), and the headline power
reductions 28.4% / 29.3% (Fig 16)."""

import math

import pytest

from repro.core.transceiver import (GtxLinkModel, LATENCY_BASE_NS,
                                    RX_BER_ONSET_V, SPEEDS_GBPS)


@pytest.fixture(scope="module")
def m():
    return GtxLinkModel(seed=0)


def test_ber_zero_above_onset(m):
    for s in SPEEDS_GBPS:
        r = m.run_link_test(1.0, 1.0, s)
        assert r.ber == 0.0 and r.bytes_received == r.bytes_sent


@pytest.mark.parametrize("speed,onset", list(RX_BER_ONSET_V.items()))
def test_ber_onset_voltages(m, speed, onset):
    """Fig 14: onsets 0.869 / 0.787 / 0.745 / 0.744 V."""
    above = m.run_link_test(onset + 0.003, onset + 0.003, speed)
    below = m.run_link_test(onset - 0.002, onset - 0.002, speed)
    assert above.ber == 0.0
    assert below.ber > 0.0


def test_ber_ramp_at_10g(m):
    """Fig 12c: ~1e-7 near 0.866 V, ~1e-6 near 0.864 V."""
    b866 = m.run_link_test(0.866, 0.866, 10.0).ber_true
    b864 = m.run_link_test(0.864, 0.864, 10.0).ber_true
    assert math.log10(b866) == pytest.approx(-7.0, abs=0.3)
    assert math.log10(b864) == pytest.approx(-6.0, abs=0.3)


def test_throughput_collapse_near_0p80(m):
    """Fig 12a: first major collapse near 0.80 V at 10 Gbps."""
    ok = m.run_link_test(0.805, 0.805, 10.0)
    dead = m.run_link_test(0.79, 0.79, 10.0)
    assert ok.bytes_received == ok.bytes_sent
    assert dead.bytes_received < 0.5 * dead.bytes_sent and not dead.link_up


def test_rx_dominant_sensitivity(m):
    """Fig 13: TX-only sweep keeps full payload to 0.7 V; RX-swept degrades;
    TX BER onset ~0.82 V vs RX ~0.869 V."""
    tx_only = m.run_link_test(0.70, 1.0, 10.0)
    rx_only = m.run_link_test(1.0, 0.79, 10.0)
    assert tx_only.bytes_received == tx_only.bytes_sent
    assert rx_only.bytes_received < rx_only.bytes_sent
    assert m.run_link_test(0.825, 1.0, 10.0).ber == 0.0
    assert m.run_link_test(0.815, 1.0, 10.0).ber_true > 1e-10


@pytest.mark.parametrize("speed,base", list(LATENCY_BASE_NS.items()))
def test_latency_baselines(m, speed, base):
    """Fig 15b: ~100/130/200/410 ns in the stable region."""
    assert m.latency_ns(1.0, 1.0, speed) == pytest.approx(base)


def test_latency_excursions_below_onset(m):
    """Fig 15a: sustained excursions appear below ~0.86 V at 10 Gbps."""
    spikes = [m.latency_ns(v, v, 10.0) for v in
              [0.84 - i * 0.002 for i in range(30)]]
    assert max(spikes) > 10 * LATENCY_BASE_NS[10.0]


def test_power_reduction_headline(m):
    """Fig 16: 28.4% at the near-zero-BER boundary; 29.3% at BER<=1e-6."""
    p_nom = m.rail_power_w("tx", 1.0, 10.0)
    assert p_nom == pytest.approx(0.200, abs=1e-3)
    p_nb = m.rail_power_w("tx", 0.869, 10.0)
    assert 1 - p_nb / p_nom == pytest.approx(0.284, abs=0.002)
    p_b6 = m.rail_power_w("tx", 0.864, 10.0)
    assert 1 - p_b6 / p_nom == pytest.approx(0.293, abs=0.002)
    assert p_nb == pytest.approx(0.1432, abs=5e-4)
    assert p_b6 == pytest.approx(0.1415, abs=5e-4)


def test_power_table_xii_anchors(m):
    """Table XII: representative rail power at 1.0/0.8 V across speeds."""
    expect = {
        (10.0, "tx"): (0.20, 0.13), (10.0, "rx"): (0.17, 0.11),
        (7.5, "tx"): (0.18, 0.12), (7.5, "rx"): (0.155, 0.10),
        (5.0, "tx"): (0.14, 0.09), (5.0, "rx"): (0.12, 0.08),
        (2.5, "tx"): (0.12, 0.08), (2.5, "rx"): (0.095, 0.07),
    }
    for (speed, side), (p10, p08) in expect.items():
        assert m.rail_power_w(side, 1.0, speed) == pytest.approx(p10, rel=0.06)
        assert m.rail_power_w(side, 0.8, speed) == pytest.approx(p08, rel=0.10)


def test_power_monotone_in_voltage(m):
    for side in ("tx", "rx"):
        for s in SPEEDS_GBPS:
            ps = [m.rail_power_w(side, v, s)
                  for v in [0.7 + 0.01 * i for i in range(31)]]
            assert all(b >= a - 1e-12 for a, b in zip(ps, ps[1:]))


def test_power_locality(m):
    """Table XI: savings localize to the swept side."""
    r = m.run_link_test(0.75, 1.0, 10.0)   # TX swept, RX fixed
    assert r.tx_power_w < 0.12 and r.rx_power_w == pytest.approx(0.17, rel=0.02)


def test_sweep_procedure_shape(m):
    sw = m.sweep(10.0, mode="both", v_stop=0.9)
    assert len(sw) == 101  # 1 mV steps over 0.1 V
    assert sw[0].v_tx == 1.0 and sw[-1].v_tx == pytest.approx(0.9)
