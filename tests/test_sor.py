"""Safe-operating-region learning tests (core/sor.py, docs/sor.md):

  * FrameHistory — ring semantics, NaN masking (unsampled chips record
    nothing), jit/vmap purity of the functional push;
  * fit — the online EWLS frontier fit recovers each chip's seeded
    error-sensitivity ordering from synthetic poll history;
  * cold start — zero history means zero confidence means the blended
    envelope IS the static one, bit-exactly: learned-envelope controllers
    produce bit-identical trajectories to today's static controllers;
  * envelope arbitration — per-chip floors tighten (weak chips) and extend
    (strong chips, bounded) the shared static rail envelope;
  * satellites — StalenessGuard age-aware margin widening, POLLED
    from_dict requires age_s, serve-side admission gating, and the
    learned-vs-static fleet_frontier smoke (strong chips undervolt below
    the shared static floor with modeled error still under the bound).
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sor
from repro.core.control_plane import (HostRailController,
                                      InGraphRailController, arbitrate,
                                      worst_chip_pinned)
from repro.core.hwspec import FleetSpec
from repro.core.policy import (ClosedLoop, Policy, RailRequest,
                               StalenessGuard, WorstChipGate)
from repro.core.power_plane import PowerPlaneState, StepProfile
from repro.core.rails import TPU_V5E_RAIL_MAP
from repro.core.telemetry import FrameHistory, Provenance, TelemetryFrame

PROFILE = StepProfile(flops_per_chip=2e12, hbm_bytes_per_chip=8e9,
                      ici_bytes_per_chip=4e9, grad_bytes_per_chip=3e9)
BOUND = 5e-3
STATIC_IO_FLOOR = TPU_V5E_RAIL_MAP.by_name("VDD_IO").v_min


def _frontier_frames(v_onsets, v_points, slope=30.0):
    """Synthetic poll stream: at voltage v every chip's measured error is
    BOUND * 10^(slope * (onset - v)) — the log-linear transition band."""
    v_on = jnp.asarray(v_onsets, jnp.float32)
    frames = []
    for v in v_points:
        v = jnp.full(v_on.shape, v, jnp.float32)
        err = BOUND * 10.0 ** jnp.clip(slope * (v_on - v), -6.0, 3.0)
        frames.append(TelemetryFrame(grad_error=err, v_io=v, v_core=v,
                                     v_hbm=v, age_s=jnp.zeros_like(v),
                                     provenance=Provenance.POLLED))
    return frames


# -- FrameHistory ---------------------------------------------------------------

def test_frame_history_ring_and_nan_masking():
    h = FrameHistory.create(4, n_chips=3)
    assert h.chip_shape == (3,)
    assert h.n_rails == 1   # default: the VDD_IO BER frontier alone
    for i in range(6):
        v = jnp.asarray([0.9 - 0.01 * i, 0.8, np.nan], jnp.float32)
        h = h.push(TelemetryFrame(grad_error=jnp.asarray([1e-3, 2e-3, 3e-3]),
                                  v_io=v, v_core=v, v_hbm=v))
    assert int(h.count) == 6 and int(h.cursor) == 2
    # the NaN-voltage chip never records a valid sample (valid is
    # [capacity, n_rails, n_chips] — rail-indexed)
    assert not np.asarray(h.valid)[:, 0, 2].any()
    assert np.asarray(h.valid)[:, 0, :2].all()
    # newest sample (slot cursor-1) holds the last push (v_io is the
    # back-compat rail slice)
    assert float(h.v_io[1, 0]) == pytest.approx(0.85)
    # recency weights: newest == 1, invalid chips == 0
    w = np.asarray(h.recency_weights(0.9))
    assert w[1, 0, 0] == pytest.approx(1.0)
    assert (w[:, 0, 2] == 0).all()


def test_frame_history_push_pure_under_jit():
    h = FrameHistory.create(4, n_chips=2)
    f = TelemetryFrame(grad_error=jnp.asarray([1e-3, 2e-3]),
                       v_io=jnp.asarray([0.9, 0.91]),
                       v_core=jnp.asarray([0.9, 0.91]),
                       v_hbm=jnp.asarray([1.1, 1.1]))
    eager = h.push(f)
    jitted = jax.jit(lambda hh, ff: hh.push(ff))(h, f)
    for a, b in zip(jax.tree_util.tree_leaves(eager),
                    jax.tree_util.tree_leaves(jitted)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_from_dict_polled_requires_age():
    plane = PowerPlaneState.nominal()
    with pytest.raises(ValueError, match="age_s"):
        TelemetryFrame.from_dict({"grad_error": 1e-3}, state=plane,
                                 provenance=Provenance.POLLED)
    # explicit staleness (including the honest NaN sentinel) is accepted
    f = TelemetryFrame.from_dict({"grad_error": 1e-3}, state=plane,
                                 age_s=jnp.float32(0.25),
                                 provenance=Provenance.POLLED)
    assert float(f.age_s) == pytest.approx(0.25)
    TelemetryFrame.from_dict({}, state=plane, age_s=math.nan,
                             provenance=Provenance.POLLED)
    # EXACT frames keep the age-0 default (unchanged behavior)
    assert float(TelemetryFrame.from_dict({}, state=plane).age_s) == 0.0


# -- the fit --------------------------------------------------------------------

def test_fit_recovers_error_sensitivity_ordering():
    """The frontier fit recovers each chip's seeded BER-curve offset: chips
    sampled through a FleetSpec-style onset spread come back with frontier
    voltages in the same order, close to the true onsets."""
    fs = FleetSpec.sample(6, seed=3)
    order = np.argsort(fs.error_sensitivity)
    v_on = 0.62 + 0.05 * (jnp.asarray(fs.error_sensitivity) - 1.0)
    cfg = sor.SorConfig(capacity=32, refresh_every=1, decay=0.96,
                        error_bound=BOUND)
    h = FrameHistory.create(cfg.capacity, n_chips=6)
    # sample the transition band (below every onset the error is log-linear;
    # far above it the detection floor clamps and carries no slope signal)
    for f in _frontier_frames(v_on, np.linspace(0.74, 0.60, 24)):
        h = h.push(f)
    est = sor.fit_history(h, cfg)
    conf = np.asarray(est.confidence)[0]   # [n_rails, n_chips], rail 0
    front = np.asarray(est.v_frontier)[0]
    assert (conf > 0.5).all()
    assert (np.asarray(est.slope) < -10.0).all()
    np.testing.assert_allclose(front, np.asarray(v_on), atol=5e-3)
    np.testing.assert_array_equal(np.argsort(front), order)


def test_fit_matches_per_chip_fits():
    """The batched fit is elementwise: fitting the [n_chips] history equals
    fitting each chip's scalar history separately (vmap-purity of the
    online update, by construction)."""
    cfg = sor.SorConfig(capacity=16, refresh_every=1)
    v_on = jnp.asarray([0.63, 0.67, 0.70], jnp.float32)
    frames = _frontier_frames(v_on, np.linspace(0.92, 0.62, 12))
    batched = FrameHistory.create(cfg.capacity, n_chips=3)
    singles = [FrameHistory.create(cfg.capacity) for _ in range(3)]
    for f in frames:
        batched = batched.push(f)
        for i in range(3):
            fi = TelemetryFrame(grad_error=f.grad_error[i], v_io=f.v_io[i],
                                v_core=f.v_core[i], v_hbm=f.v_hbm[i],
                                age_s=f.age_s[i], provenance=f.provenance)
            singles[i] = singles[i].push(fi)
    full = sor.fit_history(batched, cfg)
    for i, hi in enumerate(singles):
        one = sor.fit_history(hi, cfg)
        for field in ("intercept", "slope", "v_frontier", "confidence"):
            np.testing.assert_allclose(
                float(getattr(full, field)[0, i]),
                float(getattr(one, field)[0]),
                rtol=1e-4, atol=1e-4, err_msg=f"chip {i} {field}")


def test_observe_refresh_cadence_and_jit_purity():
    cfg = sor.SorConfig(capacity=16, refresh_every=4)
    v_on = jnp.asarray([0.65, 0.68], jnp.float32)
    frames = _frontier_frames(v_on, np.linspace(0.90, 0.62, 8))
    state = sor.init_state(cfg, n_chips=2)
    jstate = sor.init_state(cfg, n_chips=2)
    observe = jax.jit(lambda s, f: sor.observe(s, f, cfg))
    confs = []
    for f in frames:
        state = sor.observe(state, f, cfg)
        jstate = observe(jstate, f)
        confs.append(np.asarray(state.estimate.confidence).copy())
    # jit == eager on the full state (f32 fusion reorders accumulations)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(jstate)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    # the estimate only moves on refresh ticks (every 4th observation)
    for t in range(1, len(confs)):
        if (t + 1) % cfg.refresh_every:
            np.testing.assert_array_equal(confs[t], confs[t - 1])
    assert (confs[-1] > 0).all()


# -- cold start: the no-behavior-change pin -------------------------------------

def test_cold_start_envelope_is_bit_exact_static():
    est = sor.SorEstimate.init(4)
    env = sor.safe_envelope(est, sor.SorConfig())
    np.testing.assert_array_equal(np.asarray(env.floor(STATIC_IO_FLOOR)),
                                  np.full(4, np.float32(STATIC_IO_FLOOR)))
    np.testing.assert_array_equal(np.asarray(env.ceil(1.05)),
                                  np.float32(1.05))
    # decide under the zero-confidence envelope == decide without one
    plane = PowerPlaneState.fleet(4)
    frame = TelemetryFrame(grad_error=jnp.full((4,), 1e-4),
                           v_io=plane.v_io, v_core=plane.v_core,
                           v_hbm=plane.v_hbm)
    pol = ClosedLoop()
    a = pol.decide(plane, frame)
    b = pol.decide_env(plane, frame, env)
    np.testing.assert_array_equal(np.asarray(a.v_io), np.asarray(b.v_io))
    # ... and the arbitrated planes match bit-exactly too
    pa = arbitrate(plane, a)
    pb = arbitrate(plane, b, envelopes={"VDD_IO": env})
    np.testing.assert_array_equal(np.asarray(pa.v_io), np.asarray(pb.v_io))


def test_cold_start_host_trajectories_bit_identical():
    """A SOR-enabled poll-driven host controller that never polls (zero
    poll history) walks the exact same trajectory as the static one."""
    def drive(hc, rounds=8, dt=5e-3):
        plane = PowerPlaneState.nominal()
        traj = []
        for _ in range(rounds):
            hc.fleet.idle(dt)
            plane = hc.control_step(plane, {"grad_error": jnp.float32(1e-4)})
            traj.append(float(plane.v_io))
        return np.asarray(traj)

    plain = HostRailController(ClosedLoop(), settle_band_frac=0.001,
                               decide_from="poll")
    learned = HostRailController(ClosedLoop(), settle_band_frac=0.001,
                                 decide_from="poll", sor=sor.SorConfig())
    np.testing.assert_array_equal(drive(plain), drive(learned))
    s = learned.sor_summary()
    assert s["chips_learned"] == 0 and s["confidence_mean"] == 0.0


def test_host_controller_learns_from_polls():
    """The poll-fed host loop (FleetPowerManager.poll_frame -> FrameHistory)
    learns the chip's frontier online and raises a weak chip's floor above
    the policy's static one."""
    hc = HostRailController(
        ClosedLoop(v_io_floor=0.70), settle_band_frac=0.001,
        decide_from="poll",
        sor=sor.SorConfig(capacity=24, refresh_every=2, decay=0.96,
                          guard_v=0.004, max_extension_v=0.12))
    hc.enable_polling(interval_s=1e-3)
    plane = PowerPlaneState.nominal()
    for _ in range(40):
        hc.fleet.idle(5e-3)
        err = BOUND * 10.0 ** jnp.clip(30.0 * (0.78 - plane.v_io), -6.0, 3.0)
        plane = hc.control_step(plane, {"grad_error": err})
    s = hc.sor_summary()
    assert s["chips_learned"] == 1
    assert s["confidence_mean"] > 0.5
    # true onset 0.78: the learned floor lands just above it...
    assert 0.775 < s["floor_mean_v"] < 0.80
    # ...and the blended floor tightens ABOVE the policy's static 0.70/0.75
    # (last_envelope is the per-rail dict now)
    assert float(hc.last_envelope["VDD_IO"].floor(0.70)) > 0.70


# -- envelope arbitration -------------------------------------------------------

def test_arbitrate_with_per_chip_envelope():
    plane = PowerPlaneState.fleet(2)
    env = sor.SafeEnvelope(v_min=jnp.asarray([0.60, 0.70], jnp.float32),
                           confidence=jnp.asarray([1.0, 1.0], jnp.float32),
                           max_extension_v=0.05)
    out = arbitrate(plane, RailRequest(v_io=jnp.float32(0.0)),
                    envelopes={"VDD_IO": env})
    # chip 0 extends below the shared 0.65 static floor (bounded by
    # max_extension_v); chip 1's learned floor tightens above it
    np.testing.assert_allclose(np.asarray(out.v_io), [0.60, 0.70], rtol=1e-6)
    # extension is bounded: a learned floor far below static stops at
    # static - max_extension_v
    deep = sor.SafeEnvelope(v_min=jnp.float32(0.30),
                            confidence=jnp.float32(1.0), max_extension_v=0.05)
    out = arbitrate(plane, RailRequest(v_io=jnp.float32(0.0)),
                    envelopes={"VDD_IO": deep})
    np.testing.assert_allclose(np.asarray(out.v_io),
                               [STATIC_IO_FLOOR - 0.05] * 2, rtol=1e-6)
    # other rails keep the plain static clamp
    out = arbitrate(plane, RailRequest(v_core=jnp.float32(0.0)),
                    envelopes={"VDD_IO": env})
    np.testing.assert_allclose(np.asarray(out.v_core), [0.60, 0.60])


# -- StalenessGuard -------------------------------------------------------------

def test_staleness_guard_widens_with_age():
    plane = PowerPlaneState.nominal()
    guard = StalenessGuard(ClosedLoop(), grace_s=0.05, widen_v_per_s=0.5,
                           max_widen_v=0.05)
    fresh = TelemetryFrame(grad_error=jnp.float32(1e-4), v_io=plane.v_io,
                           age_s=jnp.float32(0.0))
    stale = dataclasses.replace(fresh, age_s=jnp.float32(0.15))
    very_stale = dataclasses.replace(fresh, age_s=jnp.float32(10.0))
    inner = ClosedLoop().decide(plane, fresh)
    # fresh: numerically unchanged request
    out = guard.decide(plane, fresh)
    np.testing.assert_array_equal(np.asarray(out.v_io),
                                  np.asarray(inner.v_io))
    assert "staleness-guard" in out.reason
    # stale: margin widens by (age - grace) * rate
    out_s = guard.decide(plane, stale)
    assert float(out_s.v_io) == pytest.approx(float(inner.v_io) + 0.05)
    # widening is capped
    out_vs = guard.decide(plane, very_stale)
    assert float(out_vs.v_io) == pytest.approx(float(inner.v_io) + 0.05)
    # untouched rails stay untouched
    assert out_s.v_core is None and out_s.comp_level is not None
    # NaN age (the documented "unknown staleness" sentinel) widens fully
    # instead of poisoning the rails
    unknown = dataclasses.replace(fresh, age_s=jnp.float32(np.nan))
    out_n = guard.decide(plane, unknown)
    assert float(out_n.v_io) == pytest.approx(float(inner.v_io) + 0.05)
    assert np.isfinite(float(out_n.v_io))


# -- serve-side admission gating ------------------------------------------------

class _PinPolicy(Policy):
    """Requests an impossible VDD_IO so arbitration pins every chip at the
    envelope floor — the shed condition, deterministically."""
    name = "pin-floor"

    def decide(self, state, frame):
        return RailRequest(v_io=jnp.zeros_like(jnp.asarray(state.v_io,
                                                           jnp.float32)),
                           reason="pinned-at-floor")


def _tiny_engine(**kw):
    from repro.configs import get_config
    from repro.models import registry
    from repro.serve.engine import ServeEngine
    cfg = get_config("minicpm_2b", tiny=True)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, ServeEngine(cfg, params, max_len=24, batch_size=2,
                            prefill_profile=PROFILE, decode_profile=PROFILE,
                            **kw)


def test_last_request_not_stored_under_jit():
    """Compiled into a jitted step, controllers must not store traced
    requests (leaked tracers); eager calls record them as concrete data."""
    ctrl = InGraphRailController(_PinPolicy())
    plane = PowerPlaneState.fleet(2)
    frame = TelemetryFrame(grad_error=jnp.zeros((2,)), v_io=plane.v_io)
    jax.jit(ctrl.control_step)(plane, frame)
    assert ctrl.last_request is None
    out = ctrl.control_step(plane, frame)   # eager: recorded, usable
    assert ctrl.last_request is not None
    assert worst_chip_pinned(out, ctrl.last_request)


def test_serve_sor_config_conflict_raises():
    fs = FleetSpec.sample(2, seed=5)
    from repro.core.control_plane import InGraphRailController as IGC
    ctrl = IGC(WorstChipGate(ClosedLoop()),
               sor=sor.SorConfig(capacity=16, ingest="frames"))
    with pytest.raises(ValueError, match="conflicting"):
        _tiny_engine(controller=ctrl, fleet=fs,
                     sor=sor.SorConfig(capacity=32, ingest="frames"))


def test_in_graph_sor_rejects_polled_ingest():
    """ingest="polled" is the host READ_VOUT path; in-graph SOR has no bus,
    so a 'polled-only' config must be rejected, not silently oracle-trained."""
    with pytest.raises(ValueError, match="ingest"):
        InGraphRailController(ClosedLoop(), sor=sor.SorConfig())
    InGraphRailController(ClosedLoop(), sor=sor.SorConfig(ingest="frames"))


def test_worst_chip_pinned_helper():
    plane = PowerPlaneState.fleet(2)
    floor = jnp.full((2,), np.float32(STATIC_IO_FLOOR))
    pinned_plane = dataclasses.replace(plane, v_io=floor)
    req = RailRequest(v_io=jnp.asarray([0.0, 0.9], jnp.float32))
    assert worst_chip_pinned(pinned_plane, req)
    # wanting the floor but holding above it is not pinned; nor is no request
    assert not worst_chip_pinned(plane, req)
    assert not worst_chip_pinned(pinned_plane, None)
    assert not worst_chip_pinned(pinned_plane, RailRequest(comp_level=1))


def test_serve_admission_gate_sheds_when_pinned():
    fs = FleetSpec.sample(2, seed=5)
    cfg, eng = _tiny_engine(policy=WorstChipGate(_PinPolicy()), fleet=fs,
                            admission_gate=True)
    prompts = np.zeros((2, 4), np.int32)
    out = eng.generate(prompts, max_new_tokens=4)
    assert out.shape == (2, 4)          # deferred, never dropped
    s = eng.summary()
    assert s["decode_sheds"] > 0
    assert s["defer_time_s"] > 0
    assert "pinned-at-floor" in s["shed_reason"]
    # fleet pinned at the static floor
    np.testing.assert_allclose(np.asarray(eng.plane.v_io),
                               [STATIC_IO_FLOOR] * 2, rtol=1e-6)


def test_serve_admission_gate_quiet_when_unpinned():
    fs = FleetSpec.sample(2, seed=5)
    cfg, eng = _tiny_engine(policy=WorstChipGate(ClosedLoop()), fleet=fs,
                            admission_gate=True)
    out = eng.generate(np.zeros((2, 4), np.int32), max_new_tokens=3)
    assert out.shape == (2, 3)
    s = eng.summary()
    assert s["decode_sheds"] == 0
    # gate off by default: no shed keys at all (scalar path unchanged)
    _, eng2 = _tiny_engine(policy=ClosedLoop())
    eng2.generate(np.zeros((2, 4), np.int32), max_new_tokens=3)
    assert "decode_sheds" not in eng2.summary()


# -- the learned-vs-static frontier smoke ---------------------------------------

def test_learned_envelope_fleet_frontier_smoke():
    """Acceptance: after one learned multi-rail rollout on a spread fleet,
    every rail's learner converges, chips recover headroom below the shared
    static floors, no chip's modeled observable exceeds the configured
    bound at the operating points it holds, and the fleet's rail power
    drops vs the static envelopes."""
    from benchmarks import fleet_frontier as ff

    n, steps = 8, 120
    p_st, _, h_st = ff._sor_rollout(n, False, steps)
    p_ln, ss, h_ln = ff._sor_rollout(n, True, steps)
    est = ss.estimate
    envs = sor.rail_envelopes(est, ff.SOR_CFG)
    conf = np.asarray(est.confidence)
    assert conf.shape[0] == 3 and (conf > 0.5).all()   # all rails learned

    floors = np.asarray(envs["VDD_IO"].floor(STATIC_IO_FLOOR))
    # strong chips recover headroom below the shared static floor
    assert (floors < STATIC_IO_FLOOR - 1e-3).any()
    # weak chips tighten above it (per-chip regions, not a global loosening)
    assert (floors > STATIC_IO_FLOOR + 1e-3).any()
    # safety, on every rail: modeled observable at the held operating
    # points stays at/below the bound
    for rail, held in (("VDD_CORE", p_ln.v_core), ("VDD_HBM", p_ln.v_hbm),
                       ("VDD_IO", p_ln.v_io)):
        i = ff.SOR_CFG.rail_index(rail)
        modeled = np.asarray(est.rail(i).log10_error_at(held))
        assert (modeled[conf[i] > 0] <= np.log10(BOUND) + 0.05).all(), rail
    # the static run never went below its shared floor; the learned one did
    io_floor = ff.SOR_POLICY_FLOORS["VDD_IO"]
    assert float(jnp.min(p_st.v_io)) >= io_floor - 1e-4
    assert float(jnp.min(p_ln.v_io)) < io_floor - 1e-3
    # the CORE rail recovered headroom too (the cross-rail point of PR 5)
    assert (float(jnp.min(p_ln.v_core))
            < float(jnp.min(p_st.v_core)) - 1e-3)
    # rail power drops (the paper's headline metric)
    tail = steps // 4
    assert (float(jnp.mean(h_ln["power_w"][-tail:]))
            < float(jnp.mean(h_st["power_w"][-tail:])))
