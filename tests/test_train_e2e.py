"""End-to-end training tests: loss decreases, checkpoint/restart resumes
bit-identically, failure injection recovers, straggler mitigation engages,
and the power plane + policies behave (energy drops without hurting loss)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_config
from repro.core.control_plane import HostRailController
from repro.core.policy import BERBounded, PhaseAware, StaticNominal
from repro.core.power_plane import PowerPlaneState, StepProfile
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import registry
from repro.optim import adamw
from repro.optim.schedule import wsd
from repro.train.step import StepConfig, jit_train_step, make_train_step
from repro.train.trainer import (FaultConfig, Trainer, TrainerConfig,
                                 initial_plane_and_ef)

CFG = get_config("minicpm_2b", tiny=True)
PROFILE = StepProfile(flops_per_chip=5e9, hbm_bytes_per_chip=5e8,
                      ici_bytes_per_chip=2e8, grad_bytes_per_chip=1.8e8)


def _setup(tmp_path, steps=8, policy=None, grad_sync="auto",
           faults=None, ckpt_every=4, seed=0):
    api = registry.build(CFG, remat="none")
    params = api.init(jax.random.PRNGKey(seed))
    opt_cfg = adamw.AdamWConfig(grad_clip_norm=1.0)
    opt = adamw.init_state(params, opt_cfg)
    plane, ef = initial_plane_and_ef(params)
    sched = lambda s: wsd(s, peak_lr=1e-3, warmup_steps=2, stable_steps=50,
                          decay_steps=50)
    step_cfg = StepConfig(microbatches=1, grad_sync=grad_sync, policy=policy)
    raw_step = make_train_step(
        lambda p, b: api.loss_fn(p, b), opt_cfg, sched, PROFILE, step_cfg)
    if grad_sync.startswith("ef_int8"):
        from repro.train.step import shard_map_ef_step
        mesh = jax.make_mesh((1,), ("data",))
        step = jax.jit(shard_map_ef_step(raw_step, mesh))
    else:
        step = jit_train_step(raw_step, donate=False)
    data = SyntheticLM(DataConfig(vocab_size=CFG.vocab_size, seq_len=32,
                                  global_batch=4, seed=seed))
    tcfg = TrainerConfig(total_steps=steps, ckpt_every=ckpt_every,
                         ckpt_dir=str(tmp_path), async_ckpt=False,
                         faults=faults or FaultConfig())
    return Trainer(step, data, tcfg,
                   {"params": params, "opt": opt, "plane": plane, "ef": ef})


def test_loss_decreases(tmp_path):
    tr = _setup(tmp_path, steps=30)
    log = tr.run()
    first = np.mean([r.loss for r in list(log.records)[:5]])
    last = np.mean([r.loss for r in list(log.records)[-5:]])
    assert last < first, (first, last)


def test_checkpoint_restart_exact_resume(tmp_path):
    # run 8 steps straight
    tr1 = _setup(tmp_path / "a", steps=8, ckpt_every=4)
    tr1.run()
    loss_a = [r.loss for r in tr1.log.records]

    # run 4 steps, "crash", restore into a fresh trainer, run to 8
    tr2 = _setup(tmp_path / "b", steps=4, ckpt_every=4)
    tr2.run()
    tr3 = _setup(tmp_path / "b", steps=8, ckpt_every=4)
    assert tr3.maybe_restore()
    assert tr3.start_step == 4
    tr3.run()
    loss_b = [r.loss for r in tr3.log.records]
    np.testing.assert_allclose(loss_a[4:], loss_b, rtol=1e-5)


def test_failure_injection_recovers(tmp_path):
    tr = _setup(tmp_path, steps=20, ckpt_every=5,
                faults=FaultConfig(fail_prob=0.15, seed=3))
    log = tr.run()
    assert tr.restarts >= 1
    assert log.records[-1].step == 19  # reached the end despite failures


def test_straggler_mitigation_engages(tmp_path):
    tr = _setup(tmp_path, steps=15,
                faults=FaultConfig(straggler_prob=0.4, straggler_factor=10.0,
                                   grace=1.5, seed=1))
    tr.run()
    assert tr.straggler_events >= 2
    # mitigated steps are capped near grace * median, far below the raw 10x
    times = np.asarray(tr._step_times[1:])  # drop the compile step
    assert times.max() < np.median(times) * 10.0 * 0.5


def test_ef_int8_training_converges_close_to_lossless(tmp_path):
    t_auto = _setup(tmp_path / "x", steps=25, grad_sync="auto", seed=5)
    t_auto.run()
    t_ef = _setup(tmp_path / "y", steps=25, grad_sync="ef_int8", seed=5)
    t_ef.run()
    la = np.mean([r.loss for r in list(t_auto.log.records)[-5:]])
    le = np.mean([r.loss for r in list(t_ef.log.records)[-5:]])
    # bounded-error region: compressed training tracks lossless closely
    assert abs(le - la) / la < 0.05, (la, le)
    errs = [r.grad_error for r in t_ef.log.records]
    assert max(errs) > 0  # compression actually happened


def test_phase_aware_policy_saves_energy(tmp_path):
    t_nom = _setup(tmp_path / "n", steps=12, policy=StaticNominal())
    t_nom.run()
    t_pol = _setup(tmp_path / "p", steps=12, policy=PhaseAware())
    t_pol.run()
    e_nom = t_nom.log.totals()["energy_j"]
    e_pol = t_pol.log.totals()["energy_j"]
    assert e_pol < e_nom * 0.95, (e_nom, e_pol)
    # and loss is unaffected (same data/seed; voltages don't change math)
    np.testing.assert_allclose(
        [r.loss for r in t_nom.log.records],
        [r.loss for r in t_pol.log.records], rtol=1e-6)


def test_host_controller_pays_pmbus_latency(tmp_path):
    hc = HostRailController(PhaseAware())
    tr = _setup(tmp_path, steps=6, policy=None)
    tr.cfg = TrainerConfig(
        total_steps=6, ckpt_every=10, ckpt_dir=str(tmp_path),
        async_ckpt=False, controller=hc)
    tr.run()
    assert hc.actuations >= 1
    assert hc.actuation_seconds > 0   # ms-scale PMBus cost was accounted
    st = hc.stats()
    assert st.decisions == 6 and st.actuation_seconds == hc.actuation_seconds
    # achieved voltages respect the rail envelopes
    v = hc.readback()
    from repro.core.rails import TPU_V5E_RAIL_MAP as rm
    for name, volts in v.items():
        r = rm.by_name(name)
        assert r.v_min - 1e-3 <= volts <= r.v_max + 1e-3


def test_checkpoint_manager_atomicity(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    cm.save(3, {"params": {"w": jnp.ones((4,))}})
    # a partial dir without .complete must be invisible
    os.makedirs(tmp_path / "step_00000009")
    assert cm.list_steps() == [3]
    step, out = cm.restore({"params": {"w": jnp.zeros((4,))}})
    assert step == 3 and bool(jnp.all(out["params"]["w"] == 1))


def test_checkpoint_bf16_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    x = jnp.asarray([1.5, -2.25, 0.001], jnp.bfloat16)
    cm.save(1, {"params": {"w": x}})
    _, out = cm.restore({"params": {"w": jnp.zeros((3,), jnp.bfloat16)}})
    assert out["params"]["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(out["params"]["w"] == x))
