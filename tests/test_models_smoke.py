"""Per-architecture smoke tests (deliverable f): every assigned arch as a
REDUCED same-family config runs one forward/train step + one decode step on
CPU with finite outputs and correct shapes. Full configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import registry

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B=2, T=32):
    batch = {"tokens": jnp.full((B, T), 3, jnp.int32),
             "labels": jnp.ones((B, T), jnp.int32)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.full((B, cfg.n_img_tokens, cfg.d_model),
                                       0.01, jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.full((B, cfg.enc_seq_len, cfg.d_model),
                                   0.01, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_step_smoke(arch):
    cfg = get_config(arch, tiny=True)
    api = registry.build(cfg)
    params = api.init(KEY)
    batch = _batch_for(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        api.loss_fn, has_aux=True)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0
    gnorm = sum(float(jnp.sum(jnp.square(g)))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_step_smoke(arch):
    cfg = get_config(arch, tiny=True)
    api = registry.build(cfg)
    params = api.init(KEY)
    B, max_len = 2, 64
    cache = api.init_decode_cache(B, max_len)
    db = {"tokens": jnp.full((B, 1), 3, jnp.int32), "cur_index": jnp.int32(0)}
    if cfg.family == "encdec":
        from repro.models import encdec
        frames = jnp.full((B, cfg.enc_seq_len, cfg.d_model), 0.01, jnp.float32)
        enc = encdec.encode(params, frames, cfg)
        db["cross_kv"] = encdec.cross_kv(params, enc, cfg)
    logits, cache2 = api.decode_fn(params, cache, db)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # cache must advance (some leaf changed)
    changed = any(
        not np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(cache),
                        jax.tree_util.tree_leaves(cache2)))
    assert changed, f"{arch}: decode cache did not advance"


@pytest.mark.parametrize("arch", ["minicpm_2b", "granite_20b", "rwkv6_7b",
                                  "zamba2_1p2b"])
def test_prefill_then_decode_consistency(arch):
    """Greedy next-token from (prefill of t0..tN) must equal running the
    train forward and reading position N's logits."""
    cfg = get_config(arch, tiny=True)
    api = registry.build(cfg)
    params = api.init(KEY)
    B, T = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0,
                              cfg.vocab_size)
    from repro.models import lm
    logits_pf, cache, cur = api.prefill_fn(params, toks, 32)
    x = lm.embed_tokens(params, toks, cfg)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    xx, _ = lm._run_blocks(params, x, cfg, pos, remat="none")
    logits_full = lm.logits_from(params, xx, cfg)
    np.testing.assert_allclose(
        np.asarray(logits_pf[:, -1], np.float32),
        np.asarray(logits_full[:, -1], np.float32), rtol=6e-2, atol=6e-2)


def test_vocab_padding_masked():
    cfg = get_config("minicpm_2b", tiny=True)  # vocab 512 -> padded 2048
    assert cfg.vocab_padded > cfg.vocab_size
    api = registry.build(cfg)
    params = api.init(KEY)
    cache = api.init_decode_cache(1, 8)
    logits, _ = api.decode_fn(params, cache, {
        "tokens": jnp.zeros((1, 1), jnp.int32), "cur_index": jnp.int32(0)})
    pad_logits = np.asarray(logits[0, 0, cfg.vocab_size:], np.float32)
    assert np.all(pad_logits <= -1e8), "padded vocab slots must be masked"
